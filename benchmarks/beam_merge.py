"""beam_merge microbenchmark: fused bitonic partial merge vs the seed's
full argsort merge, per search hop.

Measures the exact op the engine runs every hop — fold (B, d) scored
candidates into the sorted (B, L) beam — at representative shapes, plus
correctness (bit-identity) of each backend against the argsort oracle.
On CPU the XLA-compiled bitonic network ("jnp" backend) is the fused path;
the Pallas kernel is validated in interpret mode (its wall-clock there is
the Python interpreter's, not the merge's, so it is excluded from the
speedup claim — on TPU the kernel is the fused path)."""
from __future__ import annotations

import time

import numpy as np

from .common import emit


def _bench(fn, repeats: int = 30) -> float:
    import jax

    jax.block_until_ready(fn())            # compile warmup
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def run(shapes=((64, 64, 20), (64, 128, 32), (256, 128, 32), (64, 512, 32)),
        seed: int = 0) -> dict:
    import jax.numpy as jnp

    from repro.kernels.beam_merge import beam_merge

    rng = np.random.default_rng(seed)
    out = {}
    wins = 0
    for B, L, d in shapes:
        bd = jnp.asarray(np.sort(rng.normal(size=(B, L)).astype(np.float32),
                                 axis=1))
        bi = jnp.asarray(rng.integers(0, 4 * L, (B, L)).astype(np.int32))
        bc = jnp.asarray(rng.random((B, L)) < 0.5)
        bx = jnp.asarray(rng.random((B, L)) < 0.2)
        cd = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
        ci = jnp.asarray(rng.integers(0, 4 * L, (B, d)).astype(np.int32))
        cx = jnp.asarray(rng.random((B, d)) < 0.2)
        args = (bd, bi, bc, bx, cd, ci, cx)

        ref = beam_merge(*args, backend="argsort")
        fused = beam_merge(*args, backend="jnp")
        identical = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(fused, ref))
        pall = beam_merge(*args, backend="pallas")
        pallas_identical = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(pall, ref))

        t_argsort = _bench(lambda: beam_merge(*args, backend="argsort"))
        t_fused = _bench(lambda: beam_merge(*args, backend="jnp"))
        speedup = t_argsort / t_fused
        wins += speedup > 1.0
        emit("beam_merge", B=B, L=L, d=d,
             argsort_us=t_argsort * 1e6, fused_us=t_fused * 1e6,
             speedup=speedup, identical=identical,
             pallas_identical=pallas_identical)
        out[(B, L, d)] = speedup
    out["wins"] = f"{wins}/{len(shapes)}"
    return out


if __name__ == "__main__":
    print(run())
