"""Paper Fig. 7-left / Sec. 7.2: continuous refinement turns a *random*
even-regular graph into a competitive search graph.

Protocol: build a random d-regular connected graph over the dataset, then run
Algorithm 5 in refinement batches; after each batch record the average
neighbor distance (Eq. 4, must decrease monotonically) and the QPS<->recall
point.  The punchline the paper claims — and this reproduces — is that edge
optimization alone recovers most of the constructed-DEG quality.
"""
from __future__ import annotations

import numpy as np

from repro.core.baselines.random_regular import random_regular_index
from repro.core.build import DEGParams, build_deg
from repro.core.invariants import check_invariants
from repro.core.metrics import recall_at_k

from .common import emit, make_bench_dataset


def run(n: int = 3000, n_query: int = 200, dim: int = 24, k: int = 10,
        degree: int = 12, batches=(0, 500, 1500, 3000, 6000),
        seed: int = 0) -> dict:
    ds = make_bench_dataset("synth-lowlid", n, n_query, dim, "low", k=k,
                            seed=seed)
    params = DEGParams(degree=degree, k_ext=2 * degree, eps_ext=0.2,
                       k_opt=degree, i_opt=5)
    idx = random_regular_index(ds.base, params, seed=seed)
    out = {"and": [], "recall": []}
    done = 0
    for target in batches:
        idx.refine(target - done, seed=seed + done)
        done = target
        ok, msgs = check_invariants(idx.builder)
        assert ok, msgs
        res = idx.search(ds.queries, k=k, eps=0.1)
        rec = recall_at_k(np.asarray(res.ids), ds.gt_ids)
        and_ = idx.builder.average_neighbor_distance()
        emit("fig7_left", refine_iters=target, avg_nbr_dist=and_,
             recall=rec, hops=float(np.mean(np.asarray(res.hops))))
        out["and"].append(and_)
        out["recall"].append(rec)
    # reference: a constructed DEG with the same budget
    ref = build_deg(ds.base, params, wave_size=16)
    res = ref.search(ds.queries, k=k, eps=0.1)
    emit("fig7_left_ref", refine_iters=-1,
         avg_nbr_dist=ref.builder.average_neighbor_distance(),
         recall=recall_at_k(np.asarray(res.ids), ds.gt_ids),
         hops=float(np.mean(np.asarray(res.hops))))
    assert all(a >= b - 1e-6 for a, b in zip(out["and"], out["and"][1:])), \
        "average neighbor distance must decrease monotonically"
    return out


if __name__ == "__main__":
    print(run())
