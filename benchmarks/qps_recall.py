"""Paper Fig. 4: QPS <-> recall@k frontier, DEG vs baselines (ANNS queries).

Baselines at container scale: FAISS-style serial scan (brute force), kGraph
(NN-descent), NSW.  The paper's claim reproduced here: DEG dominates the
high-recall end of the frontier, and the gap grows with LID.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.baselines.brute_force import BruteForceIndex
from repro.core.baselines.knng import build_knng
from repro.core.baselines.nsw import NSWIndex
from repro.core.build import DEGParams, build_deg
from repro.core.metrics import recall_at_k
from repro.core.search import search_graph

from .common import auc_above, emit, frontier, make_bench_dataset


def run(n: int = 6000, n_query: int = 256, dim: int = 32, k: int = 10,
        degree: int = 16, seed: int = 0) -> dict:
    summary = {}
    for lid in ("low", "high"):
        ds = make_bench_dataset(f"synth-{lid}lid", n, n_query, dim, lid,
                                k=k, seed=seed)
        # --- DEG (paper Table 3-style params scaled down) ---------------
        deg = build_deg(ds.base, DEGParams(degree=degree, k_ext=2 * degree,
                                           eps_ext=0.2, scheme="C"),
                        wave_size=16)
        deg.refine(300, seed=seed)

        def deg_search(q, eps):
            return deg.search(q, k=k, eps=eps)

        pts = frontier("fig4_deg", ds, deg_search, k=k)
        summary[f"deg_{lid}"] = auc_above(pts)

        # --- kGraph ------------------------------------------------------
        kg = build_knng(ds.base, K=degree, iterations=6, seed=seed)
        import jax.numpy as jnp

        vecs = jnp.asarray(ds.base)

        def kg_search(q, eps):
            return search_graph(kg, vecs, jnp.asarray(q), k=k, eps=eps,
                                seed=0)

        pts = frontier("fig4_kgraph", ds, kg_search, k=k)
        summary[f"kgraph_{lid}"] = auc_above(pts)

        # --- NSW ----------------------------------------------------------
        nsw = NSWIndex(ds.dim, f=degree // 2, max_degree=3 * degree,
                       capacity=n)
        nsw.add(ds.base)

        def nsw_search(q, eps):
            return nsw.search(q, k=k, eps=eps)

        pts = frontier("fig4_nsw", ds, nsw_search, k=k)
        summary[f"nsw_{lid}"] = auc_above(pts)

        # --- serial scan (reference point, recall == 1) -------------------
        bf = BruteForceIndex(ds.base)
        bf.search(ds.queries[:4], k)                     # warmup
        t0 = time.time()
        _, ids = bf.search(ds.queries, k)
        bf_qps = n_query / (time.time() - t0)
        emit("fig4_serialscan", dataset=ds.name, eps=0.0,
             recall=recall_at_k(ids, ds.gt_ids), qps=bf_qps)
        summary[f"scan_{lid}"] = bf_qps
    return summary


if __name__ == "__main__":
    print(run())
