"""Pallas kernel benchmarks: correctness vs the jnp oracle (interpret mode)
plus the analytic VMEM/MXU roofline of each kernel's BlockSpec tiling.

No TPU here, so wall-clock kernel timing is meaningless — instead we report
the *structural* numbers that determine TPU performance: bytes moved
HBM<->VMEM per tile, MXU FLOPs per tile, arithmetic intensity, and whether
the working set fits the 128 KiB-aligned VMEM budget.
"""
from __future__ import annotations

import numpy as np

from .common import emit

VMEM_BYTES = 96 * 1024 * 1024     # v5e VMEM per core (~128MiB minus reserves)


def run(seed: int = 0) -> dict:
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    out = {}

    # --- l2_topk: Q x N distance + streaming top-k ------------------------
    from repro.kernels.l2_topk import ops as l2_ops
    from repro.kernels.l2_topk import ref as l2_ref

    Q, N, m, k = 64, 4096, 128, 10
    qs = rng.normal(size=(Q, m)).astype(np.float32)
    db = rng.normal(size=(N, m)).astype(np.float32)
    d_k, i_k = l2_ops.l2_topk(jnp.asarray(qs), jnp.asarray(db), k)
    d_r, i_r = l2_ref.l2_topk_ref(jnp.asarray(qs), jnp.asarray(db), k)
    ok = bool(np.allclose(np.sort(np.asarray(d_k)), np.sort(np.asarray(d_r)),
                          atol=1e-3))
    bq, bn = 8, 512                         # ops.l2_topk tb/tn defaults
    tile_bytes = (bq * m + bn * m + bq * bn) * 4
    tile_flops = 2 * bq * bn * m
    emit("kernel_l2_topk", allclose=ok, block_q=bq, block_n=bn,
         tile_bytes=tile_bytes, tile_flops=tile_flops,
         arith_intensity=tile_flops / tile_bytes,
         fits_vmem=tile_bytes < VMEM_BYTES)
    out["l2_topk"] = ok

    # --- gather_dist: frontier neighbor gather + distance -----------------
    from repro.kernels.gather_dist import ops as gd_ops
    from repro.kernels.gather_dist import ref as gd_ref

    B, d = 32, 16
    nbr = rng.integers(0, N, size=(B, d)).astype(np.int32)
    got = gd_ops.gather_dist(jnp.asarray(db), jnp.asarray(nbr),
                             jnp.asarray(qs[:B]))
    want = gd_ref.gather_dist_ref(jnp.asarray(db), jnp.asarray(nbr),
                                  jnp.asarray(qs[:B]))
    ok = bool(np.allclose(np.asarray(got), np.asarray(want), atol=1e-3))
    tile_bytes = (d * m + m + d) * 4          # rows + query + out per lane
    emit("kernel_gather_dist", allclose=ok, block_q=1, block_n=d,
         tile_bytes=tile_bytes, tile_flops=2 * d * m,
         arith_intensity=2 * d * m / tile_bytes, fits_vmem=True)
    out["gather_dist"] = ok

    # --- gather_dist_q: int8 gather + VMEM dequant + distance --------------
    from repro.kernels.gather_dist_q import ops as gdq_ops
    from repro.kernels.gather_dist_q import ref as gdq_ref
    from repro.quant import make_store

    store = make_store(db, "sq8")
    got = gdq_ops.gather_dist_q(store.data, store.scale, jnp.asarray(nbr),
                                jnp.asarray(qs[:B]))
    want = gdq_ref.gather_dist_q_ref(store.data, store.scale,
                                     jnp.asarray(nbr), jnp.asarray(qs[:B]))
    ok = bool(np.allclose(np.asarray(got), np.asarray(want), atol=1e-3))
    tile_bytes = d * m * 1 + m * 4 + m * 4 + d * 4  # int8 rows+scale+q+out
    float_bytes = (d * m + m + d) * 4               # the gather_dist tile
    emit("kernel_gather_dist_q", allclose=ok, block_q=1, block_n=d,
         tile_bytes=tile_bytes, tile_flops=3 * d * m,
         arith_intensity=3 * d * m / tile_bytes, fits_vmem=True,
         gather_bytes_vs_float=float_bytes / tile_bytes)
    out["gather_dist_q"] = ok

    # --- bag_lookup: embedding bag gather-reduce ---------------------------
    from repro.kernels.bag_lookup import ops as bl_ops
    from repro.kernels.bag_lookup import ref as bl_ref

    V, E, F = 50000, 64, 26
    table = rng.normal(size=(V, E)).astype(np.float32)
    ids = rng.integers(0, V, size=(B, F)).astype(np.int32)
    got = bl_ops.bag_lookup(jnp.asarray(table), jnp.asarray(ids))
    want = bl_ref.bag_lookup_ref(jnp.asarray(table), jnp.asarray(ids),
                                 jnp.ones((B, F), jnp.float32))
    ok = bool(np.allclose(np.asarray(got), np.asarray(want), atol=1e-3))
    emit("kernel_bag_lookup", allclose=ok, block_q=1, block_n=F,
         tile_bytes=(F * E + E) * 4, tile_flops=F * E,
         arith_intensity=F / (F + 1), fits_vmem=True)
    out["bag_lookup"] = ok
    return out


if __name__ == "__main__":
    print(run())
