"""Pallas kernel benchmarks: correctness vs the jnp oracle (interpret mode)
plus the analytic VMEM/MXU roofline of each kernel's BlockSpec tiling.

No TPU here, so wall-clock kernel timing is meaningless — instead we report
the *structural* numbers that determine TPU performance: bytes moved
HBM<->VMEM per tile, MXU FLOPs per tile, arithmetic intensity, and whether
the working set fits the 128 KiB-aligned VMEM budget.
"""
from __future__ import annotations

import numpy as np

from .common import emit

VMEM_BYTES = 96 * 1024 * 1024     # v5e VMEM per core (~128MiB minus reserves)


def run(seed: int = 0) -> dict:
    import jax.numpy as jnp

    from repro.analysis.roofline import kernel_tile_costs

    rng = np.random.default_rng(seed)
    out = {}

    # --- l2_topk: Q x N distance + streaming top-k ------------------------
    from repro.kernels.l2_topk import ops as l2_ops
    from repro.kernels.l2_topk import ref as l2_ref

    Q, N, m, k = 64, 4096, 128, 10
    qs = rng.normal(size=(Q, m)).astype(np.float32)
    db = rng.normal(size=(N, m)).astype(np.float32)
    d_k, i_k = l2_ops.l2_topk(jnp.asarray(qs), jnp.asarray(db), k)
    d_r, i_r = l2_ref.l2_topk_ref(jnp.asarray(qs), jnp.asarray(db), k)
    ok = bool(np.allclose(np.sort(np.asarray(d_k)), np.sort(np.asarray(d_r)),
                          atol=1e-3))
    bq, bn = 8, 512                         # ops.l2_topk tb/tn defaults
    tile_bytes = (bq * m + bn * m + bq * bn) * 4
    tile_flops = 2 * bq * bn * m
    emit("kernel_l2_topk", allclose=ok, block_q=bq, block_n=bn,
         tile_bytes=tile_bytes, tile_flops=tile_flops,
         arith_intensity=tile_flops / tile_bytes,
         fits_vmem=tile_bytes < VMEM_BYTES)
    out["l2_topk"] = ok

    # --- gather_dist: frontier neighbor gather + distance -----------------
    from repro.kernels.gather_dist import ops as gd_ops
    from repro.kernels.gather_dist import ref as gd_ref

    B, d = 32, 16
    nbr = rng.integers(0, N, size=(B, d)).astype(np.int32)
    got = gd_ops.gather_dist(jnp.asarray(db), jnp.asarray(nbr),
                             jnp.asarray(qs[:B]))
    want = gd_ref.gather_dist_ref(jnp.asarray(db), jnp.asarray(nbr),
                                  jnp.asarray(qs[:B]))
    ok = bool(np.allclose(np.asarray(got), np.asarray(want), atol=1e-3))
    tc = kernel_tile_costs("gather_dist", d=d, m=m)
    emit("kernel_gather_dist", allclose=ok, block_q=1, block_n=d,
         tile_bytes=tc["hbm_bytes"], tile_flops=tc["flops"],
         arith_intensity=tc["flops"] / tc["hbm_bytes"], fits_vmem=True)
    out["gather_dist"] = ok

    # --- gather_dist_q: int8 gather + VMEM dequant + distance --------------
    from repro.kernels.gather_dist_q import ops as gdq_ops
    from repro.kernels.gather_dist_q import ref as gdq_ref
    from repro.quant import make_store

    store = make_store(db, "sq8", n=None)
    got = gdq_ops.gather_dist_q(store.data, store.scale, jnp.asarray(nbr),
                                jnp.asarray(qs[:B]))
    want = gdq_ref.gather_dist_q_ref(store.data, store.scale,
                                     jnp.asarray(nbr), jnp.asarray(qs[:B]))
    ok = bool(np.allclose(np.asarray(got), np.asarray(want), atol=1e-3))
    tc = kernel_tile_costs("gather_dist_q", d=d, m=m)
    float_bytes = kernel_tile_costs("gather_dist", d=d, m=m)["hbm_bytes"]
    emit("kernel_gather_dist_q", allclose=ok, block_q=1, block_n=d,
         tile_bytes=tc["hbm_bytes"], tile_flops=tc["flops"],
         arith_intensity=tc["flops"] / tc["hbm_bytes"], fits_vmem=True,
         gather_bytes_vs_float=float_bytes / tc["hbm_bytes"])
    out["gather_dist_q"] = ok

    # --- mrng_occlusion: gather + distance + Alg. 2 lune test --------------
    from repro.kernels.mrng_occlusion import ops as mo_ops
    from repro.kernels.mrng_occlusion import ref as mo_ref

    K = 16
    nbr3 = jnp.asarray(rng.integers(0, N, size=(B, K, d)), jnp.int32)
    cd = jnp.asarray(rng.uniform(0.5, 8.0, size=(B, K)).astype(np.float32))
    w3 = jnp.asarray(rng.uniform(0.5, 8.0,
                                 size=(B, K, d)).astype(np.float32))
    got_d, got_o = mo_ops.mrng_occlusion(jnp.asarray(db), nbr3,
                                         jnp.asarray(qs[:B]), cd, w3,
                                         backend="pallas")
    want_d, want_o = mo_ref.mrng_occlusion_ref(jnp.asarray(db), nbr3,
                                               jnp.asarray(qs[:B]), cd, w3)
    ok = (bool(np.allclose(np.asarray(got_d), np.asarray(want_d), atol=1e-3))
          and bool((np.asarray(got_o) == np.asarray(want_o)).all()))
    tc = kernel_tile_costs("mrng_occlusion", K=K, d=d, m=m)
    emit("kernel_mrng_occlusion", allclose=ok, block_q=1, block_n=K * d,
         tile_bytes=tc["hbm_bytes"], tile_flops=tc["flops"],
         arith_intensity=tc["flops"] / tc["hbm_bytes"], fits_vmem=True)
    out["mrng_occlusion"] = ok

    # --- fused_hop: multi-expansion hop (gather+filter+distance+compact) ---
    from repro.core import visited as vset
    from repro.kernels.fused_hop import ops as fh_ops
    from repro.kernels.fused_hop import ref as fh_ref

    E, deg = 4, 16
    adj = rng.integers(0, N, size=(N, deg)).astype(np.int32)
    sel = rng.integers(0, N, size=(B, E)).astype(np.int32)
    vis = vset.make_table(B, 256)
    vis = vset.insert(vis, jnp.asarray(adj[sel[:, 0]]),
                      jnp.ones((B, deg), bool))
    dmax = jnp.full((B,), 15.0, jnp.float32)
    got = fh_ops.fused_hop(jnp.asarray(adj), jnp.asarray(db),
                           jnp.asarray(sel), jnp.asarray(qs[:B]), dmax, vis,
                           n_valid=jnp.int32(N), backend="pallas")
    want = fh_ref.fused_hop_ref(jnp.asarray(adj), jnp.asarray(db),
                                jnp.asarray(sel), jnp.asarray(qs[:B]), dmax,
                                vis, n_valid=jnp.int32(N))
    ok = (bool(np.array_equal(np.asarray(got[0]), np.asarray(want[0])))
          and bool(np.allclose(np.asarray(got[1]), np.asarray(want[1]),
                               atol=1e-3))
          and bool(np.array_equal(np.asarray(got[2]), np.asarray(want[2])))
          and bool(np.array_equal(np.asarray(got[3]), np.asarray(want[3]))))
    tc = kernel_tile_costs("fused_hop", E=E, d=deg, m=m, V=256)
    emit("kernel_fused_hop", allclose=ok, block_q=1, block_n=E * deg,
         tile_bytes=tc["hbm_bytes"], tile_flops=tc["flops"],
         arith_intensity=tc["flops"] / tc["hbm_bytes"], fits_vmem=True)
    out["fused_hop"] = ok

    # --- bag_lookup: embedding bag gather-reduce ---------------------------
    from repro.kernels.bag_lookup import ops as bl_ops
    from repro.kernels.bag_lookup import ref as bl_ref

    V, E, F = 50000, 64, 26
    table = rng.normal(size=(V, E)).astype(np.float32)
    ids = rng.integers(0, V, size=(B, F)).astype(np.int32)
    got = bl_ops.bag_lookup(jnp.asarray(table), jnp.asarray(ids))
    want = bl_ref.bag_lookup_ref(jnp.asarray(table), jnp.asarray(ids),
                                 jnp.ones((B, F), jnp.float32))
    ok = bool(np.allclose(np.asarray(got), np.asarray(want), atol=1e-3))
    emit("kernel_bag_lookup", allclose=ok, block_q=1, block_n=F,
         tile_bytes=(F * E + E) * 4, tile_flops=F * E,
         arith_intensity=F / (F + 1), fits_vmem=True)
    out["bag_lookup"] = ok
    return out


if __name__ == "__main__":
    print(run())
