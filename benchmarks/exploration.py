"""Paper Fig. 5 / Sec. 6.7: exploration queries (seed == indexed query).

Protocol: queries are random *indexed* vertices; the search starts at that
vertex, which is excluded from its own result list.  Recall is measured for
a large result list (k up to 100 here, 1000 in the paper) against exact
neighbors-excluding-self.  Reproduces paper observation 2: ANNS ranking does
not predict exploration ranking — kGraph's missing reachability hurts it
here far more than in Fig. 4.
"""
from __future__ import annotations

import numpy as np

from repro.core.baselines.knng import build_knng
from repro.core.baselines.nsw import NSWIndex
from repro.core.build import DEGParams, build_deg
from repro.core.distances import exact_knn_batched
from repro.core.graph import INVALID
from repro.core.metrics import recall_at_k
from repro.core.search import range_search

from .common import Dataset, emit, make_bench_dataset, timed_search


def run(n: int = 6000, n_query: int = 256, dim: int = 32, k: int = 50,
        degree: int = 16, seed: int = 0) -> dict:
    import jax.numpy as jnp

    summary = {}
    for lid in ("low", "high"):
        ds = make_bench_dataset(f"synth-{lid}lid", n, n_query, dim, lid,
                                k=k, seed=seed)
        rng = np.random.default_rng(seed + 1)
        seeds_np = rng.integers(0, n, size=n_query).astype(np.int32)
        qvecs = ds.base[seeds_np]
        # ground truth among base, excluding the seed itself
        _, gt = exact_knn_batched(qvecs, ds.base, k + 1)
        gt_ex = np.empty((n_query, k), dtype=np.int64)
        for i in range(n_query):
            row = [x for x in gt[i] if x != seeds_np[i]][:k]
            gt_ex[i] = row

        def explore_fn(index_search):
            def fn(eps):
                def call(_q):
                    return index_search(eps)
                return call
            return fn

        # --- DEG ----------------------------------------------------------
        deg = build_deg(ds.base, DEGParams(degree=degree, k_ext=2 * degree,
                                           eps_ext=0.2), wave_size=16)
        deg.refine(300, seed=seed)
        for eps in (0.02, 0.05, 0.1, 0.2):
            res, secs = timed_search(
                lambda q: deg.explore(seeds_np, k=k, eps=eps), qvecs)
            rec = recall_at_k(np.asarray(res.ids), gt_ex)
            emit("fig5_deg", dataset=ds.name, eps=eps, recall=rec,
                 qps=n_query / secs)
            summary.setdefault(f"deg_{lid}", []).append((rec, n_query / secs))

        # --- kGraph (seed = query vertex; reachability-limited) -----------
        kg = build_knng(ds.base, K=degree, iterations=6, seed=seed)
        vecs = jnp.asarray(ds.base)
        sj = jnp.asarray(seeds_np[:, None])
        for eps in (0.02, 0.1, 0.2):
            res, secs = timed_search(
                lambda q: range_search(kg, vecs, jnp.asarray(qvecs), sj,
                                       k=k, eps=eps,
                                       exclude=sj), qvecs)
            rec = recall_at_k(np.asarray(res.ids), gt_ex)
            emit("fig5_kgraph", dataset=ds.name, eps=eps, recall=rec,
                 qps=n_query / secs)
            summary.setdefault(f"kgraph_{lid}", []).append(
                (rec, n_query / secs))

        # --- NSW -----------------------------------------------------------
        nsw = NSWIndex(ds.dim, f=degree // 2, max_degree=3 * degree,
                       capacity=n)
        nsw.add(ds.base)
        g = nsw.frozen()
        nv = jnp.asarray(nsw.vectors)
        for eps in (0.02, 0.1, 0.2):
            res, secs = timed_search(
                lambda q: range_search(g, nv, jnp.asarray(qvecs), sj, k=k,
                                       eps=eps, exclude=sj), qvecs)
            rec = recall_at_k(np.asarray(res.ids), gt_ex)
            emit("fig5_nsw", dataset=ds.name, eps=eps, recall=rec,
                 qps=n_query / secs)
            summary.setdefault(f"nsw_{lid}", []).append((rec, n_query / secs))
    return {k2: max(r for r, _ in v) for k2, v in summary.items()}


if __name__ == "__main__":
    print(run())
