"""Quantized-store frontier: recall@10 vs vector-memory-bytes vs QPS.

The serving question behind ISSUE 2 (and the PQ tier): how much of the
float32 store's HBM footprint can the hot traversal path shed before the
two-stage rerank can no longer buy the recall back?  For each codec
(float32 / fp16 / sq8 / pq) and several ``rerank_k`` widths this sweeps
the ``bench-small`` config and emits one row per point: recall@10, QPS,
eps (pq traverses at the wider preset eps — see ``QUANT_PRESETS``), and
the traversal store's bytes for the live rows
(``DEGIndex.memory_stats``).

Acceptance bars tracked here (enforced — a breach raises, failing the CI
smoke job):

* SQ8 two-stage within 1% recall of the float32 single-stage path at
  >= 3.5x memory reduction;
* PQ at >= 8x memory reduction holding recall@10 >= 0.95 under two-stage
  rerank (full bench-small config; the --quick smoke uses a smaller
  corpus where the shared 256*dim*4-byte codebook is not yet amortized,
  so it checks a recall floor only).

The headline lands in ``BENCH_quant.json`` via ``write_bench_json`` so
the compression trajectory accrues across PRs.
"""
from __future__ import annotations

import numpy as np

from repro.configs.deg import DEG_PAPER_CONFIGS
from repro.core.build import build_deg
from repro.core.metrics import recall_at_k

from .common import emit, make_bench_dataset, timed_search, write_bench_json

#: recall floor for the --quick PQ smoke (codebook unamortized, narrow
#: rerank — this guards "the pq path works", not the full-config bar)
QUICK_PQ_FLOOR = 0.70


def run(n: int = 4000, n_query: int = 256, dim: int = 32, k: int = 10,
        eps: float = 0.1, pq_eps: float = 0.2,
        rerank_ks=(10, 20, 40), pq_rerank_ks=(80, 120),
        seed: int = 0) -> dict:
    params = DEG_PAPER_CONFIGS["bench-small"]
    ds = make_bench_dataset("synth-lowlid", n, n_query, dim, "low", k=k,
                            seed=seed)
    deg = build_deg(ds.base, params, wave_size=16)
    deg.refine(200, seed=seed)
    mem = deg.memory_stats()

    summary: dict = {}

    def measure(name, codec, rerank_k, quantized, meps):
        res, secs = timed_search(
            lambda q: deg.search_batch(q, k=k, eps=meps, quantized=quantized,
                                       rerank_k=rerank_k), ds.queries,
            repeats=2)
        rec = recall_at_k(np.asarray(res.ids)[:, :k], ds.gt_ids[:, :k])
        bytes_ = mem[f"{codec}_bytes"]
        emit("quantization", dataset=ds.name, codec=codec, eps=meps,
             rerank_k=rerank_k or 0, recall=rec, qps=n_query / secs,
             store_bytes=bytes_, mem_ratio=mem[f"{codec}_ratio"],
             evals=float(np.mean(np.asarray(res.evals))))
        return rec

    # exact single-stage baseline
    base_rec = measure("float32", "float32", None, None, eps)
    summary["float32"] = base_rec

    # pq traverses at a wider eps (QUANT_PRESETS["pq-*"].eps): ADC error
    # distorts the beam's stopping rule, so at eps=0.1 recall plateaus
    # ~0.89 no matter how wide the exact rerank is — the candidates were
    # never visited.  eps buys the visits, rerank_k restores the order.
    for codec, widths, ceps in (("fp16", rerank_ks, eps),
                                ("sq8", rerank_ks, eps),
                                ("pq", pq_rerank_ks, pq_eps)):
        best = 0.0
        for rk in widths:
            best = max(best, measure(codec, codec, rk, codec, ceps))
        summary[codec] = best
        summary[f"{codec}_ratio"] = mem[f"{codec}_ratio"]

    summary["sq8_within_1pct"] = bool(summary["sq8"] >= base_rec - 0.01)
    summary["sq8_mem_ok"] = bool(mem["sq8_ratio"] >= 3.5)

    # PQ bar: the full config amortizes the shared codebook (>= 8x); the
    # quick smoke only sanity-floors the recall of the pq path.
    full = n >= 4000
    summary["pq_full_config"] = full
    if full:
        summary["pq_ok"] = bool(mem["pq_ratio"] >= 8.0
                                and summary["pq"] >= 0.95)
    else:
        summary["pq_ok"] = bool(summary["pq"] >= QUICK_PQ_FLOOR)

    write_bench_json("quant", {
        "n": n, "n_query": n_query, "dim": dim, "k": k, "eps": eps,
        "pq_eps": pq_eps,
        "rerank_ks": list(rerank_ks), "pq_rerank_ks": list(pq_rerank_ks),
        **{kk: summary[kk] for kk in
           ("float32", "fp16", "sq8", "pq", "fp16_ratio", "sq8_ratio",
            "pq_ratio", "sq8_within_1pct", "sq8_mem_ok", "pq_full_config",
            "pq_ok")},
    })

    if not summary["sq8_within_1pct"] or not summary["sq8_mem_ok"]:
        raise AssertionError(f"sq8 acceptance breached: {summary}")
    if not summary["pq_ok"]:
        raise AssertionError(f"pq acceptance breached: {summary}")
    return summary


if __name__ == "__main__":
    print(run())
