"""Quantized-store frontier: recall@10 vs vector-memory-bytes vs QPS.

The serving question behind ISSUE 2: how much of the float32 store's HBM
footprint can the hot traversal path shed before the two-stage rerank can
no longer buy the recall back?  For each codec (float32 / fp16 / sq8) and
several ``rerank_k`` widths this sweeps the ``bench-small`` config and
emits one row per point: recall@10, QPS (fixed eps), and the traversal
store's bytes for the live rows (``DEGIndex.memory_stats``).

Acceptance bar tracked here: SQ8 two-stage must sit within 1% recall of
the float32 single-stage path at >= 3.5x memory reduction.
"""
from __future__ import annotations

import numpy as np

from repro.configs.deg import DEG_PAPER_CONFIGS
from repro.core.build import build_deg
from repro.core.metrics import recall_at_k

from .common import emit, make_bench_dataset, timed_search


def run(n: int = 4000, n_query: int = 256, dim: int = 32, k: int = 10,
        eps: float = 0.1, rerank_ks=(10, 20, 40), seed: int = 0) -> dict:
    params = DEG_PAPER_CONFIGS["bench-small"]
    ds = make_bench_dataset("synth-lowlid", n, n_query, dim, "low", k=k,
                            seed=seed)
    deg = build_deg(ds.base, params, wave_size=16)
    deg.refine(200, seed=seed)
    mem = deg.memory_stats()

    summary: dict = {}

    def measure(name, codec, rerank_k, quantized):
        res, secs = timed_search(
            lambda q: deg.search_batch(q, k=k, eps=eps, quantized=quantized,
                                       rerank_k=rerank_k), ds.queries,
            repeats=2)
        rec = recall_at_k(np.asarray(res.ids)[:, :k], ds.gt_ids[:, :k])
        bytes_ = mem[f"{codec}_bytes"]
        emit("quantization", dataset=ds.name, codec=codec,
             rerank_k=rerank_k or 0, recall=rec, qps=n_query / secs,
             store_bytes=bytes_, mem_ratio=mem[f"{codec}_ratio"],
             evals=float(np.mean(np.asarray(res.evals))))
        return rec

    # exact single-stage baseline
    base_rec = measure("float32", "float32", None, None)
    summary["float32"] = base_rec

    for codec in ("fp16", "sq8"):
        best = 0.0
        for rk in rerank_ks:
            best = max(best, measure(codec, codec, rk, codec))
        summary[codec] = best
        summary[f"{codec}_ratio"] = mem[f"{codec}_ratio"]

    summary["sq8_within_1pct"] = bool(summary["sq8"] >= base_rec - 0.01)
    summary["sq8_mem_ok"] = bool(mem["sq8_ratio"] >= 3.5)
    return summary


if __name__ == "__main__":
    print(run())
