"""Paper Appendix G (Fig. 9/10): neighbor-selection schemes A/B/C/D.

Builds DEG with each extension scheme (no insert-time optimization) on a
low-LID and a high-LID dataset and compares frontiers; then checks that
RNG/MRNG checks (Algorithm 2) help.  Paper finding reproduced: C wins on
high-LID, D on low-LID; GQ cannot tell A/C/D apart while avg-neighbor-dist
can (Fig. 1's argument).
"""
from __future__ import annotations

import numpy as np

from repro.core.build import DEGParams, build_deg
from repro.core.metrics import graph_quality, recall_at_k

from .common import emit, make_bench_dataset


def run(n: int = 3000, n_query: int = 200, dim: int = 24, k: int = 10,
        degree: int = 12, seed: int = 0) -> dict:
    out = {}
    for lid in ("low", "high"):
        ds = make_bench_dataset(f"synth-{lid}lid", n, n_query, dim, lid,
                                k=k, seed=seed)
        for scheme in ("A", "B", "C", "D"):
            idx = build_deg(ds.base,
                            DEGParams(degree=degree, k_ext=2 * degree,
                                      eps_ext=0.2, scheme=scheme,
                                      rng_checks=False),
                            wave_size=16)
            res = idx.search(ds.queries, k=k, eps=0.1)
            rec = recall_at_k(np.asarray(res.ids), ds.gt_ids)
            row = dict(
                scheme=scheme, lid=lid, recall=rec,
                avg_nbr_dist=idx.builder.average_neighbor_distance(),
                gq=graph_quality(idx.builder, idx.vectors),
                evals=float(np.mean(np.asarray(res.evals))))
            emit("appG_scheme", **row)
            out[f"{scheme}_{lid}"] = row
        # RNG-check ablation on scheme C
        idx = build_deg(ds.base,
                        DEGParams(degree=degree, k_ext=2 * degree,
                                  eps_ext=0.2, scheme="C", rng_checks=True),
                        wave_size=16)
        res = idx.search(ds.queries, k=k, eps=0.1)
        emit("appG_rng_checks", scheme="C+RNG", lid=lid,
             recall=recall_at_k(np.asarray(res.ids), ds.gt_ids),
             avg_nbr_dist=idx.builder.average_neighbor_distance(),
             gq=graph_quality(idx.builder, idx.vectors),
             evals=float(np.mean(np.asarray(res.evals))))
    return out


if __name__ == "__main__":
    print(run())
