"""Paper Table 12 (Appendix F): structural statistics of each graph.

graph quality GQ, avg/min/max in- and out-degree, source-vertex count,
search & exploration reachability — reproducing the paper's structural
explanation of *why* DEG explores better: regular degree, no sources, full
reachability; kGraph/NSW show hubs and unreachable sources.
"""
from __future__ import annotations

from collections import deque

import numpy as np

from repro.core.baselines.knng import build_knng
from repro.core.baselines.nsw import NSWIndex
from repro.core.build import DEGParams, build_deg
from repro.core.graph import INVALID
from repro.core.metrics import graph_quality

from .common import emit, make_bench_dataset


def degree_stats(adjacency: np.ndarray, n: int) -> dict:
    adj = adjacency[:n]
    out_deg = (adj != INVALID).sum(axis=1)
    in_deg = np.zeros(n, dtype=np.int64)
    flat = adj[adj != INVALID]
    np.add.at(in_deg, flat, 1)
    sources = int((in_deg == 0).sum())
    return {
        "avg_out": float(out_deg.mean()), "min_out": int(out_deg.min()),
        "max_out": int(out_deg.max()), "min_in": int(in_deg.min()),
        "max_in": int(in_deg.max()), "sources": sources,
    }


def bfs_reach(adjacency: np.ndarray, n: int, start: int) -> float:
    seen = np.zeros(n, bool)
    seen[start] = True
    dq = deque([start])
    while dq:
        v = dq.popleft()
        for u in adjacency[v]:
            if u != INVALID and not seen[u]:
                seen[u] = True
                dq.append(int(u))
    return float(seen.mean())


def explore_reach(adjacency: np.ndarray, n: int, samples: int = 32,
                  seed: int = 0) -> float:
    rng = np.random.default_rng(seed)
    return float(np.mean([bfs_reach(adjacency, n, int(s))
                          for s in rng.integers(0, n, samples)]))


def run(n: int = 3000, dim: int = 24, degree: int = 12,
        seed: int = 0) -> dict:
    ds = make_bench_dataset("synth-lowlid", n, 10, dim, "low", seed=seed)
    out = {}

    deg = build_deg(ds.base, DEGParams(degree=degree, k_ext=2 * degree,
                                       eps_ext=0.2), wave_size=16)
    adj = deg.builder.adjacency
    row = degree_stats(adj, n)
    row["gq"] = graph_quality(deg.builder, deg.vectors)
    row["search_reach"] = bfs_reach(adj, n, 0)
    row["explore_reach"] = explore_reach(adj, n, seed=seed)
    emit("table12_deg", **row)
    out["deg"] = row
    assert row["min_out"] == row["max_out"] == degree   # even-regular
    assert row["sources"] == 0
    assert row["search_reach"] == 1.0

    kg = build_knng(ds.base, K=degree, iterations=6, seed=seed)
    adj = np.asarray(kg.adjacency)
    row = degree_stats(adj, n)
    from repro.core.graph import GraphBuilder

    row["search_reach"] = bfs_reach(adj, n, 0)
    row["explore_reach"] = explore_reach(adj, n, seed=seed)
    emit("table12_kgraph", **row)
    out["kgraph"] = row

    nsw = NSWIndex(ds.dim, f=degree // 2, max_degree=3 * degree, capacity=n)
    nsw.add(ds.base)
    row = degree_stats(nsw.adjacency, n)
    row["search_reach"] = bfs_reach(nsw.adjacency, n, 0)
    row["explore_reach"] = explore_reach(nsw.adjacency, n, seed=seed)
    emit("table12_nsw", **row)
    out["nsw"] = row
    return out


if __name__ == "__main__":
    print(run())
