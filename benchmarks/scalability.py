"""Paper Fig. 6 / Sec. 7.1: empirical scalability.

Search cost (hops, and wall time) and per-vertex insertion time versus index
size n, at fixed recall target.  The paper's claim: both grow like
``O(n^(1/m') log n^(1/m'))`` with m' ~ the intrinsic dimension — i.e.
sub-logarithmic growth of hops in practice.  We fit hops ~ a + b*log(n) and
report the measured hop counts so §Roofline can rescale the DEG search
roofline by realistic trip counts.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.build import DEGParams, build_deg
from repro.core.metrics import recall_at_k
from repro.core.distances import exact_knn_batched

from .common import emit


def run(sizes=(1000, 2000, 4000, 8000), dim: int = 32, k: int = 10,
        degree: int = 16, n_query: int = 200, seed: int = 0) -> dict:
    from repro.data.synthetic import make_dataset

    base_full, queries = make_dataset("gaussian", max(sizes), n_query, dim,
                                      seed=seed)
    hops_at_n = {}
    for n in sizes:
        base = base_full[:n]
        _, gt = exact_knn_batched(queries, base, k)
        t0 = time.time()
        idx = build_deg(base, DEGParams(degree=degree, k_ext=2 * degree,
                                        eps_ext=0.2), wave_size=16)
        build_s = time.time() - t0
        # per-vertex insertion time at this size (add 64 more)
        extra = base_full[n: n + 64] + 1e-3
        t0 = time.time()
        idx.add(extra, wave_size=1)
        insert_ms = (time.time() - t0) / 64 * 1e3
        res = idx.search(queries, k=k, eps=0.1)
        rec = recall_at_k(np.asarray(res.ids), gt)
        hops = float(np.mean(np.asarray(res.hops)))
        evals = float(np.mean(np.asarray(res.evals)))
        emit("fig6_scaling", n=n, recall=rec, hops=hops, evals=evals,
             insert_ms=insert_ms, build_s=build_s)
        hops_at_n[n] = hops
    # log-fit: hops = a + b ln n  (paper: sub-logarithmic => b small)
    ns = np.array(sorted(hops_at_n))
    hs = np.array([hops_at_n[x] for x in ns])
    b, a = np.polyfit(np.log(ns), hs, 1)
    emit("fig6_fit", a=float(a), b_per_ln_n=float(b),
         hops_1e6_extrapolated=float(a + b * np.log(1e6)),
         hops_16m_extrapolated=float(a + b * np.log(1 << 24)))
    return {"hops": hops_at_n, "log_slope": float(b),
            "hops_16m": float(a + b * np.log(1 << 24))}


if __name__ == "__main__":
    print(run())
