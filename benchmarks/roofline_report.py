"""§Roofline reporting: aggregates the dry-run JSON records into the
per-(arch x shape x mesh) roofline table (assignment deliverable g).

Reads ``reports/dryrun/<mesh>/<arch>__<shape>.json`` written by
``repro.launch.dryrun``; rescales the DEG search cells' while-loops by the
*measured* average hop count from benchmarks.scalability (the compiled loop
bound is max_hops, a worst case).
"""
from __future__ import annotations

import glob
import json
import os

from .common import emit

MESHES = ("pod16x16", "pod2x16x16")


def load_records(root: str = "reports/dryrun") -> list[dict]:
    recs = []
    for mesh in MESHES:
        for path in sorted(glob.glob(os.path.join(root, mesh, "*.json"))):
            with open(path) as f:
                recs.append(json.load(f))
    return recs


def kernel_rows() -> int:
    """Structural roofline of every Pallas kernel at the production-search
    cell dims — emitted unconditionally so the report always covers the
    kernels (``mrng_occlusion`` alongside ``beam_merge`` /
    ``gather_dist_q``) even when no dry-run records exist."""
    from repro.analysis.roofline import KERNEL_DIMS, kernel_roofline

    for name, dims in KERNEL_DIMS.items():
        r = kernel_roofline(name, **dims)
        emit("roofline_kernel", kernel=name, **dims,
             t_comp=r.t_comp, t_mem=r.t_mem, bottleneck=r.bottleneck,
             arith_intensity=r.flops / max(r.hbm_bytes, 1.0))
    return len(KERNEL_DIMS)


def serving_attribution(
        metrics_path: str = "reports/serving_metrics.json") -> int:
    """Kernel-time attribution of the measured serving device time.

    ``benchmarks.serving_load`` snapshots its engine's metrics registry;
    the ``serving_flush_latency_ms`` histogram sums are the wall time the
    engine spent inside dispatched search programs, and the hop / eval
    counters (free device-side figures surfaced by ``SearchResult``) give
    per-kernel tile counts.  ``attribute_kernel_time`` splits the
    measured total across kernels by their structural roofline weights —
    a profiler-free answer to "where did the serving milliseconds go".
    Returns the number of attributed kernels (0 when no snapshot exists,
    e.g. the serving bench has not run)."""
    from repro.analysis.roofline import KERNEL_DIMS, attribute_kernel_time
    from repro.obs import MetricsRegistry

    if not os.path.exists(metrics_path):
        emit("roofline_serving", status="no serving metrics snapshot",
             path=metrics_path)
        return 0
    with open(metrics_path) as f:
        reg = MetricsRegistry.from_snapshot(json.load(f))
    flush_s = hops = evals = 0.0
    for m in reg.metrics():
        if m.name == "serving_flush_latency_ms":
            flush_s += m.sum / 1e3
        elif m.name == "serving_hops_total":
            hops = m.value
        elif m.name == "serving_evals_total":
            evals = m.value
    if flush_s <= 0 or (hops <= 0 and evals <= 0):
        emit("roofline_serving", status="snapshot has no flush/hop data",
             path=metrics_path)
        return 0
    # tile counts per kernel family on the multi-expansion serving path:
    # one fused hop + one beam partial-merge per recorded hop; the int8
    # gather covers `degree` distance evals per tile.
    tiles = {
        "fused_hop": hops,
        "beam_merge": hops,
        "gather_dist_q": evals / KERNEL_DIMS["gather_dist_q"]["d"],
    }
    attr = attribute_kernel_time(flush_s, tiles)
    for name, a in sorted(attr.items(), key=lambda kv: -kv[1]["fraction"]):
        emit("roofline_serving", kernel=name, tiles=a["tiles"],
             seconds=a["seconds"], fraction=a["fraction"],
             measured_flush_s=flush_s)
    return len(attr)


def run(root: str = "reports/dryrun", measured_deg_hops: float | None = None
        ) -> dict:
    n_kernels = kernel_rows()
    n_serving = serving_attribution()
    recs = load_records(root)
    if not recs:
        emit("roofline", status="no dry-run records found", root=root)
        return {"kernels": n_kernels, "serving_kernels": n_serving}
    n_ok = n_skip = n_err = 0
    worst = None
    most_coll = None
    for r in recs:
        if r["status"] == "skipped":
            n_skip += 1
            emit("roofline_skip", mesh=r["mesh"], arch=r["arch"],
                 shape=r["shape"], reason=r.get("reason", "")[:60])
            continue
        if r["status"] != "ok":
            n_err += 1
            emit("roofline_error", mesh=r["mesh"], arch=r["arch"],
                 shape=r["shape"], error=r.get("error", "")[:80])
            continue
        n_ok += 1
        rl = r["roofline"]
        emit("roofline", mesh=r["mesh"], arch=r["arch"], shape=r["shape"],
             variant=r.get("variant", ""),
             t_comp=rl["t_comp_s"], t_mem=rl["t_mem_s"],
             t_coll=rl["t_coll_s"], bottleneck=rl["bottleneck"],
             useful_ratio=rl["useful_ratio"], mfu_bound=rl["mfu_bound"])
        if r["mesh"] == "pod16x16" and not r.get("variant"):
            key = (r["arch"], r["shape"])
            if worst is None or rl["mfu_bound"] < worst[1]:
                worst = (key, rl["mfu_bound"])
            frac = rl["t_coll_s"] / max(rl["step_time_s"], 1e-12)
            if most_coll is None or frac > most_coll[1]:
                most_coll = (key, frac)
    emit("roofline_summary", ok=n_ok, skipped=n_skip, errors=n_err,
         worst_mfu_cell=str(worst[0]) if worst else "-",
         most_collective_cell=str(most_coll[0]) if most_coll else "-")
    return {"ok": n_ok, "skipped": n_skip, "errors": n_err,
            "kernels": n_kernels, "serving_kernels": n_serving}


if __name__ == "__main__":
    print(run())
