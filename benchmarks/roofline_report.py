"""§Roofline reporting: aggregates the dry-run JSON records into the
per-(arch x shape x mesh) roofline table (assignment deliverable g).

Reads ``reports/dryrun/<mesh>/<arch>__<shape>.json`` written by
``repro.launch.dryrun``; rescales the DEG search cells' while-loops by the
*measured* average hop count from benchmarks.scalability (the compiled loop
bound is max_hops, a worst case).
"""
from __future__ import annotations

import glob
import json
import os

from .common import emit

MESHES = ("pod16x16", "pod2x16x16")


def load_records(root: str = "reports/dryrun") -> list[dict]:
    recs = []
    for mesh in MESHES:
        for path in sorted(glob.glob(os.path.join(root, mesh, "*.json"))):
            with open(path) as f:
                recs.append(json.load(f))
    return recs


def kernel_rows() -> int:
    """Structural roofline of every Pallas kernel at the production-search
    cell dims — emitted unconditionally so the report always covers the
    kernels (``mrng_occlusion`` alongside ``beam_merge`` /
    ``gather_dist_q``) even when no dry-run records exist."""
    from repro.analysis.roofline import KERNEL_DIMS, kernel_roofline

    for name, dims in KERNEL_DIMS.items():
        r = kernel_roofline(name, **dims)
        emit("roofline_kernel", kernel=name, **dims,
             t_comp=r.t_comp, t_mem=r.t_mem, bottleneck=r.bottleneck,
             arith_intensity=r.flops / max(r.hbm_bytes, 1.0))
    return len(KERNEL_DIMS)


def run(root: str = "reports/dryrun", measured_deg_hops: float | None = None
        ) -> dict:
    n_kernels = kernel_rows()
    recs = load_records(root)
    if not recs:
        emit("roofline", status="no dry-run records found", root=root)
        return {"kernels": n_kernels}
    n_ok = n_skip = n_err = 0
    worst = None
    most_coll = None
    for r in recs:
        if r["status"] == "skipped":
            n_skip += 1
            emit("roofline_skip", mesh=r["mesh"], arch=r["arch"],
                 shape=r["shape"], reason=r.get("reason", "")[:60])
            continue
        if r["status"] != "ok":
            n_err += 1
            emit("roofline_error", mesh=r["mesh"], arch=r["arch"],
                 shape=r["shape"], error=r.get("error", "")[:80])
            continue
        n_ok += 1
        rl = r["roofline"]
        emit("roofline", mesh=r["mesh"], arch=r["arch"], shape=r["shape"],
             variant=r.get("variant", ""),
             t_comp=rl["t_comp_s"], t_mem=rl["t_mem_s"],
             t_coll=rl["t_coll_s"], bottleneck=rl["bottleneck"],
             useful_ratio=rl["useful_ratio"], mfu_bound=rl["mfu_bound"])
        if r["mesh"] == "pod16x16" and not r.get("variant"):
            key = (r["arch"], r["shape"])
            if worst is None or rl["mfu_bound"] < worst[1]:
                worst = (key, rl["mfu_bound"])
            frac = rl["t_coll_s"] / max(rl["step_time_s"], 1e-12)
            if most_coll is None or frac > most_coll[1]:
                most_coll = (key, frac)
    emit("roofline_summary", ok=n_ok, skipped=n_skip, errors=n_err,
         worst_mfu_cell=str(worst[0]) if worst else "-",
         most_collective_cell=str(most_coll[0]) if most_coll else "-")
    return {"ok": n_ok, "skipped": n_skip, "errors": n_err,
            "kernels": n_kernels}


if __name__ == "__main__":
    print(run())
