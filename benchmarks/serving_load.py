"""Open-loop serving latency under Poisson load — the honest online
version of ``search_pareto.py``'s offline QPS.

A closed-loop benchmark (submit a batch, wait, repeat) can never observe
queueing delay: the load adapts to the server.  This harness drives the
continuous-batching ``AsyncQueryEngine`` **open-loop**: request arrival
times are drawn from a Poisson process at a fixed offered rate and each
request is submitted at its scheduled instant *regardless of how the
server is doing* — late submission (the generator falling behind) counts
against the measured latency, exactly like a real front end under heavy
traffic.  Per-request latency = completion time − scheduled arrival
time, so p50/p99/p99.9 include queueing, coalescing linger, device
compute, and extract.

Protocol:

1. build the bench-small index (+refine), exact ground truth;
2. measure the **offline closed-loop baseline**: full-batch
   ``DEGIndex.search`` wall-clock QPS (the ``search_pareto.py`` figure
   this engine is held to — acceptance: sustained online QPS within
   1.3x at equal recall@10);
3. boot the async engine, ``warmup()`` (every (bucket, variant) program
   precompiled — no request pays a trace);
4. offered rate = ``rate`` or ``rate_fraction`` × the offline baseline;
   submit for ``duration`` seconds of Poisson arrivals, block for all
   completions;
5. report p50/p99/p99.9 latency, sustained QPS, recall@10, partial /
   deadline-forced-flush counts; write ``BENCH_serving.json`` at the
   repo root (the standing perf trajectory across PRs).

Telemetry (obs/) is part of the protocol: the headline (non-quick) run
drives the same open-loop trace twice — tracing off, then tracing at
sample rate 1.0 with the JSONL query log — and reports the QPS overhead
ratio (the <3% gate of ISSUE 7).  Every traced phase closes the loop:
the query log is reloaded (``obs.querylog.read_query_log``), replayed
into a fresh registry, and the replayed request-latency p50/p99 and
recall@10 must equal the live registry's / the harness's figures
*exactly* — bench and prod share one measurement path, and the log is
proven to carry it.  The final registry snapshot lands in
``reports/serving_metrics.json`` (the roofline report's kernel-time
attribution input).

``quick=True`` (the CI smoke gate) shrinks everything, pins the seed,
runs one traced phase (timing-ratio gates are too flaky for shared
runners), and enforces the floors: recall@10 >= ``recall_floor`` (the
differential-grid float32 floor), p99 <= ``p99_floor_ms`` (a generous
bound — the gate catches an engine that stops batching or retraces per
request, not millisecond regressions), plus the exact query-log
round-trip equalities.

``--burst`` (:func:`run_burst`) is the overload protocol: the same
open-loop driver against a *bounded* engine (``max_queue`` +
degradation ladder armed), first uncontended (0.5x the offline
baseline) and then at 2x — every submission must end in exactly one of
served / typed ``OverloadError`` shed / typed crash, nothing may hang,
shed rejections must come back within the deadline, and the recall@10
of degraded-mode responses must hold the 0.95 floor.  Counts and
degraded recall land in the ``burst`` section of the commit's
``BENCH_serving.json`` entry.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.configs.deg import DEG_PAPER_CONFIGS
from repro.core.build import build_deg
from repro.core.metrics import recall_at_k
from repro.obs import (LATENCY_METRIC, MetricsRegistry, QueryLogWriter,
                       clock, read_query_log, recall_from_log,
                       replay_registry)

from .common import emit, make_bench_dataset, write_bench_json


#: the CI smoke configuration (deterministic seed, small index, short
#: duration, un-overloaded rate) — shared by ``--quick`` and
#: ``benchmarks.run``'s QUICK_OVERRIDES so the gate is one config.
#: multi-e2-l64 is the saturated-recall preset (PR 4's headline point),
#: which is what the 0.95 differential-grid float32 floor pins.
QUICK_CONFIG = dict(n=1500, n_query=128, duration=1.5, refine=100,
                    search_preset="multi-e2-l64", max_batch=64,
                    bucket_floor=16, deadline_ms=400.0,
                    rate_fraction=0.6, quick=True)


def _percentiles(lats_ms: np.ndarray) -> dict:
    return {
        "p50_ms": float(np.percentile(lats_ms, 50)),
        "p99_ms": float(np.percentile(lats_ms, 99)),
        "p999_ms": float(np.percentile(lats_ms, 99.9)),
        "max_ms": float(lats_ms.max()),
    }


def run(n: int = 6000, n_query: int = 256, dim: int = 32, k: int = 10,
        eps: float = 0.1, seed: int = 0, refine: int = 300,
        search_preset: str = "multi-e2-l64", max_batch: int = 128,
        bucket_floor: int = 32, deadline_ms: float = 600.0,
        linger_ms: float = 4.0, partial_hops: int = 8,
        rate: float | None = None, rate_fraction: float = 0.85,
        duration: float = 6.0, max_requests: int = 20000,
        quick: bool = False, p99_floor_ms: float = 1000.0,
        recall_floor: float = 0.95) -> dict:
    from repro.serving.async_engine import AsyncQueryEngine

    from repro.configs.deg import SEARCH_PRESETS

    ds = make_bench_dataset("bench-small", n, n_query, dim, "low", k=k,
                            seed=seed)
    params = DEG_PAPER_CONFIGS["bench-small"]
    idx = build_deg(ds.base, params, wave_size=16)
    if refine:
        idx.refine(refine, seed=seed)

    # -- offline closed-loop baseline (the search_pareto protocol, same
    # search program as the engine will serve — equal-recall comparison) --
    sp = SEARCH_PRESETS[search_preset]

    def offline(qs):
        res = idx.search(qs, k=k, eps=eps, beam_width=sp.beam_width,
                         expand_width=sp.expand_width,
                         visited_size=sp.visited_size,
                         hop_backend=sp.hop_backend)
        jax.block_until_ready(res.ids)
        return res

    offline(ds.queries)                       # compile
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        res = offline(ds.queries)
        best = min(best, time.perf_counter() - t0)
    offline_qps = n_query / best
    offline_recall = recall_at_k(np.asarray(res.ids)[:, :k],
                                 ds.gt_ids[:, :k])
    emit("serving_offline_baseline", dataset=ds.name, qps=offline_qps,
         recall=offline_recall, batch=n_query)

    # -- the async engine under open-loop Poisson load --------------------
    offered = rate if rate is not None else rate_fraction * offline_qps
    rng = np.random.default_rng(seed)
    n_req = int(min(offered * duration, max_requests))
    if n_req < 32:
        n_req = 32
    inter = rng.exponential(1.0 / offered, size=n_req)
    arrivals = np.cumsum(inter)               # scheduled instants
    q_idx = rng.integers(0, n_query, size=n_req)

    engine_cfg = dict(k=k, eps=eps, preset=search_preset,
                      max_batch=max_batch, bucket_floor=bucket_floor,
                      deadline_ms=deadline_ms, linger_ms=linger_ms,
                      partial_hops=partial_hops)

    def drive(eng):
        """One open-loop pass over the precomputed arrival schedule.

        Returns (futures, wall seconds, exact per-request latency ms).
        clock.now() (perf_counter) on both sides of the subtraction —
        AsyncResult stamps come from the same clock (obs/clock.py)."""
        futs = []
        t_start = clock.now()
        for i in range(n_req):
            # open loop: sleep only when ahead of schedule; when behind,
            # fire immediately — the backlog shows up as latency, never
            # as a lower offered rate
            lag = arrivals[i] - (clock.now() - t_start)
            if lag > 0:
                time.sleep(lag)
            futs.append(eng.submit(ds.queries[q_idx[i]]))
        for f in futs:
            f.result(timeout=300.0)
        t_last = clock.now() - t_start
        # latency vs the *scheduled* arrival (open-loop convention)
        lats_ms = np.array([
            (f.completed_at - (t_start + arrivals[i])) * 1e3
            for i, f in enumerate(futs)])
        return futs, t_last, lats_ms

    def phase_recall(futs):
        full = [i for i, f in enumerate(futs) if not f.partial]
        if not full:   # partial (deadline-shed) results are load-shedding
            return 0.0, full          # by design, not a recall sample
        got = np.stack([futs[i].ids for i in full])
        return recall_at_k(got[:, :k], ds.gt_ids[q_idx[full]][:, :k]), full

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    reports = os.path.join(root, "reports")
    os.makedirs(reports, exist_ok=True)

    # Phase A (headline runs only): tracing *off* — the baseline QPS the
    # <3% telemetry-overhead gate is measured against.  Quick/CI skips it:
    # a wall-clock ratio on a shared runner is noise, and the quick gates
    # are the deterministic round-trip equalities below.
    base_sustained = None
    if not quick:
        eng0 = AsyncQueryEngine(idx, **engine_cfg)
        eng0.warmup()
        _, t_last0, lats0 = drive(eng0)
        eng0.close()
        base_sustained = n_req / t_last0
        emit("serving_untraced_baseline", sustained_qps=base_sustained,
             p99_ms=float(np.percentile(lats0, 99)))

    # Phase B: tracing at sample rate 1.0 + the structured query log —
    # the instrumented run all reported figures come from.
    qlog_path = os.path.join(reports, "serving_querylog.jsonl")
    for seg in [qlog_path] + [f"{qlog_path}.{j}" for j in range(1, 9)]:
        if os.path.exists(seg):
            os.remove(seg)            # fresh log: round trip counts it
    registry = MetricsRegistry()
    qlog = QueryLogWriter(qlog_path)
    eng = AsyncQueryEngine(idx, metrics=registry, trace_sample=1.0,
                           query_log=qlog, **engine_cfg)
    t0 = time.perf_counter()
    compile_times = eng.warmup()
    warmup_s = time.perf_counter() - t0
    emit("serving_warmup", programs=len(compile_times), seconds=warmup_s,
         slowest_ms=max(compile_times.values()) * 1e3)

    futs, t_last, lats_ms = drive(eng)
    eng.close()
    qlog.close()

    pct = _percentiles(lats_ms)
    sustained = n_req / t_last
    rec, full = phase_recall(futs)
    st = eng.stats
    lat_hist = registry.histogram(LATENCY_METRIC)
    overhead_pct = (None if base_sustained is None else
                    (base_sustained - sustained) / base_sustained * 100.0)
    row = emit("serving_open_loop", dataset=ds.name,
               preset=search_preset, offered_qps=offered,
               sustained_qps=sustained, recall=rec,
               online_vs_offline=offline_qps / max(sustained, 1e-9),
               partials=st.partials, forced_flushes=st.forced_flushes,
               flushes=st.flushes, requests=n_req,
               engine_p50_ms=lat_hist.percentile(50),
               engine_p99_ms=lat_hist.percentile(99), **pct)
    if overhead_pct is not None:
        emit("serving_trace_overhead", untraced_qps=base_sustained,
             traced_qps=sustained, overhead_pct=overhead_pct,
             gate_pct=3.0)

    # -- query-log round trip: the log must carry the measurement ---------
    # Reload the JSONL, replay it into a *fresh* registry, and demand the
    # replayed request-latency histogram and recall@k equal the live
    # figures exactly — deterministic (bucket counts and set-intersection
    # recall are pure functions of the records), so asserted on every
    # run including CI.
    recs = read_query_log(qlog_path)
    assert len(recs) == n_req, (
        f"query log has {len(recs)} records for {n_req} requests "
        f"(trace_sample=1.0 must log every query)")
    replayed = replay_registry(recs).histogram(LATENCY_METRIC)
    assert replayed.counts == lat_hist.counts, (
        "replayed latency histogram != live registry histogram")
    assert (replayed.percentile(50), replayed.percentile(99)) == \
        (lat_hist.percentile(50), lat_hist.percentile(99))
    log_rec = recall_from_log(recs, lambda qid: ds.gt_ids[q_idx[qid]][:k],
                              k)
    assert abs(log_rec - rec) < 1e-12, (
        f"query-log recall {log_rec} != harness recall {rec}")
    emit("serving_log_roundtrip", records=len(recs),
         replay_p50_ms=replayed.percentile(50),
         replay_p99_ms=replayed.percentile(99), replay_recall=log_rec)

    # registry snapshot for the roofline report's serving attribution
    metrics_path = os.path.join(reports, "serving_metrics.json")
    with open(metrics_path, "w") as f:
        f.write(registry.snapshot_json())
        f.write("\n")

    write_bench_json("serving", {
        "dataset": ds.name,
        "config": {
            "n": n, "n_query": n_query, "dim": dim, "k": k, "eps": eps,
            "seed": seed, "refine": refine, "search_preset": search_preset,
            "max_batch": max_batch, "bucket_floor": bucket_floor,
            "deadline_ms": deadline_ms, "linger_ms": linger_ms,
            "partial_hops": partial_hops, "duration": duration,
            "quick": quick,
        },
        "offered_qps": offered, "sustained_qps": sustained,
        "offline_qps": offline_qps, "offline_recall": offline_recall,
        "online_vs_offline": offline_qps / max(sustained, 1e-9),
        "recall_at_10": rec, "requests": n_req,
        "partials": st.partials, "forced_flushes": st.forced_flushes,
        "flushes": st.flushes, "bucket_hist": {
            str(b): c for b, c in sorted(st.bucket_hist.items())},
        "warmup_programs": len(compile_times), "warmup_s": warmup_s,
        "engine_p50_ms": lat_hist.percentile(50),
        "engine_p99_ms": lat_hist.percentile(99),
        "untraced_qps": base_sustained,
        "trace_overhead_pct": overhead_pct,
        "query_log_records": len(recs),
        **pct,
    })

    summary = dict(offered_qps=offered, sustained_qps=sustained,
                   offline_qps=offline_qps, recall=rec,
                   p50_ms=pct["p50_ms"], p99_ms=pct["p99_ms"],
                   p999_ms=pct["p999_ms"], partials=st.partials,
                   trace_overhead_pct=overhead_pct)
    if quick:
        # CI smoke gates (generous floors — catch an engine that stopped
        # batching / retraced per request, not shared-runner jitter)
        assert rec >= recall_floor, (
            f"serving recall@{k}={rec:.4f} under the pinned floor "
            f"{recall_floor} (differential-grid float32 floor)")
        assert pct["p99_ms"] <= p99_floor_ms, (
            f"serving p99={pct['p99_ms']:.1f}ms over the {p99_floor_ms}ms "
            f"smoke floor")
    return summary


#: the --quick --burst configuration (the chaos-smoke CI job's gate).
QUICK_BURST_CONFIG = dict(n=1500, n_query=128, duration=1.25, refine=100,
                          search_preset="multi-e2-l64", max_batch=64,
                          bucket_floor=16, deadline_ms=400.0, quick=True)


def run_burst(n: int = 6000, n_query: int = 256, dim: int = 32, k: int = 10,
              eps: float = 0.1, seed: int = 0, refine: int = 300,
              search_preset: str = "multi-e2-l64", max_batch: int = 128,
              bucket_floor: int = 32, deadline_ms: float = 600.0,
              linger_ms: float = 4.0, partial_hops: int = 8,
              max_queue: int | None = None, shed_policy: str = "reject",
              burst_factor: float = 2.0, duration: float = 4.0,
              max_requests: int = 20000, quick: bool = False,
              degraded_recall_floor: float = 0.95) -> dict:
    """Overload protocol: drive the bounded engine uncontended, then at
    ``burst_factor`` x the offline closed-loop baseline, and account for
    every submission.  See the module docstring for the gates."""
    from repro.resilience import EngineCrashedError, OverloadError
    from repro.serving.async_engine import AsyncQueryEngine

    from repro.configs.deg import SEARCH_PRESETS

    ds = make_bench_dataset("bench-small", n, n_query, dim, "low", k=k,
                            seed=seed)
    params = DEG_PAPER_CONFIGS["bench-small"]
    idx = build_deg(ds.base, params, wave_size=16)
    if refine:
        idx.refine(refine, seed=seed)

    sp = SEARCH_PRESETS[search_preset]

    def offline(qs):
        res = idx.search(qs, k=k, eps=eps, beam_width=sp.beam_width,
                         expand_width=sp.expand_width,
                         visited_size=sp.visited_size,
                         hop_backend=sp.hop_backend)
        jax.block_until_ready(res.ids)
        return res

    offline(ds.queries)
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        offline(ds.queries)
        best = min(best, time.perf_counter() - t0)
    offline_qps = n_query / best

    if max_queue is None:
        max_queue = 4 * max_batch
    eng = AsyncQueryEngine(idx, k=k, eps=eps, preset=search_preset,
                           max_batch=max_batch, bucket_floor=bucket_floor,
                           deadline_ms=deadline_ms, linger_ms=linger_ms,
                           partial_hops=partial_hops, max_queue=max_queue,
                           shed_policy=shed_policy, degrade=True)
    eng.warmup()

    rng = np.random.default_rng(seed)

    def drive_typed(offered):
        """Open-loop pass where every submission is accounted to exactly
        one typed outcome: served / shed / crashed / hung."""
        n_req = max(32, int(min(offered * duration, max_requests)))
        arrivals = np.cumsum(rng.exponential(1.0 / offered, size=n_req))
        q_idx = rng.integers(0, n_query, size=n_req)
        pend = []                      # (arrival, submit_t, future)
        served, shed, crashed, hung = [], [], [], 0
        t_start = clock.now()
        for i in range(n_req):
            lag = arrivals[i] - (clock.now() - t_start)
            if lag > 0:
                time.sleep(lag)
            t_sub = clock.now()
            try:
                fut = eng.submit(ds.queries[q_idx[i]])
            except OverloadError:
                shed.append(clock.now() - t_sub)   # time to typed reject
                continue
            except EngineCrashedError:
                crashed.append(i)
                continue
            pend.append((i, t_sub, fut))
        for i, t_sub, fut in pend:
            try:
                fut.result(timeout=120.0)
            except TimeoutError:       # a hung future — the satellite bug
                hung += 1
                continue
            except OverloadError:      # drop-policy eviction from the queue
                shed.append(fut.completed_at - t_sub)
                continue
            except EngineCrashedError:
                crashed.append(i)
                continue
            served.append((i, q_idx[i], fut,
                           fut.completed_at - (t_start + arrivals[i])))
        assert len(served) + len(shed) + len(crashed) + hung == n_req, \
            "submission accounting leak — an outcome was double/un-counted"
        return n_req, served, shed, crashed, hung

    def served_recall(served, degraded_only):
        rows = [(qi, f) for _, qi, f, _ in served
                if not f.partial and (f.degraded if degraded_only else True)]
        if not rows:
            return None
        got = np.stack([f.ids for _, f in rows])
        gt = ds.gt_ids[np.array([qi for qi, _ in rows])]
        return recall_at_k(got[:, :k], gt[:, :k])

    # Phase 1: uncontended — the p99 yardstick the burst is held to.
    n0, served0, shed0, crashed0, hung0 = drive_typed(0.5 * offline_qps)
    lats0 = np.array([s[3] for s in served0]) * 1e3
    base_p99 = float(np.percentile(lats0, 99))
    emit("serving_burst_uncontended", offered_qps=0.5 * offline_qps,
         served=len(served0), shed=len(shed0), p99_ms=base_p99)

    # Phase 2: the burst — burst_factor x the offline closed-loop QPS.
    offered = burst_factor * offline_qps
    n1, served1, shed1, crashed1, hung1 = drive_typed(offered)
    peak_level = eng.health()["degrade_level"]
    eng.close()

    lats1 = np.array([s[3] for s in served1]) * 1e3 if served1 else \
        np.array([0.0])
    burst_p99 = float(np.percentile(lats1, 99))
    degraded_served = sum(1 for _, _, f, _ in served1 if f.degraded)
    rec_all = served_recall(served1, degraded_only=False)
    rec_degraded = served_recall(served1, degraded_only=True)
    max_reject_ms = max((t * 1e3 for t in shed0 + shed1), default=0.0)

    row = emit("serving_burst", offered_qps=offered,
               requests=n1, served=len(served1), shed=len(shed1),
               crashed=len(crashed1), hung=hung1,
               degraded=degraded_served, degrade_level=peak_level,
               recall=rec_all, degraded_recall=rec_degraded,
               p99_ms=burst_p99, uncontended_p99_ms=base_p99,
               max_reject_ms=max_reject_ms)

    # -- the resilience gates (every run, quick included, except the
    # wall-clock p99 ratio which is too noisy for shared runners) --------
    assert hung0 + hung1 == 0, (
        f"{hung0 + hung1} requests hung past the timeout — every submit "
        "must resolve to a result or a typed error")
    assert not crashed0 and not crashed1, (
        f"engine crashed under overload ({len(crashed0) + len(crashed1)} "
        "typed crash errors) — shedding must protect the loops")
    assert len(shed1) + degraded_served > 0, (
        f"burst at {burst_factor}x offered neither shed nor degraded — "
        "the bounded queue/ladder never engaged (overload not exercised)")
    assert max_reject_ms <= deadline_ms, (
        f"slowest typed rejection took {max_reject_ms:.1f}ms — sheds must "
        f"come back within the {deadline_ms}ms deadline, not after it")
    if rec_degraded is not None:
        assert rec_degraded >= degraded_recall_floor, (
            f"degraded-mode recall@{k}={rec_degraded:.4f} under the "
            f"{degraded_recall_floor} floor — the ladder traded too much "
            "accuracy for throughput")
    if not quick:
        assert burst_p99 <= 2.0 * base_p99, (
            f"burst p99={burst_p99:.1f}ms > 2x uncontended "
            f"p99={base_p99:.1f}ms — served requests must stay fast while "
            "the overflow sheds")

    write_bench_json("serving", {"burst": {
        "offered_qps": offered, "offline_qps": offline_qps,
        "burst_factor": burst_factor, "max_queue": max_queue,
        "shed_policy": shed_policy, "requests": n1,
        "served": len(served1), "shed": len(shed1),
        "crashed": len(crashed1), "hung": hung1,
        "degraded": degraded_served,
        "recall_at_10": rec_all, "degraded_recall_at_10": rec_degraded,
        "p99_ms": burst_p99, "uncontended_p99_ms": base_p99,
        "max_reject_ms": max_reject_ms, "quick": quick,
    }}, merge=True)

    return dict(requests=n1, served=len(served1), shed=len(shed1),
                degraded=degraded_served, hung=hung1,
                recall=rec_all, degraded_recall=rec_degraded,
                p99_ms=burst_p99, uncontended_p99_ms=base_p99)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small index, short duration, deterministic seed, "
                    "recall/p99 floors enforced (the CI smoke gate)")
    ap.add_argument("--burst", action="store_true",
                    help="run the overload protocol instead: bounded "
                    "queue + degradation ladder at 2x offered load, "
                    "typed-outcome accounting (the chaos-smoke gate)")
    ap.add_argument("--rate", type=float, default=None,
                    help="offered QPS (default: 0.8x the measured offline "
                    "closed-loop baseline)")
    ap.add_argument("--duration", type=float, default=4.0)
    a = ap.parse_args()
    if a.burst:
        cfg = dict(QUICK_BURST_CONFIG) if a.quick else \
            dict(duration=a.duration)
        print(run_burst(**cfg))
    elif a.quick:
        print(run(**dict(QUICK_CONFIG, rate=a.rate)))
    else:
        print(run(rate=a.rate, duration=a.duration))
