"""Benchmark orchestrator: ``PYTHONPATH=src python -m benchmarks.run``.

One section per paper table/figure (Sec. 6-7 + Appendix F/G), plus the
kernel structural benchmarks and the §Roofline aggregation of the dry-run
artifacts.  Emits a CSV (reports/bench.csv) and prints one line per
measurement.  ``--quick`` shrinks every dataset ~4x for smoke use.
"""
from __future__ import annotations

import argparse
import time
import traceback


SECTIONS = [
    ("fig4_qps_recall", "qps_recall"),
    ("fig5_exploration", "exploration"),
    ("table4_build_cost", "build_cost"),
    ("fig6_scalability", "scalability"),
    ("fig7_left_edge_optimization", "edge_optimization"),
    ("fig7_right_degree_sweep", "degree_sweep"),
    ("table12_graph_stats", "graph_stats"),
    ("appG_neighbor_choice", "neighbor_choice"),
    ("kernels", "kernels"),
    ("kernel_beam_merge", "beam_merge"),
    ("quantized_store", "quantization"),
    ("search_pareto", "search_pareto"),
    ("serving_open_loop", "serving_load"),
    ("roofline", "roofline_report"),
]

QUICK_OVERRIDES = {
    "qps_recall": dict(n=2000, n_query=128),
    "exploration": dict(n=2000, n_query=128),
    "build_cost": dict(n=1500, n_query=100),
    "scalability": dict(sizes=(500, 1000, 2000)),
    "edge_optimization": dict(n=1200, n_query=100,
                              batches=(0, 300, 900)),
    "degree_sweep": dict(n=1500, n_query=100, degrees=(8, 16)),
    "graph_stats": dict(n=1200),
    "neighbor_choice": dict(n=1200, n_query=100),
    "beam_merge": dict(shapes=((64, 64, 20), (64, 128, 32))),
    "quantization": dict(n=1500, n_query=128, rerank_ks=(10, 20),
                         pq_rerank_ks=(80,)),
    "search_pareto": dict(n=1500, n_query=128, expand_widths=(1, 2),
                          beam_widths=(32, 48), backends=("jnp",),
                          refine=100),
    # the serving smoke shares the CI gate config so there is exactly one
    # quick configuration (see serving_load.QUICK_CONFIG)
    "serving_load": None,       # resolved below: serving_load.QUICK_CONFIG
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module names to run")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--csv", default="reports/bench.csv")
    args = ap.parse_args()

    import importlib
    import os

    from . import common

    only = set(args.only.split(",")) if args.only else None
    failures = []
    for title, mod_name in SECTIONS:
        if only and mod_name not in only:
            continue
        print(f"\n=== {title} ({mod_name}) " + "=" * 30, flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
            kw = QUICK_OVERRIDES.get(mod_name, {}) if args.quick else {}
            if kw is None:      # module exports its own quick config
                kw = dict(mod.QUICK_CONFIG)
            summary = mod.run(**kw)
            print(f"--- {mod_name} done in {time.time()-t0:.1f}s: {summary}")
        except Exception as e:
            failures.append((mod_name, e))
            traceback.print_exc()
            # a broken section must leave a machine-readable trace in the
            # CSV, not just a traceback on a terminal nobody scrolls back
            common.emit("section_failure", section=mod_name,
                        error=f"{type(e).__name__}: {e}",
                        seconds=time.time() - t0)
    if args.csv:
        os.makedirs(os.path.dirname(args.csv) or ".", exist_ok=True)
        common.write_csv(args.csv)
        print(f"\nwrote {len(common.rows())} rows to {args.csv}")
    if failures:
        print(f"\n{len(failures)} benchmark sections FAILED: "
              f"{[m for m, _ in failures]}")
        return 1
    print("\nall benchmark sections passed")
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
