"""Multi-expansion Pareto sweep: QPS <-> recall@10 over (E, beam_width,
engine backend) on bench-small.

The multi-expansion engine trades ``while_loop`` trips for per-hop width:
expanding E beam entries per hop cuts the sequential trip count (87 -> 46
-> 28 for E = 1/2/4 at L=48 on bench-small) while the per-trip work (E*d
gathered neighbors, a (L + E*d) merge) grows.  Whether a given (E, L,
backend) point wins depends on how much of the step time is per-trip
fixed cost vs per-byte work — exactly what a Pareto frontier exposes.
Engine backends:

* ``jnp``          — composed hop, beam-broadcast dedup (E=1 = the seed
                     program bit for bit);
* ``jnp-visited``  — composed hop, O(probes) visited hash filter
                     (``core/visited.py``; remembers evicted vertices, so
                     ``evals`` drops below the broadcast engine's);
* ``pallas``       — the fused ``kernels/fused_hop`` kernel (implies the
                     visited filter; interpret-mode off-TPU, so only
                     meaningful for wall-clock on real hardware).

Per-hop counters from the engine (``BeamState.hops`` / ``evals``) are
emitted per point so the frontier reads next to the work performed.

The headline row (``pareto_best``) is the equal-or-better-recall gate for
flipping the ``configs/deg.py`` presets: for each E>1 point, the baseline
is the *strongest* E=1 configuration it matches — among E=1 points with
recall <= the point's, those with the highest recall, and of those the
fastest.  ``speedup > 1`` therefore means: at that recall level, no E=1
configuration reaches the E>1 point's throughput.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs.deg import DEG_PAPER_CONFIGS
from repro.core.build import build_deg
from repro.core.metrics import recall_at_k

from .common import emit, make_bench_dataset, timed_search, write_bench_json

_BACKENDS = {
    "jnp": dict(hop_backend="jnp", visited_size=0),
    "jnp-visited": dict(hop_backend="jnp", visited_size=2048),
    "pallas": dict(hop_backend="pallas"),
}


def run(n: int = 6000, n_query: int = 256, dim: int = 32, k: int = 10,
        eps: float = 0.1, expand_widths=(1, 2, 4),
        beam_widths=(32, 48, 56, 64), backends=("jnp", "jnp-visited"),
        seed: int = 0, refine: int = 300) -> dict:
    ds = make_bench_dataset("bench-small", n, n_query, dim, "low", k=k,
                            seed=seed)
    params = DEG_PAPER_CONFIGS["bench-small"]
    idx = build_deg(ds.base, params, wave_size=16)
    if refine:
        idx.refine(refine, seed=seed)

    pts = []
    for backend in backends:
        kw = _BACKENDS[backend]
        for L in beam_widths:
            for E in expand_widths:
                def search(q, E=E, L=L, kw=kw):
                    res = idx.search(q, k=k, eps=eps, beam_width=L,
                                     expand_width=E, **kw)
                    # jax dispatch is async: block so the wall clock
                    # measures the search, not the enqueue
                    jax.block_until_ready(res.ids)
                    return res

                res, secs = timed_search(search, ds.queries, repeats=5)
                rec = recall_at_k(np.asarray(res.ids)[:, :k],
                                  ds.gt_ids[:, :k])
                row = emit("pareto_point", dataset=ds.name, E=E,
                           beam_width=L, backend=backend, eps=eps,
                           recall=rec, qps=n_query / secs,
                           hops=float(np.mean(np.asarray(res.hops))),
                           evals=float(np.mean(np.asarray(res.evals))))
                pts.append(row)

    best = None
    e1 = [q for q in pts if q["E"] == 1 and q["backend"] == "jnp"]
    if not e1:          # sweep without the default backend (e.g. TPU-only)
        e1 = [q for q in pts if q["E"] == 1]
    for p in pts:
        if p["E"] == 1:
            continue
        rivals = [q for q in e1 if q["recall"] <= p["recall"]]
        if not rivals:
            continue
        top = max(q["recall"] for q in rivals)
        base = max((q for q in rivals if q["recall"] == top),
                   key=lambda q: q["qps"])
        speedup = p["qps"] / base["qps"]
        if best is None or speedup > best[0]:
            best = (speedup, p, base)
    summary = {}
    if best is not None:
        speedup, p, base = best
        emit("pareto_best", dataset=ds.name, E=p["E"],
             beam_width=p["beam_width"], backend=p["backend"],
             recall=p["recall"], qps=p["qps"],
             baseline_qps=base["qps"], baseline_recall=base["recall"],
             baseline_L=base["beam_width"], speedup=speedup)
        summary.update(best_E=p["E"], best_L=p["beam_width"],
                       best_backend=p["backend"], best_qps=p["qps"],
                       best_recall=p["recall"], baseline_qps=base["qps"],
                       baseline_recall=base["recall"], speedup=speedup)
    else:
        emit("pareto_best", dataset=ds.name, E=0, speedup=0.0)
        summary.update(speedup=0.0)

    write_bench_json("pareto", {
        "dataset": ds.name,
        "config": {
            "n": n, "n_query": n_query, "dim": dim, "k": k, "eps": eps,
            "seed": seed, "refine": refine,
            "expand_widths": list(expand_widths),
            "beam_widths": list(beam_widths), "backends": list(backends),
        },
        "points": [{kk: p[kk] for kk in
                    ("E", "beam_width", "backend", "recall", "qps",
                     "hops", "evals")} for p in pts],
        "best": summary,
    })
    return summary


if __name__ == "__main__":
    print(run())
