"""Paper Table 4: indexing time and index size, plus the beyond-paper
bulk-build (wave) ablation and the device-vs-host insert-wave throughput
comparison (the PR-3 acceptance metric: build QPS of the device-resident
Alg. 2/3 path against the pre-PR host path at equal recall).

At container scale we report: single-threaded build time, bytes of the
index (adjacency + weights + vectors — DEG's regularity makes this exactly
predictable: n*(d*8 + dim*4) bytes), recall after build, and the
wave-size trade-off quantified (DESIGN.md §2: bounded staleness vs. device
dispatches).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.build import DEGIndex, DEGParams, build_deg
from repro.core.baselines.knng import build_knng
from repro.core.baselines.nsw import NSWIndex
from repro.core.invariants import check_invariants
from repro.core.metrics import recall_at_k

from .common import emit, make_bench_dataset


def insert_wave_throughput(ds, *, k: int, degree: int, wave: int = 128,
                           seeds=(0, 1, 2)) -> dict:
    """Timed insert waves, device-resident vs host Alg. 2/3 selection.

    Both paths bootstrap an untimed n/4 prefix — jit programs are keyed on
    the full-capacity buffer shapes, so this bootstrap (same index, same
    shapes) is what absorbs the compiles — then time only whole waves so
    both runs execute identical program shapes.  Two throughput numbers
    per path:

    * ``build_qps``  — end-to-end inserted vertices/second (candidate
      search + extension);
    * ``extend_qps`` — the vertex-extension stage alone (Alg. 2/3
      selection + edge surgery, from ``DEGIndex.build_stats``).  The
      candidate search was already a batched device program before this
      PR, so the extension stage is where the device-resident rework
      shows up; the PR acceptance gate (>= 3x) applies to it.

    Recall@k is measured at a saturated operating point (beam_width 3*k)
    and averaged over per-entry-RNG build repetitions: recall at default
    effort swings several points with construction order alone (graph
    plateau noise), far above the 1-percent parity band of interest."""
    n = ds.base.shape[0]
    out = {}
    for path, dev in (("host", False), ("device", True)):
        p = DEGParams(degree=degree, k_ext=2 * degree, eps_ext=0.2,
                      device_extend=dev)
        qps, ext_qps, recs = [], [], []
        for s in seeds:
            idx = DEGIndex(ds.dim, p, capacity=n)
            idx._rng = np.random.default_rng(s)        # entry-vertex RNG
            n0 = n // 4
            idx.add(ds.base[:n0], wave_size=wave)      # untimed bootstrap
            n1 = n0 + (n - n0) // wave * wave          # whole waves only
            idx.build_stats = {"search_s": 0.0, "extend_s": 0.0,
                               "vertices": 0}
            t0 = time.time()
            idx.add(ds.base[n0:n1], wave_size=wave)
            dt = time.time() - t0
            st = dict(idx.build_stats)
            idx.add(ds.base[n1:], wave_size=wave)      # untimed tail
            ok, msgs = check_invariants(idx.builder)
            assert ok, msgs
            res = idx.search(ds.queries, k=k, eps=0.1, beam_width=3 * k)
            recs.append(recall_at_k(np.asarray(res.ids), ds.gt_ids))
            qps.append((n1 - n0) / dt)
            ext_qps.append(st["vertices"] / max(st["extend_s"], 1e-9))
        rec, q, eq = (float(np.mean(x)) for x in (recs, qps, ext_qps))
        emit("build_insert_wave", path=path, wave=wave, reps=len(qps),
             build_qps=q, extend_qps=eq, recall=rec)
        out[path] = (q, eq, rec)
    summary = {
        "build_speedup": out["device"][0] / out["host"][0],
        "extend_speedup": out["device"][1] / out["host"][1],
        "recall_delta": out["device"][2] - out["host"][2],
        "device_qps": out["device"][0], "host_qps": out["host"][0],
    }
    emit("build_insert_wave_summary", wave=wave, **summary)
    return summary


def run(n: int = 4000, n_query: int = 200, dim: int = 32, k: int = 10,
        degree: int = 16, seed: int = 0) -> dict:
    ds = make_bench_dataset("synth-lowlid", n, n_query, dim, "low", k=k,
                            seed=seed)
    out = {}
    out["insert_wave"] = insert_wave_throughput(ds, k=k, degree=degree)

    def deg_size(idx):
        return idx.n * (idx.builder.degree * 8 + ds.dim * 4)

    for wave in (1, 16, 128):
        t0 = time.time()
        idx = build_deg(ds.base, DEGParams(degree=degree, k_ext=2 * degree,
                                           eps_ext=0.2), wave_size=wave)
        build_s = time.time() - t0
        ok, msgs = check_invariants(idx.builder)
        assert ok, msgs
        res = idx.search(ds.queries, k=k, eps=0.1)
        rec = recall_at_k(np.asarray(res.ids), ds.gt_ids)
        emit("table4_deg", wave=wave, build_s=build_s,
             index_bytes=deg_size(idx), recall=rec,
             avg_nbr_dist=idx.builder.average_neighbor_distance())
        out[f"deg_wave{wave}"] = (build_s, rec)

    t0 = time.time()
    kg = build_knng(ds.base, K=degree, iterations=6, seed=seed)
    emit("table4_kgraph", wave=0, build_s=time.time() - t0,
         index_bytes=int(np.asarray(kg.adjacency).nbytes
                         + np.asarray(kg.weights).nbytes + ds.base.nbytes),
         recall=float("nan"))

    t0 = time.time()
    nsw = NSWIndex(ds.dim, f=degree // 2, max_degree=3 * degree, capacity=n)
    nsw.add(ds.base)
    res = nsw.search(ds.queries, k=k, eps=0.1)
    emit("table4_nsw", wave=0, build_s=time.time() - t0,
         index_bytes=int(nsw.adjacency.nbytes + nsw.weights.nbytes
                         + ds.base.nbytes),
         recall=recall_at_k(np.asarray(res.ids), ds.gt_ids))
    return out


if __name__ == "__main__":
    print(run())
