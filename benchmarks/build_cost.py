"""Paper Table 4: indexing time and index size, plus the beyond-paper
bulk-build (wave) ablation.

At container scale we report: single-threaded build time, bytes of the
index (adjacency + weights + vectors — DEG's regularity makes this exactly
predictable: n*(d*8 + dim*4) bytes), recall after build, and the
wave-size trade-off quantified (DESIGN.md §2: bounded staleness vs. device
dispatches).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.build import DEGParams, build_deg
from repro.core.baselines.knng import build_knng
from repro.core.baselines.nsw import NSWIndex
from repro.core.invariants import check_invariants
from repro.core.metrics import recall_at_k

from .common import emit, make_bench_dataset


def run(n: int = 4000, n_query: int = 200, dim: int = 32, k: int = 10,
        degree: int = 16, seed: int = 0) -> dict:
    ds = make_bench_dataset("synth-lowlid", n, n_query, dim, "low", k=k,
                            seed=seed)
    out = {}

    def deg_size(idx):
        return idx.n * (idx.builder.degree * 8 + ds.dim * 4)

    for wave in (1, 16, 128):
        t0 = time.time()
        idx = build_deg(ds.base, DEGParams(degree=degree, k_ext=2 * degree,
                                           eps_ext=0.2), wave_size=wave)
        build_s = time.time() - t0
        ok, msgs = check_invariants(idx.builder)
        assert ok, msgs
        res = idx.search(ds.queries, k=k, eps=0.1)
        rec = recall_at_k(np.asarray(res.ids), ds.gt_ids)
        emit("table4_deg", wave=wave, build_s=build_s,
             index_bytes=deg_size(idx), recall=rec,
             avg_nbr_dist=idx.builder.average_neighbor_distance())
        out[f"deg_wave{wave}"] = (build_s, rec)

    t0 = time.time()
    kg = build_knng(ds.base, K=degree, iterations=6, seed=seed)
    emit("table4_kgraph", wave=0, build_s=time.time() - t0,
         index_bytes=int(np.asarray(kg.adjacency).nbytes
                         + np.asarray(kg.weights).nbytes + ds.base.nbytes),
         recall=float("nan"))

    t0 = time.time()
    nsw = NSWIndex(ds.dim, f=degree // 2, max_degree=3 * degree, capacity=n)
    nsw.add(ds.base)
    res = nsw.search(ds.queries, k=k, eps=0.1)
    emit("table4_nsw", wave=0, build_s=time.time() - t0,
         index_bytes=int(nsw.adjacency.nbytes + nsw.weights.nbytes
                         + ds.base.nbytes),
         recall=recall_at_k(np.asarray(res.ids), ds.gt_ids))
    return out


if __name__ == "__main__":
    print(run())
