"""Shared benchmark machinery.

The paper's evaluation protocol (Sec. 6) at container scale: synthetic
datasets with controlled LID (low ~ SIFT-like, high ~ GloVe-like), exact
ground truth, and QPS <-> recall frontiers swept over the search-time
``eps`` / ``beam_width`` knobs with a fixed index — exactly how Fig. 4/5
curves are produced.

All results are emitted as CSV rows through :func:`emit` so
``benchmarks.run`` can tee a single machine-readable report.
"""
from __future__ import annotations

import dataclasses
import os
import sys
import time
from typing import Callable, Iterable, Optional

import numpy as np

from repro.core.distances import exact_knn_batched
from repro.core.metrics import recall_at_k

_ROWS: list[dict] = []


def emit(bench: str, **fields) -> dict:
    row = {"bench": bench, **fields}
    _ROWS.append(row)
    print(f"[{bench}] " + " ".join(f"{k}={_fmt(v)}" for k, v in fields.items()),
          flush=True)
    return row


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.5g}"
    return v


def rows() -> list[dict]:
    return _ROWS


def write_csv(path: str) -> None:
    import csv

    keys: list[str] = []
    for r in _ROWS:
        for k in r:
            if k not in keys:
                keys.append(k)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        w.writerows(_ROWS)


@dataclasses.dataclass
class Dataset:
    name: str
    base: np.ndarray
    queries: np.ndarray
    gt_ids: np.ndarray        # exact top-k ids
    lid: str                  # 'low' | 'high'

    @property
    def dim(self) -> int:
        return self.base.shape[1]


def make_bench_dataset(name: str, n: int, n_query: int, dim: int,
                       lid: str = "low", k: int = 10,
                       seed: int = 0) -> Dataset:
    from repro.data.synthetic import make_dataset

    kind = "gaussian" if lid == "low" else "manifold"
    base, queries = make_dataset(kind, n, n_query, dim, seed=seed)
    _, gt = exact_knn_batched(queries, base, k)
    return Dataset(name, base, queries, gt, lid)


def timed_search(search_fn: Callable, queries: np.ndarray,
                 repeats: int = 1) -> tuple:
    """Returns (result of last call, best wall seconds)."""
    best = float("inf")
    out = None
    for _ in range(repeats + 1):           # first call = compile warmup
        t0 = time.time()
        out = search_fn(queries)
        dt = time.time() - t0
        best = min(best, dt)
    return out, best


def frontier(name: str, dataset: Dataset, search_fn: Callable, *,
             k: int = 10,
             eps_grid: Iterable[float] = (0.0, 0.02, 0.05, 0.1, 0.2, 0.4),
             extra: Optional[dict] = None) -> list[dict]:
    """Sweep search-time eps -> (recall, qps) points.

    search_fn(queries, eps) -> SearchResult-like with .ids / .hops / .evals
    """
    pts = []
    nq = dataset.queries.shape[0]
    for eps in eps_grid:
        (res), secs = timed_search(lambda q: search_fn(q, eps),
                                   dataset.queries)
        rec = recall_at_k(np.asarray(res.ids)[:, :k], dataset.gt_ids[:, :k])
        row = emit(name, dataset=dataset.name, eps=eps, recall=rec,
                   qps=nq / secs,
                   hops=float(np.mean(np.asarray(res.hops))),
                   evals=float(np.mean(np.asarray(res.evals))),
                   **(extra or {}))
        pts.append(row)
    return pts


def auc_above(pts: list[dict], recall_floor: float = 0.8) -> float:
    """Scalar frontier summary: mean QPS of points with recall >= floor."""
    good = [p["qps"] for p in pts if p["recall"] >= recall_floor]
    return float(np.mean(good)) if good else 0.0


def _git_commit() -> str:
    import subprocess

    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def write_bench_json(name: str, payload: dict, merge: bool = False) -> str:
    """The standing perf trajectory: append to the history list in
    ``BENCH_<name>.json`` at the repo root, so headline numbers accrue
    across PRs instead of each commit overwriting the last.

    Schema: ``{"bench": name, "history": [entry, ...]}`` where each entry
    is ``{commit, written_at, **payload}`` (config + measured figures:
    p50/p99, QPS, recall@10, ...), oldest first.  A re-run on the same
    commit replaces that commit's entry in place (fresher numbers, no
    same-commit duplicates); ``merge=True`` instead updates that entry's
    keys in place, so a sibling harness (e.g. serving_load's burst mode)
    can add its section to the commit entry without clobbering the main
    run's figures.  Pre-history single-document files (the old overwrite
    format) are migrated as the first entry.  Returns the path
    written."""
    import json

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, f"BENCH_{name}.json")
    history: list[dict] = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                old = json.load(f)
            if isinstance(old.get("history"), list):
                history = old["history"]
            else:                      # old single-doc format -> entry 0
                old.pop("bench", None)
                history = [old]
        except (ValueError, OSError):
            history = []               # corrupt file: restart the history
    entry = {"commit": _git_commit(),
             "written_at": time.strftime("%Y-%m-%dT%H:%M:%S%z"), **payload}
    replaced = False
    for i, e in enumerate(history):
        if e.get("commit") == entry["commit"]:
            history[i] = {**e, **entry} if merge else entry
            replaced = True
            break
    if not replaced:
        history.append(entry)
    with open(path, "w") as f:
        json.dump({"bench": name, "history": history}, f, indent=2,
                  sort_keys=True, default=float)
        f.write("\n")
    print(f"[bench-json] wrote {path} "
          f"({len(history)} history entr{'y' if len(history) == 1 else 'ies'})")
    return path
