"""Paper Fig. 7-right / Sec. 7.3: effect of the edge count (degree d).

On the high-LID dataset, increasing d beyond 2-3 dozen keeps improving
search speed at matched recall up to a point, then declines — DEG is the
only graph in the paper whose frontier keeps moving with more edges.
"""
from __future__ import annotations

import numpy as np

from repro.core.build import DEGParams, build_deg
from repro.core.metrics import recall_at_k

from .common import emit, make_bench_dataset


def run(n: int = 4000, n_query: int = 200, dim: int = 32, k: int = 10,
        degrees=(8, 16, 24, 32), seed: int = 0) -> dict:
    ds = make_bench_dataset("synth-highlid", n, n_query, dim, "high", k=k,
                            seed=seed)
    out = {}
    for d in degrees:
        idx = build_deg(ds.base, DEGParams(degree=d, k_ext=2 * d,
                                           eps_ext=0.2), wave_size=16)
        best = None
        for eps in (0.0, 0.05, 0.1, 0.2, 0.4):
            import time

            idx.search(ds.queries[:8], k=k, eps=eps)      # warmup/compile
            t0 = time.time()
            res = idx.search(ds.queries, k=k, eps=eps)
            qps = n_query / (time.time() - t0)
            rec = recall_at_k(np.asarray(res.ids), ds.gt_ids)
            emit("fig7_right", degree=d, eps=eps, recall=rec, qps=qps,
                 evals=float(np.mean(np.asarray(res.evals))))
            if rec >= 0.9 and (best is None or qps > best):
                best = qps
        out[d] = best
    return out


if __name__ == "__main__":
    print(run())
