"""Distance functions for DEG.

The paper (Sec. 2.1) defines DEG over a generic metric ``delta``. Everything in
``repro.core`` goes through this registry so the graph works for any of the
supported metrics.  Note that edge *weights* store the actual metric value
(not e.g. squared L2): the edge-optimization gains (Sec. 5.3) are *sums* of
distances, which are only meaningful in the true metric.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array

_METRICS: dict[str, "Metric"] = {}


class Metric:
    """A distance with pointwise, one-to-many and many-to-many forms."""

    def __init__(self, name: str, pair: Callable, needs_norms: bool):
        self.name = name
        self._pair = pair
        self.needs_norms = needs_norms
        _METRICS[name] = self

    def pair(self, x: Array, y: Array) -> Array:
        """delta(x, y) for x: (..., m), y: (..., m) broadcast together."""
        return self._pair(x, y)

    def one_to_many(self, q: Array, xs: Array) -> Array:
        """delta(q, xs[i]): q (m,), xs (n, m) -> (n,)."""
        return self._pair(q[None, :], xs)

    def cross(self, qs: Array, xs: Array) -> Array:
        """Full distance matrix: qs (b, m), xs (n, m) -> (b, n).

        Written MXU-style (one big matmul + rank-1 corrections) because this is
        the compute hot spot of every ANNS system; the Pallas kernel
        ``repro.kernels.l2_topk`` implements the tiled fused version.
        """
        if self.name == "l2":
            qn = jnp.sum(qs * qs, axis=-1, keepdims=True)  # (b, 1)
            xn = jnp.sum(xs * xs, axis=-1)                 # (n,)
            sq = qn - 2.0 * (qs @ xs.T) + xn[None, :]
            return jnp.sqrt(jnp.maximum(sq, 0.0))
        if self.name == "sqeuclidean":
            qn = jnp.sum(qs * qs, axis=-1, keepdims=True)
            xn = jnp.sum(xs * xs, axis=-1)
            return jnp.maximum(qn - 2.0 * (qs @ xs.T) + xn[None, :], 0.0)
        if self.name == "ip":
            return -(qs @ xs.T)
        if self.name == "cos":
            qs_n = qs / jnp.maximum(jnp.linalg.norm(qs, axis=-1, keepdims=True), 1e-12)
            xs_n = xs / jnp.maximum(jnp.linalg.norm(xs, axis=-1, keepdims=True), 1e-12)
            return 1.0 - qs_n @ xs_n.T
        raise NotImplementedError(self.name)


def _l2(x, y):
    d = x - y
    return jnp.sqrt(jnp.maximum(jnp.sum(d * d, axis=-1), 0.0))


def _sql2(x, y):
    d = x - y
    return jnp.maximum(jnp.sum(d * d, axis=-1), 0.0)


def _ip(x, y):
    return -jnp.sum(x * y, axis=-1)


def _cos(x, y):
    xn = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
    yn = y / jnp.maximum(jnp.linalg.norm(y, axis=-1, keepdims=True), 1e-12)
    return 1.0 - jnp.sum(xn * yn, axis=-1)


L2 = Metric("l2", _l2, needs_norms=True)
SQEUCLIDEAN = Metric("sqeuclidean", _sql2, needs_norms=True)
IP = Metric("ip", _ip, needs_norms=False)
COS = Metric("cos", _cos, needs_norms=False)


def get_metric(name: str) -> Metric:
    try:
        return _METRICS[name]
    except KeyError:
        raise ValueError(f"unknown metric {name!r}; have {sorted(_METRICS)}") from None


@functools.partial(jax.jit, static_argnames=("metric", "k"))
def exact_knn(queries: Array, base: Array, k: int, metric: str = "l2"):
    """Exact k-NN (ground truth / serial-scan baseline). Returns (dists, ids)."""
    m = get_metric(metric)
    dmat = m.cross(queries, base)
    neg_d, ids = jax.lax.top_k(-dmat, k)
    return -neg_d, ids


def exact_knn_batched(queries, base, k, metric="l2", tile: int = 8192):
    """Tiled exact k-NN for large bases: bounds the (b, n) matrix to (b, tile)."""
    import numpy as np

    n = base.shape[0]
    best_d = None
    best_i = None
    for lo in range(0, n, tile):
        hi = min(lo + tile, n)
        d, i = exact_knn(queries, base[lo:hi], min(k, hi - lo), metric)
        i = i + lo
        if best_d is None:
            best_d, best_i = np.asarray(d), np.asarray(i)
        else:
            cat_d = np.concatenate([best_d, np.asarray(d)], axis=1)
            cat_i = np.concatenate([best_i, np.asarray(i)], axis=1)
            order = np.argsort(cat_d, axis=1, kind="stable")[:, :k]
            best_d = np.take_along_axis(cat_d, order, axis=1)
            best_i = np.take_along_axis(cat_i, order, axis=1)
    return best_d, best_i
