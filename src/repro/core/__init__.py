"""Core DEG library: the paper's contribution as composable JAX modules."""
from .beam import BeamState, beam_search, default_visited_size
from .build import DEGIndex, DEGParams, build_deg
from .distances import exact_knn, exact_knn_batched, get_metric
from .graph import DEGraph, GraphBuilder, INVALID, complete_graph
from .metrics import average_neighbor_distance, graph_quality, recall_at_k
from .optimize import dynamic_edge_optimization, optimize_edge, refine_sweep
from .search import (SearchResult, exact_rerank, medoid_seed, range_search,
                     search_graph)

__all__ = [
    "BeamState", "beam_search", "default_visited_size",
    "DEGIndex", "DEGParams", "build_deg",
    "exact_knn", "exact_knn_batched", "get_metric",
    "DEGraph", "GraphBuilder", "INVALID", "complete_graph",
    "average_neighbor_distance", "graph_quality", "recall_at_k",
    "dynamic_edge_optimization", "optimize_edge", "refine_sweep",
    "SearchResult", "exact_rerank", "medoid_seed", "range_search",
    "search_graph",
]
