"""Quality metrics from the paper: recall@k (Eq. 2), graph quality GQ (Eq. 3),
average neighbor distance (Eq. 4)."""
from __future__ import annotations

import numpy as np

from .distances import exact_knn_batched
from .graph import DEGraph, GraphBuilder, INVALID


def recall_at_k(found_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Eq. (2): mean fraction of true k-NN retrieved. Shapes (Q, k)."""
    found_ids = np.asarray(found_ids)
    true_ids = np.asarray(true_ids)
    q, k = true_ids.shape
    hits = 0
    for i in range(q):
        t = set(true_ids[i].tolist())
        t.discard(INVALID)
        f = set(int(x) for x in found_ids[i].tolist() if x != INVALID)
        hits += len(t & f)
    return hits / (q * k)


def graph_quality(builder: GraphBuilder, vectors: np.ndarray,
                  metric: str = "l2") -> float:
    """Eq. (3): neighborhood vs. true k-NN overlap, k = per-vertex degree.

    The paper notes GQ is *insensitive* to small beneficial changes — we
    reproduce that observation in tests (test_metrics.py)."""
    n = builder.n
    d = builder.degree
    # true (d+1)-NN includes the vertex itself at distance 0
    _, knn = exact_knn_batched(vectors[:n], vectors[:n], d + 1, metric)
    total = 0.0
    for v in range(n):
        nbrs = set(builder.neighbors(v).tolist())
        true = [int(x) for x in knn[v] if int(x) != v][: len(nbrs)]
        if not nbrs:
            continue
        total += len(nbrs & set(true)) / len(nbrs)
    return total / max(n, 1)


def average_neighbor_distance(graph_or_builder) -> float:
    """Eq. (4) — the paper's proposed edge-quality metric."""
    if isinstance(graph_or_builder, DEGraph):
        b = graph_or_builder.to_builder()
    else:
        b = graph_or_builder
    return b.average_neighbor_distance()


def hop_histogram(hops: np.ndarray, bins: int = 16):
    hops = np.asarray(hops)
    return np.histogram(hops, bins=bins)
