"""Batched RangeSearch (paper Algorithm 1) — thin jitted driver over the
beam engine.

The actual search loop lives in :mod:`repro.core.beam` (see ARCHITECTURE.md,
"Multi-expansion beam layering"): a lock-step beam over ``B`` query lanes
inside one ``jax.lax.while_loop``, where each hop gathers the ``E * d``
neighbors of the ``expand_width`` closest unchecked beam entries, dedups
them (beam broadcast, or the O(probes) visited filter of
``core/visited.py``), scores them (``gather_dist`` Pallas kernel on TPU —
or the whole hop fused into ``kernels/fused_hop``), and folds them into the
distance-sorted beam with the fused ``beam_merge`` bitonic partial-merge
kernel (bit-identical to, and cheaper than, the seed's full ``(B, L+d)``
argsort per hop).

This module keeps the public query API: :func:`range_search` resolves the
beam-width/hop-budget defaults and jits the engine program;
:func:`search_graph` adds the shared-medoid-seed convenience.  All other
layers (build, optimize, delete, distributed, serving) drive the same
engine — either through :func:`range_search` or directly via
``beam.beam_search`` inside their own jitted programs.

Exploration queries (paper Sec. 6.7) are supported natively: seeds can be
graph vertices and an ``exclude`` list removes already-seen vertices from
the *result list* (and from the radius ``r``) while still allowing
navigation through them — exactly the browsing protocol the paper
describes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import beam
from .beam import neighbor_distances_jnp as _neighbor_distances_jnp  # noqa: F401  (re-export)
from .distances import get_metric
from .graph import DEGraph, INVALID

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SearchResult:
    ids: Array      # (B, k) int32, INVALID-padded
    dists: Array    # (B, k) float32, inf-padded
    hops: Array     # (B,) int32 — number of expanded vertices
    evals: Array    # (B,) int32 — number of distance evaluations (|C| analogue)
    # (B,) float32 visited-table occupancy in [0, 1], or None when the
    # search ran the beam-broadcast dedup (no visited set).  Saturation
    # near 1.0 means dropped inserts — duplicate expansions and wasted
    # evals — which the query log records per query (obs/querylog.py).
    # One cheap reduction over state already on device: free telemetry.
    visited_frac: Optional[Array] = None


def exact_rerank(exact_vectors: Array, queries: Array, cand_ids: Array,
                 *, k: int, metric: str = "l2") -> tuple[Array, Array]:
    """Stage two of the quantized search: exactly re-score INVALID-padded
    candidate ids against the float store and return the exact top-k.

    One gather of ``rerank_k`` rows per query — the only touch of the exact
    store on the whole query path (the beam itself traversed compressed
    rows).  Stable sort keeps ties deterministic.
    """
    metric_obj = get_metric(metric)
    safe = jnp.where(cand_ids == INVALID, 0, cand_ids)
    d = metric_obj.pair(queries[:, None, :],
                        exact_vectors[safe].astype(jnp.float32))
    d = jnp.where(cand_ids == INVALID, jnp.inf, d)
    order = jnp.argsort(d, axis=1, stable=True)[:, :k]
    out_ids = jnp.take_along_axis(cand_ids, order, axis=1)
    out_d = jnp.take_along_axis(d, order, axis=1)
    out_ids = jnp.where(jnp.isinf(out_d), INVALID, out_ids)
    return out_ids, out_d


@functools.partial(
    jax.jit,
    static_argnames=("k", "beam_width", "max_hops", "metric", "backend",
                     "merge_backend", "rerank_k", "expand_width",
                     "visited_size", "hop_backend"),
)
def range_search(
    graph: DEGraph,
    vectors: Array,
    queries: Array,
    seed_ids: Array,
    *,
    k: int,
    eps: float = 0.1,
    beam_width: Optional[int] = None,
    max_hops: int = 0,
    metric: str = "l2",
    exclude: Optional[Array] = None,
    backend: str = "jnp",
    merge_backend: str = "jnp",
    rerank_k: int = 0,
    exact_vectors: Optional[Array] = None,
    expand_width: int = 1,
    visited_size: Optional[int] = None,
    hop_backend: str = "jnp",
    hop_budget: Optional[Array] = None,
) -> SearchResult:
    """Approximate k-NN for a batch of queries.

    Args:
      graph: the DEG to search.
      vectors: (capacity, m) float — the indexed points (rows >= graph.n
        unused) — or a :class:`repro.quant.VectorStore` view of them (the
        beam then traverses compressed distances).
      queries: (B, m) float.
      seed_ids: (B, S) int32 seed vertices, INVALID-padded.
      k: result count.
      eps: range-search slack factor (Alg. 1).
      beam_width: beam length L (defaults to a heuristic >= k).
      max_hops: safety bound on loop iterations (0 -> auto).
      exclude: optional (B, X) int32 vertices excluded from results (still
        traversable) — the exploration protocol.
      backend: distance backend ("jnp" | "pallas" fused gather_dist /
        gather_dist_q per the store codec).
      merge_backend: per-hop beam merge ("jnp" bitonic | "pallas" kernel |
        "argsort" seed semantics) — all bit-identical.
      rerank_k: two-stage search — take this many beam candidates and
        re-score them exactly against ``exact_vectors`` (requires
        ``rerank_k >= k``).  0 disables the second stage: results carry the
        store's (possibly compressed) distances.
      exact_vectors: (capacity, m) float32 exact rows for the rerank stage.
      expand_width: E — beam entries expanded per lane per hop
        (multi-expansion; 1 = the seed engine, bit for bit).
      visited_size: per-lane visited hash-set slots (power of two).  None
        auto-sizes: ``beam.default_visited_size`` when the fused hop
        kernel is requested (which needs the filter), else 0 — the
        beam-broadcast dedup, which benchmarks/search_pareto.py measures
        faster than the hash ops for the jnp hop on CPU.  Pass an explicit
        size to force the filter (e.g. the "visited" sweep variant).
      hop_backend: "jnp" composed hop | "pallas" fused hop kernel
        (``kernels/fused_hop``: adjacency gather -> visited filter ->
        vector gather -> distance -> compaction in one kernel).
      hop_budget: optional (B,) int32 per-lane expansion caps — the
        serving layer's deadline early-extract: a budget-exhausted lane
        stops hopping and returns its best-so-far beam (a traced operand,
        so every budget value shares one compiled program; ``None`` keeps
        the unbudgeted golden program).
    """
    n_ex = exclude.shape[1] if exclude is not None else 0
    L = (beam_width if beam_width is not None
         else beam.default_beam_width(k, graph.degree, seed_ids.shape[1],
                                      n_ex))
    L = max(L, k, seed_ids.shape[1])
    if exclude is not None:
        L = max(L, k + n_ex)
    if rerank_k:
        if rerank_k < k:
            raise ValueError(f"rerank_k={rerank_k} must be >= k={k}")
        if exact_vectors is None:
            raise ValueError("rerank_k > 0 requires exact_vectors")
        L = max(L, rerank_k + n_ex)   # room for rerank_k non-excluded hits
    if max_hops <= 0:
        max_hops = beam.default_max_hops(L)
    if visited_size is None:
        visited_size = (beam.default_visited_size(L, graph.degree)
                        if hop_backend == "pallas" else 0)
    # dropped visited inserts can (rarely) duplicate a beam entry; the
    # dedup in extract is the result-level guarantee
    dedup = visited_size > 0

    state = beam.beam_search(
        graph, vectors, queries, seed_ids, k=k, eps=eps, beam_width=L,
        max_hops=max_hops, metric=metric, exclude=exclude, backend=backend,
        merge_backend=merge_backend, expand_width=expand_width,
        visited_size=visited_size, hop_backend=hop_backend,
        hop_budget=hop_budget)
    if rerank_k:
        cand_ids, _ = beam.extract(state, rerank_k, dedup=dedup)
        out_ids, out_d = exact_rerank(exact_vectors, queries, cand_ids,
                                      k=k, metric=metric)
        evals = state.evals + (cand_ids != INVALID).sum(axis=1,
                                                        dtype=jnp.int32)
    else:
        out_ids, out_d = beam.extract(state, k, dedup=dedup)
        evals = state.evals
    visited_frac = None
    if state.visited is not None:
        visited_frac = jnp.mean((state.visited != INVALID)
                                .astype(jnp.float32), axis=1)
    return SearchResult(ids=out_ids, dists=out_d, hops=state.hops,
                        evals=evals, visited_frac=visited_frac)


def medoid_seed(vectors: Array, n: int) -> int:
    """Approximate median vertex (paper Sec. 5.4 uses it as the search seed).

    One device reduction per call — ``DEGIndex`` caches the result and
    invalidates it on vector mutation (add/remove), so hot query paths
    do not pay this repeatedly.
    """
    mean = jnp.mean(vectors[:n], axis=0, keepdims=True)
    d = jnp.linalg.norm(vectors[:n] - mean, axis=1)
    return int(jnp.argmin(d))


def search_graph(graph: DEGraph, vectors: Array, queries: Array, *,
                 k: int, eps: float = 0.1, seed: Optional[int] = None,
                 beam_width: Optional[int] = None, max_hops: int = 0,
                 metric: str = "l2", exclude: Optional[Array] = None,
                 backend: str = "jnp", merge_backend: str = "jnp",
                 rerank_k: int = 0, exact_vectors: Optional[Array] = None,
                 expand_width: int = 1, visited_size: Optional[int] = None,
                 hop_backend: str = "jnp") -> SearchResult:
    """Convenience wrapper: single shared seed (median vertex by default),
    otherwise the full :func:`range_search` signature passed through
    verbatim.

    ``vectors`` doubles as the seed-medoid source, so when a
    :class:`~repro.quant.VectorStore` is searched with ``rerank_k``, pass
    the float rows via ``exact_vectors`` and an explicit ``seed``."""
    if seed is None:
        seed = medoid_seed(vectors, int(graph.n))
    B = queries.shape[0]
    seeds = jnp.full((B, 1), seed, dtype=jnp.int32)
    return range_search(graph, vectors, queries, seeds, k=k, eps=eps,
                        beam_width=beam_width, max_hops=max_hops,
                        metric=metric, exclude=exclude, backend=backend,
                        merge_backend=merge_backend, rerank_k=rerank_k,
                        exact_vectors=exact_vectors,
                        expand_width=expand_width,
                        visited_size=visited_size, hop_backend=hop_backend)
