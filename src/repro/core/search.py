"""Batched RangeSearch (paper Algorithm 1) as a fixed-shape TPU program.

The paper's single-query best-first loop becomes a *lock-step beam search*
over ``B`` query lanes inside one ``jax.lax.while_loop``:

* the candidate set ``S`` and result list ``R`` of Alg. 1 are merged into one
  distance-sorted *beam* of static width ``L >= k`` per lane (the classic
  ef-style formulation, exact w.r.t. Alg. 1 semantics: ``r`` is the k-th best
  distance seen, expansion requires ``delta <= r * (1 + eps)``);
* one hop = gather the ``d`` neighbors of the closest unchecked beam entry
  (a dense ``(B, d)`` lookup thanks to DEG's even regularity), compute their
  distances (``(B, d, m)`` gather + reduction — the `gather_dist` Pallas
  kernel implements the fused HBM->VMEM version), and merge into the beam
  with an argsort;
* a lane deactivates exactly when Alg. 1 line 7 would return: the closest
  unchecked candidate is farther than ``r * (1 + eps)``.

Exploration queries (paper Sec. 6.7) are supported natively: seeds can be
graph vertices and an ``exclude`` list removes already-seen vertices from the
*result list* (and from the radius ``r``) while still allowing navigation
through them — exactly the browsing protocol the paper describes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .distances import get_metric
from .graph import DEGraph, INVALID

Array = jax.Array
_INF = jnp.inf


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SearchResult:
    ids: Array      # (B, k) int32, INVALID-padded
    dists: Array    # (B, k) float32, inf-padded
    hops: Array     # (B,) int32 — number of expanded vertices
    evals: Array    # (B,) int32 — number of distance evaluations (|C| analogue)


def _neighbor_distances_jnp(vectors, queries, nbr_ids, metric_name):
    metric = get_metric(metric_name)
    nvecs = vectors[nbr_ids]                       # (B, d, m)
    return metric.pair(queries[:, None, :], nvecs)  # (B, d)


def _neighbor_distances(vectors, queries, nbr_ids, metric_name, backend):
    if backend == "pallas" and metric_name == "l2":
        from repro.kernels.gather_dist import ops as gd_ops

        return gd_ops.gather_dist(vectors, nbr_ids, queries)
    return _neighbor_distances_jnp(vectors, queries, nbr_ids, metric_name)


@functools.partial(
    jax.jit,
    static_argnames=("k", "beam_width", "max_hops", "metric", "backend"),
)
def range_search(
    graph: DEGraph,
    vectors: Array,
    queries: Array,
    seed_ids: Array,
    *,
    k: int,
    eps: float = 0.1,
    beam_width: Optional[int] = None,
    max_hops: int = 0,
    metric: str = "l2",
    exclude: Optional[Array] = None,
    backend: str = "jnp",
) -> SearchResult:
    """Approximate k-NN for a batch of queries.

    Args:
      graph: the DEG to search.
      vectors: (capacity, m) float — the indexed points (rows >= graph.n unused).
      queries: (B, m) float.
      seed_ids: (B, S) int32 seed vertices, INVALID-padded.
      k: result count.
      eps: range-search slack factor (Alg. 1).
      beam_width: beam length L (defaults to a heuristic >= k).
      max_hops: safety bound on loop iterations (0 -> auto).
      exclude: optional (B, X) int32 vertices excluded from results (still
        traversable) — the exploration protocol.
    """
    B, m = queries.shape
    d = graph.degree
    L = beam_width if beam_width is not None else max(k + d, 2 * k)
    L = max(L, k, seed_ids.shape[1])
    if exclude is not None:
        L = max(L, k + exclude.shape[1])
    if max_hops <= 0:
        max_hops = 4 * L + 64
    metric_obj = get_metric(metric)
    eps1 = jnp.float32(1.0 + eps)

    n_valid = graph.n
    adjacency = graph.adjacency

    if exclude is None:
        exclude = jnp.full((B, 1), INVALID, dtype=jnp.int32)

    # ---- initial beam from seeds ----------------------------------------
    seed_valid = (seed_ids != INVALID) & (seed_ids < n_valid)
    # dedup seeds within each lane (keep first occurrence)
    first_pos = jnp.argmax(seed_ids[:, :, None] == seed_ids[:, None, :], axis=2)
    seed_valid &= first_pos == jnp.arange(seed_ids.shape[1])[None, :]
    safe_seeds = jnp.where(seed_valid, seed_ids, 0)
    seed_d = metric_obj.pair(queries[:, None, :], vectors[safe_seeds])
    seed_d = jnp.where(seed_valid, seed_d, _INF)
    seed_ids_m = jnp.where(seed_valid, seed_ids, INVALID)

    pad = L - seed_ids.shape[1]
    beam_ids = jnp.concatenate(
        [seed_ids_m, jnp.full((B, pad), INVALID, jnp.int32)], axis=1)
    beam_dists = jnp.concatenate([seed_d, jnp.full((B, pad), _INF)], axis=1)
    beam_checked = beam_ids == INVALID  # invalid slots never selected
    beam_excl = _in_set(beam_ids, exclude)

    order = jnp.argsort(beam_dists, axis=1)
    beam_ids = jnp.take_along_axis(beam_ids, order, axis=1)
    beam_dists = jnp.take_along_axis(beam_dists, order, axis=1)
    beam_checked = jnp.take_along_axis(beam_checked, order, axis=1)
    beam_excl = jnp.take_along_axis(beam_excl, order, axis=1)

    evals = seed_valid.sum(axis=1).astype(jnp.int32)
    hops = jnp.zeros((B,), jnp.int32)

    def radius(ids, dists, excl):
        """k-th best non-excluded distance (inf if fewer than k)."""
        ok = (ids != INVALID) & ~excl
        cnt = jnp.cumsum(ok.astype(jnp.int32), axis=1)
        at_k = ok & (cnt == k)
        has_k = at_k.any(axis=1)
        kth = jnp.where(at_k, dists, _INF).min(axis=1)
        return jnp.where(has_k, kth, _INF)

    def cond(state):
        _, _, _, _, _, _, it, alive = state
        return alive & (it < max_hops)

    def body(state):
        b_ids, b_dists, b_chk, b_exc, hops, evals, it, _ = state
        r = radius(b_ids, b_dists, b_exc)
        cur = jnp.argmax(~b_chk, axis=1)                    # first unchecked
        lane = jnp.arange(B)
        cur_id = b_ids[lane, cur]
        cur_d = b_dists[lane, cur]
        active = (~b_chk.all(axis=1)) & (cur_d <= r * eps1) & (cur_id != INVALID)

        b_chk = b_chk.at[lane, cur].set(jnp.where(active, True, b_chk[lane, cur]))

        nbrs = adjacency[jnp.where(active, cur_id, 0)]       # (B, d)
        ok = active[:, None] & (nbrs != INVALID) & (nbrs < n_valid)
        ok &= ~(nbrs[:, :, None] == b_ids[:, None, :]).any(axis=2)  # dedup
        safe = jnp.where(ok, nbrs, 0)
        nd = _neighbor_distances(vectors, queries, safe, metric, backend)
        nd = jnp.where(ok, nd, _INF)
        keep = ok & (nd <= r[:, None] * eps1)                # Alg.1 line 12
        cand_ids = jnp.where(keep, nbrs, INVALID)
        cand_d = jnp.where(keep, nd, _INF)
        cand_exc = _in_set(cand_ids, exclude) & keep

        evals = evals + ok.sum(axis=1).astype(jnp.int32)
        hops = hops + active.astype(jnp.int32)

        all_ids = jnp.concatenate([b_ids, cand_ids], axis=1)
        all_d = jnp.concatenate([b_dists, cand_d], axis=1)
        all_chk = jnp.concatenate([b_chk, jnp.zeros_like(keep)], axis=1)
        all_exc = jnp.concatenate([b_exc, cand_exc], axis=1)
        order = jnp.argsort(all_d, axis=1)[:, :L]
        b_ids = jnp.take_along_axis(all_ids, order, axis=1)
        b_dists = jnp.take_along_axis(all_d, order, axis=1)
        b_chk = jnp.take_along_axis(all_chk, order, axis=1)
        b_exc = jnp.take_along_axis(all_exc, order, axis=1)
        b_chk = jnp.where(b_ids == INVALID, True, b_chk)

        # lane is alive if its closest unchecked entry is within the radius
        r2 = radius(b_ids, b_dists, b_exc)
        nxt = jnp.argmax(~b_chk, axis=1)
        nxt_d = b_dists[lane, nxt]
        lane_alive = (~b_chk.all(axis=1)) & (nxt_d <= r2 * eps1)
        return (b_ids, b_dists, b_chk, b_exc, hops, evals, it + 1,
                lane_alive.any())

    state = (beam_ids, beam_dists, beam_checked, beam_excl, hops, evals,
             jnp.int32(0), jnp.asarray(True))
    b_ids, b_dists, b_chk, b_exc, hops, evals, _, _ = jax.lax.while_loop(
        cond, body, state)

    # ---- extract top-k, skipping excluded --------------------------------
    final_d = jnp.where(b_exc | (b_ids == INVALID), _INF, b_dists)
    order = jnp.argsort(final_d, axis=1)[:, :k]
    out_ids = jnp.take_along_axis(b_ids, order, axis=1)
    out_d = jnp.take_along_axis(final_d, order, axis=1)
    out_ids = jnp.where(jnp.isinf(out_d), INVALID, out_ids)
    return SearchResult(ids=out_ids, dists=out_d, hops=hops, evals=evals)


def _in_set(ids: Array, excl: Array) -> Array:
    """ids (B, L), excl (B, X) -> bool (B, L) membership (INVALID never member)."""
    hit = (ids[:, :, None] == excl[:, None, :]).any(axis=2)
    return hit & (ids != INVALID)


def medoid_seed(vectors: Array, n: int) -> int:
    """Approximate median vertex (paper Sec. 5.4 uses it as the search seed)."""
    mean = jnp.mean(vectors[:n], axis=0, keepdims=True)
    d = jnp.linalg.norm(vectors[:n] - mean, axis=1)
    return int(jnp.argmin(d))


def search_graph(graph: DEGraph, vectors: Array, queries: Array, *,
                 k: int, eps: float = 0.1, seed: Optional[int] = None,
                 beam_width: Optional[int] = None, metric: str = "l2",
                 backend: str = "jnp") -> SearchResult:
    """Convenience wrapper: single shared seed (median vertex by default)."""
    if seed is None:
        seed = medoid_seed(vectors, int(graph.n))
    B = queries.shape[0]
    seeds = jnp.full((B, 1), seed, dtype=jnp.int32)
    return range_search(graph, vectors, queries, seeds, k=k, eps=eps,
                        beam_width=beam_width, metric=metric, backend=backend)
