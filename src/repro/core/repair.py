"""Quarantine repair: restore Table-1 invariants on damaged vertices.

The online scrubber (serving/scrub.py) quarantines vertices whose rows
fail the vectorized audit (core/invariants.py).  This module turns a
quarantined set back into a clean even-regular undirected graph in three
stages, mirroring the delete-repair machinery (core/delete.py):

1. **Sanitize** — drop every structurally invalid adjacency entry
   (out-of-range id, self loop, duplicate slot, asymmetric half-edge) and
   heal weight drift in place by recomputing the true distance on both
   ends.  After this stage the graph is undirected and duplicate-free but
   the touched vertices may be degree-deficient.
2. **Complete** — re-pair the deficient slots greedily by ascending true
   distance (the same Eq.-4 reasoning as deletion's matching), falling
   back to Alg.-3-style edge splits (remove an existing (c, e), add
   (a, c) and (b, e)) when no direct pair is valid — including the
   same-vertex case where one vertex is short two slots.  Degree-sum
   parity guarantees the deficiency total is even, so completion
   terminates with exact regularity whenever splits are available.
3. **Reconnect** — if the damage (or the repair) split the graph, splice
   minor components back into the main one with edge swaps that preserve
   regularity on both sides.

``repair_vertices`` drives all three and optionally finishes with a
batched Alg.-5 refinement sweep (core/optimize.py) over the repaired
vertices, so the re-completed edges are immediately pulled toward the
continuous-refinement optimum rather than left wherever the greedy pairing
put them.  Re-admission (a clean re-audit) is the caller's decision.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from .build import DEGIndex, np_pair_dist
from .graph import INVALID
from .invariants import component_labels

_W_RTOL, _W_ATOL = 1e-5, 1e-6


def _true_dist(index: DEGIndex, u: int, v: int) -> float:
    return float(np_pair_dist(index.params.metric, index.vectors[u],
                              index.vectors[v])[0])


def sanitize_rows(index: DEGIndex, rows: Sequence[int]) -> list[int]:
    """Stage 1: drop invalid entries from the given rows and heal weight
    drift; returns the vertices left degree-deficient.

    Must be called with *all* quarantined rows at once: a flipped entry
    ``u -> w`` leaves a dangling reverse edge ``v -> u`` on the old
    partner, and the audit flags both ``u`` and ``v``, so sanitizing the
    full flagged set drops both halves and confines deficiency to the
    quarantined rows."""
    b = index.builder
    n = b.n
    for u in sorted(set(int(r) for r in rows)):
        if not (0 <= u < n):
            continue
        seen: set[int] = set()
        for s in range(b.degree):
            v = int(b.adjacency[u, s])
            if v == INVALID:
                continue
            bad = not (0 <= v < n) or v == u or v in seen
            if not bad:
                sv = b.edge_slot(v, u)
                if sv < 0:
                    bad = True          # asymmetric half-edge
                else:
                    w_true = _true_dist(index, u, v)
                    if not (np.isclose(b.weights[u, s], w_true,
                                       rtol=_W_RTOL, atol=_W_ATOL)
                            and np.isclose(b.weights[v, sv], w_true,
                                           rtol=_W_RTOL, atol=_W_ATOL)):
                        b.weights[u, s] = w_true
                        b.weights[v, sv] = w_true
                        b.mark_dirty(u, v)
            if bad:
                b.adjacency[u, s] = INVALID
                b.weights[u, s] = 0.0
                b.mark_dirty(u)
            else:
                seen.add(v)
    return [u for u in sorted(set(int(r) for r in rows))
            if 0 <= u < n and b.vertex_degree(u) < b.degree]


def _complete_deficient(index: DEGIndex, deficient: Sequence[int]) -> bool:
    """Stage 2: add edges until every listed vertex is back at degree d.
    Greedy nearest valid pairing over the deficient slot pool, with edge
    splits when the pool can't pair directly.  Returns True when every
    slot was filled."""
    b = index.builder
    d = b.degree
    pool: list[int] = []
    for v in sorted(set(int(v) for v in deficient)):
        pool.extend([v] * (d - b.vertex_degree(v)))
    while pool:
        if len(pool) == 1:
            return False                # odd parity: sanitize was partial
        # nearest valid direct pair anywhere in the pool
        best = None
        for i in range(len(pool)):
            for j in range(i + 1, len(pool)):
                a, c = pool[i], pool[j]
                if a == c or b.has_edge(a, c):
                    continue
                w = _true_dist(index, a, c)
                if best is None or w < best[0]:
                    best = (w, i, j)
        if best is not None:
            _, i, j = best
            a, c = pool[i], pool[j]
            b.add_edge(a, c, _true_dist(index, a, c))
            del pool[j], pool[i]        # j > i: delete high index first
            continue
        # no direct pair (dense neighborhood or a == c twice): split an
        # existing edge (x, y) away from the pool — add (a, x), (c, y)
        a, c = pool[0], pool[1]
        pool_set = set(pool)
        split = None
        for x in range(b.n):
            if x in pool_set or x == a or b.has_edge(a, x):
                continue
            for y in b.neighbors(x):
                y = int(y)
                if (y in pool_set or y == c or y == a
                        or b.has_edge(c, y)):
                    continue
                cost = (_true_dist(index, a, x) + _true_dist(index, c, y)
                        - b.edge_weight(x, y))
                if split is None or cost < split[0]:
                    split = (cost, x, y)
            if split is not None and split[0] <= 0:
                break                   # good enough; keep the scan bounded
        if split is None:
            return False
        _, x, y = split
        b.remove_edge(x, y)
        b.add_edge(a, x, _true_dist(index, a, x))
        b.add_edge(c, y, _true_dist(index, c, y))
        del pool[1], pool[0]
    return True


def reconnect(index: DEGIndex, max_rounds: int = 32) -> bool:
    """Stage 3: splice minor components into the largest one with
    regularity-preserving double swaps: remove (u, x) inside the minor
    component and (c, e) inside the main one, add (u, c) and (x, e).
    Returns True when the graph ends single-component."""
    b = index.builder
    for _ in range(max_rounds):
        labels = component_labels(b)
        if labels.size == 0 or int(labels.max()) == 0:
            return True
        counts = np.bincount(labels)
        main = int(np.argmax(counts))
        minor = int(np.argmin(counts))
        minor_ids = np.flatnonzero(labels == minor)
        main_ids = np.flatnonzero(labels == main)
        done = False
        for u in minor_ids:
            u = int(u)
            for x in b.neighbors(u):
                x = int(x)
                # nearest main-side anchor for u with a spare edge to break
                best = None
                for c in main_ids:
                    c = int(c)
                    if b.has_edge(u, c):
                        continue
                    for e in b.neighbors(c):
                        e = int(e)
                        if e == c or b.has_edge(x, e) or x == e:
                            continue
                        cost = (_true_dist(index, u, c)
                                + _true_dist(index, x, e))
                        if best is None or cost < best[0]:
                            best = (cost, c, e)
                    if best is not None:
                        break           # first anchor is fine; stay bounded
                if best is None:
                    continue
                _, c, e = best
                # all four adds/removes pre-validated (no dups, no self
                # loops, one free slot on each endpoint after the removes)
                b.remove_edge(u, x)
                b.remove_edge(c, e)
                b.add_edge(u, c, _true_dist(index, u, c))
                b.add_edge(x, e, _true_dist(index, x, e))
                done = True
                break
            if done:
                break
        if not done:
            return False
    return int(component_labels(b).max()) == 0


def repair_vertices(index: DEGIndex, vertices: Sequence[int], *,
                    refine_after: bool = True
                    ) -> tuple[list[int], list[int]]:
    """Full repair pipeline over a quarantined set; call under the index
    mutation lock.  Returns ``(candidates, failed)`` — ``candidates`` are
    the vertices that went through repair and should be re-audited before
    re-admission; ``failed`` is the subset whose completion could not
    restore regularity (they must stay quarantined)."""
    b = index.builder
    if b is None:
        return [], []
    rows = [int(v) for v in sorted(set(int(v) for v in vertices))
            if 0 <= int(v) < b.n]
    if not rows:
        return [], []
    deficient = sanitize_rows(index, rows)
    completed = _complete_deficient(index, deficient)
    reconnect(index)
    failed = [] if completed else [v for v in rows
                                   if b.vertex_degree(v) != b.degree]
    repaired = [v for v in rows if v not in set(failed)]
    if refine_after and repaired:
        from .optimize import refine_sweep

        refine_sweep(index, repaired, i_opt=index.params.i_opt,
                     k_opt=index.params.k_opt,
                     eps_opt=index.params.eps_opt)
    return repaired, failed
