"""Device-resident construction programs (paper Alg. 2/3 + the Alg. 5 hot
decisions).

Host-side graph *surgery* stays sequential numpy (``GraphBuilder``), but the
hot inner decisions of construction — which neighbors a new vertex takes
(Alg. 3 with scheme A-D selection and Alg. 2 occlusion checks), which edges
of a refined vertex are MRNG-conform, and which swap Alg. 4's first search
proposes — are pure functions of a graph snapshot.  This module implements
them as jitted, wave-batched device programs over the
:meth:`GraphBuilder.device_graph` buffers, all sharing the fused
``kernels/mrng_occlusion`` gather+distance+lune-test primitive:

* :func:`extend_wave_device` — Alg. 3 steps 4-16 for a whole insert wave in
  one fixed-shape call: candidate neighbor rows are gathered, the occlusion
  matrix is computed once, and the sequential (b, n) pair selection runs as
  a ``fori_loop`` of ``d/2`` masked steps.  Bit-faithful to the host
  ``_extend_vertex`` given the same snapshot: candidate eligibility under
  Alg. 2 is *monotone* (the selected set U only grows, and rows of
  unselected candidates never change), so "repeatedly take the first
  eligible candidate" reproduces the host's pass-based order, including the
  one-way phase-2 transition that drops the occlusion check (Alg. 3 line
  14).  Lanes that exhaust their candidates report ``ok=False`` and fall
  back to the host path (which widens with exact candidates).

* :func:`mrng_conform_batch` — Alg. 2 for every edge of a batch of existing
  vertices (the Alg. 5 agenda test) in one call.

* :func:`propose_swaps` — Alg. 4 step (2): the best
  ``gain - d(v2, s) + w(s, n)`` swap over all (search result s, neighbor n)
  pairs, for a whole chunk of edge tasks in one call.

Float caveat, shared by all three: distances the host path reads back from
stored edge weights are *recomputed* on device (same float32 formula, so
divergence is confined to exact lune/argmax boundary ties), and the gain
accumulation runs in float32 instead of host float64.  Structural decisions
are always re-validated against the live builder before edges are written.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.mrng_occlusion import ops as occ_ops

from .graph import INVALID, pow2_bucket

_INF = jnp.inf


# ---------------------------------------------------------------------------
# Alg. 3: wave-batched vertex extension
# ---------------------------------------------------------------------------
@functools.partial(
    jax.jit, static_argnames=("scheme", "rng_checks", "metric", "backend"))
def extend_wave_device(adjacency: jax.Array, weights: jax.Array,
                       vectors: jax.Array, cand_ids: jax.Array,
                       cand_dists: jax.Array, queries: jax.Array,
                       v_ids: jax.Array, *, scheme: str = "C",
                       rng_checks: bool = True, metric: str = "l2",
                       backend: str = "jnp"):
    """Select the d neighbors of W new vertices in one device call.

    cand_ids/cand_dists (W, K): each lane's Alg. 3 candidate search result
    (ascending, INVALID-padded); queries (W, m): the new points; v_ids (W,):
    the ids the new vertices will take.  Returns ``(sel_ids (W, d),
    sel_dists (W, d), ok (W,))`` — slot 2t holds the t-th selected candidate
    b, slot 2t+1 its surrendered neighbor n (the edge (b, n) is replaced by
    (v, b) and (v, n)).  ``ok=False`` lanes ran out of candidates and must
    use the host fallback.
    """
    W, K = cand_ids.shape
    D = adjacency.shape[1]
    valid = (cand_ids != INVALID) & (cand_ids < v_ids[:, None])
    safe_cand = jnp.where(valid, cand_ids, 0)
    nbr_ids = jnp.where(valid[:, :, None], adjacency[safe_cand], INVALID)
    nbr_w = jnp.where(valid[:, :, None], weights[safe_cand], 0.0)
    nbr_dist, occl = occ_ops.mrng_occlusion(
        vectors, jnp.where(nbr_ids == INVALID, 0, nbr_ids), queries,
        cand_dists, nbr_w, metric=metric, backend=backend)
    nbr_valid = nbr_ids != INVALID
    occl = occl & nbr_valid
    nbr_dist = jnp.where(nbr_valid, nbr_dist, _INF)
    lane = jnp.arange(W)

    def step(t, state):
        U_ids, U_d, skip, fail = state
        cand_in_U = ((cand_ids[:, :, None] == U_ids[:, None, :]).any(-1)
                     & valid)
        nbr_in_U = ((nbr_ids[:, :, :, None]
                     == U_ids[:, None, None, :]).any(-1) & nbr_valid)
        blocked = (occl & nbr_in_U).any(-1)                 # Alg. 2 over U
        # surrendered edges need no extra mask: both endpoints of a taken
        # (b, n) pair joined U, so ~nbr_in_U already hides those slots
        avail = nbr_valid & ~nbr_in_U
        elig_base = valid & ~cand_in_U & avail.any(-1)
        elig_mrng = elig_base & ~blocked
        skip = skip | ~elig_mrng.any(-1)                    # phase 2 latch
        elig = jnp.where(skip[:, None], elig_base, elig_mrng)
        any_elig = elig.any(-1)
        i_sel = jnp.argmax(elig, axis=1)                    # first eligible
        row_avail = avail[lane, i_sel]
        row_w = nbr_w[lane, i_sel]
        row_nd = nbr_dist[lane, i_sel]
        if scheme == "C":
            j_sel = jnp.argmax(jnp.where(row_avail, row_w, -_INF), axis=1)
        elif scheme == "B":
            j_sel = jnp.argmin(jnp.where(row_avail, row_w, _INF), axis=1)
        elif scheme == "A":
            j_sel = jnp.argmin(jnp.where(row_avail, row_nd, _INF), axis=1)
        elif scheme == "D":
            j_sel = jnp.argmin(jnp.where(row_avail, row_nd - row_w, _INF),
                               axis=1)
        else:
            raise ValueError(f"unknown selection scheme {scheme!r}")
        b_sel = cand_ids[lane, i_sel]
        b_d = cand_dists[lane, i_sel]
        n_sel = nbr_ids[lane, i_sel, j_sel]
        n_d = nbr_dist[lane, i_sel, j_sel]
        do = any_elig & ~fail
        U_ids = U_ids.at[:, 2 * t].set(
            jnp.where(do, b_sel, U_ids[:, 2 * t]))
        U_ids = U_ids.at[:, 2 * t + 1].set(
            jnp.where(do, n_sel, U_ids[:, 2 * t + 1]))
        U_d = U_d.at[:, 2 * t].set(jnp.where(do, b_d, U_d[:, 2 * t]))
        U_d = U_d.at[:, 2 * t + 1].set(
            jnp.where(do, n_d, U_d[:, 2 * t + 1]))
        fail = fail | ~any_elig
        return U_ids, U_d, skip, fail

    state0 = (
        jnp.full((W, D), INVALID, jnp.int32),
        jnp.full((W, D), _INF, jnp.float32),
        jnp.full((W,), not rng_checks),
        jnp.zeros((W,), bool),
    )
    U_ids, U_d, _, fail = jax.lax.fori_loop(0, D // 2, step, state0)
    return U_ids, U_d, ~fail


def extend_wave(index, pts: np.ndarray, cand_ids: np.ndarray,
                cand_dists: np.ndarray, start: int, *,
                backend: str = "jnp"):
    """Host driver for :func:`extend_wave_device`: syncs the device graph,
    pads the wave to a power-of-two lane count (a handful of jit entries
    across all waves of a build), returns numpy selections."""
    W = pts.shape[0]
    Wp = pow2_bucket(W, floor=4)
    K = cand_ids.shape[1]
    c_ids = np.full((Wp, K), INVALID, np.int32)
    c_ids[:W] = cand_ids
    c_d = np.full((Wp, K), np.inf, np.float32)
    c_d[:W] = cand_dists
    q = np.zeros((Wp, pts.shape[1]), np.float32)
    q[:W] = pts
    v_ids = np.zeros((Wp,), np.int32)
    v_ids[:W] = start + np.arange(W)
    g = index.builder.device_graph()
    sel_ids, sel_d, ok = extend_wave_device(
        g.adjacency, g.weights, index._dev_vectors, jnp.asarray(c_ids),
        jnp.asarray(c_d), jnp.asarray(q), jnp.asarray(v_ids),
        scheme=index.params.scheme, rng_checks=index.params.rng_checks,
        metric=index.params.metric, backend=backend)
    return (np.asarray(sel_ids)[:W], np.asarray(sel_d)[:W],
            np.asarray(ok)[:W])


# ---------------------------------------------------------------------------
# Alg. 5: batched conformity + first-swap proposals
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("metric", "backend"))
def mrng_conform_batch(adjacency: jax.Array, weights: jax.Array,
                       vectors: jax.Array, v_ids: jax.Array, *,
                       metric: str = "l2", backend: str = "jnp"):
    """Alg. 2 for every edge of a batch of vertices: (C,) ids -> (C, d)
    bool, True where the edge in that slot is MRNG-conform (INVALID slots
    are True).  The batched twin of ``mrng.mrng_conform_mask``."""
    row_ids = adjacency[v_ids]                              # (C, d)
    row_w = weights[v_ids]
    row_valid = row_ids != INVALID
    safe = jnp.where(row_valid, row_ids, 0)
    nbr2 = jnp.where(row_valid[:, :, None], adjacency[safe], INVALID)
    w2 = weights[safe]
    _, occl = occ_ops.mrng_occlusion(
        vectors, jnp.where(nbr2 == INVALID, 0, nbr2), vectors[v_ids],
        row_w, w2, metric=metric, backend=backend)
    # only *common* neighbors (u adjacent to both endpoints) occlude
    common = ((nbr2[:, :, :, None] == row_ids[:, None, None, :]).any(-1)
              & (nbr2 != INVALID))
    violated = (occl & common).any(-1)
    return jnp.where(row_valid, ~violated, True)


@jax.jit
def propose_swaps(adjacency: jax.Array, weights: jax.Array, ids: jax.Array,
                  dists: jax.Array, v1: jax.Array, v2: jax.Array,
                  gain: jax.Array):
    """Batched Alg. 4 step (2) first-iteration scan.

    ids/dists (C, k): the prefetched candidate search around each task's
    v2; v1/v2/gain (C,): the edge under optimization and its weight.
    Returns ``(s (C,), n (C,), ds (C,), best (C,), found (C,))`` — the swap
    maximizing ``gain - d(v2, s) + w(s, n)`` over admissible pairs, with
    ``found`` iff that beats keeping the edge.  Row-major argmax matches
    the host scan's first-strict-improvement tie-break."""
    C, k = ids.shape
    D = adjacency.shape[1]
    valid_s = (ids != INVALID) & (ids != v1[:, None]) & (ids != v2[:, None])
    v2row = adjacency[v2]
    valid_s &= ~(ids[:, :, None] == v2row[:, None, :]).any(-1)
    safe = jnp.where(ids == INVALID, 0, ids)
    srow = adjacency[safe]                                  # (C, k, D)
    srow_w = weights[safe]
    valid_n = (valid_s[:, :, None] & (srow != INVALID)
               & (srow != v2[:, None, None]))
    cand = gain[:, None, None] - dists[:, :, None] + srow_w
    flat = jnp.where(valid_n, cand, -_INF).reshape(C, k * D)
    idx = jnp.argmax(flat, axis=1)
    best = jnp.take_along_axis(flat, idx[:, None], axis=1)[:, 0]
    lane = jnp.arange(C)
    s_sel = ids[lane, idx // D]
    n_sel = srow[lane, idx // D, idx % D]
    ds_sel = dists[lane, idx // D]
    return s_sel, n_sel, ds_sel, best, best > gain
