"""Epoch-published graph snapshots: safe concurrent reads under mutation.

The paper's continuous-refinement story ("a well-organized graph structure
at all times", Sec. 1) only pays off in production if refinement can run
*while* queries flow.  The serving layers historically pinned the opposite
invariant — index read-only while an async engine is live — because the
device cache donates buffers on every post-mutation sync, so a flush racing
a writer could observe a half-applied edge surgery (torn read).

This module replaces that restriction with an epoch protocol:

* Writers mutate the live :class:`GraphBuilder` under the index's mutation
  lock and call ``DEGIndex.publish()`` at batch boundaries.  ``publish``
  captures an *independent, immutable* :class:`PublishedEpoch` — graph rows
  (``freeze()``), a copy of the device vector store (the live one is
  donation-invalidated by inserts), the quarantine set, and a
  quarantine-aware medoid — and atomically swaps it in.
* Readers (the bucket dispatch path) ``acquire()`` the current epoch per
  flush and search only its frozen buffers; every lane of a batch therefore
  sees one coherent graph, tagged with ``epoch`` / ``builder_gen`` so a
  replay against the same snapshot must be bit-identical.
* Old epochs are refcounted and retired only when the last in-flight flush
  releases its reference — never under a reader.

The protocol is deliberately wait-free for readers: ``acquire``/``release``
are a refcount under a small lock, writers never block on readers, and
readers never block on writers (they just keep searching the previous
epoch until the next flush picks up the new one).
"""
from __future__ import annotations

import threading
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.obs import clock
from repro.obs.metrics import EPOCH_RETIRED_LAG_MS

from .search import SearchResult, range_search


class PublishedEpoch:
    """One immutable published generation of a :class:`DEGIndex`.

    Exposes the same ``search_batch`` / ``medoid`` surface the serving
    bucket dispatcher uses on the index itself, so ``buckets.dispatch``
    accepts either interchangeably.  All buffers are independent copies:
    no later builder mutation, donation, or cache drop can touch them.
    """

    __slots__ = ("epoch", "graph", "vectors", "n", "medoid_id", "metric",
                 "params", "quarantine", "builder_gen", "published_at",
                 "superseded_at", "refs", "_stores", "_lock")

    def __init__(self, *, epoch: int, graph, vectors, n: int, medoid_id: int,
                 metric: str, params, quarantine=(), builder_gen: int = -1):
        self.epoch = int(epoch)
        self.graph = graph               # independent DEGraph (freeze())
        self.vectors = vectors           # independent device copy
        self.n = int(n)
        self.medoid_id = int(medoid_id)
        self.metric = metric
        self.params = params
        self.quarantine = tuple(int(q) for q in quarantine)
        self.builder_gen = int(builder_gen)
        self.published_at = clock.now()
        self.superseded_at: Optional[float] = None
        self.refs = 0                    # guarded by the owning manager
        self._stores: dict = {}          # per-epoch quant stores, lazy
        self._lock = threading.Lock()

    @property
    def dim(self) -> int:
        return int(self.vectors.shape[1])

    def medoid(self) -> int:
        return self.medoid_id

    def store_for(self, codec: str):
        """Quant store over *this epoch's* vectors (lazy, cached for the
        epoch's lifetime — degraded-ladder rungs traverse sq8)."""
        from repro.quant import make_store

        with self._lock:
            st = self._stores.get(codec)
            if st is None:
                st = make_store(self.vectors, codec, n=self.n)
                self._stores[codec] = st
        return st

    def search_batch(self, queries, seed_ids=None, exclude=None, *, k: int,
                     eps: float = 0.1, beam_width=None, backend: str = "jnp",
                     quantized=None, rerank_k=None, expand_width=None,
                     visited_size=None, hop_backend=None,
                     hop_budget=None) -> SearchResult:
        """Mirror of ``DEGIndex.search_batch`` against this epoch's frozen
        buffers.  Shapes and static config match the live index's, so the
        jitted beam program is shared — publishing costs no retrace."""
        p = self.params
        E = p.expand_width if expand_width is None else expand_width
        hb = p.hop_backend if hop_backend is None else hop_backend
        vs = p.visited_size if visited_size is None else visited_size
        q = jnp.asarray(np.atleast_2d(np.asarray(queries, np.float32)))
        if seed_ids is None:
            seeds = jnp.full((q.shape[0], 1), self.medoid_id,
                             dtype=jnp.int32)
        else:
            seeds = jnp.asarray(np.asarray(seed_ids, np.int32))
            if seeds.ndim == 1:
                seeds = seeds[:, None]
        excl = None if exclude is None else jnp.asarray(
            np.asarray(exclude, np.int32))
        hbud = None if hop_budget is None else jnp.asarray(
            np.asarray(hop_budget, np.int32))
        if quantized in (None, "float32"):
            return range_search(self.graph, self.vectors, q, seeds, k=k,
                                eps=eps, beam_width=beam_width,
                                metric=self.metric, exclude=excl,
                                backend=backend, expand_width=E,
                                visited_size=vs, hop_backend=hb,
                                hop_budget=hbud)
        store = self.store_for(quantized)
        rk = int(rerank_k) if rerank_k else 4 * k
        return range_search(self.graph, store, q, seeds, k=k, eps=eps,
                            beam_width=beam_width, metric=self.metric,
                            exclude=excl, backend=backend,
                            rerank_k=max(rk, k), exact_vectors=self.vectors,
                            expand_width=E, visited_size=vs, hop_backend=hb,
                            hop_budget=hbud)


class EpochManager:
    """Refcounted publish / acquire / release / retire state machine.

    * ``publish(ep)`` swaps the current epoch; the superseded one is
      retired immediately if unreferenced, else when its last reader
      releases.
    * ``acquire()`` hands the current epoch to a flush (refcount++).
    * ``release(ep)`` drops a flush's reference; a superseded epoch whose
      refcount reaches zero is retired (buffers become collectible) and
      its supersede→retire lag is observed on the ``epoch_retired_lag_ms``
      histogram — the backpressure signal for publish frequency.
    """

    def __init__(self, owner=None):
        self._lock = threading.Lock()
        self._owner = owner              # DEGIndex, for metrics resolution
        self.current: Optional[PublishedEpoch] = None
        self.live: dict[int, PublishedEpoch] = {}
        self.retired_total = 0

    @property
    def next_epoch(self) -> int:
        with self._lock:
            return 0 if self.current is None else self.current.epoch + 1

    def publish(self, ep: PublishedEpoch) -> None:
        with self._lock:
            old = self.current
            self.current = ep
            self.live[ep.epoch] = ep
            if old is not None:
                old.superseded_at = clock.now()
                if old.refs == 0:
                    self._retire_locked(old)

    def acquire(self) -> PublishedEpoch:
        with self._lock:
            ep = self.current
            if ep is None:
                raise RuntimeError("no epoch published yet")
            ep.refs += 1
            return ep

    def release(self, ep: PublishedEpoch) -> None:
        with self._lock:
            ep.refs -= 1
            if ep.refs <= 0 and ep is not self.current:
                self._retire_locked(ep)

    def live_epochs(self) -> list[int]:
        with self._lock:
            return sorted(self.live)

    def _retire_locked(self, ep: PublishedEpoch) -> None:
        if self.live.pop(ep.epoch, None) is None:
            return                       # already retired
        self.retired_total += 1
        metrics = getattr(self._owner, "metrics", None)
        if metrics is not None and ep.superseded_at is not None:
            lag_ms = (clock.now() - ep.superseded_at) * 1e3
            metrics.histogram(EPOCH_RETIRED_LAG_MS).observe(lag_ms)
