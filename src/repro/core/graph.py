"""Graph containers for the Dynamic Exploration Graph.

Two layers:

* :class:`DEGraph` — an immutable JAX pytree used on device (search, serving,
  dry-run).  The even-regularity of DEG (paper Sec. 5.1) means the *entire*
  graph is one dense ``(capacity, d) int32`` adjacency array plus a matching
  ``float32`` weight array.  This is the core of the TPU adaptation: every
  search hop is a fixed-shape gather, there is no raggedness and no hubs by
  construction.

* :class:`GraphBuilder` — a mutable host-side (numpy) twin used by the
  incremental construction (Alg. 3) and edge optimization (Alg. 4/5), which
  are graph-surgery procedures.  ``freeze()`` converts to a :class:`DEGraph`.

Slots that are transiently unused hold ``INVALID`` (= -1).  A *valid* DEG has
no ``INVALID`` entries among its first ``n`` rows.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

INVALID = -1


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DEGraph:
    """Immutable device-side even-regular graph."""

    adjacency: jax.Array          # (capacity, d) int32, INVALID-padded
    weights: jax.Array            # (capacity, d) float32
    n: jax.Array                  # () int32 — number of active vertices

    @property
    def capacity(self) -> int:
        return self.adjacency.shape[0]

    @property
    def degree(self) -> int:
        return self.adjacency.shape[1]

    def to_builder(self) -> "GraphBuilder":
        b = GraphBuilder.__new__(GraphBuilder)
        b.adjacency = np.asarray(self.adjacency).copy()
        b.weights = np.asarray(self.weights).copy()
        b.n = int(self.n)
        return b


class GraphBuilder:
    """Mutable host-side graph for construction / refinement."""

    def __init__(self, capacity: int, degree: int):
        if degree < 4 or degree % 2 != 0:
            raise ValueError(f"DEG degree must be even and >= 4, got {degree}")
        if capacity < degree + 1:
            raise ValueError("capacity must be at least degree + 1")
        self.adjacency = np.full((capacity, degree), INVALID, dtype=np.int32)
        self.weights = np.zeros((capacity, degree), dtype=np.float32)
        self.n = 0

    # -- basic accessors -------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.adjacency.shape[0]

    @property
    def degree(self) -> int:
        return self.adjacency.shape[1]

    def neighbors(self, v: int) -> np.ndarray:
        row = self.adjacency[v]
        return row[row != INVALID]

    def neighbor_weights(self, v: int) -> np.ndarray:
        row = self.adjacency[v]
        return self.weights[v][row != INVALID]

    def vertex_degree(self, v: int) -> int:
        return int((self.adjacency[v] != INVALID).sum())

    def has_edge(self, u: int, v: int) -> bool:
        return bool((self.adjacency[u] == v).any())

    def edge_weight(self, u: int, v: int) -> float:
        slots = np.nonzero(self.adjacency[u] == v)[0]
        if slots.size == 0:
            raise KeyError(f"no edge ({u}, {v})")
        return float(self.weights[u, slots[0]])

    # -- mutation --------------------------------------------------------
    def _free_slot(self, v: int) -> int:
        slots = np.nonzero(self.adjacency[v] == INVALID)[0]
        if slots.size == 0:
            raise RuntimeError(f"vertex {v} already has degree {self.degree}")
        return int(slots[0])

    def add_edge(self, u: int, v: int, w: float) -> None:
        if u == v:
            raise ValueError(f"self loop at {u}")
        if self.has_edge(u, v):
            raise ValueError(f"duplicate edge ({u}, {v})")
        su, sv = self._free_slot(u), self._free_slot(v)
        self.adjacency[u, su] = v
        self.weights[u, su] = w
        self.adjacency[v, sv] = u
        self.weights[v, sv] = w

    def remove_edge(self, u: int, v: int) -> float:
        w = None
        for a, b in ((u, v), (v, u)):
            slots = np.nonzero(self.adjacency[a] == b)[0]
            if slots.size == 0:
                raise KeyError(f"no edge ({a}, {b})")
            w = float(self.weights[a, slots[0]])
            self.adjacency[a, slots[0]] = INVALID
            self.weights[a, slots[0]] = 0.0
        return w

    def add_vertex(self) -> int:
        if self.n >= self.capacity:
            raise RuntimeError("capacity exhausted; grow() first")
        v = self.n
        self.n += 1
        return v

    def grow(self, new_capacity: int) -> None:
        if new_capacity <= self.capacity:
            return
        d = self.degree
        adj = np.full((new_capacity, d), INVALID, dtype=np.int32)
        w = np.zeros((new_capacity, d), dtype=np.float32)
        adj[: self.capacity] = self.adjacency
        w[: self.capacity] = self.weights
        self.adjacency, self.weights = adj, w

    # -- snapshot / rollback (Alg. 4 step 6 "revert all changes") --------
    def snapshot(self, vertices: Iterable[int]) -> dict:
        vs = sorted(set(int(v) for v in vertices))
        return {
            "vs": vs,
            "adj": self.adjacency[vs].copy(),
            "w": self.weights[vs].copy(),
        }

    def restore(self, snap: dict) -> None:
        self.adjacency[snap["vs"]] = snap["adj"]
        self.weights[snap["vs"]] = snap["w"]

    # -- conversion ------------------------------------------------------
    def freeze(self) -> DEGraph:
        return DEGraph(
            adjacency=jnp.asarray(self.adjacency),
            weights=jnp.asarray(self.weights),
            n=jnp.asarray(self.n, dtype=jnp.int32),
        )

    # -- stats used by Alg. 5 / benchmarks -------------------------------
    def longest_edge_slot(self, v: int) -> int:
        row = self.adjacency[v]
        w = np.where(row != INVALID, self.weights[v], -np.inf)
        return int(np.argmax(w))

    def average_neighbor_distance(self) -> float:
        """Eq. (4) over the whole graph (active vertices only)."""
        if self.n == 0:
            return 0.0
        adj = self.adjacency[: self.n]
        w = self.weights[: self.n]
        valid = adj != INVALID
        denom = np.maximum(valid.sum(axis=1), 1)
        per_vertex = (w * valid).sum(axis=1) / denom
        return float(per_vertex.mean())


def complete_graph(vectors: np.ndarray, degree: int, capacity: int,
                   metric_name: str = "l2") -> GraphBuilder:
    """The smallest possible DEG_d: the complete graph K_{d+1} (Sec. 5.1)."""
    from .distances import get_metric

    metric = get_metric(metric_name)
    k = degree + 1
    if vectors.shape[0] < k:
        raise ValueError(f"need at least {k} vectors for DEG_{degree}")
    b = GraphBuilder(capacity, degree)
    pts = jnp.asarray(vectors[:k])
    dmat = np.asarray(metric.cross(pts, pts))
    for _ in range(k):
        b.add_vertex()
    for i in range(k):
        for j in range(i + 1, k):
            b.add_edge(i, j, float(dmat[i, j]))
    return b
