"""Graph containers for the Dynamic Exploration Graph.

Two layers:

* :class:`DEGraph` — an immutable JAX pytree used on device (search, serving,
  dry-run, and the device-resident construction programs).  The
  even-regularity of DEG (paper Sec. 5.1) means the *entire* graph is one
  dense ``(capacity, d) int32`` adjacency array plus a matching ``float32``
  weight array.  This is the core of the TPU adaptation: every search hop is
  a fixed-shape gather, there is no raggedness and no hubs by construction.

* :class:`GraphBuilder` — a mutable host-side (numpy) twin used by the
  incremental construction (Alg. 3) and edge optimization (Alg. 4/5), which
  are graph-surgery procedures.

Buffer ownership (ARCHITECTURE.md "Device-resident construction layering"):
the numpy arrays are the mutable source of truth; the builder additionally
owns a *device cache* of both buffers.  Every mutator records the touched
rows, and :meth:`device_graph` re-syncs the cache by scattering only the
dirty rows through a **donated** jitted update — per-wave sync cost is
O(rows touched), not O(capacity).  Because the scatter donates the previous
cache buffers, a :class:`DEGraph` obtained from ``device_graph()`` /
``freeze()`` is valid only until the *next* sync after a mutation; consumers
that need a stable snapshot must copy (``to_builder()`` does).

Slots that are transiently unused hold ``INVALID`` (= -1).  A *valid* DEG has
no ``INVALID`` entries among its first ``n`` rows.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

INVALID = -1

# full re-upload beats the gather+scatter once more than capacity / this
# fraction of the rows are dirty
_FULL_SYNC_FRACTION = 4


def pow2_bucket(n: int, floor: int = 1) -> int:
    """Round up to a power of two (>= floor) — the lane/row bucketing every
    batched construction path uses so repeated calls reuse a handful of
    compiled jit entries instead of one per distinct size."""
    p = floor
    while p < n:
        p *= 2
    return p


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DEGraph:
    """Immutable device-side even-regular graph."""

    adjacency: jax.Array          # (capacity, d) int32, INVALID-padded
    weights: jax.Array            # (capacity, d) float32
    n: jax.Array                  # () int32 — number of active vertices

    @property
    def capacity(self) -> int:
        return self.adjacency.shape[0]

    @property
    def degree(self) -> int:
        return self.adjacency.shape[1]

    def to_builder(self) -> "GraphBuilder":
        b = GraphBuilder.__new__(GraphBuilder)
        b.adjacency = np.asarray(self.adjacency).copy()
        b.weights = np.asarray(self.weights).copy()
        b.n = int(self.n)
        b._init_device_state()
        return b


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _scatter_rows(adj: jax.Array, w: jax.Array, rows: jax.Array,
                  adj_rows: jax.Array, w_rows: jax.Array):
    return adj.at[rows].set(adj_rows), w.at[rows].set(w_rows)


class GraphBuilder:
    """Mutable host-side graph for construction / refinement."""

    def __init__(self, capacity: int, degree: int):
        if degree < 4 or degree % 2 != 0:
            raise ValueError(f"DEG degree must be even and >= 4, got {degree}")
        if capacity < degree + 1:
            raise ValueError("capacity must be at least degree + 1")
        self.adjacency = np.full((capacity, degree), INVALID, dtype=np.int32)
        self.weights = np.zeros((capacity, degree), dtype=np.float32)
        self.n = 0
        # lifetime edge-surgery counters (plain ints — two adds per edge
        # op; obs snapshots read them, see DEGIndex metrics wiring).  The
        # add/remove *ratio* is the churn signal: refine sweeps that swap
        # without converging show up as counters racing with no
        # refine_improved_edges_total growth.
        self.edges_added = 0
        self.edges_removed = 0
        self._init_device_state()

    def _init_device_state(self) -> None:
        self._dev_adj = None          # device cache of adjacency/weights
        self._dev_w = None
        self._dirty: set[int] = set() # host rows ahead of the device cache
        # Monotonic mutation counter: bumped on every host-side write
        # (including bulk loads and capacity growth).  Epoch publication
        # stamps this onto each published snapshot so a reader can prove
        # which graph state a flush actually searched — the guard against
        # the stale-epoch hazard where a cached device twin silently mixes
        # rows from before and after a mutation.
        self._gen = 0
        self._dev_sync_gen = -1       # generation the device cache matches

    # -- basic accessors -------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.adjacency.shape[0]

    @property
    def degree(self) -> int:
        return self.adjacency.shape[1]

    def neighbors(self, v: int) -> np.ndarray:
        row = self.adjacency[v]
        return row[row != INVALID]

    def neighbor_weights(self, v: int) -> np.ndarray:
        row = self.adjacency[v]
        return self.weights[v][row != INVALID]

    def vertex_degree(self, v: int) -> int:
        return int((self.adjacency[v] != INVALID).sum())

    @property
    def generation(self) -> int:
        """Monotonic mutation counter of the host graph (see
        ``_init_device_state``); equal generations imply identical content
        under the single-writer lock discipline."""
        return self._gen

    def device_generation(self) -> int:
        """Generation the cached device buffers correspond to, or -1 when
        no cache exists.  ``device_generation() == generation`` iff a
        ``device_graph()`` call right now would be a pure cache hit."""
        if self._dev_adj is None:
            return -1
        return self._dev_sync_gen if not self._dirty else -1

    def edge_slot(self, u: int, v: int) -> int:
        """Slot of ``v`` in ``u``'s row, or -1 — the one lookup shared by
        ``has_edge`` / ``edge_weight`` / ``remove_edge`` (argmax over the
        fixed-width row; no index-array allocation per call)."""
        row = self.adjacency[u]
        s = int(np.argmax(row == v))
        return s if row[s] == v else -1

    def has_edge(self, u: int, v: int) -> bool:
        return self.edge_slot(u, v) >= 0

    def edge_weight(self, u: int, v: int) -> float:
        s = self.edge_slot(u, v)
        if s < 0:
            raise KeyError(f"no edge ({u}, {v})")
        return float(self.weights[u, s])

    # -- device sync -----------------------------------------------------
    def mark_dirty(self, *rows: int) -> None:
        """Record host-side row writes so the next ``device_graph()`` can
        re-sync the device cache.  Mutator methods call this themselves;
        callers writing ``adjacency`` / ``weights`` directly must too."""
        self._gen += 1
        if self._dev_adj is not None:
            self._dirty.update(int(r) for r in rows)

    def invalidate_device(self) -> None:
        """Drop the device cache entirely (bulk host rewrites)."""
        self._gen += 1
        self._drop_cache()
        self._dev_adj = self._dev_w = None
        self._dirty = set()

    def _drop_cache(self) -> None:
        """Free the cached device buffers.  Like the donating scatter path,
        this makes any still-held ``device_graph()`` twin raise on use
        (deterministic failure) instead of silently serving stale rows —
        the documented contract; holders use ``freeze()``."""
        for buf in (self._dev_adj, self._dev_w):
            if buf is not None:
                buf.delete()

    def device_graph(self) -> DEGraph:
        """The device twin of the current host graph.

        First call (or after ``invalidate_device`` / ``grow``) uploads the
        whole buffers; afterwards only the dirty rows are scattered into the
        cache via a donated jit — the donation means any previously returned
        :class:`DEGraph` is invalidated by this call whenever there were
        pending writes.  Dirty-row counts are bucketed to powers of two so
        repeated waves reuse a handful of compiled entries."""
        if (self._dev_adj is None
                or self._dev_adj.shape != self.adjacency.shape):
            self._drop_cache()         # stale twins must fail loudly
            self._dev_adj = jnp.asarray(self.adjacency)
            self._dev_w = jnp.asarray(self.weights)
            self._dirty = set()
            self._dev_sync_gen = self._gen
        elif self._dirty:
            rows = np.fromiter(self._dirty, dtype=np.int32)
            if rows.size * _FULL_SYNC_FRACTION >= self.capacity:
                self._drop_cache()
                self._dev_adj = jnp.asarray(self.adjacency)
                self._dev_w = jnp.asarray(self.weights)
            else:
                rows.sort()
                width = pow2_bucket(rows.size)
                # idempotent pad: repeat the last dirty row
                rows = np.concatenate(
                    [rows, np.full(width - rows.size, rows[-1], np.int32)])
                self._dev_adj, self._dev_w = _scatter_rows(
                    self._dev_adj, self._dev_w, jnp.asarray(rows),
                    jnp.asarray(self.adjacency[rows]),
                    jnp.asarray(self.weights[rows]))
            self._dirty = set()
            self._dev_sync_gen = self._gen
        return DEGraph(adjacency=self._dev_adj, weights=self._dev_w,
                       n=jnp.asarray(self.n, dtype=jnp.int32))

    # -- mutation --------------------------------------------------------
    def _free_slot(self, v: int) -> int:
        s = self.edge_slot(v, INVALID)
        if s < 0:
            raise RuntimeError(f"vertex {v} already has degree {self.degree}")
        return s

    def add_edge(self, u: int, v: int, w: float) -> None:
        if u == v:
            raise ValueError(f"self loop at {u}")
        if self.has_edge(u, v):
            raise ValueError(f"duplicate edge ({u}, {v})")
        su, sv = self._free_slot(u), self._free_slot(v)
        self.adjacency[u, su] = v
        self.weights[u, su] = w
        self.adjacency[v, sv] = u
        self.weights[v, sv] = w
        self.edges_added += 1
        self.mark_dirty(u, v)

    def remove_edge(self, u: int, v: int) -> float:
        w = None
        for a, b in ((u, v), (v, u)):
            s = self.edge_slot(a, b)
            if s < 0:
                raise KeyError(f"no edge ({a}, {b})")
            w = float(self.weights[a, s])
            self.adjacency[a, s] = INVALID
            self.weights[a, s] = 0.0
        self.edges_removed += 1
        self.mark_dirty(u, v)
        return w

    def replace_edges(self, v_rows: np.ndarray, v_slots: np.ndarray,
                      bs: np.ndarray, ns: np.ndarray, w_vb: np.ndarray,
                      w_vn: np.ndarray) -> np.ndarray:
        """Vectorized Alg. 3 edge swaps: for every pair t, the edge
        (bs[t], ns[t]) becomes (v_rows[t], bs[t]) + (v_rows[t], ns[t]),
        written into ``v_rows[t]``'s row at slots ``v_slots[t]`` and
        ``v_slots[t] + 1``.

        Contract (the device-wave apply in ``core/build.py``): the claimed
        edges are pairwise-distinct, so every write lands in a distinct
        (row, slot); ``v_rows`` are fresh vertices whose target slots are
        empty.  Pairs whose edge is absent (a wave conflict) are skipped —
        the returned bool mask says which pairs were applied."""
        m = len(bs)
        if m == 0:
            return np.zeros(0, dtype=bool)
        idx = np.arange(m)
        rows_b = self.adjacency[bs]
        s1 = np.argmax(rows_b == ns[:, None], axis=1)
        ok = rows_b[idx, s1] == ns
        rows_n = self.adjacency[ns]
        s2 = np.argmax(rows_n == bs[:, None], axis=1)
        ok &= rows_n[idx, s2] == bs
        bs, ns, s1, s2 = bs[ok], ns[ok], s1[ok], s2[ok]
        v_r, v_s = v_rows[ok], v_slots[ok]
        w_b, w_n = w_vb[ok], w_vn[ok]
        self.adjacency[bs, s1] = v_r
        self.weights[bs, s1] = w_b
        self.adjacency[ns, s2] = v_r
        self.weights[ns, s2] = w_n
        self.adjacency[v_r, v_s] = bs
        self.weights[v_r, v_s] = w_b
        self.adjacency[v_r, v_s + 1] = ns
        self.weights[v_r, v_s + 1] = w_n
        # each applied pair removes (b, n) and adds (v, b) + (v, n)
        self.edges_removed += len(bs)
        self.edges_added += 2 * len(bs)
        self.mark_dirty(*bs, *ns, *v_r)
        return ok

    def clear_vertex(self, v: int) -> None:
        """Reset one row to the empty state (deletion compaction)."""
        self.adjacency[v] = INVALID
        self.weights[v] = 0.0
        self.mark_dirty(v)

    def load(self, adjacency: np.ndarray, weights: np.ndarray,
             n: int) -> None:
        """Bulk-load a stored graph (index restore paths)."""
        self.adjacency[: adjacency.shape[0]] = adjacency
        self.weights[: weights.shape[0]] = weights
        self.n = int(n)
        self.invalidate_device()

    def add_vertex(self) -> int:
        if self.n >= self.capacity:
            raise RuntimeError("capacity exhausted; grow() first")
        v = self.n
        self.n += 1
        self._gen += 1                 # n is part of the graph content
        return v

    def grow(self, new_capacity: int) -> None:
        if new_capacity <= self.capacity:
            return
        d = self.degree
        adj = np.full((new_capacity, d), INVALID, dtype=np.int32)
        w = np.zeros((new_capacity, d), dtype=np.float32)
        adj[: self.capacity] = self.adjacency
        w[: self.capacity] = self.weights
        self.adjacency, self.weights = adj, w
        self.invalidate_device()

    # -- snapshot / rollback (Alg. 4 step 6 "revert all changes") --------
    def snapshot(self, vertices: Iterable[int]) -> dict:
        vs = sorted(set(int(v) for v in vertices))
        return {
            "vs": vs,
            "adj": self.adjacency[vs].copy(),
            "w": self.weights[vs].copy(),
        }

    def restore(self, snap: dict) -> None:
        self.adjacency[snap["vs"]] = snap["adj"]
        self.weights[snap["vs"]] = snap["w"]
        self.mark_dirty(*snap["vs"])

    # -- conversion ------------------------------------------------------
    def freeze(self) -> DEGraph:
        """An *independent* device snapshot, safe to hold across later
        mutations (the pre-device-cache contract).  Hot paths that consume
        the graph transiently use :meth:`device_graph` instead — its
        buffers are donated away by the next post-mutation sync."""
        g = self.device_graph()
        return DEGraph(adjacency=jnp.array(g.adjacency),
                       weights=jnp.array(g.weights), n=g.n)

    # -- stats used by Alg. 5 / benchmarks -------------------------------
    def longest_edge_slot(self, v: int) -> int:
        row = self.adjacency[v]
        w = np.where(row != INVALID, self.weights[v], -np.inf)
        return int(np.argmax(w))

    def average_neighbor_distance(self) -> float:
        """Eq. (4) over the whole graph (active vertices only)."""
        if self.n == 0:
            return 0.0
        adj = self.adjacency[: self.n]
        w = self.weights[: self.n]
        valid = adj != INVALID
        denom = np.maximum(valid.sum(axis=1), 1)
        per_vertex = (w * valid).sum(axis=1) / denom
        return float(per_vertex.mean())


def complete_graph(vectors: np.ndarray, degree: int, capacity: int,
                   metric_name: str = "l2") -> GraphBuilder:
    """The smallest possible DEG_d: the complete graph K_{d+1} (Sec. 5.1)."""
    from .distances import get_metric

    metric = get_metric(metric_name)
    k = degree + 1
    if vectors.shape[0] < k:
        raise ValueError(f"need at least {k} vectors for DEG_{degree}")
    b = GraphBuilder(capacity, degree)
    pts = jnp.asarray(vectors[:k])
    dmat = np.asarray(metric.cross(pts, pts))
    for _ in range(k):
        b.add_vertex()
    for i in range(k):
        for j in range(i + 1, k):
            b.add_edge(i, j, float(dmat[i, j]))
    return b
