"""Exact serial-scan baseline (paper uses FAISS serial scan, Sec. 6.3).

Ground truth for every recall computation, and the reference the ``l2_topk``
Pallas kernel is validated against.
"""
from __future__ import annotations

import numpy as np

from ..distances import exact_knn, exact_knn_batched


class BruteForceIndex:
    def __init__(self, vectors: np.ndarray, metric: str = "l2"):
        self.vectors = np.asarray(vectors, dtype=np.float32)
        self.metric = metric

    @property
    def n(self) -> int:
        return self.vectors.shape[0]

    def search(self, queries: np.ndarray, k: int, tile: int = 8192,
               backend: str = "jnp"):
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        if backend == "pallas" and self.metric == "l2":
            from repro.kernels.l2_topk import ops as l2_ops

            d, i = l2_ops.l2_topk(queries, self.vectors, k)
            return np.asarray(d), np.asarray(i)
        if self.n <= tile:
            d, i = exact_knn(queries, self.vectors, k, self.metric)
            return np.asarray(d), np.asarray(i)
        return exact_knn_batched(queries, self.vectors, k, self.metric, tile)
