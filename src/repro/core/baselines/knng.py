"""NN-descent k-NN graph (kGraph baseline, paper Sec. 3 / Dong et al. 2011).

"A neighbor of a neighbor is probably also a neighbor": start from a random
directed K-NN list and iteratively refine it with neighbor-of-neighbor joins.
Vectorized over the whole graph with batched distance evaluations.

The resulting *directed* graph is searched with the same batched range search
(adjacency rows are just followed); the paper's Table 1 / Appendix F points —
no connectivity guarantee, source vertices with zero in-degree, poor
exploration — are reproduced as tests and benchmark observations.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from ..distances import get_metric
from ..graph import DEGraph


def _batch_dists(vectors: np.ndarray, src: np.ndarray, cand: np.ndarray,
                 metric: str, chunk: int = 512) -> np.ndarray:
    """dist(vectors[src[i]], vectors[cand[i, j]]) -> (n, C)."""
    m = get_metric(metric)
    out = np.empty(cand.shape, dtype=np.float32)
    for lo in range(0, src.shape[0], chunk):
        hi = min(lo + chunk, src.shape[0])
        x = jnp.asarray(vectors[src[lo:hi]])[:, None, :]
        y = jnp.asarray(vectors[cand[lo:hi]])
        out[lo:hi] = np.asarray(m.pair(x, y))
    return out


def nn_descent(vectors: np.ndarray, K: int, iterations: int = 8,
               sample: int = 8, metric: str = "l2", seed: int = 0,
               verbose: bool = False):
    """Returns (ids (n, K) int32, dists (n, K) f32) approximate KNN lists."""
    vectors = np.asarray(vectors, dtype=np.float32)
    n = vectors.shape[0]
    rng = np.random.default_rng(seed)
    ids = np.empty((n, K), dtype=np.int32)
    for v in range(n):
        ids[v] = rng.choice(n - 1, size=K, replace=False)
        ids[v][ids[v] >= v] += 1  # exclude self
    src = np.arange(n)
    dists = _batch_dists(vectors, src, ids, metric)
    order = np.argsort(dists, axis=1)
    ids = np.take_along_axis(ids, order, axis=1)
    dists = np.take_along_axis(dists, order, axis=1)

    for it in range(iterations):
        # forward sample: neighbors of (sampled) neighbors
        cols = rng.integers(0, K, size=(n, sample))
        hop1 = np.take_along_axis(ids, cols, axis=1)            # (n, s)
        cand_fwd = ids[hop1.reshape(-1)].reshape(n, sample * K)
        if cand_fwd.shape[1] > sample * sample:
            sub = rng.integers(0, cand_fwd.shape[1], size=(n, sample * sample))
            cand_fwd = np.take_along_axis(cand_fwd, sub, axis=1)
        # reverse sample: who points at me
        rev_src = ids.reshape(-1)
        rev_dst = np.repeat(np.arange(n), K)
        perm = rng.permutation(rev_src.shape[0])
        rev_cand = np.full((n, sample), -1, dtype=np.int64)
        fill = np.zeros(n, dtype=np.int32)
        for s, t in zip(rev_src[perm], rev_dst[perm]):
            if fill[s] < sample:
                rev_cand[s, fill[s]] = t
                fill[s] += 1
        cand = np.concatenate([cand_fwd, np.where(rev_cand < 0, cand_fwd[:, :sample], rev_cand)], axis=1)
        cand = np.where(cand == src[:, None], ids[:, :1], cand)  # no self
        cdist = _batch_dists(vectors, src, cand, metric)
        # merge + dedup per row
        allc = np.concatenate([ids, cand], axis=1)
        alld = np.concatenate([dists, cdist], axis=1)
        o = np.argsort(alld, axis=1, kind="stable")
        allc = np.take_along_axis(allc, o, axis=1)
        alld = np.take_along_axis(alld, o, axis=1)
        updates = 0
        for v in range(n):
            seen: set[int] = set()
            row_i, row_d, w = ids[v], dists[v], 0
            for c, dd in zip(allc[v], alld[v]):
                c = int(c)
                if c in seen or c == v:
                    continue
                seen.add(c)
                if w < K:
                    if row_i[w] != c:
                        updates += 1
                    row_i[w], row_d[w] = c, dd
                    w += 1
                else:
                    break
        if verbose:
            print(f"nn-descent iter {it}: {updates} updates")
        if updates == 0:
            break
    return ids, dists


def build_knng(vectors: np.ndarray, K: int, iterations: int = 8,
               metric: str = "l2", seed: int = 0) -> DEGraph:
    """kGraph-style directed index as a device DEGraph (no weights needed)."""
    ids, dists = nn_descent(vectors, K, iterations, metric=metric, seed=seed)
    return DEGraph(adjacency=jnp.asarray(ids), weights=jnp.asarray(dists),
                   n=jnp.asarray(ids.shape[0], dtype=jnp.int32))
