from .brute_force import BruteForceIndex
from .knng import build_knng, nn_descent
from .random_regular import random_regular_graph, random_regular_index
from .nsw import NSWIndex

__all__ = [
    "BruteForceIndex", "build_knng", "nn_descent",
    "random_regular_graph", "random_regular_index", "NSWIndex",
]
