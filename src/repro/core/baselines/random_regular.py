"""Random even-regular undirected graphs.

The starting point of the paper's Fig. 7-left experiment: Algorithm 5 turns a
*random* even-regular graph into a competitive search graph purely through
continuous edge optimization.  Construction: a union of d/2 independent random
Hamiltonian cycles — each cycle contributes degree 2 to every vertex and is
itself connected, so the union is d-regular and connected by construction.
Duplicate edges between cycles are repaired with 2-opt rotations.
"""
from __future__ import annotations

import numpy as np

from ..build import DEGIndex, DEGParams, np_pair_dist
from ..graph import GraphBuilder


def random_regular_graph(n: int, degree: int, rng: np.random.Generator,
                         vectors: np.ndarray | None = None,
                         metric: str = "l2") -> GraphBuilder:
    if degree % 2 != 0 or degree < 4:
        raise ValueError("degree must be even and >= 4")
    if n < degree + 2:
        raise ValueError("need n >= degree + 2")
    b = GraphBuilder(n, degree)
    for _ in range(n):
        b.add_vertex()
    edges: set[tuple[int, int]] = set()

    def key(u, v):
        return (u, v) if u < v else (v, u)

    for _ in range(degree // 2):
        cyc = None
        for attempt in range(256):
            perm = [int(x) for x in rng.permutation(n)]
            # 2-opt repair: if (perm[i], perm[i+1]) collides with an existing
            # edge, reverse the segment after i+j for a random j — changes two
            # cycle edges, keeps it a single Hamiltonian cycle.
            ok = True
            for _rep in range(8 * n):
                bad = next((i for i in range(n)
                            if key(perm[i], perm[(i + 1) % n]) in edges), None)
                if bad is None:
                    break
                j = int(rng.integers(2, n - 1))
                lo, hi = (bad + 1) % n, (bad + j) % n
                if lo < hi:
                    perm[lo : hi + 1] = perm[lo : hi + 1][::-1]
                else:
                    perm = perm[lo:] + perm[:lo]
                    perm[: j + 1] = perm[: j + 1][::-1]
            else:
                ok = False
            if not ok:
                continue
            cyc = [key(perm[i], perm[(i + 1) % n]) for i in range(n)]
            if len(set(cyc)) == n and not (set(cyc) & edges):
                break
            cyc = None
        if cyc is None:
            raise RuntimeError("could not draw a disjoint Hamiltonian cycle")
        edges.update(cyc)
        for u, v in cyc:
            w = 0.0
            if vectors is not None:
                w = float(np_pair_dist(metric, vectors[u], vectors[v])[0])
            b.add_edge(u, v, w)
    return b


def random_regular_index(vectors: np.ndarray, params: DEGParams,
                         seed: int = 0) -> DEGIndex:
    """A DEGIndex whose graph is random-regular (Fig. 7-left protocol):
    same search / refine machinery, garbage edges."""
    vectors = np.asarray(vectors, dtype=np.float32)
    n = vectors.shape[0]
    rng = np.random.default_rng(seed)
    idx = DEGIndex(vectors.shape[1], params, capacity=n)
    idx.vectors[:n] = vectors
    idx._put_rows(vectors, 0)
    idx.builder = random_regular_graph(n, params.degree, rng, vectors,
                                       params.metric)
    return idx
