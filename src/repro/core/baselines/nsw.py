"""Navigable-Small-World baseline (Malkov et al. 2014, paper Sec. 3).

Incremental, undirected, *non-regular*: each new vertex connects to the best
``f`` search results; no edges are ever removed, so hubs form — exactly the
failure mode DEG's regularity eliminates.  To keep the dense array layout we
cap the per-vertex degree at ``max_degree`` and, when a vertex is full, its
longest edge is displaced (a mild concession; the hub statistics remain and
are reported by benchmarks/graph_stats.py).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from ..build import np_pair_dist
from ..distances import get_metric
from ..graph import DEGraph, INVALID
from ..search import range_search


class NSWIndex:
    def __init__(self, dim: int, f: int = 10, max_degree: int = 48,
                 k_search: int = 40, eps: float = 0.2, metric: str = "l2",
                 capacity: int = 1024):
        self.dim, self.f, self.max_degree = dim, f, max_degree
        self.k_search, self.eps, self.metric = k_search, eps, metric
        self.vectors = np.zeros((capacity, dim), dtype=np.float32)
        self.adjacency = np.full((capacity, max_degree), INVALID, np.int32)
        self.weights = np.zeros((capacity, max_degree), np.float32)
        self.n = 0

    def frozen(self) -> DEGraph:
        return DEGraph(adjacency=jnp.asarray(self.adjacency),
                       weights=jnp.asarray(self.weights),
                       n=jnp.asarray(self.n, jnp.int32))

    def _connect(self, u: int, v: int, w: float) -> None:
        for a, b in ((u, v), (v, u)):
            row = self.adjacency[a]
            if (row == b).any():
                continue
            free = np.nonzero(row == INVALID)[0]
            if free.size:
                s = free[0]
            else:
                s = int(np.argmax(self.weights[a]))     # displace longest
                old = int(row[s])
                if old != INVALID:                       # drop back-edge
                    back = np.nonzero(self.adjacency[old] == a)[0]
                    if back.size:
                        self.adjacency[old, back[0]] = INVALID
                        self.weights[old, back[0]] = 0.0
            self.adjacency[a, s] = b
            self.weights[a, s] = w

    def add(self, points: np.ndarray) -> None:
        points = np.atleast_2d(np.asarray(points, np.float32))
        for p in points:
            v = self.n
            if v >= self.vectors.shape[0]:
                raise RuntimeError("capacity exhausted")
            self.vectors[v] = p
            if v == 0:
                self.n = 1
                continue
            if v <= self.f:
                nbrs = list(range(v))
            else:
                res = range_search(
                    self.frozen(), jnp.asarray(self.vectors),
                    jnp.asarray(p[None]),
                    jnp.zeros((1, 1), jnp.int32),
                    k=self.k_search, eps=self.eps, metric=self.metric)
                nbrs = [int(x) for x in np.asarray(res.ids)[0]
                        if x != INVALID][: self.f]
            ds = np_pair_dist(self.metric, p, self.vectors[nbrs])
            self.n = v + 1
            for u, w in zip(nbrs, ds):
                self._connect(v, int(u), float(w))

    def search(self, queries: np.ndarray, k: int, eps: float = 0.1,
               beam_width=None):
        q = jnp.asarray(np.atleast_2d(np.asarray(queries, np.float32)))
        seeds = jnp.zeros((q.shape[0], 1), jnp.int32)
        return range_search(self.frozen(), jnp.asarray(self.vectors), q,
                            seeds, k=k, eps=eps, beam_width=beam_width,
                            metric=self.metric)
