"""Vertex deletion — the missing half of "fully dynamic" (beyond-paper).

Paper Table 1 lists DEG as the only *fully dynamic* graph but defers the
deletion procedure to future work (§8); Appendix A sketches the requirement:
removal must preserve even regularity and connectivity, without tombstones
(flagged-deleted vertices "still consume memory and must be visited").

Procedure for deleting vertex ``v`` (degree d, d even):

1. remove the d edges (v, u_i) — the d neighbors are now degree d-1;
2. re-pair the d deficient neighbors with a *perfect matching* among
   themselves (d is even), chosen greedily by ascending distance subject to
   no-duplicate-edge validity, with 2-swap repair when greedy jams — each
   neighbor gets exactly +1 edge, restoring regularity;  the matching
   minimizes added average-neighbor-distance (Eq. 4) the same way scheme D
   reasons about insertion;
3. verify connectivity (cheap BFS on the ~d affected vertices' component);
   in the (rare — Appendix B bounds it) case the graph split, retry with a
   randomized matching, else revert and report;
4. compact storage: move the last vertex into slot ``v`` (rewriting its
   neighbors' adjacency entries), shrink ``n`` — the index stays a dense
   ``[0, n)`` array, no holes, no tombstones;
5. optionally run Alg. 5 refinement on the re-paired vertices.

``DEGIndex.remove`` wires this up and keeps the device vector buffer in
sync; the QueryEngine exposes online deletes between flushes.
"""
from __future__ import annotations

from typing import Iterable, Optional, Sequence

import numpy as np

from .build import DEGIndex, np_pair_dist
from .graph import GraphBuilder


def _greedy_matching(cands: list, pairs_needed: int,
                     invalid: set) -> Optional[list]:
    """cands: [(w, a, b)] ascending; returns pairs or None."""
    used: set = set()
    out = []
    for w, a, b in cands:
        if a in used or b in used or (a, b) in invalid:
            continue
        out.append((a, b, w))
        used.add(a)
        used.add(b)
        if len(out) == pairs_needed:
            return out
    return None


def delete_vertex(index: DEGIndex, v: int, *, rng=None,
                  refine_after: int = 0, max_retries: int = 8) -> bool:
    """Delete vertex ``v`` preserving regularity + connectivity.

    Returns True on success.  Raises ValueError for out-of-range ids and
    RuntimeError if the graph is at its minimum size (K_{d+1}).
    """
    b = index.builder
    if b is None or not (0 <= v < b.n):
        raise ValueError(f"no such vertex {v}")
    d = b.degree
    if b.n <= d + 2:
        raise RuntimeError("cannot shrink below the minimal DEG (K_{d+1})")
    rng = rng or np.random.default_rng(v)
    metric = index.params.metric

    nbrs = [int(x) for x in b.neighbors(v)]
    assert len(nbrs) == d, (v, nbrs)
    # 1. remove v's edges (log for rollback)
    removed = [(u, b.remove_edge(v, u)) for u in nbrs]

    # candidate pair weights among the deficient neighbors
    base_cands = []
    invalid = set()
    for i, a in enumerate(nbrs):
        ds = np_pair_dist(metric, index.vectors[a],
                          index.vectors[np.asarray(nbrs[i + 1:])]) \
            if i + 1 < len(nbrs) else []
        for off, bb in enumerate(nbrs[i + 1:]):
            if a == bb or b.has_edge(a, bb):
                invalid.add((a, bb))
                invalid.add((bb, a))
            base_cands.append((float(ds[off]), a, bb))
            base_cands.append((float(ds[off]), bb, a))

    def try_matching(cands) -> Optional[list]:
        m = _greedy_matching(sorted(cands), d // 2, invalid)
        return m

    success = False
    for attempt in range(max_retries):
        if attempt == 0:
            matching = try_matching(base_cands)
        else:                       # randomized retry: jitter the order
            jit = [(w * (1.0 + 0.5 * rng.random()), a, bb)
                   for w, a, bb in base_cands]
            matching = try_matching(jit)
        added = []
        if matching is None:
            # dense fallback (small graphs: neighbors mutually adjacent):
            # pair the leftover deficient vertices via an Alg.3-style edge
            # split — connect (a, c), (bb, e) and remove an existing (c, e).
            matching = _split_matching(index, b, nbrs, invalid, v)
            if matching is None:
                continue
            ok_add = True
            for a, bb, kind, c, e in matching:
                if kind == "pair":
                    b.add_edge(a, bb, float(np_pair_dist(
                        metric, index.vectors[a], index.vectors[bb])[0]))
                    added.append(("pair", a, bb, 0.0))
                else:
                    w_ce = b.remove_edge(c, e)
                    b.add_edge(a, c, float(np_pair_dist(
                        metric, index.vectors[a], index.vectors[c])[0]))
                    b.add_edge(bb, e, float(np_pair_dist(
                        metric, index.vectors[bb], index.vectors[e])[0]))
                    added.append(("split", a, bb, w_ce, c, e))
        else:
            for a, bb, w in matching:
                b.add_edge(a, bb, float(np_pair_dist(
                    metric, index.vectors[a], index.vectors[bb])[0]))
                added.append(("pair", a, bb, 0.0))
        # 3. connectivity check from one affected vertex
        if _connected_among(b, nbrs, exclude=v):
            success = True
            break
        for op in reversed(added):  # revert this attempt, retry
            if op[0] == "pair":
                b.remove_edge(op[1], op[2])
            else:
                _, a, bb, w_ce, c, e = op
                b.remove_edge(a, c)
                b.remove_edge(bb, e)
                b.add_edge(c, e, w_ce)
    if not success:
        for u, w in removed:       # full rollback
            b.add_edge(v, u, w)
        return False

    # 4. compact: move last vertex into slot v
    index._medoid = None     # vector set shrinks even when v == last
    last = b.n - 1
    if v != last:
        last_nbrs = [int(x) for x in b.neighbors(last)]
        last_ws = [b.edge_weight(last, u) for u in last_nbrs]
        for u in last_nbrs:
            b.remove_edge(last, u)
        index.vectors[v] = index.vectors[last]
        index._put_rows(index.vectors[v][None], v)
        for u, w in zip(last_nbrs, last_ws):
            b.add_edge(v, u if u != v else last, w)
    b.clear_vertex(last)           # marks the row dirty for the device sync
    b.n -= 1

    # quarantine ids (scrubber state) track the compaction remap: the
    # deleted vertex leaves the set, and if the moved last vertex was
    # quarantined its damage now lives in slot v
    q = index.quarantine
    if q:
        q.discard(v)
        if last in q:
            q.discard(last)
            if v != last:
                q.add(v)

    if refine_after:
        # repair ride-along: one batched Alg. 5 sweep over the re-paired
        # neighbors (a single prefetch device call via the beam engine)
        from .optimize import refine_sweep

        refine_sweep(index, [u for u in nbrs[: refine_after] if u < b.n],
                     i_opt=index.params.i_opt, k_opt=index.params.k_opt,
                     eps_opt=index.params.eps_opt)
    return True


def _split_matching(index: DEGIndex, b: GraphBuilder, nbrs: Sequence[int],
                    invalid: set, v: int) -> Optional[list]:
    """Fallback matching for dense neighborhoods: pair what greedy can,
    resolve leftover deficient pairs (a, bb) by splitting an existing edge
    (c, e) not incident to the deficient set: add (a, c), (bb, e).  Returns
    [(a, bb, 'pair'|'split', c, e)] or None."""
    metric = index.params.metric
    left = list(nbrs)
    out = []
    # first: valid direct pairs greedily
    while len(left) >= 2:
        a = left[0]
        best = None
        for bb in left[1:]:
            if (a, bb) in invalid or b.has_edge(a, bb):
                continue
            w = float(np_pair_dist(metric, index.vectors[a],
                                   index.vectors[bb])[0])
            if best is None or w < best[0]:
                best = (w, bb)
        if best is not None:
            out.append((a, best[1], "pair", -1, -1))
            left.remove(a)
            left.remove(best[1])
            continue
        # a cannot pair directly with anyone -> split an existing edge
        bb = left[1]
        deficient = set(left) | {v}
        split = None
        for c in range(b.n):
            if c in deficient or b.has_edge(a, c):
                continue
            for e in b.neighbors(c):
                e = int(e)
                if e in deficient or e == c or b.has_edge(bb, e):
                    continue
                cost = (float(np_pair_dist(metric, index.vectors[a],
                                           index.vectors[c])[0])
                        + float(np_pair_dist(metric, index.vectors[bb],
                                             index.vectors[e])[0])
                        - b.edge_weight(c, e))
                if split is None or cost < split[0]:
                    split = (cost, c, e)
            if split is not None and split[0] <= 0:
                break               # good enough; keep scan bounded
        if split is None:
            return None
        out.append((a, bb, "split", split[1], split[2]))
        left.remove(a)
        left.remove(bb)
    return out


def _connected_among(b: GraphBuilder, seeds: Sequence[int],
                     exclude: int, cap: int = 100000) -> bool:
    """BFS from seeds[0]: all other seeds reachable without ``exclude``?"""
    from collections import deque

    target = set(int(s) for s in seeds)
    seen = {int(seeds[0])}
    dq = deque([int(seeds[0])])
    hits = 1
    steps = 0
    while dq and hits < len(target) and steps < cap:
        u = dq.popleft()
        steps += 1
        for w in b.neighbors(u):
            w = int(w)
            if w == exclude or w in seen:
                continue
            seen.add(w)
            if w in target:
                hits += 1
            dq.append(w)
    return hits == len(target)


def delete_vertices(index: DEGIndex, ids: Iterable[int], *,
                    refine_after: int = 0) -> int:
    """Delete several vertices; later ids are remapped as slots compact
    (each deletion moves the last vertex into the freed slot).  Returns the
    number deleted."""
    remaining = sorted(set(int(i) for i in ids), reverse=True)
    done = 0
    for v in remaining:             # descending: compaction-safe
        if delete_vertex(index, v, refine_after=refine_after):
            done += 1
    return done
