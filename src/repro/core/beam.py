"""The device-side beam engine — the shared inner loop of the whole system.

The paper's RangeSearch (Alg. 1) appears in every layer of this repro:
queries (``core/search.py``), incremental-build candidate searches (Alg. 3,
``core/build.py``), delete-repair and continuous edge optimization (Alg. 5,
``core/delete.py`` / ``core/optimize.py``), shard-local search
(``distributed/index.py``) and the serving flush (``serving/engine.py``).
This module is the single implementation all of them drive:

* :class:`BeamState` — a registered-dataclass pytree holding the lock-step
  beam of ``B`` query lanes: ids / dists / checked / excluded, all ``(B, L)``
  with the *sorted invariant* (ascending by ``(dist, stable-rank)``), plus
  per-lane hop and distance-evaluation counters and (optionally) a per-lane
  visited hash set (``core/visited.py``);
* jitted primitives :func:`init` / :func:`expand` / :func:`merge` /
  :func:`extract` — each usable standalone, and composed by
  :func:`beam_search` into one ``lax.while_loop`` program;
* **multi-expansion** (CAGRA-style): ``expand_width=E`` expands the E
  closest unchecked beam entries per lane per hop instead of one, gathering
  and scoring all ``E*d`` neighbors in a single pass — ~E× fewer
  ``while_loop`` trips at higher arithmetic intensity per dispatch.  With
  ``E=1`` the program is bit-identical to the seed engine (pinned by the
  golden fixture);
* the per-hop dedup is either the seed *beam broadcast* (O(L) compares per
  candidate — the E=1 default, exact seed semantics) or the O(probes)
  *visited filter* of ``core/visited.py`` (``visited_size > 0`` — the
  multi-expansion default, which also remembers evicted vertices, so
  ``evals`` can run below the broadcast engine's);
* ``hop_backend="pallas"`` routes the whole hop body — adjacency-row
  gather, visited filter, vector gather, distance, candidate compaction —
  through the fused ``kernels/fused_hop`` Pallas kernel (requires the
  visited filter and an exact float store); the per-hop beam merge
  dispatches to ``kernels/beam_merge`` as before.

``core/search.py::range_search`` is a thin jitted driver over this engine;
see ARCHITECTURE.md ("Multi-expansion beam layering") for how the layers
stack.

Exploration queries (paper Sec. 6.7) are native: seeds may be graph
vertices and ``exclude`` removes vertices from the *result list* (and from
the radius ``r``) while still allowing navigation through them.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant.store import VectorStore, as_store  # noqa: F401  (re-export)

from . import visited as visited_set
from .distances import get_metric
from .graph import DEGraph, INVALID
from .visited import default_size as default_visited_size  # noqa: F401

Array = jax.Array
_INF = jnp.inf


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BeamState:
    """Lock-step beam over B query lanes (sorted invariant along axis 1)."""

    ids: Array        # (B, L) int32, INVALID-padded
    dists: Array      # (B, L) float32, inf-padded
    checked: Array    # (B, L) bool — expanded (or never-expandable) entries
    excluded: Array   # (B, L) bool — in the beam but banned from results
    hops: Array       # (B,) int32 — expanded vertices
    evals: Array      # (B,) int32 — distance evaluations (|C| analogue)
    # (B, V) int32 open-addressing visited set (core/visited.py), or None
    # when the engine runs the seed beam-broadcast dedup instead
    visited: Optional[Array] = None

    @property
    def width(self) -> int:
        return self.ids.shape[1]


def neighbor_distances_jnp(vectors, queries, nbr_ids, metric_name):
    """jnp gather+pair distance path.  ``vectors`` may be a raw (n, m) array
    (exact float32 semantics — the pre-store program verbatim) or a
    :class:`repro.quant.VectorStore` of any codec."""
    return as_store(vectors).neighbor_distances(queries, nbr_ids, metric_name,
                                                backend="jnp")


def _neighbor_distances(vectors, queries, nbr_ids, metric_name, backend):
    return as_store(vectors).neighbor_distances(queries, nbr_ids, metric_name,
                                                backend=backend)


def in_set(ids: Array, excl: Array) -> Array:
    """ids (B, L), excl (B, X) -> bool (B, L) membership (INVALID never
    member)."""
    hit = (ids[:, :, None] == excl[:, None, :]).any(axis=2)
    return hit & (ids != INVALID)


def radius(state: BeamState, k: int) -> Array:
    """k-th best non-excluded distance per lane (inf if fewer than k)."""
    ok = (state.ids != INVALID) & ~state.excluded
    cnt = jnp.cumsum(ok.astype(jnp.int32), axis=1)
    at_k = ok & (cnt == k)
    has_k = at_k.any(axis=1)
    kth = jnp.where(at_k, state.dists, _INF).min(axis=1)
    return jnp.where(has_k, kth, _INF)


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------
def init(vectors: Array | VectorStore, queries: Array, seed_ids: Array,
         exclude: Array, n_valid: Array, *, beam_width: int,
         metric: str, visited_size: int = 0) -> BeamState:
    """Seed the beam: dedup seeds per lane, score them, sort, pad to L.

    ``visited_size > 0`` additionally allocates the per-lane visited hash
    set (that many slots, power of two) and records the seeds in it —
    :func:`expand` then uses it for the per-hop dedup instead of the beam
    broadcast."""
    B = queries.shape[0]
    L = beam_width
    store = as_store(vectors)
    metric_obj = get_metric(metric)

    seed_valid = (seed_ids != INVALID) & (seed_ids < n_valid)
    # dedup seeds within each lane (keep first occurrence)
    first_pos = jnp.argmax(seed_ids[:, :, None] == seed_ids[:, None, :],
                           axis=2)
    seed_valid &= first_pos == jnp.arange(seed_ids.shape[1])[None, :]
    safe_seeds = jnp.where(seed_valid, seed_ids, 0)
    seed_d = metric_obj.pair(queries[:, None, :], store.decode(safe_seeds))
    seed_d = jnp.where(seed_valid, seed_d, _INF)
    seed_ids_m = jnp.where(seed_valid, seed_ids, INVALID)

    pad = L - seed_ids.shape[1]
    ids = jnp.concatenate(
        [seed_ids_m, jnp.full((B, pad), INVALID, jnp.int32)], axis=1)
    dists = jnp.concatenate([seed_d, jnp.full((B, pad), _INF)], axis=1)
    checked = ids == INVALID        # invalid slots never selected
    excl = in_set(ids, exclude)

    vis = None
    if visited_size:
        vis = visited_set.make_table(B, visited_size)
        vis = visited_set.insert(vis, seed_ids_m, seed_valid)

    order = jnp.argsort(dists, axis=1)
    take = functools.partial(jnp.take_along_axis, indices=order, axis=1)
    return BeamState(
        ids=take(ids), dists=take(dists), checked=take(checked),
        excluded=take(excl),
        hops=jnp.zeros((B,), jnp.int32),
        evals=seed_valid.sum(axis=1).astype(jnp.int32),
        visited=vis)


def merge(state: BeamState, cand_ids: Array, cand_dists: Array,
          cand_exc: Array, *, merge_backend: str = "jnp") -> BeamState:
    """Fold (B, d) scored candidates into the beam, keeping the sorted
    invariant.  Newly merged INVALID slots become checked (never
    expandable)."""
    d, ids, chk, exc = _merge_dispatch(
        state.dists, state.ids, state.checked, state.excluded,
        cand_dists, cand_ids, cand_exc, merge_backend)
    chk = jnp.where(ids == INVALID, True, chk)
    return dataclasses.replace(state, ids=ids, dists=d, checked=chk,
                               excluded=exc)


def _merge_dispatch(beam_d, beam_ids, beam_chk, beam_exc,
                    cand_d, cand_ids, cand_exc, merge_backend):
    from repro.kernels.beam_merge import ops as bm_ops

    return bm_ops.beam_merge(beam_d, beam_ids, beam_chk, beam_exc,
                             cand_d, cand_ids, cand_exc,
                             backend=merge_backend)


def _select_unchecked(state: BeamState, expand_width: int):
    """Positions of the E closest unchecked beam entries per lane.

    Returns (positions (B, E) int32, was_unchecked (B, E) bool).  The beam
    is distance-sorted, so "closest unchecked" = "first unchecked"; for
    E=1 this is exactly the seed's ``argmax(~checked)`` selection, and
    E>1 iterates it (E masked argmax passes beat a per-hop argsort of the
    whole beam — selection runs every ``while_loop`` trip)."""
    B, L = state.ids.shape
    open_ = ~state.checked
    pos_list, un_list = [], []
    for _ in range(expand_width):
        p = jnp.argmax(open_, axis=1)
        pos_list.append(p)
        un_list.append(open_.any(axis=1))
        open_ = open_.at[jnp.arange(B), p].set(False)
    return (jnp.stack(pos_list, axis=1),
            jnp.stack(un_list, axis=1))


def _fused_hop_eligible(vectors, metric: str) -> bool:
    """Static: can this hop lower to the fused_hop Pallas kernel?"""
    store = as_store(vectors)
    return store.exact and metric in ("l2", "sqeuclidean")


def expand(state: BeamState, adjacency: Array, n_valid: Array,
           vectors: Array | VectorStore, queries: Array, exclude: Array, *,
           k: int,
           eps: float, metric: str, backend: str = "jnp",
           merge_backend: str = "jnp", expand_width: int = 1,
           hop_backend: str = "jnp",
           hop_budget: Optional[Array] = None) -> BeamState:
    """One hop: expand each lane's ``expand_width`` closest unchecked
    entries (Alg. 1 lines 8-15, generalized to a multi-expansion frontier)
    and merge their scored neighbors into the beam in one pass.

    Dedup of freshly gathered neighbors is the seed beam broadcast when
    ``state.visited is None`` and the O(probes) visited filter otherwise.
    ``hop_backend="pallas"`` fuses gather→filter→gather→distance→compaction
    into ``kernels/fused_hop`` (visited filter + exact float store + l2
    only; anything else statically falls back to the jnp composition, which
    is bit-identical).

    ``hop_budget`` (B,) int32 caps each lane's expansions: a lane whose
    ``hops`` counter has reached its budget stops expanding (its beam is
    then extractable as a best-so-far result — the serving layer's
    deadline early-extract).  ``None`` (the default) is the unbudgeted
    program, bit for bit.  With ``expand_width > 1`` a lane may overshoot
    its budget by up to E-1 expansions (the E selections of one hop are
    committed together)."""
    B, L = state.ids.shape
    E = expand_width
    d = adjacency.shape[1]
    eps1 = jnp.float32(1.0 + eps)
    r = radius(state, k)
    lane = jnp.arange(B)

    cur, sel_unchecked = _select_unchecked(state, E)
    sel_id = jnp.take_along_axis(state.ids, cur, axis=1)
    sel_d = jnp.take_along_axis(state.dists, cur, axis=1)
    active = (sel_unchecked & (sel_d <= (r * eps1)[:, None])
              & (sel_id != INVALID))
    if hop_budget is not None:
        active &= (state.hops < hop_budget)[:, None]

    # scatter-max == OR: marks active selections checked; inactive (or
    # duplicate, on exhausted lanes) selections are no-ops, associatively
    checked = state.checked.at[lane[:, None], cur].max(active)

    use_visited = state.visited is not None
    fused = (hop_backend == "pallas" and use_visited
             and _fused_hop_eligible(vectors, metric))
    if fused:
        from repro.kernels.fused_hop import ops as fh_ops

        cand_ids, cand_d, nbr_out, evals_inc = fh_ops.fused_hop(
            adjacency, as_store(vectors).data,
            jnp.where(active, sel_id, INVALID), queries, r * eps1,
            state.visited, n_valid=n_valid,
            squared=(metric == "sqeuclidean"), backend="pallas")
        cand_exc = in_set(cand_ids, exclude) & (cand_ids != INVALID)
        new_visited = visited_set.insert(state.visited, nbr_out,
                                         nbr_out != INVALID)
    else:
        nbrs = adjacency[jnp.where(active, sel_id, 0)]       # (B, E, d)
        valid = active[:, :, None] & (nbrs != INVALID) & (nbrs < n_valid)
        flat = nbrs.reshape(B, E * d)
        vmask = valid.reshape(B, E * d)
        if use_visited:
            if E > 1:
                # two expanded vertices may share a neighbor: keep the
                # first occurrence among valid ids
                vmask = vmask & visited_set.first_occurrence_mask(flat,
                                                                  vmask)
            ok = vmask & ~visited_set.contains(state.visited, flat)
        elif E > 1:
            # beam-membership dedup + intra-block first occurrence (the
            # shared mask keeps this bit-identical to the fused_hop
            # oracle), both in one pass over the candidate block
            in_beam = (flat[:, :, None] == state.ids[:, None, :]).any(axis=2)
            ok = (vmask & ~in_beam
                  & visited_set.first_occurrence_mask(flat, vmask))
        else:
            ok = vmask & ~(flat[:, :, None]
                           == state.ids[:, None, :]).any(axis=2)  # dedup
        safe = jnp.where(ok, flat, 0)
        nd = _neighbor_distances(vectors, queries, safe, metric, backend)
        nd = jnp.where(ok, nd, _INF)
        keep = ok & (nd <= r[:, None] * eps1)                # Alg. 1 line 12
        cand_ids = jnp.where(keep, flat, INVALID)
        cand_d = jnp.where(keep, nd, _INF)
        cand_exc = in_set(cand_ids, exclude) & keep
        evals_inc = ok.sum(axis=1).astype(jnp.int32)
        new_visited = (visited_set.insert(state.visited, flat, ok)
                       if use_visited else state.visited)

    state = dataclasses.replace(
        state, checked=checked,
        hops=state.hops + active.sum(axis=1).astype(jnp.int32),
        evals=state.evals + evals_inc,
        visited=new_visited)
    return merge(state, cand_ids, cand_d, cand_exc,
                 merge_backend=merge_backend)


def alive(state: BeamState, *, k: int, eps: float,
          hop_budget: Optional[Array] = None) -> Array:
    """(B,) bool: does the lane still have an expandable entry within the
    range radius (Alg. 1 line 7 would NOT yet return)?  A lane whose
    ``hop_budget`` is spent is dead regardless — its beam is the
    best-so-far result."""
    eps1 = jnp.float32(1.0 + eps)
    r = radius(state, k)
    nxt = jnp.argmax(~state.checked, axis=1)
    nxt_d = state.dists[jnp.arange(state.ids.shape[0]), nxt]
    live = (~state.checked.all(axis=1)) & (nxt_d <= r * eps1)
    if hop_budget is not None:
        live &= state.hops < hop_budget
    return live


def extract(state: BeamState, k: int, *, dedup: bool = False
            ) -> tuple[Array, Array]:
    """Top-k non-excluded results: (ids (B, k), dists (B, k)).

    Extraction is a *stable* sort so duplicate distances resolve by beam
    position, matching ``search.exact_rerank`` tie semantics.  ``dedup``
    masks repeated ids (keeping the first occurrence) — the safety net for
    visited-filter searches, where a dropped hash insert can in principle
    let a vertex enter the beam twice."""
    final_d = jnp.where(state.excluded | (state.ids == INVALID), _INF,
                        state.dists)
    if dedup:
        first = visited_set.first_occurrence_mask(state.ids,
                                                  state.ids != INVALID)
        final_d = jnp.where(first, final_d, _INF)
    order = jnp.argsort(final_d, axis=1, stable=True)[:, :k]
    out_ids = jnp.take_along_axis(state.ids, order, axis=1)
    out_d = jnp.take_along_axis(final_d, order, axis=1)
    out_ids = jnp.where(jnp.isinf(out_d), INVALID, out_ids)
    return out_ids, out_d


# ---------------------------------------------------------------------------
# the composed program
# ---------------------------------------------------------------------------
def beam_search(graph: DEGraph, vectors: Array | VectorStore, queries: Array,
                seed_ids: Array, *, k: int, eps: float, beam_width: int,
                max_hops: int, metric: str = "l2",
                exclude: Optional[Array] = None, backend: str = "jnp",
                merge_backend: str = "jnp", expand_width: int = 1,
                visited_size: int = 0,
                hop_backend: str = "jnp",
                hop_budget: Optional[Array] = None) -> BeamState:
    """init -> while(expand) -> final BeamState.  Pure (un-jitted): callers
    embed it in their own jitted programs (``range_search``, the sharded
    search step) so every layer reuses one implementation.

    ``vectors`` may be a raw float array (exact) or a
    :class:`repro.quant.VectorStore` — with a compressed codec the beam
    traverses *approximate* distances; callers that need exact results run
    the two-stage rerank in ``core/search.py`` on top.

    ``expand_width`` (E) widens the per-hop frontier; ``visited_size``
    swaps the beam-broadcast dedup for the visited filter (required for
    ``hop_backend="pallas"``, which fuses the hop into one kernel).  The
    defaults (E=1, no visited, jnp) are the seed program, bit for bit.

    ``hop_budget`` (B,) int32 per-lane expansion caps (serving early
    extract): a budget-exhausted lane stops hopping and its final beam is
    its best-so-far answer.  ``None`` = unbudgeted (the golden program —
    the budget branch is not even traced)."""
    if expand_width < 1:
        raise ValueError(f"expand_width must be >= 1, got {expand_width}")
    expand_width = min(expand_width, beam_width)
    if hop_backend == "pallas" and not visited_size:
        raise ValueError("hop_backend='pallas' (fused hop) requires the "
                         "visited filter: pass visited_size > 0")
    B = queries.shape[0]
    if exclude is None:
        exclude = jnp.full((B, 1), INVALID, dtype=jnp.int32)
    n_valid = graph.n
    adjacency = graph.adjacency

    state0 = init(vectors, queries, seed_ids, exclude, n_valid,
                  beam_width=beam_width, metric=metric,
                  visited_size=visited_size)

    def cond(carry):
        _, it, any_alive = carry
        return any_alive & (it < max_hops)

    def body(carry):
        state, it, _ = carry
        state = expand(state, adjacency, n_valid, vectors, queries, exclude,
                       k=k, eps=eps, metric=metric, backend=backend,
                       merge_backend=merge_backend,
                       expand_width=expand_width, hop_backend=hop_backend,
                       hop_budget=hop_budget)
        return (state, it + 1,
                alive(state, k=k, eps=eps, hop_budget=hop_budget).any())

    state, _, _ = jax.lax.while_loop(
        cond, body, (state0, jnp.int32(0), jnp.asarray(True)))
    return state


# jitted standalone primitives (library surface for out-of-loop callers)
init_jit = jax.jit(init, static_argnames=("beam_width", "metric",
                                          "visited_size"))
merge_jit = jax.jit(merge, static_argnames=("merge_backend",))
expand_jit = jax.jit(
    expand, static_argnames=("k", "metric", "backend", "merge_backend",
                             "expand_width", "hop_backend"))
extract_jit = jax.jit(extract, static_argnames=("k", "dedup"))


def default_beam_width(k: int, degree: int, n_seeds: int,
                       n_exclude: int = 0) -> int:
    """The L heuristic shared by every driver (seed semantics)."""
    L = max(k + degree, 2 * k)
    L = max(L, k, n_seeds)
    if n_exclude:
        L = max(L, k + n_exclude)
    return L


def default_max_hops(beam_width: int) -> int:
    return 4 * beam_width + 64
