"""The device-side beam engine — the shared inner loop of the whole system.

The paper's RangeSearch (Alg. 1) appears in every layer of this repro:
queries (``core/search.py``), incremental-build candidate searches (Alg. 3,
``core/build.py``), delete-repair and continuous edge optimization (Alg. 5,
``core/delete.py`` / ``core/optimize.py``), shard-local search
(``distributed/index.py``) and the serving flush (``serving/engine.py``).
This module is the single implementation all of them drive:

* :class:`BeamState` — a registered-dataclass pytree holding the lock-step
  beam of ``B`` query lanes: ids / dists / checked / excluded, all ``(B, L)``
  with the *sorted invariant* (ascending by ``(dist, stable-rank)``), plus
  per-lane hop and distance-evaluation counters;
* jitted primitives :func:`init` / :func:`expand` / :func:`merge` /
  :func:`extract` — each usable standalone, and composed by
  :func:`beam_search` into one ``lax.while_loop`` program;
* the per-hop beam merge dispatches to ``kernels/beam_merge`` — a fused
  bitonic partial-merge (Pallas kernel + XLA fast path) that replaces the
  seed's full ``(B, L+d)`` argsort and is bit-identical to it.

``core/search.py::range_search`` is a thin jitted driver over this engine;
see ARCHITECTURE.md ("Beam engine layering") for how the layers stack.

Exploration queries (paper Sec. 6.7) are native: seeds may be graph
vertices and ``exclude`` removes vertices from the *result list* (and from
the radius ``r``) while still allowing navigation through them.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant.store import VectorStore, as_store  # noqa: F401  (re-export)

from .distances import get_metric
from .graph import DEGraph, INVALID

Array = jax.Array
_INF = jnp.inf


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BeamState:
    """Lock-step beam over B query lanes (sorted invariant along axis 1)."""

    ids: Array        # (B, L) int32, INVALID-padded
    dists: Array      # (B, L) float32, inf-padded
    checked: Array    # (B, L) bool — expanded (or never-expandable) entries
    excluded: Array   # (B, L) bool — in the beam but banned from results
    hops: Array       # (B,) int32 — expanded vertices
    evals: Array      # (B,) int32 — distance evaluations (|C| analogue)

    @property
    def width(self) -> int:
        return self.ids.shape[1]


def neighbor_distances_jnp(vectors, queries, nbr_ids, metric_name):
    """jnp gather+pair distance path.  ``vectors`` may be a raw (n, m) array
    (exact float32 semantics — the pre-store program verbatim) or a
    :class:`repro.quant.VectorStore` of any codec."""
    return as_store(vectors).neighbor_distances(queries, nbr_ids, metric_name,
                                                backend="jnp")


def _neighbor_distances(vectors, queries, nbr_ids, metric_name, backend):
    return as_store(vectors).neighbor_distances(queries, nbr_ids, metric_name,
                                                backend=backend)


def in_set(ids: Array, excl: Array) -> Array:
    """ids (B, L), excl (B, X) -> bool (B, L) membership (INVALID never
    member)."""
    hit = (ids[:, :, None] == excl[:, None, :]).any(axis=2)
    return hit & (ids != INVALID)


def radius(state: BeamState, k: int) -> Array:
    """k-th best non-excluded distance per lane (inf if fewer than k)."""
    ok = (state.ids != INVALID) & ~state.excluded
    cnt = jnp.cumsum(ok.astype(jnp.int32), axis=1)
    at_k = ok & (cnt == k)
    has_k = at_k.any(axis=1)
    kth = jnp.where(at_k, state.dists, _INF).min(axis=1)
    return jnp.where(has_k, kth, _INF)


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------
def init(vectors: Array | VectorStore, queries: Array, seed_ids: Array,
         exclude: Array, n_valid: Array, *, beam_width: int,
         metric: str) -> BeamState:
    """Seed the beam: dedup seeds per lane, score them, sort, pad to L."""
    B = queries.shape[0]
    L = beam_width
    store = as_store(vectors)
    metric_obj = get_metric(metric)

    seed_valid = (seed_ids != INVALID) & (seed_ids < n_valid)
    # dedup seeds within each lane (keep first occurrence)
    first_pos = jnp.argmax(seed_ids[:, :, None] == seed_ids[:, None, :],
                           axis=2)
    seed_valid &= first_pos == jnp.arange(seed_ids.shape[1])[None, :]
    safe_seeds = jnp.where(seed_valid, seed_ids, 0)
    seed_d = metric_obj.pair(queries[:, None, :], store.decode(safe_seeds))
    seed_d = jnp.where(seed_valid, seed_d, _INF)
    seed_ids_m = jnp.where(seed_valid, seed_ids, INVALID)

    pad = L - seed_ids.shape[1]
    ids = jnp.concatenate(
        [seed_ids_m, jnp.full((B, pad), INVALID, jnp.int32)], axis=1)
    dists = jnp.concatenate([seed_d, jnp.full((B, pad), _INF)], axis=1)
    checked = ids == INVALID        # invalid slots never selected
    excl = in_set(ids, exclude)

    order = jnp.argsort(dists, axis=1)
    take = functools.partial(jnp.take_along_axis, indices=order, axis=1)
    return BeamState(
        ids=take(ids), dists=take(dists), checked=take(checked),
        excluded=take(excl),
        hops=jnp.zeros((B,), jnp.int32),
        evals=seed_valid.sum(axis=1).astype(jnp.int32))


def merge(state: BeamState, cand_ids: Array, cand_dists: Array,
          cand_exc: Array, *, merge_backend: str = "jnp") -> BeamState:
    """Fold (B, d) scored candidates into the beam, keeping the sorted
    invariant.  Newly merged INVALID slots become checked (never
    expandable)."""
    d, ids, chk, exc = _merge_dispatch(
        state.dists, state.ids, state.checked, state.excluded,
        cand_dists, cand_ids, cand_exc, merge_backend)
    chk = jnp.where(ids == INVALID, True, chk)
    return dataclasses.replace(state, ids=ids, dists=d, checked=chk,
                               excluded=exc)


def _merge_dispatch(beam_d, beam_ids, beam_chk, beam_exc,
                    cand_d, cand_ids, cand_exc, merge_backend):
    from repro.kernels.beam_merge import ops as bm_ops

    return bm_ops.beam_merge(beam_d, beam_ids, beam_chk, beam_exc,
                             cand_d, cand_ids, cand_exc,
                             backend=merge_backend)


def expand(state: BeamState, adjacency: Array, n_valid: Array,
           vectors: Array | VectorStore, queries: Array, exclude: Array, *,
           k: int,
           eps: float, metric: str, backend: str = "jnp",
           merge_backend: str = "jnp") -> BeamState:
    """One hop: expand each lane's closest unchecked entry (Alg. 1 lines
    8-15) and merge its scored neighbors into the beam."""
    B = queries.shape[0]
    eps1 = jnp.float32(1.0 + eps)
    r = radius(state, k)
    cur = jnp.argmax(~state.checked, axis=1)            # first unchecked
    lane = jnp.arange(B)
    cur_id = state.ids[lane, cur]
    cur_d = state.dists[lane, cur]
    active = ((~state.checked.all(axis=1)) & (cur_d <= r * eps1)
              & (cur_id != INVALID))

    checked = state.checked.at[lane, cur].set(
        jnp.where(active, True, state.checked[lane, cur]))

    nbrs = adjacency[jnp.where(active, cur_id, 0)]       # (B, d)
    ok = active[:, None] & (nbrs != INVALID) & (nbrs < n_valid)
    ok &= ~(nbrs[:, :, None] == state.ids[:, None, :]).any(axis=2)  # dedup
    safe = jnp.where(ok, nbrs, 0)
    nd = _neighbor_distances(vectors, queries, safe, metric, backend)
    nd = jnp.where(ok, nd, _INF)
    keep = ok & (nd <= r[:, None] * eps1)                # Alg. 1 line 12
    cand_ids = jnp.where(keep, nbrs, INVALID)
    cand_d = jnp.where(keep, nd, _INF)
    cand_exc = in_set(cand_ids, exclude) & keep

    state = dataclasses.replace(
        state, checked=checked,
        hops=state.hops + active.astype(jnp.int32),
        evals=state.evals + ok.sum(axis=1).astype(jnp.int32))
    return merge(state, cand_ids, cand_d, cand_exc,
                 merge_backend=merge_backend)


def alive(state: BeamState, *, k: int, eps: float) -> Array:
    """(B,) bool: does the lane still have an expandable entry within the
    range radius (Alg. 1 line 7 would NOT yet return)?"""
    eps1 = jnp.float32(1.0 + eps)
    r = radius(state, k)
    nxt = jnp.argmax(~state.checked, axis=1)
    nxt_d = state.dists[jnp.arange(state.ids.shape[0]), nxt]
    return (~state.checked.all(axis=1)) & (nxt_d <= r * eps1)


def extract(state: BeamState, k: int) -> tuple[Array, Array]:
    """Top-k non-excluded results: (ids (B, k), dists (B, k))."""
    final_d = jnp.where(state.excluded | (state.ids == INVALID), _INF,
                        state.dists)
    order = jnp.argsort(final_d, axis=1)[:, :k]
    out_ids = jnp.take_along_axis(state.ids, order, axis=1)
    out_d = jnp.take_along_axis(final_d, order, axis=1)
    out_ids = jnp.where(jnp.isinf(out_d), INVALID, out_ids)
    return out_ids, out_d


# ---------------------------------------------------------------------------
# the composed program
# ---------------------------------------------------------------------------
def beam_search(graph: DEGraph, vectors: Array | VectorStore, queries: Array,
                seed_ids: Array, *, k: int, eps: float, beam_width: int,
                max_hops: int, metric: str = "l2",
                exclude: Optional[Array] = None, backend: str = "jnp",
                merge_backend: str = "jnp") -> BeamState:
    """init -> while(expand) -> final BeamState.  Pure (un-jitted): callers
    embed it in their own jitted programs (``range_search``, the sharded
    search step) so every layer reuses one implementation.

    ``vectors`` may be a raw float array (exact) or a
    :class:`repro.quant.VectorStore` — with a compressed codec the beam
    traverses *approximate* distances; callers that need exact results run
    the two-stage rerank in ``core/search.py`` on top."""
    B = queries.shape[0]
    if exclude is None:
        exclude = jnp.full((B, 1), INVALID, dtype=jnp.int32)
    n_valid = graph.n
    adjacency = graph.adjacency

    state0 = init(vectors, queries, seed_ids, exclude, n_valid,
                  beam_width=beam_width, metric=metric)

    def cond(carry):
        _, it, any_alive = carry
        return any_alive & (it < max_hops)

    def body(carry):
        state, it, _ = carry
        state = expand(state, adjacency, n_valid, vectors, queries, exclude,
                       k=k, eps=eps, metric=metric, backend=backend,
                       merge_backend=merge_backend)
        return (state, it + 1, alive(state, k=k, eps=eps).any())

    state, _, _ = jax.lax.while_loop(
        cond, body, (state0, jnp.int32(0), jnp.asarray(True)))
    return state


# jitted standalone primitives (library surface for out-of-loop callers)
init_jit = jax.jit(init, static_argnames=("beam_width", "metric"))
merge_jit = jax.jit(merge, static_argnames=("merge_backend",))
expand_jit = jax.jit(
    expand, static_argnames=("k", "metric", "backend", "merge_backend"))
extract_jit = jax.jit(extract, static_argnames=("k",))


def default_beam_width(k: int, degree: int, n_seeds: int,
                       n_exclude: int = 0) -> int:
    """The L heuristic shared by every driver (seed semantics)."""
    L = max(k + degree, 2 * k)
    L = max(L, k, n_seeds)
    if n_exclude:
        L = max(L, k + n_exclude)
    return L


def default_max_hops(beam_width: int) -> int:
    return 4 * beam_width + 64
