"""Incremental DEG construction (paper Algorithm 3 + Sec. 5.2).

`DEGIndex` is the user-facing object: it owns the host-side mutable graph
(`GraphBuilder`), a host mirror of the vectors, and a device-resident vector
buffer kept in sync with donated in-place row updates.  Construction is
host-orchestrated (graph surgery is inherently sequential, paper Sec. 5.2)
around *jitted, batched* range searches — the compute-heavy part.

Two build modes:

* ``wave_size=1`` — paper-faithful sequential insertion;
* ``wave_size=W`` — beyond-paper bulk build: the candidate searches of W
  pending vertices run as ONE batched device call against the pre-wave graph,
  then the W integrations are applied sequentially on the host.  Later wave
  members cannot select earlier ones as neighbors (their searches predate
  them) — a bounded staleness that trades a small recall delta for ~W× fewer
  device dispatches; quantified in benchmarks/build_cost.py.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .distances import get_metric
from .graph import (DEGraph, GraphBuilder, INVALID, complete_graph,
                    pow2_bucket)
from .mrng import check_mrng_candidate
from .search import SearchResult, medoid_seed, range_search


# ---------------------------------------------------------------------------
# host-side metric helpers (small vectors; avoids device dispatch overhead)
# ---------------------------------------------------------------------------
def np_pair_dist(metric: str, x: np.ndarray, ys: np.ndarray) -> np.ndarray:
    x = np.asarray(x, dtype=np.float32)
    ys = np.asarray(ys, dtype=np.float32)
    if ys.ndim == 1:
        ys = ys[None, :]
    if metric in ("l2", "sqeuclidean"):
        d = ys - x[None, :]
        sq = np.maximum(np.einsum("ij,ij->i", d, d), 0.0)
        return sq if metric == "sqeuclidean" else np.sqrt(sq)
    if metric == "ip":
        return -(ys @ x)
    if metric == "cos":
        xn = x / max(np.linalg.norm(x), 1e-12)
        yn = ys / np.maximum(np.linalg.norm(ys, axis=1, keepdims=True), 1e-12)
        return 1.0 - yn @ xn
    raise ValueError(metric)


@dataclasses.dataclass
class DEGParams:
    """Paper Table 3 hyperparameters."""

    degree: int = 20          # d
    k_ext: int = 40
    eps_ext: float = 0.3
    k_opt: int = 20
    eps_opt: float = 0.001
    i_opt: int = 5
    scheme: str = "C"         # paper default: C for extension
    rng_checks: bool = True   # Algorithm 2 during extension
    # Alg. 3 line 17 — marked *optional* in the paper.  Under our batched-beam
    # search, insert-time optimization of the new vertex's far edges degraded
    # the QPS<->recall frontier, while post-build continuous refinement
    # (Alg. 5 via DEGIndex.refine) improves it (see EXPERIMENTS.md, "Edge
    # optimization").  Default off; the faithful knob remains available.
    optimize_new: bool = False
    metric: str = "l2"
    # Alg. 3 neighbor selection as one wave-batched device program (see
    # core/extend.py); False forces the per-vertex host path (the pre-PR
    # behavior, kept as the fallback and the benchmark baseline).
    device_extend: bool = True
    # selection block size within an insert wave: the device program
    # selects this many vertices per call against the freshly synced graph
    # (dirty-row scatter), bounding selection staleness — and wave
    # conflicts — to the block instead of the whole wave.
    extend_block: int = 16
    # -- query-path engine knobs (every search this index runs — queries,
    # build/optimize/delete candidate searches — inherits these unless the
    # caller overrides them per call).  expand_width=1 + jnp hop is the
    # seed program bit for bit; E>1 widens the per-hop frontier with the
    # broadcast dedup (see benchmarks/search_pareto); the visited filter
    # (core/visited.py) engages via an explicit visited_size or the fused
    # pallas hop, which requires it.
    expand_width: int = 1
    hop_backend: str = "jnp"          # "jnp" | "pallas" (fused hop kernel)
    visited_size: Optional[int] = None  # None = auto (0 unless fused hop)

    def __post_init__(self):
        if self.k_ext < self.degree:
            raise ValueError("k_ext must be >= degree (paper Sec. 5.2)")


def _locked(fn):
    """Serialize a mutator on the index's mutation lock (re-entrant, so
    mutators may call each other and ``publish`` from inside)."""
    @functools.wraps(fn)
    def wrapper(self, *args, **kwargs):
        with self._mutex:
            return fn(self, *args, **kwargs)
    return wrapper


@functools.partial(jax.jit, donate_argnums=(0,))
def _write_rows(buf: jax.Array, rows: jax.Array, start: jax.Array) -> jax.Array:
    return jax.lax.dynamic_update_slice(buf, rows, (start, jnp.int32(0)))


class DEGIndex:
    """A Dynamic Exploration Graph over a growing set of vectors."""

    def __init__(self, dim: int, params: DEGParams | None = None,
                 capacity: int = 1024):
        self.params = params or DEGParams()
        self.dim = dim
        capacity = max(capacity, self.params.degree + 1)
        self.vectors = np.zeros((capacity, dim), dtype=np.float32)
        self._dev_vectors = jnp.zeros((capacity, dim), dtype=jnp.float32)
        self.builder: Optional[GraphBuilder] = None
        self._pending: list[np.ndarray] = []   # points before K_{d+1} exists
        self._rng = np.random.default_rng(0)
        self._medoid: Optional[int] = None     # cached medoid_seed entry
        # compressed views of _dev_vectors, keyed by codec name; invalidated
        # whenever the indexed vector set changes (post-training recipe:
        # re-encode + re-calibrate from the live rows, never retrain)
        self._stores: dict = {}
        # per-stage wall time of _insert_wave (candidate search vs vertex
        # extension) — benchmarks/build_cost.py reports both
        self.build_stats = {"search_s": 0.0, "extend_s": 0.0, "vertices": 0}
        # optional obs.MetricsRegistry: when attached (launch/serve.py,
        # benches), insert waves and refine sweeps record their stage
        # spans/counters into it; None (the default) costs a None check
        self.metrics = None
        # mid-build checkpointing (persist/snapshot.py): every insert wave
        # and refine chunk ticks the counter; when due, the full index state
        # is snapshotted at the wave boundary (the only mid-build points
        # where the graph invariants hold)
        self._ckpt_path = None
        self._ckpt_every = 0
        self._wave_counter = 0
        # mutation WAL (persist/wal.py): when enabled, every mutation unit
        # (bootstrap take / insert wave / remove / refine) is journaled
        # before it is applied, so load(snapshot) + replay(wal) is
        # bit-identical to the uninterrupted build.  _wal_replay holds the
        # record being re-applied (verify, don't re-append); _wal_op_active
        # suppresses checkpoint *saves* inside a journaled op (a snapshot
        # there would advance the cursor past a half-applied record)
        self._wal = None
        self._wal_seq = 0
        self._wal_replay = None
        self._wal_op_active = False
        # live-mutation-under-serving state (core/epoch.py): mutators
        # serialize on _mutex (re-entrant — publish() runs inside remove's
        # lock scope); _epochs holds the refcounted published snapshots
        # once enable_publishing() ran; quarantine is the scrubber's set of
        # damaged vertices, excluded from published seeds/results until
        # repaired and re-audited
        self._mutex = threading.RLock()
        self._epochs = None
        self.quarantine: set[int] = set()
        self._publish_every_chunks = 0
        self._refine_chunk_counter = 0

    # -- sizes -------------------------------------------------------------
    @property
    def n(self) -> int:
        return 0 if self.builder is None else self.builder.n

    @property
    def capacity(self) -> int:
        return self.vectors.shape[0]

    def grow(self, new_capacity: int) -> None:
        if new_capacity <= self.capacity:
            return
        vecs = np.zeros((new_capacity, self.dim), dtype=np.float32)
        vecs[: self.capacity] = self.vectors
        self.vectors = vecs
        self._dev_vectors = jnp.asarray(vecs)
        self._stores = {}
        if self.builder is not None:
            self.builder.grow(new_capacity)

    # -- device sync ---------------------------------------------------------
    def _put_rows(self, rows: np.ndarray, start: int) -> None:
        self._medoid = None                    # vector set changed
        self._stores = {}
        self._dev_vectors = _write_rows(
            self._dev_vectors, jnp.asarray(rows, dtype=jnp.float32),
            jnp.asarray(start, dtype=jnp.int32))

    def medoid(self) -> int:
        """Cached approximate-median entry vertex (paper Sec. 5.4).

        ``medoid_seed`` is a full device reduction over the vector buffer;
        recomputing it per query was pure overhead.  The cache is
        invalidated whenever the indexed vector set changes (insert waves,
        deletion compaction — both funnel through ``_put_rows`` — and
        ``remove``'s slot shrink)."""
        if self._medoid is None or self._medoid >= self.n:
            self._medoid = medoid_seed(self._dev_vectors, self.n)
        return self._medoid

    def frozen(self) -> DEGraph:
        """The device twin consumed transiently by every search call —
        valid until the next graph mutation + sync (donated buffers); use
        ``builder.freeze()`` for a snapshot that must survive mutations."""
        return self.builder.device_graph()

    # -- epoch publication (core/epoch.py; live mutation under serving) ------
    @property
    def mutation_lock(self) -> threading.RLock:
        """Re-entrant lock every mutator holds; external writers (the
        scrubber, background refinement threads) take it around any direct
        builder surgery so a ``publish()`` can never capture mid-surgery
        state."""
        return self._mutex

    @property
    def publishing(self) -> bool:
        return self._epochs is not None

    def enable_publishing(self, publish_now: bool = True,
                          every_chunks: int = 0):
        """Turn on epoch publication: serving flushes will search
        refcounted immutable snapshots (``acquire_view``) instead of the
        live donation-invalidated device cache, making the index safely
        mutable while an async engine is live.  ``every_chunks > 0``
        additionally republishes every that-many refine chunks so long
        sweeps surface improvements mid-run.  Returns the epoch manager."""
        from .epoch import EpochManager

        with self._mutex:
            if self._epochs is None:
                self._epochs = EpochManager(self)
            self._publish_every_chunks = int(every_chunks)
            if publish_now and self.builder is not None:
                self.publish()
        return self._epochs

    def publish(self) -> int:
        """Atomically publish the current graph + vector state as a new
        epoch (at a mutation-batch boundary — the caller guarantees the
        Table-1 window, i.e. not mid-surgery).  Journals an
        ``epoch_publish`` record when a WAL is attached and the publish is
        not nested inside a journaled op, so ``recover()`` lands exactly on
        the last published epoch.  Returns the new epoch number."""
        from repro.obs.metrics import EPOCH_GAUGE, EPOCH_PUBLISH_TOTAL
        from repro.resilience import faults as _faults

        from .epoch import PublishedEpoch

        if self._epochs is None:
            raise RuntimeError("enable_publishing() first")
        if self.builder is None:
            raise RuntimeError("nothing to publish: index is empty")
        with self._mutex:
            e = self._epochs.next_epoch
            gen = self.builder.generation
            quar = tuple(sorted(q for q in self.quarantine if q < self.n))
            # mid-op publishes (refine-chunk ticks) are serving-only: the
            # enclosing journaled record already replays the mutations, and
            # a nested record would break the seq/verify protocol
            if not self._wal_op_active and self._wal_replay is None:
                self._wal_record("epoch_publish",
                                 {"epoch": int(e), "n": int(self.n),
                                  "gen": int(gen),
                                  "quarantine": [int(q) for q in quar]}, {})
            ep = PublishedEpoch(
                epoch=e, graph=self.builder.freeze(),
                vectors=jnp.array(self._dev_vectors), n=self.n,
                medoid_id=self._publish_medoid(quar),
                metric=self.params.metric, params=self.params,
                quarantine=quar, builder_gen=gen)
            _faults.fire("publish.swap", epoch=e, n=self.n)
            self._epochs.publish(ep)
        if self.metrics is not None:
            self.metrics.gauge(EPOCH_GAUGE).set(e)
            self.metrics.counter(EPOCH_PUBLISH_TOTAL).inc()
        return e

    def _publish_medoid(self, quarantine) -> int:
        """The entry vertex a published epoch seeds from: the cached medoid
        unless it is quarantined, in which case the nearest-to-centroid
        healthy vertex."""
        m = self.medoid()
        bad = set(quarantine)
        if m not in bad:
            return m
        vecs = self.vectors[: self.n]
        dist = np.linalg.norm(vecs - vecs.mean(axis=0), axis=1)
        dist[list(bad)] = np.inf
        return int(np.argmin(dist))

    def acquire_view(self):
        """The view a serving flush searches.  With publishing enabled:
        the current epoch, refcounted — the caller MUST pass it back to
        :meth:`release_view` once results are on host.  Without publishing
        (the historical single-writer mode) the index itself is returned
        and release is a no-op."""
        if self._epochs is not None:
            return self._epochs.acquire()
        return self

    def release_view(self, view) -> None:
        if self._epochs is not None and view is not self and view is not None:
            self._epochs.release(view)

    def _publish_tick(self) -> None:
        """Refine-chunk boundary hook (core/optimize.py): republish every
        ``every_chunks`` chunks when configured via
        ``enable_publishing(every_chunks=...)``."""
        if self._epochs is None or self._publish_every_chunks <= 0:
            return
        self._refine_chunk_counter += 1
        if self._refine_chunk_counter % self._publish_every_chunks == 0:
            self.publish()

    # -- insertion -----------------------------------------------------------
    @_locked
    def add(self, points: np.ndarray, wave_size: int = 1) -> None:
        """Insert points (Alg. 3). ``wave_size>1`` enables bulk build."""
        points = np.asarray(points, dtype=np.float32)
        if points.ndim == 1:
            points = points[None]
        if self.n + len(self._pending) + points.shape[0] > self.capacity:
            self.grow(max(2 * self.capacity,
                          self.n + len(self._pending) + points.shape[0]))
        d = self.params.degree
        i = 0
        # bootstrap: K_{d+1} complete graph (Sec. 5.1)
        if self.builder is None:
            need = d + 1 - len(self._pending)
            take = min(need, points.shape[0])
            if take:
                self._wal_record("add", {"wave_size": int(wave_size)},
                                 {"points": points[:take]})
            self._pending.extend(points[:take])
            i = take
            if len(self._pending) == d + 1:
                init = np.stack(self._pending)
                self.vectors[: d + 1] = init
                self._put_rows(init, 0)
                self.builder = complete_graph(
                    init, d, self.capacity, self.params.metric)
                self._pending = []
            if i >= points.shape[0]:
                return
        while i < points.shape[0]:
            w = min(wave_size, points.shape[0] - i)
            # one WAL record per wave (not per add() call): the record is
            # durable before the wave mutates anything, and the
            # end-of-wave checkpoint sees a cursor that exactly covers
            # the applied waves
            self._wal_record("add", {"wave_size": int(w)},
                             {"points": points[i : i + w]})
            self._insert_wave(points[i : i + w])
            i += w

    def _insert_wave(self, pts: np.ndarray) -> None:
        from repro.obs import clock

        W = pts.shape[0]
        start = self.builder.n
        self.vectors[start : start + W] = pts
        self._put_rows(pts, start)
        # one batched candidate search for the whole wave (pre-wave graph),
        # through the same engine program as every other consumer
        t0 = clock.now()
        seeds = np.full((W, 1), self._entry_vertex(), dtype=np.int32)
        res = self.search_batch(pts, seeds, k=self.params.k_ext,
                                eps=self.params.eps_ext)
        ids = np.asarray(res.ids)
        dists = np.asarray(res.dists)
        t1 = clock.now()
        use_device = self.params.device_extend
        block = max(int(self.params.extend_block), 1) if use_device else W
        for j0 in range(0, W, block):
            j1 = min(j0 + block, W)
            vs = [self.builder.add_vertex() for _ in range(j0, j1)]
            assert vs[0] == start + j0
            if use_device:
                # Alg. 3 selection for a block of vertices in ONE device
                # program against the freshly synced graph (the dirty-row
                # scatter in device_graph picks up the previous block's
                # edge swaps), then ONE vectorized application of every
                # selection that survived intra-block conflicts
                # (first-lane-wins, matching the host application order).
                from .extend import extend_wave

                sel_ids, sel_d, ok = extend_wave(
                    self, pts[j0:j1], ids[j0:j1], dists[j0:j1], start + j0)
                self._apply_extension_block(start + j0, sel_ids, sel_d, ok)
            for j in range(j0, j1):
                v = start + j
                # warm start from the LIVE row: a host completion of an
                # earlier lane may have stolen (or added) edges of this
                # vertex since the bulk apply
                live = self.builder.neighbors(v)
                if len(live) == self.params.degree:
                    new_edges = [int(x) for x in live]
                else:
                    new_edges = self._extend_vertex(
                        v, pts[j], ids[j], dists[j],
                        [int(x) for x in live],
                        [float(x) for x in
                         self.builder.neighbor_weights(v)])
                self._post_insert(v, new_edges, ids[j])
        t2 = clock.now()
        self.build_stats["search_s"] += t1 - t0
        self.build_stats["extend_s"] += t2 - t1
        self.build_stats["vertices"] += W
        if self.metrics is not None:
            # wave-stage spans: same timestamps build_stats accumulates,
            # but as histograms (per-wave distribution, not just totals)
            self.metrics.histogram("build_wave_search_ms").observe(
                (t1 - t0) * 1e3)
            self.metrics.histogram("build_wave_extend_ms").observe(
                (t2 - t1) * 1e3)
            self.metrics.counter("build_vertices_total").inc(W)
        self._checkpoint_tick()

    def _post_insert(self, v: int, new_edges, cand_ids) -> None:
        if not self.params.optimize_new:
            return
        from .optimize import optimize_edge

        in_s = set(int(x) for x in cand_ids if x != INVALID)
        for u in new_edges:
            if u not in in_s and self.builder.has_edge(v, u):
                # Alg. 3 line 17: replace the far neighbors of the new
                # vertex.  Alg. 4's search finds a new neighbor for its
                # *second* argument, so the new vertex goes second
                # (the paper's prose reading; measured better than the
                # literal (v, u) order — see EXPERIMENTS.md).
                optimize_edge(self, u, v,
                              i_opt=self.params.i_opt,
                              k_opt=self.params.k_opt,
                              eps_opt=self.params.eps_opt)

    def _entry_vertex(self) -> int:
        return int(self._rng.integers(0, max(self.builder.n, 1)))

    def _apply_extension_block(self, start_v: int, sel_ids: np.ndarray,
                               sel_d: np.ndarray, ok: np.ndarray) -> None:
        """Apply a block of device-selected neighborhoods in one vectorized
        pass of Alg. 3 edge swaps.

        An edge may be surrendered by several lanes of the block (they all
        selected against the same snapshot); the first lane wins — exactly
        the host application order — via a lane-major first-occurrence
        dedup, and ``GraphBuilder.replace_edges`` skips anything else that
        is stale.  Lanes left short of ``degree`` edges are completed
        through the host path by the caller (off the live rows)."""
        b = self.builder
        Wb, D = sel_ids.shape
        P = D // 2
        v_arr = start_v + np.arange(Wb)
        lane_ok = np.asarray(ok, bool).copy()
        # structural sanity (the device program guarantees these; cheap)
        lane_ok &= ((sel_ids >= 0).all(axis=1)
                    & (sel_ids < v_arr[:, None]).all(axis=1))
        srt = np.sort(sel_ids, axis=1)
        lane_ok &= (srt[:, 1:] != srt[:, :-1]).all(axis=1)
        bs, ns = sel_ids[:, 0::2], sel_ids[:, 1::2]          # (Wb, P)
        lo = np.minimum(bs, ns).astype(np.int64)
        hi = np.maximum(bs, ns).astype(np.int64)
        key = lo * b.capacity + hi
        # failed lanes claim nothing: give them unique sentinel keys
        sentinel = -1 - (np.arange(Wb, dtype=np.int64)[:, None] * P
                         + np.arange(P, dtype=np.int64)[None, :])
        key = np.where(lane_ok[:, None], key, sentinel)
        _, first = np.unique(key.reshape(-1), return_index=True)
        keep = np.zeros(key.size, dtype=bool)
        keep[first] = True
        keep = keep.reshape(Wb, P) & lane_ok[:, None]
        k = keep.reshape(-1)
        # v-row slots stay at the pair's original position (2t, 2t+1);
        # dropped pairs leave INVALID holes the host completion refills
        t_idx = np.broadcast_to(np.arange(P), (Wb, P))
        b.replace_edges(
            np.broadcast_to(v_arr[:, None], (Wb, P)).reshape(-1)[k],
            (2 * t_idx).reshape(-1)[k].astype(np.int64),
            bs.reshape(-1)[k], ns.reshape(-1)[k],
            sel_d[:, 0::2].reshape(-1)[k], sel_d[:, 1::2].reshape(-1)[k])

    # -- Alg. 3 core: select d/2 (b, n) pairs -------------------------------
    def _extend_vertex(self, v: int, vec: np.ndarray, cand_ids: np.ndarray,
                       cand_dists: np.ndarray,
                       U0: Optional[list[int]] = None,
                       U0_d: Optional[list[float]] = None) -> list[int]:
        """Host Alg. 3 selection (the pre-device reference path).  ``U0`` /
        ``U0_d`` optionally seed the selected set with already-applied
        pairs (device-wave completion after conflicts)."""
        b = self.builder
        d = b.degree
        metric = self.params.metric
        cands: list[tuple[int, float]] = [
            (int(c), float(x)) for c, x in zip(cand_ids, cand_dists)
            if c != INVALID and c < v
        ]
        U: list[int] = list(U0 or [])
        U_d: list[float] = list(U0_d or [])
        n_pre = len(U)            # warm-start edges already in the graph

        def select_n(bb: int, b_dist: float) -> Optional[tuple[int, float]]:
            nbrs = [int(x) for x in b.neighbors(bb) if int(x) not in U]
            if not nbrs:
                return None
            ws = np.array([b.edge_weight(bb, x) for x in nbrs])
            scheme = self.params.scheme
            if scheme == "C":
                j = int(np.argmax(ws))
            elif scheme == "B":
                j = int(np.argmin(ws))
            else:
                nd = np_pair_dist(metric, vec, self.vectors[nbrs])
                if scheme == "A":
                    j = int(np.argmin(nd))
                elif scheme == "D":
                    j = int(np.argmin(nd - ws))
                else:
                    raise ValueError(self.params.scheme)
            n_sel = nbrs[j]
            n_dist = float(np_pair_dist(metric, vec, self.vectors[n_sel])[0])
            return n_sel, n_dist

        skip_rng = not self.params.rng_checks
        exhausted_fallbacks = 0
        while len(U) < d:
            progressed = False
            for bb, bd in cands:
                if len(U) >= d:
                    break
                if bb in U:
                    continue
                if not skip_rng and not check_mrng_candidate(b, bb, bd, U, U_d):
                    continue
                sel = select_n(bb, bd)
                if sel is None:
                    continue
                n_sel, n_dist = sel
                b.remove_edge(bb, n_sel)
                U.extend((bb, n_sel))
                U_d.extend((bd, n_dist))
                progressed = True
            if len(U) >= d:
                break
            if not skip_rng:
                skip_rng = True      # phase 2 (Alg. 3 line 14)
                continue
            if not progressed:
                # candidate list exhausted — widen with exact nearest actives
                exhausted_fallbacks += 1
                if exhausted_fallbacks > 3:
                    raise RuntimeError(
                        f"cannot complete neighborhood for vertex {v}")
                cands = self._exact_candidates(vec, set(U), v)
        for u, w in zip(U[n_pre:], U_d[n_pre:]):
            b.add_edge(v, u, w)
        return U

    def _exact_candidates(self, vec, exclude, v):
        """Widened pool for an exhausted extension: every vertex below the
        one being inserted — same-block vertices above ``v`` are added but
        not yet extended, so ``builder.n`` is not the right bound."""
        ds = np_pair_dist(self.params.metric, vec, self.vectors[:v])
        order = np.argsort(ds)
        return [(int(i), float(ds[i])) for i in order if int(i) not in exclude]

    # -- deletion (beyond-paper: completes "fully dynamic", Table 1) --------
    @_locked
    def remove(self, ids, refine_after: int = 0) -> int:
        """Delete vertices preserving regularity/connectivity (no
        tombstones); see core/delete.py. Returns the number deleted.
        NOTE: deletion compacts slots — the last vertex moves into the freed
        slot, so external id maps must be updated via the return protocol of
        delete_vertices."""
        from .delete import delete_vertices

        id_list = [int(v) for v in
                   (ids if hasattr(ids, "__iter__") else [ids])]
        self._wal_record("remove", {"refine_after": int(refine_after)},
                         {"ids": np.asarray(id_list, np.int64)})
        self._medoid = None
        self._stores = {}
        self._wal_op_active = True
        try:
            return delete_vertices(self, id_list,
                                   refine_after=refine_after)
        finally:
            self._wal_op_active = False

    # -- continuous refinement (Alg. 5 driver) -------------------------------
    @_locked
    def refine(self, iterations: int, seed: Optional[int] = None) -> int:
        """Continuous edge optimization (Alg. 5) over ``iterations`` random
        vertices, via the *batched* candidate-search path: each chunk of
        vertices prefetches the first Alg.-4 search of every edge task in
        ONE device call (optimize.refine_sweep), instead of a per-edge
        ``_search_from`` round-trip.  Host-side graph surgery is unchanged.
        Returns the number of improved edges."""
        from .optimize import refine_sweep

        if self.builder is None or self.builder.n <= self.builder.degree + 1:
            return 0
        journaled = self._wal is not None or self._wal_replay is not None
        drew = seed is None
        if drew and journaled:
            # a replayable run must not depend on OS entropy: resolve the
            # seed from the persisted build stream, so replay (which
            # restores the stream from the snapshot) re-draws it exactly
            seed = int(self._rng.integers(0, 2**31 - 1))
        self._wal_record("refine",
                         {"iterations": int(iterations),
                          "seed": None if seed is None else int(seed),
                          "drew": drew}, {})
        rng = np.random.default_rng(seed)
        vertices = rng.integers(0, self.builder.n, size=int(iterations))
        self._wal_op_active = journaled
        try:
            return refine_sweep(
                self, vertices,
                i_opt=self.params.i_opt, k_opt=self.params.k_opt,
                eps_opt=self.params.eps_opt)
        finally:
            self._wal_op_active = False

    # -- quantized store views ----------------------------------------------
    def store_for(self, codec: str):
        """The :class:`repro.quant.VectorStore` view the beam traverses
        under ``codec`` — encoded once per (codec, vector-set version) and
        cached until the indexed vectors change."""
        from repro.quant import make_store

        if codec not in self._stores:
            self._stores[codec] = make_store(self._dev_vectors, codec,
                                             n=self.n)
        return self._stores[codec]

    def memory_stats(self) -> dict:
        """Vector-store footprint of the *hot traversal path* per codec
        (live rows only).  The exact float32 copy used by two-stage rerank
        is reported separately — it is touched ``rerank_k`` rows per query,
        not per hop, so it can live off the accelerator."""
        from repro.quant import codec as qc

        n, m = self.n, self.dim
        exact = qc.store_bytes("float32", n, m)
        out = {"n": n, "dim": m, "exact_bytes": exact}
        for name in qc.CODECS:
            b = qc.store_bytes(name, n, m)
            out[f"{name}_bytes"] = b
            out[f"{name}_ratio"] = exact / b if b else 0.0
        return out

    # -- persistence (persist/snapshot.py owns the format) -------------------
    def save(self, path) -> None:
        """Snapshot the complete index state (graph, vectors, materialized
        quant stores, params, RNG/build counters, medoid cache) to one
        versioned npz.  ``DEGIndex.load(path)`` restores a search-identical,
        immediately mutable index."""
        from repro.persist import save_index

        save_index(self, path)

    @classmethod
    def load(cls, path, params: "DEGParams | None" = None,
             capacity: Optional[int] = None) -> "DEGIndex":
        """Restore an index saved by :meth:`save`.  The device caches
        (graph adjacency, vector buffer, quant stores) are rebuilt lazily
        from the restored host state — see persist/snapshot.py."""
        from repro.persist import load_index

        return load_index(path, params=params, capacity=capacity)

    def enable_wal(self, path, sync: bool = True) -> None:
        """Journal every future mutation unit to ``path`` (append-only,
        CRC-framed — persist/wal.py) before applying it.  Recovery is
        ``persist.wal.recover(snapshot, wal)``: load the snapshot, replay
        the records past its cursor, bit-identical to the uninterrupted
        build.  NOTE: with the WAL enabled, ``refine(seed=None)`` resolves
        its seed from the persisted build RNG stream (a replayable run
        cannot depend on OS entropy)."""
        from repro.persist.wal import WALWriter

        self._wal = WALWriter(path, sync=sync)

    def _wal_record(self, op: str, meta: dict, arrays: dict) -> None:
        """Journal one mutation unit — or, during replay, verify the op
        against the record being re-applied instead of re-appending it.
        No-op when the WAL is disabled (the sequence counter only
        advances for journaled ops, keeping snapshot cursors aligned)."""
        rec = self._wal_replay
        if rec is not None:
            from repro.persist.wal import WALError

            if rec.op != op:
                raise WALError(
                    f"replay mismatch at seq {rec.seq}: journal says "
                    f"{rec.op!r}, index replayed {op!r}")
            if op == "refine" and rec.meta.get("seed") != meta.get("seed"):
                raise WALError(
                    f"replay mismatch at seq {rec.seq}: refine seed "
                    f"{meta.get('seed')} != journaled "
                    f"{rec.meta.get('seed')} — RNG stream diverged "
                    "(snapshot and WAL don't belong together?)")
            self._wal_seq += 1
            return
        if self._wal is not None:
            self._wal.append(self._wal_seq, op, meta, arrays)
            self._wal_seq += 1

    def enable_checkpoints(self, path, every_waves: int = 1) -> None:
        """Snapshot the full index to ``path`` every ``every_waves``
        insert waves / refine chunks (at wave boundaries, where the graph
        invariants hold).  ``path`` may contain ``{waves}`` / ``{n}``
        placeholders to keep a checkpoint series instead of overwriting.
        ``every_waves=0`` disables."""
        try:
            str(path).format(waves=0, n=0)   # fail at config time, not
        except (KeyError, IndexError) as e:  # waves deep into the build
            raise ValueError(
                f"bad checkpoint path template {path!r}: only {{waves}} and "
                f"{{n}} placeholders are supported ({e!r})")
        self._ckpt_path = path
        self._ckpt_every = int(every_waves)

    def _checkpoint_tick(self) -> None:
        self._wave_counter += 1
        if (self._ckpt_path is not None and self._ckpt_every > 0
                and self._wave_counter % self._ckpt_every == 0
                # inside a journaled remove/refine the WAL cursor already
                # covers the op but the graph is mid-surgery: a snapshot
                # here could not be continued by replay.  Waves are safe
                # (one record per wave).  Replay itself never writes.
                and not self._wal_op_active and self._wal_replay is None):
            self.save(str(self._ckpt_path).format(
                waves=self._wave_counter, n=self.n))

    # -- queries --------------------------------------------------------------
    def search_batch(self, queries: np.ndarray,
                     seed_ids: Optional[np.ndarray] = None,
                     exclude: Optional[np.ndarray] = None, *, k: int,
                     eps: float = 0.1, beam_width: Optional[int] = None,
                     backend: str = "jnp",
                     quantized: Optional[str] = None,
                     rerank_k: Optional[int] = None,
                     expand_width: Optional[int] = None,
                     visited_size: Optional[int] = None,
                     hop_backend: Optional[str] = None,
                     hop_budget: Optional[np.ndarray] = None) -> SearchResult:
        """The one device entry point every query path funnels through.

        ``seed_ids`` (B, S) / ``exclude`` (B, X) go straight into the beam
        engine; plain searches, exploration sessions and the serving
        flush all share this jitted program (one cache entry per shape
        family instead of one per calling layer).

        ``quantized`` selects the store codec the beam traverses ("fp16" |
        "sq8" | "pq"; None/"float32" = the exact path, bit-identical to the
        pre-quantization engine).  With a compressed codec the search is
        two-stage: the beam runs over compressed distances, then the best
        ``rerank_k`` candidates (default ``4 * k``) are re-scored exactly
        against the float store and the exact top-k is returned.

        ``expand_width`` / ``visited_size`` / ``hop_backend`` default to
        the index's ``DEGParams`` engine knobs (multi-expansion config);
        pass explicit values to override per call.

        ``hop_budget`` (B,) int32 per-lane expansion caps (serving
        deadline early-extract; a traced operand, so all budget values
        share one compiled program per shape family).
        """
        E = self.params.expand_width if expand_width is None else expand_width
        hb = self.params.hop_backend if hop_backend is None else hop_backend
        vs = self.params.visited_size if visited_size is None else visited_size
        q = jnp.asarray(np.atleast_2d(np.asarray(queries, np.float32)))
        if seed_ids is None:
            seeds = jnp.full((q.shape[0], 1), self.medoid(), dtype=jnp.int32)
        else:
            seeds = jnp.asarray(np.asarray(seed_ids, np.int32))
            if seeds.ndim == 1:
                seeds = seeds[:, None]
        excl = None if exclude is None else jnp.asarray(
            np.asarray(exclude, np.int32))
        hbud = None if hop_budget is None else jnp.asarray(
            np.asarray(hop_budget, np.int32))
        if quantized in (None, "float32"):
            return range_search(self.frozen(), self._dev_vectors, q, seeds,
                                k=k, eps=eps, beam_width=beam_width,
                                metric=self.params.metric, exclude=excl,
                                backend=backend, expand_width=E,
                                visited_size=vs, hop_backend=hb,
                                hop_budget=hbud)
        store = self.store_for(quantized)
        rk = int(rerank_k) if rerank_k else 4 * k
        return range_search(self.frozen(), store, q, seeds, k=k, eps=eps,
                            beam_width=beam_width,
                            metric=self.params.metric, exclude=excl,
                            backend=backend, rerank_k=max(rk, k),
                            exact_vectors=self._dev_vectors, expand_width=E,
                            visited_size=vs, hop_backend=hb,
                            hop_budget=hbud)

    def search(self, queries: np.ndarray, k: int, eps: float = 0.1,
               beam_width: Optional[int] = None, seed: Optional[int] = None,
               backend: str = "jnp", quantized: Optional[str] = None,
               rerank_k: Optional[int] = None,
               expand_width: Optional[int] = None,
               visited_size: Optional[int] = None,
               hop_backend: Optional[str] = None) -> SearchResult:
        if seed is None:
            seed = self.medoid()
        q = np.atleast_2d(np.asarray(queries, np.float32))
        seeds = np.full((q.shape[0], 1), seed, dtype=np.int32)
        return self.search_batch(q, seeds, k=k, eps=eps,
                                 beam_width=beam_width, backend=backend,
                                 quantized=quantized, rerank_k=rerank_k,
                                 expand_width=expand_width,
                                 visited_size=visited_size,
                                 hop_backend=hop_backend)

    def explore(self, seed_vertices: Sequence[int], k: int, eps: float = 0.1,
                exclude: Optional[np.ndarray] = None,
                beam_width: Optional[int] = None) -> SearchResult:
        """Exploration queries (paper Sec. 6.7): seed == query vertex; the
        seed (and optionally already-seen vertices) are excluded from results."""
        sv = np.asarray(seed_vertices, dtype=np.int32).reshape(-1)
        if exclude is None:
            excl = sv[:, None]
        else:
            excl = np.concatenate([sv[:, None], np.asarray(exclude, np.int32)],
                                  axis=1)
        return self.search_batch(self.vectors[sv], sv[:, None], excl,
                                 k=k, eps=eps, beam_width=beam_width)

    # -- internal search used by optimize.py ----------------------------------
    def _search_from(self, query_vec: np.ndarray, seed_ids: Sequence[int],
                     k: int, eps: float) -> tuple[np.ndarray, np.ndarray]:
        s = np.full((1, 2), INVALID, dtype=np.int32)
        for j, sid in enumerate(list(seed_ids)[:2]):
            s[0, j] = sid
        res = self.search_batch(
            np.asarray(query_vec, np.float32)[None, :], s, k=k, eps=eps)
        return np.asarray(res.ids)[0], np.asarray(res.dists)[0]

    def _search_from_batch(self, query_vecs: np.ndarray,
                           seed_ids: np.ndarray, k: int, eps: float
                           ) -> tuple[np.ndarray, np.ndarray]:
        """Batched sibling of ``_search_from``: (B, m) queries, (B, S)
        seeds -> host (B, k) ids/dists.  Lanes are padded to a power of two
        so the repeated Alg.-5 sweeps reuse a handful of jit entries."""
        B = query_vecs.shape[0]
        Bp = pow2_bucket(B, floor=8)
        q = np.zeros((Bp, self.dim), np.float32)
        q[:B] = query_vecs
        s = np.full((Bp, seed_ids.shape[1]), INVALID, np.int32)
        s[:B] = seed_ids
        res = self.search_batch(q, s, k=k, eps=eps)
        return np.asarray(res.ids)[:B], np.asarray(res.dists)[:B]


def build_deg(vectors: np.ndarray, params: DEGParams | None = None,
              wave_size: int = 1, refine_iterations: int = 0,
              capacity: Optional[int] = None) -> DEGIndex:
    """One-shot construction of a DEG over ``vectors``."""
    vectors = np.asarray(vectors, dtype=np.float32)
    idx = DEGIndex(vectors.shape[1], params,
                   capacity=capacity or vectors.shape[0])
    idx.add(vectors, wave_size=wave_size)
    if refine_iterations:
        idx.refine(refine_iterations)
    return idx
