"""checkMRNG (paper Algorithm 2) — host and vectorized device variants.

An edge (v1, v2) is MRNG-conform iff no *common neighbor* u of v1 and v2 lies
inside the lune, i.e. ``delta(v1, v2) <= max(w(v1,u), w(v2,u))`` for all
``u in N(v1) & N(v2)``.  During insertion (Alg. 3) the "neighborhood" of the
new vertex is the set ``U`` of neighbors selected so far (Appendix D: the
order of operations is what makes DEG an MRNG *approximation*).
"""
from __future__ import annotations

import numpy as np

from .graph import GraphBuilder, INVALID


def check_mrng(builder: GraphBuilder, v1: int, v2: int, dist_v1_v2: float) -> bool:
    """Algorithm 2 for two existing vertices."""
    n1 = builder.neighbors(v1)
    n2 = set(builder.neighbors(v2).tolist())
    common = [u for u in n1.tolist() if u in n2]
    for u in common:
        w1 = builder.edge_weight(v1, u)
        w2 = builder.edge_weight(v2, u)
        if dist_v1_v2 > max(w1, w2):
            return False
    return True


def check_mrng_candidate(builder: GraphBuilder, cand: int, dist_v_cand: float,
                         selected: list[int], selected_dists: list[float]) -> bool:
    """Algorithm 2 during insertion of a *new* vertex v.

    ``selected`` plays the role of N(G, v): the neighbors already chosen for v
    with their distances ``selected_dists``.  The common-neighbor set is
    ``selected & N(G, cand)``.
    """
    if not selected:
        return True
    cand_nbrs = builder.adjacency[cand]
    cand_set = set(int(x) for x in cand_nbrs if x != INVALID)
    for u, w_vu in zip(selected, selected_dists):
        if u in cand_set:
            w_cu = builder.edge_weight(cand, u)
            if dist_v_cand > max(w_vu, w_cu):
                return False
    return True


def mrng_conform_mask(builder: GraphBuilder, v1: int) -> np.ndarray:
    """For Alg. 5: boolean mask over v1's adjacency slots — True if the edge
    to that neighbor is MRNG-conform."""
    row = builder.adjacency[v1]
    out = np.zeros(row.shape, dtype=bool)
    for s, v2 in enumerate(row):
        if v2 == INVALID:
            out[s] = True
            continue
        out[s] = check_mrng(builder, v1, int(v2), float(builder.weights[v1, s]))
    return out
