"""Dynamic edge optimization (paper Algorithms 4 and 5, Sec. 5.3).

``optimize_edge`` tries to replace one edge (v1, v2) with a better edge
constellation.  All mutations are recorded in a change log and rolled back if
no configuration with positive *gain* (reduction in total edge weight, i.e.
in the average neighbor distance, Eq. 4) is found — so the graph invariants
(regularity, connectivity) hold after every call, success or not.

The candidate searches inside Alg. 4 are beam-engine programs (see
ARCHITECTURE.md).  :func:`refine_sweep` is the *batched* Alg. 5 driver used
by ``DEGIndex.refine``: for a chunk of vertices it prefetches the first
Alg.-4 candidate search of every edge task as ONE batched device call
(``DEGIndex._search_from_batch``) instead of a per-edge round-trip; the
host-side graph surgery is unchanged.  The prefetched search runs against
the pre-chunk graph (the edge under optimization still present) — a bounded
staleness: every structural decision re-validates against the live builder,
so only candidate *quality* can drift, never invariants.

Note on Alg. 4 line 30: the paper's pseudocode says ``add (v1,v5),(v1,v3)``
which contradicts the prose of step (4a) ("the edge (vE,vF) is replaced with
the two edges (vA,vE) and (vA,vF)"); we follow the prose — add (v1,v5) and
(v1,v6), remove (v5,v6) — which is the only degree-conserving reading.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from .build import DEGIndex, np_pair_dist
from .graph import INVALID, pow2_bucket
from .mrng import check_mrng, mrng_conform_mask


class ChangeLog:
    """Invertible edit log over a GraphBuilder."""

    def __init__(self, builder):
        self.builder = builder
        self.ops: list[tuple[str, int, int, float]] = []

    def add_edge(self, u: int, v: int, w: float) -> None:
        self.builder.add_edge(u, v, w)
        self.ops.append(("add", u, v, w))

    def remove_edge(self, u: int, v: int) -> float:
        w = self.builder.remove_edge(u, v)
        self.ops.append(("remove", u, v, w))
        return w

    def revert(self) -> None:
        for op, u, v, w in reversed(self.ops):
            if op == "add":
                self.builder.remove_edge(u, v)
            else:
                self.builder.add_edge(u, v, w)
        self.ops.clear()

    def __len__(self) -> int:
        return len(self.ops)


def _search(index: DEGIndex, query_vertex: int, seeds, k: int, eps: float):
    ids, dists = index._search_from(index.vectors[query_vertex], seeds, k, eps)
    keep = ids != INVALID
    return ids[keep], dists[keep]


def optimize_edge(index: DEGIndex, v1: int, v2: int, *, i_opt: int = 5,
                  k_opt: int = 20, eps_opt: float = 0.001,
                  first_search: Optional[tuple] = None,
                  first_found: Optional[tuple] = None) -> bool:
    """Algorithm 4. Returns True iff the graph was improved (changes kept).

    ``first_search`` optionally supplies a prefetched (ids, dists) result
    for the first step-(2) candidate search (the batched Alg. 5 path);
    INVALID lanes are filtered here.  Later iterations always search live.

    ``first_found`` optionally supplies the *device-proposed* first swap
    (s, n, ds, found) from ``extend.propose_swaps`` (computed from the same
    prefetched search against the pre-chunk graph).  A no-swap proposal
    ends the attempt before any mutation; a proposed swap is re-validated
    against the live builder (and its gain recomputed) before being taken,
    falling back to the host scan when stale.
    """
    b = index.builder
    metric = index.params.metric
    vecs = index.vectors

    def dist(u: int, v: int) -> float:
        return float(np_pair_dist(metric, vecs[u], vecs[v])[0])

    if not b.has_edge(v1, v2):
        return False
    if first_found is not None and not first_found[3]:
        return False                # device scan: no improving first swap
    log = ChangeLog(b)
    gain = log.remove_edge(v1, v2)
    v3, v4 = v1, v1

    for it in range(max(i_opt, 1)):
        # ---- step (2): find (v3', v4') maximizing the running gain --------
        best, found = gain, None
        if it == 0 and first_found is not None:
            s, n, ds = (int(first_found[0]), int(first_found[1]),
                        float(first_found[2]))
            if (s not in (v1, v2) and n != v2 and not b.has_edge(v2, s)
                    and b.has_edge(s, n)):
                cand = gain - ds + b.edge_weight(s, n)
                if cand > best:
                    best, found = cand, (s, n, ds)
        if found is None:
            if it == 0 and first_search is not None:
                ids, dists = first_search
                keep = ids != INVALID
                ids, dists = ids[keep], dists[keep]
            else:
                ids, dists = _search(index, v2, (v3, v4), k_opt, eps_opt)
            for s, ds in zip(ids.tolist(), dists.tolist()):
                if s in (v1, v2) or b.has_edge(v2, s):
                    continue
                for n in b.neighbors(int(s)).tolist():
                    if n == v2:
                        continue
                    cand = gain - ds + b.edge_weight(int(s), int(n))
                    if cand > best:
                        best, found = cand, (int(s), int(n), float(ds))
        if found is None:           # Alg. 4 lines 14-15
            break
        s, n, ds = found
        gain = best
        # step (3): replace (vC, vD) with (vB, vC).  The paper's pseudocode
        # adds before removing (transient degree d+1); we remove first — same
        # end state, keeps the degree-cap invariant checkable at all times.
        log.remove_edge(s, n)
        log.add_edge(v2, s, ds)
        v3, v4 = s, n

        if v4 == v1:
            # ---- step (4a): v1 is missing two edges -----------------------
            ids1, dists1 = _search(index, v1, (v2, v3), k_opt, eps_opt)
            best2, found2 = 0.0, None
            for s2, ds2 in zip(ids1.tolist(), dists1.tolist()):
                s2 = int(s2)
                if s2 == v1 or b.has_edge(v1, s2):
                    continue
                for n2 in b.neighbors(s2).tolist():
                    n2 = int(n2)
                    if n2 == v1 or b.has_edge(v1, n2):
                        continue
                    cand = (gain + b.edge_weight(s2, n2)
                            - ds2 - dist(v1, n2))
                    if cand > best2:
                        best2, found2 = cand, (s2, n2, float(ds2))
            if found2 is not None:
                s2, n2, ds2 = found2
                log.remove_edge(s2, n2)
                log.add_edge(v1, s2, ds2)
                log.add_edge(v1, n2, dist(v1, n2))
                return True
        else:
            # ---- step (4b): connect the two deficient vertices v1, v4 -----
            d14 = dist(v1, v4)
            if (not b.has_edge(v1, v4)) and gain - d14 > 0:
                ids1, _ = _search(index, v1, (v2, v3), k_opt, eps_opt)
                ids4, _ = _search(index, v4, (v2, v3), k_opt, eps_opt)
                if v1 in set(ids1.tolist()) or v4 in set(ids4.tolist()):
                    log.add_edge(v1, v4, d14)
                    return True
        # ---- step (5): rotate labels, keep searching -----------------------
        v2, v3, v4 = v4, v2, v3

    log.revert()                    # step (6)
    return False


def _edge_tasks(b, v1: int, conform=None) -> list:
    """Alg. 5's edge agenda for one vertex: every non-MRNG-conform edge,
    then the longest remaining edge (Alg. 5 lines 6-7).

    ``conform`` optionally supplies a precomputed per-slot conformity mask
    (the batched Alg. 2 device call in ``refine_sweep`` — one program for a
    whole chunk instead of a host neighbor scan per vertex)."""
    tasks: list[int] = []
    if conform is None:
        conform = mrng_conform_mask(b, v1)
    nbrs = b.adjacency[v1].copy()
    for slot, v2 in enumerate(nbrs):
        if v2 == INVALID or conform[slot]:
            continue
        tasks.append(int(v2))
    if b.vertex_degree(v1):
        slot = b.longest_edge_slot(v1)
        v2 = int(b.adjacency[v1, slot])
        if v2 != INVALID:
            tasks.append(v2)
    return tasks


def dynamic_edge_optimization(index: DEGIndex, rng: np.random.Generator, *,
                              i_opt: int = 5, k_opt: int = 20,
                              eps_opt: float = 0.001,
                              vertex: Optional[int] = None) -> bool:
    """Algorithm 5: improve the edges of one (random) vertex (serial path)."""
    b = index.builder
    if b is None or b.n <= b.degree + 1:
        return False
    v1 = int(rng.integers(0, b.n)) if vertex is None else vertex
    improved = False
    for v2 in _edge_tasks(b, v1):
        if b.has_edge(v1, v2):             # may have been removed by a swap
            improved |= optimize_edge(index, v1, v2, i_opt=i_opt,
                                      k_opt=k_opt, eps_opt=eps_opt)
    return improved


def refine_sweep(index: DEGIndex, vertices: Sequence[int], *,
                 i_opt: int = 5, k_opt: int = 20, eps_opt: float = 0.001,
                 chunk: int = 16) -> int:
    """Batched Algorithm 5 over many vertices — ``DEGIndex.refine``'s path.

    Per chunk of vertices: build the edge agenda against the live graph,
    prefetch the first step-(2) candidate search of EVERY edge task in one
    batched device call, then run the host-side surgery edge by edge with
    the prefetched warm start.  Compared to the serial driver this removes
    one device round-trip per edge task (the only search most tasks make —
    failed swaps revert after iteration 1); searches inside later Alg. 4
    iterations still run live.  Returns the number of improved edges.

    Lane counts are bucketed to powers of two (``_search_from_batch``), so
    the first sweeps compile a handful of programs and every later sweep —
    the continuous-refinement serving loop — reuses them.  Steady-state this
    matches the serial driver even on CPU and removes the per-edge
    host->device round-trip that dominates on accelerators.
    """
    from repro.obs import clock
    from .extend import mrng_conform_batch, propose_swaps

    b = index.builder
    if b is None or b.n <= b.degree + 1:
        return 0
    metrics = index.metrics
    improved = 0
    verts = [int(v) for v in vertices]
    for c0 in range(0, len(verts), chunk):
        t_chunk = clock.now()
        if c0:
            # chunk boundary = invariant-clean point; same checkpoint
            # cadence as _insert_wave (persist/snapshot.py).  Epoch
            # republish ticks ride the same boundary so long sweeps
            # surface improvements to live readers mid-run.
            index._checkpoint_tick()
            index._publish_tick()
        verts_c = verts[c0:c0 + chunk]
        # batched Alg. 2: conformity of every chunk edge in ONE device call,
        # cached for the chunk instead of a host neighbor scan per vertex
        g = b.device_graph()
        conform = np.asarray(mrng_conform_batch(
            g.adjacency, g.weights, index._dev_vectors,
            jnp.asarray(np.asarray(verts_c, np.int32)),
            metric=index.params.metric))
        tasks = [(v1, v2) for i, v1 in enumerate(verts_c)
                 for v2 in _edge_tasks(b, v1, conform=conform[i])]
        if not tasks:
            continue
        # lane j: query = vectors[v2], seed = v1  (the (v3,v4)=(v1,v1) seeds
        # of Alg. 4's first iteration)
        q = index.vectors[np.asarray([v2 for _, v2 in tasks])]
        seeds = np.asarray([[v1] for v1, _ in tasks], np.int32)
        ids, dists = index._search_from_batch(q, seeds, k_opt, eps_opt)
        # batched Alg. 4 step (2): every task's first swap decision in ONE
        # device call against the pre-surgery chunk graph (lanes padded to
        # a power of two so sweeps reuse a handful of jit entries)
        T = len(tasks)
        Tp = pow2_bucket(T, floor=4)
        p_ids = np.full((Tp, ids.shape[1]), INVALID, np.int32)
        p_ids[:T] = ids
        p_d = np.full((Tp, ids.shape[1]), np.inf, np.float32)
        p_d[:T] = dists
        v1s = np.zeros((Tp,), np.int32)
        v1s[:T] = [v1 for v1, _ in tasks]
        v2s = np.zeros((Tp,), np.int32)
        v2s[:T] = [v2 for _, v2 in tasks]
        gains = np.zeros((Tp,), np.float32)
        gains[:T] = [b.edge_weight(v1, v2) for v1, v2 in tasks]
        prop = [np.asarray(x) for x in propose_swaps(
            g.adjacency, g.weights, jnp.asarray(p_ids), jnp.asarray(p_d),
            jnp.asarray(v1s), jnp.asarray(v2s), jnp.asarray(gains))]
        clean = True     # no surgery since the chunk snapshot was taken
        for t, ((v1, v2), lane_ids, lane_d) in enumerate(
                zip(tasks, ids, dists)):
            if not b.has_edge(v1, v2):     # removed by an earlier swap
                continue
            # a found=True proposal is re-validated live inside
            # optimize_edge, so it stays usable on a mutated chunk; the
            # found=False shortcut (skip the attempt entirely) is only
            # sound while the chunk snapshot still matches the graph —
            # a reverted attempt restores it exactly, a kept one doesn't.
            p_found = bool(prop[4][t])
            first_found = ((prop[0][t], prop[1][t], prop[2][t], p_found)
                           if (p_found or clean) else None)
            changed = optimize_edge(
                index, v1, v2, i_opt=i_opt, k_opt=k_opt, eps_opt=eps_opt,
                first_search=(lane_ids, lane_d), first_found=first_found)
            improved += int(changed)
            clean = clean and not changed
        if metrics is not None:
            # refine telemetry: per-chunk span + swap yield, so the
            # continuous-refinement loop's cost/benefit shows up next to
            # the serving metrics it shares a host with
            metrics.histogram("refine_chunk_ms").observe(
                (clock.now() - t_chunk) * 1e3)
            metrics.counter("refine_edge_tasks_total").inc(len(tasks))
    if metrics is not None and verts:
        metrics.counter("refine_improved_edges_total").inc(improved)
        metrics.counter("refine_vertices_total").inc(len(verts))
    if verts:
        index._checkpoint_tick()
    return improved
