"""Dynamic edge optimization (paper Algorithms 4 and 5, Sec. 5.3).

``optimize_edge`` tries to replace one edge (v1, v2) with a better edge
constellation.  All mutations are recorded in a change log and rolled back if
no configuration with positive *gain* (reduction in total edge weight, i.e.
in the average neighbor distance, Eq. 4) is found — so the graph invariants
(regularity, connectivity) hold after every call, success or not.

Note on Alg. 4 line 30: the paper's pseudocode says ``add (v1,v5),(v1,v3)``
which contradicts the prose of step (4a) ("the edge (vE,vF) is replaced with
the two edges (vA,vE) and (vA,vF)"); we follow the prose — add (v1,v5) and
(v1,v6), remove (v5,v6) — which is the only degree-conserving reading.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .build import DEGIndex, np_pair_dist
from .graph import INVALID
from .mrng import check_mrng, mrng_conform_mask


class ChangeLog:
    """Invertible edit log over a GraphBuilder."""

    def __init__(self, builder):
        self.builder = builder
        self.ops: list[tuple[str, int, int, float]] = []

    def add_edge(self, u: int, v: int, w: float) -> None:
        self.builder.add_edge(u, v, w)
        self.ops.append(("add", u, v, w))

    def remove_edge(self, u: int, v: int) -> float:
        w = self.builder.remove_edge(u, v)
        self.ops.append(("remove", u, v, w))
        return w

    def revert(self) -> None:
        for op, u, v, w in reversed(self.ops):
            if op == "add":
                self.builder.remove_edge(u, v)
            else:
                self.builder.add_edge(u, v, w)
        self.ops.clear()

    def __len__(self) -> int:
        return len(self.ops)


def _search(index: DEGIndex, query_vertex: int, seeds, k: int, eps: float):
    ids, dists = index._search_from(index.vectors[query_vertex], seeds, k, eps)
    keep = ids != INVALID
    return ids[keep], dists[keep]


def optimize_edge(index: DEGIndex, v1: int, v2: int, *, i_opt: int = 5,
                  k_opt: int = 20, eps_opt: float = 0.001) -> bool:
    """Algorithm 4. Returns True iff the graph was improved (changes kept)."""
    b = index.builder
    metric = index.params.metric
    vecs = index.vectors

    def dist(u: int, v: int) -> float:
        return float(np_pair_dist(metric, vecs[u], vecs[v])[0])

    if not b.has_edge(v1, v2):
        return False
    log = ChangeLog(b)
    gain = log.remove_edge(v1, v2)
    v3, v4 = v1, v1

    for _ in range(max(i_opt, 1)):
        # ---- step (2): find (v3', v4') maximizing the running gain --------
        ids, dists = _search(index, v2, (v3, v4), k_opt, eps_opt)
        best, found = gain, None
        for s, ds in zip(ids.tolist(), dists.tolist()):
            if s in (v1, v2) or b.has_edge(v2, s):
                continue
            for n in b.neighbors(int(s)).tolist():
                if n == v2:
                    continue
                cand = gain - ds + b.edge_weight(int(s), int(n))
                if cand > best:
                    best, found = cand, (int(s), int(n), float(ds))
        if found is None:           # Alg. 4 lines 14-15
            break
        s, n, ds = found
        gain = best
        # step (3): replace (vC, vD) with (vB, vC).  The paper's pseudocode
        # adds before removing (transient degree d+1); we remove first — same
        # end state, keeps the degree-cap invariant checkable at all times.
        log.remove_edge(s, n)
        log.add_edge(v2, s, ds)
        v3, v4 = s, n

        if v4 == v1:
            # ---- step (4a): v1 is missing two edges -----------------------
            ids1, dists1 = _search(index, v1, (v2, v3), k_opt, eps_opt)
            best2, found2 = 0.0, None
            for s2, ds2 in zip(ids1.tolist(), dists1.tolist()):
                s2 = int(s2)
                if s2 == v1 or b.has_edge(v1, s2):
                    continue
                for n2 in b.neighbors(s2).tolist():
                    n2 = int(n2)
                    if n2 == v1 or b.has_edge(v1, n2):
                        continue
                    cand = (gain + b.edge_weight(s2, n2)
                            - ds2 - dist(v1, n2))
                    if cand > best2:
                        best2, found2 = cand, (s2, n2, float(ds2))
            if found2 is not None:
                s2, n2, ds2 = found2
                log.remove_edge(s2, n2)
                log.add_edge(v1, s2, ds2)
                log.add_edge(v1, n2, dist(v1, n2))
                return True
        else:
            # ---- step (4b): connect the two deficient vertices v1, v4 -----
            d14 = dist(v1, v4)
            if (not b.has_edge(v1, v4)) and gain - d14 > 0:
                ids1, _ = _search(index, v1, (v2, v3), k_opt, eps_opt)
                ids4, _ = _search(index, v4, (v2, v3), k_opt, eps_opt)
                if v1 in set(ids1.tolist()) or v4 in set(ids4.tolist()):
                    log.add_edge(v1, v4, d14)
                    return True
        # ---- step (5): rotate labels, keep searching -----------------------
        v2, v3, v4 = v4, v2, v3

    log.revert()                    # step (6)
    return False


def dynamic_edge_optimization(index: DEGIndex, rng: np.random.Generator, *,
                              i_opt: int = 5, k_opt: int = 20,
                              eps_opt: float = 0.001,
                              vertex: Optional[int] = None) -> bool:
    """Algorithm 5: improve the edges of one (random) vertex."""
    b = index.builder
    if b is None or b.n <= b.degree + 1:
        return False
    v1 = int(rng.integers(0, b.n)) if vertex is None else vertex
    improved = False
    conform = mrng_conform_mask(b, v1)
    nbrs = b.adjacency[v1].copy()
    for slot, v2 in enumerate(nbrs):
        if v2 == INVALID or conform[slot]:
            continue
        if b.has_edge(v1, int(v2)):        # may have been removed by a swap
            improved |= optimize_edge(index, v1, int(v2), i_opt=i_opt,
                                      k_opt=k_opt, eps_opt=eps_opt)
    # ... and the longest remaining edge (Alg. 5 lines 6-7)
    if b.vertex_degree(v1):
        slot = b.longest_edge_slot(v1)
        v2 = int(b.adjacency[v1, slot])
        if v2 != INVALID and b.has_edge(v1, v2):
            improved |= optimize_edge(index, v1, v2, i_opt=i_opt, k_opt=k_opt,
                                      eps_opt=eps_opt)
    return improved
