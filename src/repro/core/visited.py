"""Per-lane visited filter for the multi-expansion beam engine.

The seed engine answered "have I already proposed this vertex?" with an
all-pairs broadcast against the live beam — O(L) compares per candidate,
O(B·E·d·L) per hop.  This module replaces that with a fixed-size
**open-addressing hash set** per query lane, carried through the search
``while_loop`` inside :class:`repro.core.beam.BeamState`:

* membership is O(P) gathered compares per candidate (``P = n_probes``,
  default :data:`DEFAULT_PROBES`), independent of beam width;
* insertion is ``P`` rounds of *deterministic* parallel claiming — empty
  slots are claimed with a scatter-``max`` (ids are non-negative, empty
  slots hold ``INVALID`` = -1), so same-slot races resolve to the largest
  id order-independently, and losers retry their next probe position;
* the table is best-effort by construction: an id whose probe sequence is
  exhausted is simply not recorded.  A dropped insert can only cause a
  re-scored candidate later (a wasted distance evaluation, and — if the
  vertex still sits in the beam — a duplicate entry that
  ``beam.extract(dedup=True)`` removes at result time), never a missed
  vertex, so search correctness does not depend on table occupancy.

Because the visited set remembers vertices that were *evicted* from the
beam (the broadcast dedup forgets them), a visited-filtered search performs
at most as many distance evaluations as the seed semantics; the trajectory
— and the ``evals`` counters — can therefore differ from the E=1 broadcast
engine.  See ARCHITECTURE.md ("Multi-expansion beam layering").

The probe-position formula is shared verbatim with the ``fused_hop`` Pallas
kernel (which performs the membership test in VMEM); keep them in sync by
importing :func:`probe_positions` rather than re-deriving the hash.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .graph import INVALID, pow2_bucket

Array = jax.Array

# Knuth multiplicative hash + a golden-ratio second hash (forced odd) for
# double hashing; the table size is a power of two so ``& (V - 1)`` folds.
_MULT1 = 2654435761        # 2^32 / phi, Knuth
_MULT2 = 0x9E3779B1        # golden-ratio constant
DEFAULT_PROBES = 4


def probe_positions(ids: Array, n_slots: int, n_probes: int) -> Array:
    """Probe sequence of every id: (...,) int32 ids -> (..., P) int32 slot
    positions in [0, n_slots).  ``n_slots`` must be a power of two."""
    x = ids.astype(jnp.uint32)
    h1 = x * jnp.uint32(_MULT1)
    h2 = (x * jnp.uint32(_MULT2)) | jnp.uint32(1)          # odd stride
    t = jnp.arange(n_probes, dtype=jnp.uint32)
    pos = (h1[..., None] + t * h2[..., None]) & jnp.uint32(n_slots - 1)
    return pos.astype(jnp.int32)


def make_table(batch: int, n_slots: int) -> Array:
    """Empty (B, V) table (all INVALID).  ``n_slots`` is rounded up to a
    power of two — the probe fold is ``& (V - 1)``, which only addresses
    the whole table for pow2 sizes (a 1000-slot table would silently use
    256 slots)."""
    return jnp.full((batch, pow2_bucket(n_slots)), INVALID,
                    dtype=jnp.int32)


def contains(table: Array, ids: Array, *,
             n_probes: int = DEFAULT_PROBES) -> Array:
    """(B, V) table, (B, C) ids -> (B, C) bool membership (INVALID never a
    member).  An id is present iff it is stored at one of its P probe
    slots."""
    B, C = ids.shape
    V = table.shape[1]
    pos = probe_positions(ids, V, n_probes)                # (B, C, P)
    vals = jnp.take_along_axis(table, pos.reshape(B, C * n_probes),
                               axis=1).reshape(B, C, n_probes)
    return (vals == ids[..., None]).any(axis=-1) & (ids != INVALID)


def insert(table: Array, ids: Array, mask: Array, *,
           n_probes: int = DEFAULT_PROBES) -> Array:
    """Insert ``ids`` where ``mask`` into each lane's table (best-effort).

    Ids already present anywhere in their probe sequence are skipped
    outright (re-inserting a member is a strict no-op, so two callers that
    insert supersets of each other's id sets produce bit-identical
    tables).  The rest run P rounds of probe-claim: in round t every
    still-unplaced id reads its slot ``pos[..., t]`` and claims it if
    empty via scatter-``max`` (deterministic under same-slot races — the
    largest id wins order-independently, and the loser retries at its next
    probe position).  Ids whose P probes are all occupied are dropped.
    """
    B, C = ids.shape
    V = table.shape[1]
    pos = probe_positions(ids, V, n_probes)                # (B, C, P)
    lane = jnp.arange(B)[:, None]
    vals = jnp.take_along_axis(table, pos.reshape(B, C * n_probes),
                               axis=1).reshape(B, C, n_probes)
    present = (vals == ids[..., None]).any(axis=-1)
    need0 = mask & (ids != INVALID) & ~present

    def body(t, carry):
        table, need = carry
        p = jax.lax.dynamic_index_in_dim(pos, t, axis=2, keepdims=False)
        cur = jnp.take_along_axis(table, p, axis=1)
        need = need & (cur != ids)       # a same-batch duplicate placed it
        claim = need & (cur == INVALID)
        table = table.at[lane, p].max(jnp.where(claim, ids, INVALID))
        placed = jnp.take_along_axis(table, p, axis=1) == ids
        return table, need & ~placed

    table, _ = jax.lax.fori_loop(0, n_probes, body, (table, need0))
    return table


def first_occurrence_mask(ids: Array, valid: Array) -> Array:
    """(B, C) bool: is position j the first occurrence of ``ids[b, j]``
    among the valid positions of lane b?  Masked lanes get unique negative
    sentinels so they never alias each other or real ids.

    This is THE intra-block dedup of the multi-expansion hop — shared by
    the engine's jnp paths and the ``fused_hop`` oracle so they stay
    bit-identical (the Pallas kernel reproduces it sequentially via its
    ``seen`` scratch row)."""
    import numpy as np

    C = ids.shape[1]
    sent = -(jnp.arange(C, dtype=jnp.int32) + 2)
    tagged = jnp.where(valid, ids, sent[None, :])
    lower = np.tril(np.ones((C, C), bool), -1)       # j' < j, trace-safe
    dup = ((tagged[:, :, None] == tagged[:, None, :]) & lower).any(axis=2)
    return ~dup


def default_size(beam_width: int, degree: int) -> int:
    """Table-size heuristic: comfortably above the unique-visit count of a
    typical search (≈ hops · new-neighbor fraction · degree, which scales
    with ``beam_width * degree``), rounded to a power of two for the
    ``& (V-1)`` fold.  Dropped inserts degrade gracefully (see module
    docstring), so this is a load-factor target, not a hard capacity."""
    return pow2_bucket(max(512, beam_width * max(degree, 1)))
