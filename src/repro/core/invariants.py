"""Structural invariants of DEG (paper Table 1 / Sec. 5.1).

These are *hard guarantees* of the data structure, so the test suite asserts
them after every construction / optimization operation:

* even regularity: every active vertex has exactly ``d`` valid neighbors;
* undirectedness: ``v in N(u)  <=>  u in N(v)`` with equal weights;
* no self loops, no duplicate edges;
* connectivity: a single connected component (Euler-cycle argument, Sec. 5.1).

The checks are vectorized (numpy sort/searchsorted for the edge bijection,
a frontier sweep for connectivity) so the online scrubber and the stateful
lifecycle suite can assert Table 1 at realistic ``n`` without the audit
dominating runtime.  The original per-edge Python loops are kept as
``*_loop`` references for the slow-marked differential tests.  Unlike the
loop versions (which assume ids are in range and will raise on garbage),
the vectorized versions are corruption-tolerant: an out-of-range neighbor
id makes the check return ``False`` instead of crashing — a requirement
for auditing a live index that may hold damaged rows.

``audit_rows`` is the scrubber's chunked entry point: it returns a per-row
reason bitmask instead of a single bool so quarantine decisions and repair
can be targeted at the damaged vertices only.
"""
from __future__ import annotations

from collections import deque

import numpy as np

from .graph import GraphBuilder, DEGraph, INVALID

# ``audit_rows`` reason bits (a row may carry several).
BAD_RANGE = np.uint8(1)     # neighbor id outside [0, n)
BAD_SELF = np.uint8(2)      # self loop
BAD_DUP = np.uint8(4)       # duplicate neighbor in the row
BAD_DEGREE = np.uint8(8)    # valid-slot count != d (regularity breach)
BAD_ASYM = np.uint8(16)     # neighbor does not list this vertex back
BAD_WEIGHT = np.uint8(32)   # reverse edge exists but weights disagree

_W_RTOL, _W_ATOL = 1e-5, 1e-6


def _as_builder(g) -> GraphBuilder:
    return g.to_builder() if isinstance(g, DEGraph) else g


def check_regular(g, *, allow_partial: bool = False) -> bool:
    b = _as_builder(g)
    if b.n == 0:
        return True
    adj = b.adjacency[: b.n]
    degs = (adj != INVALID).sum(axis=1)
    if allow_partial:
        return bool((degs <= b.degree).all())
    return bool((degs == b.degree).all())


def check_undirected(g) -> bool:
    """Vectorized edge-bijection check: every directed entry ``u -> v`` has
    exactly one matching ``v -> u`` with the same weight.  Implemented by
    sorting the forward edge keys and binary-searching each reversed key —
    O(E log E) numpy work instead of the per-edge Python scan."""
    b = _as_builder(g)
    n = b.n
    if n == 0:
        return True
    adj = b.adjacency[:n]
    valid = adj != INVALID
    vs = adj[valid].astype(np.int64)
    if vs.size == 0:
        return True
    if (vs < 0).any() or (vs >= n).any():
        return False                       # corrupt id: trivially asymmetric
    us = np.broadcast_to(np.arange(n, dtype=np.int64)[:, None],
                         adj.shape)[valid]
    ws = b.weights[:n][valid]
    key = us * n + vs
    order = np.argsort(key, kind="stable")
    skey = key[order]
    if skey.size > 1 and (skey[1:] == skey[:-1]).any():
        return False                       # duplicate entry breaks bijection
    pos = np.searchsorted(skey, vs * n + us)
    if (pos >= skey.size).any() or (skey[pos] != vs * n + us).any():
        return False                       # some reverse edge is missing
    return bool(np.isclose(ws[order][pos], ws,
                           rtol=_W_RTOL, atol=_W_ATOL).all())


def check_undirected_loop(g) -> bool:
    """Reference O(n*d*d) implementation — differential oracle for
    :func:`check_undirected` (slow-marked tests only).  Assumes neighbor
    ids are in range."""
    b = _as_builder(g)
    for u in range(b.n):
        for s, v in enumerate(b.adjacency[u]):
            if v == INVALID:
                continue
            v = int(v)
            back = np.nonzero(b.adjacency[v] == u)[0]
            if back.size != 1:
                return False
            if not np.isclose(b.weights[v, back[0]], b.weights[u, s],
                              rtol=_W_RTOL, atol=_W_ATOL):
                return False
    return True


def check_no_self_loops(g) -> bool:
    b = _as_builder(g)
    if b.n == 0:
        return True
    adj = b.adjacency[: b.n]
    return not bool((adj == np.arange(b.n)[:, None]).any())


def check_no_duplicate_edges(g) -> bool:
    b = _as_builder(g)
    if b.n == 0:
        return True
    srt = np.sort(b.adjacency[: b.n], axis=1)
    dup = (srt[:, 1:] == srt[:, :-1]) & (srt[:, 1:] != INVALID)
    return not bool(dup.any())


def component_labels(g) -> np.ndarray:
    """Connected-component label per active vertex (0-based, in discovery
    order) via a vectorized frontier sweep.  Out-of-range neighbor ids are
    ignored, so this is safe on a corrupted graph."""
    b = _as_builder(g)
    n = b.n
    labels = np.full(n, -1, dtype=np.int64)
    if n == 0:
        return labels
    adj = b.adjacency[:n]
    comp = 0
    cursor = 0
    while True:
        unseen = np.flatnonzero(labels[cursor:] < 0)
        if unseen.size == 0:
            break
        start = cursor + int(unseen[0])
        cursor = start
        labels[start] = comp
        frontier = np.array([start], dtype=np.int64)
        while frontier.size:
            nxt = adj[frontier].reshape(-1)
            nxt = nxt[(nxt >= 0) & (nxt < n)].astype(np.int64)
            nxt = np.unique(nxt)
            nxt = nxt[labels[nxt] < 0]
            labels[nxt] = comp
            frontier = nxt
        comp += 1
    return labels


def connected_components(g) -> int:
    b = _as_builder(g)
    if b.n == 0:
        return 0
    return int(component_labels(b).max()) + 1


def connected_components_loop(g) -> int:
    """Reference Python-BFS implementation — differential oracle for
    :func:`connected_components` (slow-marked tests only)."""
    b = _as_builder(g)
    if b.n == 0:
        return 0
    seen = np.zeros(b.n, dtype=bool)
    comps = 0
    for start in range(b.n):
        if seen[start]:
            continue
        comps += 1
        q = deque([start])
        seen[start] = True
        while q:
            u = q.popleft()
            for v in b.adjacency[u]:
                if v != INVALID and not seen[v]:
                    seen[int(v)] = True
                    q.append(int(v))
    return comps


def check_connected(g) -> bool:
    return connected_components(g) <= 1


def unreachable_vertices(g, entry: int = 0) -> np.ndarray:
    """Active vertices not reachable from ``entry`` (ascending ids).
    Corruption-tolerant like :func:`component_labels`."""
    b = _as_builder(g)
    if b.n == 0:
        return np.empty(0, dtype=np.int64)
    labels = component_labels(b)
    return np.flatnonzero(labels != labels[int(entry)])


def audit_rows(b: GraphBuilder, rows) -> np.ndarray:
    """Chunked Table-1 audit for the online scrubber: a ``uint8`` reason
    bitmask per requested row (``0`` = clean; see the ``BAD_*`` bits).

    All row-local properties (range / self loop / duplicates / regularity)
    plus reciprocity and weight agreement of every listed edge are checked
    with batched numpy gathers — no Python per-edge loop.  A dangling
    *reverse* entry (``v`` lists ``u`` but ``u`` does not list ``v``) is
    flagged on ``v``'s row, so a full sweep over all rows covers both ends
    of every broken edge even though each chunk only looks outward.
    """
    rows = np.asarray(rows, dtype=np.int64).reshape(-1)
    out = np.zeros(rows.size, dtype=np.uint8)
    n, d = b.n, b.degree
    if rows.size == 0 or n == 0:
        return out
    adj = b.adjacency[rows]                     # (R, d)
    w = b.weights[rows]
    valid = adj != INVALID
    out[valid.sum(axis=1) != d] |= BAD_DEGREE
    in_range = valid & (adj >= 0) & (adj < n)
    out[(valid & ~in_range).any(axis=1)] |= BAD_RANGE
    out[(adj == rows[:, None]).any(axis=1)] |= BAD_SELF
    srt = np.sort(adj, axis=1)
    dup = (srt[:, 1:] == srt[:, :-1]) & (srt[:, 1:] != INVALID)
    out[dup.any(axis=1)] |= BAD_DUP
    # reciprocity + weight agreement, only over in-range entries (the rest
    # are already flagged BAD_RANGE and would poison the gather)
    safe = np.where(in_range, adj, 0)
    back = b.adjacency[safe]                    # (R, d, d) gathered rows
    match = back == rows[:, None, None]
    has_back = match.any(axis=2)
    out[(in_range & ~has_back).any(axis=1)] |= BAD_ASYM
    slot = np.argmax(match, axis=2)             # first matching back slot
    bw = b.weights[safe, slot]
    w_ok = np.isclose(bw, w, rtol=_W_RTOL, atol=_W_ATOL)
    out[(in_range & has_back & ~w_ok).any(axis=1)] |= BAD_WEIGHT
    return out


def assert_valid_deg(g, *, context: str = "") -> None:
    """Assert all DEG invariants; raise AssertionError with a diagnosis."""
    b = _as_builder(g)
    assert check_no_self_loops(b), f"self loop {context}"
    assert check_no_duplicate_edges(b), f"duplicate edge {context}"
    assert check_undirected(b), f"asymmetric adjacency {context}"
    assert check_regular(b), f"not {b.degree}-regular {context}"
    assert check_connected(b), f"disconnected {context}"


def check_invariants(g) -> tuple[bool, list]:
    """All Table-1 invariants at once: returns (ok, failure messages)."""
    msgs = []
    if not check_regular(g):
        msgs.append("not even-regular")
    if not check_undirected(g):
        msgs.append("not undirected")
    if not check_no_self_loops(g):
        msgs.append("self loops present")
    if not check_no_duplicate_edges(g):
        msgs.append("duplicate edges present")
    if not check_connected(g):
        msgs.append(f"{connected_components(g)} connected components")
    return (not msgs), msgs
