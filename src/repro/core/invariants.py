"""Structural invariants of DEG (paper Table 1 / Sec. 5.1).

These are *hard guarantees* of the data structure, so the test suite asserts
them after every construction / optimization operation:

* even regularity: every active vertex has exactly ``d`` valid neighbors;
* undirectedness: ``v in N(u)  <=>  u in N(v)`` with equal weights;
* no self loops, no duplicate edges;
* connectivity: a single connected component (Euler-cycle argument, Sec. 5.1).
"""
from __future__ import annotations

from collections import deque

import numpy as np

from .graph import GraphBuilder, DEGraph, INVALID


def _as_builder(g) -> GraphBuilder:
    return g.to_builder() if isinstance(g, DEGraph) else g


def check_regular(g, *, allow_partial: bool = False) -> bool:
    b = _as_builder(g)
    if b.n == 0:
        return True
    adj = b.adjacency[: b.n]
    degs = (adj != INVALID).sum(axis=1)
    if allow_partial:
        return bool((degs <= b.degree).all())
    return bool((degs == b.degree).all())


def check_undirected(g) -> bool:
    b = _as_builder(g)
    for u in range(b.n):
        for s, v in enumerate(b.adjacency[u]):
            if v == INVALID:
                continue
            v = int(v)
            back = np.nonzero(b.adjacency[v] == u)[0]
            if back.size != 1:
                return False
            if not np.isclose(b.weights[v, back[0]], b.weights[u, s], rtol=1e-5,
                              atol=1e-6):
                return False
    return True


def check_no_self_loops(g) -> bool:
    b = _as_builder(g)
    for u in range(b.n):
        if (b.adjacency[u] == u).any():
            return False
    return True


def check_no_duplicate_edges(g) -> bool:
    b = _as_builder(g)
    for u in range(b.n):
        row = [int(v) for v in b.adjacency[u] if v != INVALID]
        if len(row) != len(set(row)):
            return False
    return True


def connected_components(g) -> int:
    b = _as_builder(g)
    if b.n == 0:
        return 0
    seen = np.zeros(b.n, dtype=bool)
    comps = 0
    for start in range(b.n):
        if seen[start]:
            continue
        comps += 1
        q = deque([start])
        seen[start] = True
        while q:
            u = q.popleft()
            for v in b.adjacency[u]:
                if v != INVALID and not seen[v]:
                    seen[int(v)] = True
                    q.append(int(v))
    return comps


def check_connected(g) -> bool:
    return connected_components(g) <= 1


def assert_valid_deg(g, *, context: str = "") -> None:
    """Assert all DEG invariants; raise AssertionError with a diagnosis."""
    b = _as_builder(g)
    assert check_no_self_loops(b), f"self loop {context}"
    assert check_no_duplicate_edges(b), f"duplicate edge {context}"
    assert check_undirected(b), f"asymmetric adjacency {context}"
    assert check_regular(b), f"not {b.degree}-regular {context}"
    assert check_connected(b), f"disconnected {context}"


def check_invariants(g) -> tuple[bool, list]:
    """All Table-1 invariants at once: returns (ok, failure messages)."""
    msgs = []
    if not check_regular(g):
        msgs.append("not even-regular")
    if not check_undirected(g):
        msgs.append("not undirected")
    if not check_no_self_loops(g):
        msgs.append("self loops present")
    if not check_no_duplicate_edges(g):
        msgs.append("duplicate edges present")
    if not check_connected(g):
        msgs.append(f"{connected_components(g)} connected components")
    return (not msgs), msgs
