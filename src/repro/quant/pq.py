"""Product quantization: per-subspace k-means codebooks + uint8 codes.

SQ8 is a 4x codec; product quantization is the 8-32x one.  A row of
dimension ``m`` is split into ``m_sub`` contiguous subspaces of
``subspace_dim(m)`` dims each; every subspace gets its own 256-centroid
codebook fit (post-training, over the *live* rows only) with plain
deterministic Lloyd k-means, and a row is stored as ``m_sub`` uint8
centroid indices — one byte per subspace vs four bytes per dimension.

Asymmetric distance computation (ADC) is what makes the codec searchable
without decoding: for the l2 metric,

    ||q - decode(x)||^2  =  sum_s ||q_s - C[s, code_s(x)]||^2,

so a per-query LUT of the ``(m_sub, 256)`` squared sub-distances (built
once per query) turns every gathered code row into ``m_sub`` table
lookups + adds.  ``kernels/pq_adc`` fuses the gather with that LUT scan
in VMEM; :func:`adc_lut` is the jnp form the reference path uses.

Like the sq8 recipe, everything here is calibrate-after-build: codebooks
are fit from the indexed data and never retrained.  The fit is host-side
numpy, seeded, and fully deterministic (ties broken by ``argmin``'s
first-minimum rule; empty clusters keep their previous centroid), so a
snapshot round-trip or a re-encode under the same seed is bit-stable.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

Array = jax.Array

#: centroids per subspace — one uint8 code byte addresses the full book
PQ_K = 256


def subspace_dim(dim: int) -> int:
    """Dims per PQ subspace: the largest of 8/4/2/1 dividing ``dim``.

    Preferring wide (8-dim) subspaces keeps the code small — ``dim / 8``
    bytes per row, >= 8x vs float32 once the shared codebook amortizes —
    while 256 centroids per 8-dim subspace is the classic PQ operating
    point (Jegou et al.'s ``m = dim/8, k* = 256``).
    """
    if dim < 1:
        raise ValueError(f"dim must be >= 1, got {dim}")
    for cand in (8, 4, 2, 1):
        if dim % cand == 0:
            return cand
    raise AssertionError("unreachable: 1 divides every dim")


def n_subspaces(dim: int) -> int:
    """Code bytes per row (= number of subspaces) for a ``dim``-dim store."""
    return dim // subspace_dim(dim)


def fit(vectors, n=None, *, seed: int = 0, iters: int = 25) -> np.ndarray:
    """Fit per-subspace k-means codebooks over the live rows.

    vectors (capacity, dim); ``n`` restricts training to the first ``n``
    rows (the live vertices — capacity padding must not pull centroids
    toward zero).  Returns ``(m_sub, 256, dsub)`` float32 codebooks.

    Deterministic Lloyd: init = a seeded permutation of the training rows
    (tiled when fewer than 256 rows — duplicated centroids are harmless,
    assignment ties resolve to the first), then ``iters`` rounds of
    assign / recenter with empty clusters keeping their old centroid.
    """
    x = np.asarray(vectors, np.float32)
    rows = x if n is None else x[: int(n)]
    if rows.shape[0] < 1:
        raise ValueError("pq.fit needs at least one live row")
    dim = x.shape[1]
    dsub = subspace_dim(dim)
    m_sub = dim // dsub
    rng = np.random.default_rng(seed)
    books = np.empty((m_sub, PQ_K, dsub), np.float32)
    for s in range(m_sub):
        xs = np.ascontiguousarray(rows[:, s * dsub: (s + 1) * dsub])
        init = np.resize(rng.permutation(xs.shape[0]), PQ_K)
        cent = xs[init].copy()
        xn = np.sum(xs * xs, axis=1)
        prev = None
        for _ in range(iters):
            cn = np.sum(cent * cent, axis=1)
            d2 = xn[:, None] - 2.0 * (xs @ cent.T) + cn[None, :]
            assign = np.argmin(d2, axis=1)
            if prev is not None and np.array_equal(assign, prev):
                break
            prev = assign
            counts = np.bincount(assign, minlength=PQ_K)
            sums = np.zeros((PQ_K, dsub), np.float64)
            np.add.at(sums, assign, xs)
            nonempty = counts > 0
            cent[nonempty] = (sums[nonempty]
                              / counts[nonempty, None]).astype(np.float32)
        books[s] = cent
    return books


def encode(vectors, codebooks) -> Array:
    """Nearest-centroid codes: (rows, dim) -> (rows, m_sub) uint8."""
    cb = jnp.asarray(codebooks, jnp.float32)
    m_sub, _, dsub = cb.shape
    v = jnp.asarray(vectors, jnp.float32)
    sub = v.reshape(v.shape[0], m_sub, dsub)
    sn = jnp.sum(sub * sub, axis=-1)[:, :, None]          # (n, m_sub, 1)
    cn = jnp.sum(cb * cb, axis=-1)[None]                  # (1, m_sub, 256)
    cross = jnp.einsum("nsd,skd->nsk", sub, cb)
    d2 = sn - 2.0 * cross + cn
    return jnp.argmin(d2, axis=-1).astype(jnp.uint8)


def decode(codes: Array, codebooks: Array) -> Array:
    """Centroid lookup: (..., m_sub) uint8 -> (..., dim) float32."""
    cb = jnp.asarray(codebooks, jnp.float32)
    m_sub, _, dsub = cb.shape
    g = cb[jnp.arange(m_sub), codes.astype(jnp.int32)]    # (..., m_sub, dsub)
    return g.reshape(codes.shape[:-1] + (m_sub * dsub,))


def adc_lut(queries: Array, codebooks: Array) -> Array:
    """Per-query squared sub-distance tables: (B, dim) -> (B, m_sub, 256).

    ``lut[b, s, c] = ||q_b[s] - C[s, c]||^2`` — summing ``m_sub`` entries
    per code row reproduces the exact squared l2 to the decoded vector.
    """
    cb = jnp.asarray(codebooks, jnp.float32)
    m_sub, _, dsub = cb.shape
    q = jnp.asarray(queries, jnp.float32)
    qs = q.reshape(q.shape[0], m_sub, dsub)
    diff = qs[:, :, None, :] - cb[None]                   # (B, m_sub, 256, d)
    return jnp.sum(diff * diff, axis=-1)
