"""The vector store the beam engine traverses.

:class:`VectorStore` sits between ``core/graph.py`` (which owns topology)
and ``core/beam.py`` (which owns traversal): the engine asks the store for
seed/neighbor distances and never touches a raw ``(n, m)`` array again.
It is a registered-dataclass pytree — ``data``/``scale``/``codebooks`` are
leaves, the codec name is static — so it passes through ``jax.jit`` /
``shard_map`` boundaries exactly like :class:`repro.core.graph.DEGraph`
does.

Four views behind one interface:

* ``float32`` — the exact store.  ``decode`` is the identity and
  ``neighbor_distances`` lowers to the *same ops* as the pre-quantization
  engine, so this path stays bit-identical (pinned by the golden fixture).
* ``fp16`` — half-precision rows, gathered at half width and upcast
  per-tile inside the kernel.
* ``sq8`` — int8 codes + per-dimension scale; the hot gather path runs the
  fused ``kernels/gather_dist_q`` gather→dequant→distance kernel (Pallas on
  TPU, jnp elsewhere).
* ``pq`` — product-quantized uint8 codes (one byte per subspace) + shared
  ``(m_sub, 256, dsub)`` codebooks; the hot path runs the fused
  ``kernels/pq_adc`` LUT-ADC kernel, which never decodes — for l2 the
  per-query sub-distance table reproduces the exact distance to the
  decoded vector (``quant.pq``).

The store deliberately does NOT hold the exact copy used by two-stage
rerank — that stays with the index owner (host / cold path); see
ARCHITECTURE.md ("Quantized store layering").
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import codec as C
from . import pq as PQ

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class VectorStore:
    """Encoded vector rows + dequant state behind one distance interface."""

    data: Array    # (capacity, m) f32/f16/int8 — or (capacity, m_sub) uint8
    scale: Array   # (m,) float32 — sq8 dequant scale (ones otherwise)
    codec: str = dataclasses.field(metadata=dict(static=True))
    #: (m_sub, 256, dsub) float32 k-means codebooks — pq only, else None
    codebooks: Optional[Array] = None

    @property
    def capacity(self) -> int:
        return self.data.shape[0]

    @property
    def dim(self) -> int:
        if self.codec == "pq":      # data rows hold m_sub code bytes, not m
            m_sub, _, dsub = self.codebooks.shape
            return m_sub * dsub
        return self.data.shape[1]

    @property
    def exact(self) -> bool:
        return self.codec == "float32"

    def decode(self, ids: Array) -> Array:
        """Gather rows by id and decode to float32 (identity for float32).

        Ids are clamped to ``[0, capacity)`` the way ``gather_dist``'s
        ``safe_ids`` are: callers mask INVALID (-1) lanes *after* the
        distance, and an unclipped ``-1`` would silently wrap to the last
        row and feed a junk vector into the jnp distance path and the
        exact rerank.
        """
        safe = jnp.clip(ids, 0, self.capacity - 1)
        if self.codec == "pq":
            return PQ.decode(self.data[safe], self.codebooks)
        return C.decode(self.codec, self.data[safe], self.scale)

    def neighbor_distances(self, queries: Array, nbr_ids: Array,
                           metric_name: str, backend: str = "jnp") -> Array:
        """dist(q_b, decode(row ids[b, j])) for (B, d) ids -> (B, d).

        The one call the beam engine makes per hop.  ``backend='pallas'``
        routes l2 to the fused gather kernels (``gather_dist`` for float
        codecs, ``gather_dist_q`` for sq8, ``pq_adc`` for pq — the last
        never decodes: it scans gathered code bytes against a per-query
        LUT built once in VMEM); everything else takes the jnp
        gather+pair path, which for float32 is the exact pre-store
        program.
        """
        from repro.core.distances import get_metric

        if backend == "pallas" and metric_name == "l2":
            if self.codec == "sq8":
                from repro.kernels.gather_dist_q import ops as gdq_ops

                return gdq_ops.gather_dist_q(self.data, self.scale, nbr_ids,
                                             queries)
            if self.codec == "pq":
                from repro.kernels.pq_adc import ops as adc_ops

                return adc_ops.pq_adc(self.data, self.codebooks, nbr_ids,
                                      queries)
            from repro.kernels.gather_dist import ops as gd_ops

            return gd_ops.gather_dist(self.data, nbr_ids, queries)
        metric = get_metric(metric_name)
        nvecs = self.decode(nbr_ids)                       # (B, d, m)
        return metric.pair(queries[:, None, :], nvecs)     # (B, d)

    # -- footprint ---------------------------------------------------------
    def memory_bytes(self, n=None) -> int:
        """Store bytes for ``n`` rows (default: full capacity) + dequant
        state."""
        rows = self.capacity if n is None else int(n)
        return C.store_bytes(self.codec, rows, self.dim)


def make_store(vectors: Array, codec: str = "float32", *,
               n: Optional[int]) -> VectorStore:
    """Encode ``vectors`` under ``codec``.

    ``n`` is the live-row count and is deliberately a *required* keyword:
    calibrated codecs (sq8 scales, pq codebooks) must see only the live
    vertices — calibrating over capacity-padding rows silently skews the
    sq8 range and pulls pq centroids toward zero.  Pass ``n=None``
    explicitly only when every row is live.
    """
    vectors = jnp.asarray(vectors)
    m = vectors.shape[1]
    if codec == "pq":
        books = jnp.asarray(PQ.fit(vectors, n))
        return VectorStore(data=PQ.encode(vectors, books),
                           scale=jnp.ones((m,), jnp.float32),
                           codec=codec, codebooks=books)
    if codec == "sq8":
        scale = C.calibrate_sq8_scale(vectors, n)
    else:
        scale = jnp.ones((m,), jnp.float32)
    return VectorStore(data=C.encode(codec, vectors, scale), scale=scale,
                       codec=codec)


def as_store(vectors) -> VectorStore:
    """Normalize the beam engine's ``vectors`` argument: raw float arrays
    become exact float32 stores (identical ops), stores pass through."""
    if isinstance(vectors, VectorStore):
        return vectors
    vectors = jnp.asarray(vectors)
    return VectorStore(data=vectors,
                       scale=jnp.ones((vectors.shape[1],), jnp.float32),
                       codec="float32")
