"""Post-training vector codecs for the in-graph store.

The DEG search roofline is the random gather of neighbor rows (see
``kernels/gather_dist``); at serving scale the float32 store — not compute —
caps how many vertices a shard can hold.  Following the standard
post-training-quantization recipe (quantize after build, calibrate from the
indexed data, never retrain), this module provides the *codec* layer:

* ``sq8`` — per-dimension symmetric scalar quantization to int8.  The scale
  of dimension ``j`` is calibrated as ``max_i |x[i, j]| / 127`` over the
  indexed vectors, so every indexed value round-trips with
  ``|deq(q(x)) - x| <= scale/2`` (round-to-nearest, no clipping inside the
  calibration range — the property test pins this bound).
* ``fp16`` — IEEE half precision, a 2x codec with no calibration state.
* ``float32`` — the identity codec (the exact store; decode is a no-op so
  the float path stays bit-identical to the pre-quantization engine).
* ``pq`` — product quantization (``repro.quant.pq``): per-subspace
  256-centroid k-means codebooks, one uint8 code byte per subspace —
  ``n_subspaces(dim)`` bytes per row plus a shared ``256 * dim * 4``-byte
  codebook.  Stateful (codebooks, not a scale vector), so its
  encode/decode live in :mod:`repro.quant.pq` and are wired up by
  :class:`repro.quant.store.VectorStore`; this module only carries the
  registry entry and the byte accounting.

Codecs are deliberately stateless functions over ``(data, scale)`` pairs;
:mod:`repro.quant.store` packages them with the arrays as a pytree the beam
engine can traverse.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

#: codec name -> (storage dtype, bytes per element); pq's "element" is one
#: subspace code byte, not one dimension — see :func:`bytes_per_row`
CODECS = {
    "float32": (jnp.float32, 4),
    "fp16": (jnp.float16, 2),
    "sq8": (jnp.int8, 1),
    "pq": (jnp.uint8, 1),
}


def calibrate_sq8_scale(vectors: Array, n=None) -> Array:
    """Per-dimension symmetric scale from the indexed rows.

    vectors (capacity, m); ``n`` restricts calibration to the first ``n``
    rows (the live vertices — capacity padding must not inflate scales,
    though zero padding cannot since |0| contributes nothing).
    """
    x = vectors if n is None else vectors[:n]
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=0)
    return jnp.maximum(amax, 1e-12) / 127.0


def sq8_encode(vectors: Array, scale: Array) -> Array:
    """Round-to-nearest symmetric int8: q = clip(round(x / scale), ±127)."""
    q = jnp.round(vectors.astype(jnp.float32) / scale[None, :])
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def sq8_decode(codes: Array, scale: Array) -> Array:
    return codes.astype(jnp.float32) * scale


def encode(codec: str, vectors: Array, scale: Array) -> Array:
    if codec == "float32":
        return vectors.astype(jnp.float32)
    if codec == "fp16":
        return vectors.astype(jnp.float16)
    if codec == "sq8":
        return sq8_encode(vectors, scale)
    if codec == "pq":
        raise ValueError("pq is codebook-stateful; encode via "
                         "repro.quant.make_store / repro.quant.pq")
    raise ValueError(f"unknown codec {codec!r} (have {sorted(CODECS)})")


def decode(codec: str, data: Array, scale: Array) -> Array:
    """Decoded rows in float32.  ``float32`` decode must be the identity
    (astype to the same dtype is a no-op) so the exact path is bit-identical
    to a raw-array store."""
    if codec == "float32":
        return data.astype(jnp.float32)
    if codec == "fp16":
        return data.astype(jnp.float32)
    if codec == "sq8":
        return sq8_decode(data, scale)
    if codec == "pq":
        raise ValueError("pq is codebook-stateful; decode via "
                         "VectorStore.decode / repro.quant.pq")
    raise ValueError(f"unknown codec {codec!r} (have {sorted(CODECS)})")


def bytes_per_row(codec: str, dim: int) -> int:
    """Bytes of one stored row (shared calibration state — sq8's scale
    vector, pq's codebooks — is charged to the store, not the row)."""
    if codec not in CODECS:
        raise ValueError(f"unknown codec {codec!r} (have {sorted(CODECS)})")
    if codec == "pq":
        from . import pq

        return pq.n_subspaces(dim)          # one uint8 code per subspace
    return CODECS[codec][1] * dim


def store_bytes(codec: str, n_rows: int, dim: int) -> int:
    """Total traversal-store bytes for ``n_rows`` rows: rows plus codec
    calibration state (sq8's shared per-dimension scale vector; pq's
    shared ``(m_sub, 256, dsub)`` float32 codebooks = ``256 * dim * 4``
    bytes).  The ONE byte-accounting rule — VectorStore.memory_bytes,
    DEGIndex.memory_stats and ShardedDEG.memory_stats all delegate
    here."""
    total = n_rows * bytes_per_row(codec, dim)
    if codec == "sq8":
        total += dim * 4
    if codec == "pq":
        from . import pq

        total += pq.PQ_K * dim * 4
    return total
