from . import pq
from .codec import (CODECS, calibrate_sq8_scale, sq8_decode, sq8_encode)
from .store import VectorStore, as_store, make_store

__all__ = [
    "CODECS", "VectorStore", "as_store", "calibrate_sq8_scale",
    "make_store", "pq", "sq8_decode", "sq8_encode",
]
