from .synthetic import make_dataset, gaussian_mixture, planted_manifold

__all__ = ["make_dataset", "gaussian_mixture", "planted_manifold"]
