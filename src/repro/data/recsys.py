"""Criteo-like categorical click stream (synthetic, reproducible).

Generates batches matching the recsys ``forward`` input contract:
``sparse (B, F) int32`` per-field ids, ``dense (B, n_dense) float32``,
``label (B,) float32`` and (DIN) ``hist (B, S) int32`` with ragged -1
padding.  Ids follow a Zipf distribution (real CTR traffic is heavy-tailed,
which is what makes the embedding gather the hot path).  Labels come from a
planted logistic model over a low-rank embedding of the ids, so training
actually reduces the BCE loss (integration tests rely on this).
"""
from __future__ import annotations

import numpy as np


class CriteoLikeStream:
    def __init__(self, cfg, seed: int = 0, zipf_a: float = 1.3):
        self.cfg = cfg
        self.seed = seed
        self.zipf_a = zipf_a
        rng = np.random.default_rng(seed ^ 0x5EED)
        # planted model: a secret scalar weight per (field, bucket-of-64)
        self._w = {
            f: rng.normal(0, 1, size=64).astype(np.float32)
            for f in range(cfg.n_sparse)
        }
        self._wd = rng.normal(0, 0.3, size=max(cfg.n_dense, 1)
                              ).astype(np.float32)

    def _ids(self, rng, vocab: int, size) -> np.ndarray:
        """Zipf-ish ids in [0, vocab): rank = zipf sample clipped."""
        z = rng.zipf(self.zipf_a, size=size)
        return ((z - 1) % vocab).astype(np.int32)

    def batch(self, step: int, batch_size: int) -> dict:
        """Deterministic in (seed, step) — the fault-tolerance contract."""
        cfg = self.cfg
        rng = np.random.default_rng((self.seed, step))
        out = {}
        sparse = np.stack(
            [self._ids(rng, v, batch_size) for v in cfg.vocab_sizes], axis=1)
        out["sparse"] = sparse
        logit = np.zeros(batch_size, np.float32)
        for f in range(cfg.n_sparse):
            logit += self._w[f][sparse[:, f] % 64]
        if cfg.n_dense:
            dense = rng.gamma(2.0, 2.0, size=(batch_size, cfg.n_dense)
                              ).astype(np.float32)
            out["dense"] = dense
            logit += np.log1p(dense) @ self._wd[: cfg.n_dense]
        if cfg.kind == "din":
            S = cfg.seq_len
            hist = self._ids(rng, cfg.vocab_sizes[cfg.item_field],
                             (batch_size, S))
            lengths = rng.integers(1, S + 1, size=batch_size)
            mask = np.arange(S)[None, :] >= lengths[:, None]
            hist[mask] = -1
            out["hist"] = hist
        p = 1.0 / (1.0 + np.exp(-(logit - logit.mean())))
        out["label"] = (rng.uniform(size=batch_size) < p).astype(np.float32)
        return out

    def batches(self, batch_size: int, start_step: int = 0):
        step = start_step
        while True:
            yield self.batch(step, batch_size)
            step += 1
