"""Graph data: CSR containers, synthetic generators, and a real neighbor
sampler (GraphSAGE-style fanout sampling) for the `minibatch_lg` shape.

The sampler is jittable: uniform-with-replacement sampling from CSR rows via
``row_ptr[v] + randint(deg[v])``; isolated nodes fall back to self-loops.
Output subgraphs have *static* shapes: ``B*(1+f1+f1*f2)`` nodes and
``B*(f1+f1*f2)`` child->parent edges, ready for ``egnn_forward``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    row_ptr: np.ndarray      # (N+1,) int64
    col_idx: np.ndarray      # (E,) int32
    n_nodes: int

    @property
    def n_edges(self) -> int:
        return int(self.col_idx.shape[0])

    def degrees(self) -> np.ndarray:
        return np.diff(self.row_ptr)


def random_power_law_graph(n: int, avg_degree: int, seed: int = 0,
                           alpha: float = 1.6) -> CSRGraph:
    """Synthetic power-law graph (reddit/ogb stand-in for smoke tests)."""
    rng = np.random.default_rng(seed)
    w = rng.pareto(alpha, size=n) + 1.0
    p = w / w.sum()
    n_edges = n * avg_degree
    src = rng.choice(n, size=n_edges, p=p)
    dst = rng.integers(0, n, size=n_edges)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    row_ptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(row_ptr[1:], src, 1)
    row_ptr = np.cumsum(row_ptr)
    return CSRGraph(row_ptr=row_ptr, col_idx=dst.astype(np.int32), n_nodes=n)


def random_geometric_graph(n: int, k: int, dim: int = 3, seed: int = 0):
    """kNN graph over random coordinates (cora/molecule stand-in).
    Returns (CSRGraph, coords (n, dim))."""
    from repro.core.distances import exact_knn_batched

    rng = np.random.default_rng(seed)
    coords = rng.normal(size=(n, dim)).astype(np.float32)
    _, ids = exact_knn_batched(coords, coords, k + 1, tile=4096)
    dst = ids[:, 1:].reshape(-1).astype(np.int32)
    row_ptr = np.arange(0, n * k + 1, k, dtype=np.int64)
    return CSRGraph(row_ptr=row_ptr, col_idx=dst, n_nodes=n), coords


@functools.partial(jax.jit, static_argnames=("fanouts",))
def sample_neighbors(row_ptr: Array, col_idx: Array, deg: Array,
                     seeds: Array, rng_key: Array,
                     fanouts: tuple[int, ...]):
    """Fanout sampling. seeds (B,) -> (nodes (n_sub,), edges (2, n_edge)).

    Layout: nodes = [seeds | hop1 | hop2 | ...]; every sampled neighbor adds
    one edge (child -> parent index *within the subgraph*).
    """
    B = seeds.shape[0]
    frontier = seeds
    frontier_off = 0
    nodes = [seeds]
    edges_src: list = []
    edges_dst: list = []
    total = B
    for f in fanouts:
        key, rng_key = jax.random.split(rng_key)
        nf = frontier.shape[0]
        d = jnp.maximum(deg[frontier], 1)
        r = jax.random.randint(key, (nf, f), 0, 1 << 30)
        off = (r % d[:, None]).astype(row_ptr.dtype)
        gather_at = row_ptr[frontier][:, None] + off            # (nf, f)
        nbr = jnp.take(col_idx, gather_at.reshape(-1), axis=0)
        isolated = (deg[frontier] == 0)[:, None]
        nbr = jnp.where(jnp.broadcast_to(isolated, (nf, f)).reshape(-1),
                        jnp.repeat(frontier, f), nbr)
        child_pos = total + jnp.arange(nf * f, dtype=jnp.int32)
        parent_pos = jnp.repeat(
            frontier_off + jnp.arange(nf, dtype=jnp.int32), f)
        nodes.append(nbr.astype(jnp.int32))
        edges_src.append(child_pos)
        edges_dst.append(parent_pos)
        frontier_off = total
        total += nf * f
        frontier = nbr
    nodes = jnp.concatenate(nodes)
    edges = jnp.stack([jnp.concatenate(edges_src),
                       jnp.concatenate(edges_dst)])
    return nodes, edges


def subgraph_batch(graph: CSRGraph, feats: np.ndarray, labels: np.ndarray,
                   seeds: np.ndarray, rng_key, fanouts: Sequence[int],
                   coords: np.ndarray | None = None) -> dict:
    """Assemble an EGNN-ready batch from a sampled subgraph."""
    deg = jnp.asarray(graph.degrees().astype(np.int32))
    nodes, edges = sample_neighbors(
        jnp.asarray(graph.row_ptr), jnp.asarray(graph.col_idx), deg,
        jnp.asarray(seeds, jnp.int32), rng_key, tuple(fanouts))
    nodes_np = np.asarray(nodes)
    f = feats[nodes_np]
    if coords is None:
        rng = np.random.default_rng(0)
        coords_all = rng.normal(size=(graph.n_nodes, 3)).astype(np.float32)
        c = coords_all[nodes_np]
    else:
        c = coords[nodes_np]
    lab = np.full(nodes_np.shape[0], -1, dtype=np.int32)
    lab[: seeds.shape[0]] = labels[seeds]        # supervise seeds only
    return {
        "feats": jnp.asarray(f),
        "coords": jnp.asarray(c),
        "edges": edges,
        "labels": jnp.asarray(lab),
    }


def partition_edges_by_dst(edges: np.ndarray, n_nodes_pad: int,
                           n_shards: int,
                           edge_valid: np.ndarray | None = None):
    """Reorder edges so shard ``s`` holds exactly the edges whose dst lies in
    its node range [s*Nl, (s+1)*Nl) — the data-layout contract of
    ``models.egnn.make_sharded_loss``.  Per-shard blocks are padded to equal
    size with invalid self-edges.  Returns (edges (2, E_pad), valid (E_pad,)).
    """
    edges = np.asarray(edges)
    if edge_valid is None:
        edge_valid = np.ones(edges.shape[1], bool)
    Nl = n_nodes_pad // n_shards
    owner = edges[1] // Nl
    blocks = []
    max_e = 0
    for s in range(n_shards):
        sel = np.nonzero((owner == s) & edge_valid)[0]
        blocks.append(edges[:, sel])
        max_e = max(max_e, sel.size)
    out = np.zeros((2, n_shards * max_e), dtype=np.int32)
    valid = np.zeros(n_shards * max_e, bool)
    for s, blk in enumerate(blocks):
        lo = s * max_e
        out[:, lo: lo + blk.shape[1]] = blk
        # padding edges: self-loop on the shard's first node, masked out
        out[:, lo + blk.shape[1]: lo + max_e] = s * Nl
        valid[lo: lo + blk.shape[1]] = True
    return out, valid


def subgraph_shapes(batch_nodes: int, fanouts: Sequence[int]) -> tuple[int, int]:
    """Static (n_sub_nodes, n_sub_edges) for given batch/fanouts."""
    total, frontier, n_edges = batch_nodes, batch_nodes, 0
    for f in fanouts:
        n_edges += frontier * f
        frontier *= f
        total += frontier
    return total, n_edges
