"""Reproducible synthetic datasets.

The paper evaluates on SIFT1M / GloVe / Audio / Enron and stresses that the
*local intrinsic dimensionality* (LID) of a dataset governs difficulty
(Sec. 6.1, observation 2 in Sec. 6.5).  Offline we generate controlled
analogues: ``planted_manifold`` embeds a k-dimensional manifold into R^m so
the LID (~k) can be dialed independently of the ambient dimension — letting
benchmarks reproduce the paper's LID-dependent behavior without the files.
"""
from __future__ import annotations

import numpy as np


def gaussian_mixture(n: int, dim: int, n_clusters: int = 32,
                     cluster_std: float = 0.15, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, dim)).astype(np.float32)
    assign = rng.integers(0, n_clusters, size=n)
    pts = centers[assign] + cluster_std * rng.normal(size=(n, dim))
    return pts.astype(np.float32)


def planted_manifold(n: int, dim: int, intrinsic_dim: int = 8,
                     noise: float = 0.01, seed: int = 0) -> np.ndarray:
    """Points on a random smooth intrinsic_dim-manifold in R^dim (LID control)."""
    rng = np.random.default_rng(seed)
    z = rng.normal(size=(n, intrinsic_dim)).astype(np.float32)
    # random degree-2 feature lift, then random projection to R^dim
    n_feat = intrinsic_dim * (intrinsic_dim + 3) // 2
    feats = [z]
    iu = np.triu_indices(intrinsic_dim)
    feats.append((z[:, :, None] * z[:, None, :])[:, iu[0], iu[1]])
    phi = np.concatenate(feats, axis=1)
    proj = rng.normal(size=(phi.shape[1], dim)).astype(np.float32)
    proj /= np.sqrt(phi.shape[1])
    x = phi @ proj + noise * rng.normal(size=(n, dim))
    return x.astype(np.float32)


def uniform_cube(n: int, dim: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.uniform(-1, 1, size=(n, dim)).astype(np.float32)


_GENERATORS = {
    "gaussian": gaussian_mixture,
    "manifold": planted_manifold,
    "uniform": uniform_cube,
}


def make_dataset(kind: str, n_base: int, n_query: int, dim: int,
                 seed: int = 0, **kw):
    """Returns (base (n_base, dim), queries (n_query, dim))."""
    gen = _GENERATORS[kind]
    pts = gen(n_base + n_query, dim, seed=seed, **kw)
    rng = np.random.default_rng(seed + 1)
    perm = rng.permutation(pts.shape[0])
    return pts[perm[:n_base]], pts[perm[n_base:]]
