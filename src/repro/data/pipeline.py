"""Sharded, deterministic host data pipeline (DESIGN.md §4).

``ShardedPipeline`` wraps any ``batch_fn(step) -> global batch`` and

* slices each host's shard of the global batch (``host_id/num_hosts`` —
  on this single-process container both are 0/1, on a real pod they come
  from ``jax.process_index()``);
* prefetches ahead on a background thread (the host-side analogue of the
  device-side overlap the train step does with collectives);
* is deterministic in ``(seed, step)``: a restart at step k replays the
  identical stream, which is what makes checkpoint-resume exact.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Optional

import jax
import numpy as np


def host_shard(batch: Any, host_id: int, num_hosts: int) -> Any:
    """Slice the leading dim of every array leaf to this host's shard."""
    if num_hosts <= 1:
        return batch

    def slc(x):
        b = x.shape[0]
        assert b % num_hosts == 0, (b, num_hosts)
        per = b // num_hosts
        return x[host_id * per: (host_id + 1) * per]

    return jax.tree.map(slc, batch)


class ShardedPipeline:
    def __init__(self, batch_fn: Callable[[int], Any], *,
                 host_id: Optional[int] = None,
                 num_hosts: Optional[int] = None,
                 prefetch: int = 2):
        self.batch_fn = batch_fn
        self.host_id = host_id if host_id is not None else 0
        self.num_hosts = num_hosts if num_hosts is not None else 1
        self.prefetch = prefetch
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._next_step = 0

    def __call__(self, step: int) -> Any:
        """Random access (the train_loop contract)."""
        return host_shard(self.batch_fn(step), self.host_id, self.num_hosts)

    # -- streaming with prefetch -----------------------------------------
    def start(self, start_step: int = 0) -> "ShardedPipeline":
        self._next_step = start_step
        self._stop.clear()

        def worker():
            step = start_step
            while not self._stop.is_set():
                try:
                    self._q.put(( step, self(step)), timeout=0.2)
                    step += 1
                except queue.Full:
                    continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()
        return self

    def get(self) -> tuple[int, Any]:
        return self._q.get()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None


def lm_synthetic_batch_fn(vocab: int, batch: int, seq: int, seed: int = 0):
    """Deterministic synthetic LM stream (markov-ish for a learnable signal)."""

    def fn(step: int) -> dict:
        rng = np.random.default_rng((seed, step))
        toks = rng.integers(0, vocab, size=(batch, seq + 1), dtype=np.int64)
        # plant bigram structure: with p=.5 the next token = (t*7+3) % vocab
        flip = rng.uniform(size=(batch, seq)) < 0.5
        nxt = (toks[:, :-1] * 7 + 3) % vocab
        toks[:, 1:][flip] = nxt[flip]
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    return fn
