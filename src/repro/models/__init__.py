"""Model substrate: the assigned architectures as pure-JAX pytree models.

Nothing here depends on the ANN core; the integration point is that these
models *produce embeddings* that DEG indexes (see DESIGN.md §5).
"""
