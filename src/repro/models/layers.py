"""Shared neural layers (pure JAX, pytree params, no framework deps).

Conventions:
* params are nested dicts of fp32 arrays; compute casts to ``cfg.dtype``
  (bf16 by default) with fp32 logits/softmax/norm statistics;
* every ``init_*`` has a matching ``abs_*`` twin returning
  ``jax.ShapeDtypeStruct`` so the dry-run can build the full-size parameter
  tree without allocating memory.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------
def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)
            ).astype(dtype)


def abs_p(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------
def rope_frequencies(d_head: int, theta: float = 10000.0) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x (..., S, H, Dh), positions (..., S) -> rotated x (pairwise halves)."""
    d_head = x.shape[-1]
    inv = rope_frequencies(d_head, theta)                    # (Dh/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, Dh/2)
    cos = jnp.cos(ang)[..., None, :]                         # (..., S, 1, Dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention (GQA + causal + optional sliding window + query chunking)
# --------------------------------------------------------------------------
_NEG = -1e30   # large finite mask value: softmax of an all-masked row is
               # uniform, never NaN (matters under remat'd backward)


def _mask_bias(q_pos: Array, k_pos: Array, window: Optional[int],
               k_valid: Optional[Array] = None) -> Array:
    """additive fp32 bias (Sq, Sk): 0 where attendable, -1e30 otherwise.

    Positions are **1-D** — they are identical across the batch, so the bias
    must not carry a batch dim (a (B, Sq, Sk) fp32 bias is a replicated
    multi-GB buffer under SPMD; found via the dry-run HLO, see EXPERIMENTS.md
    §Perf)."""
    causal = k_pos[None, :] <= q_pos[:, None]
    ok = causal
    if window is not None:  # may be a traced per-layer scalar (scan body)
        w = jnp.asarray(window, jnp.int32)
        ok &= (q_pos[:, None] - k_pos[None, :]) < w
    if k_valid is not None:
        ok &= k_valid[None, :]
    return jnp.where(ok, 0.0, _NEG).astype(jnp.float32)


def gqa_attention(q: Array, k: Array, v: Array, q_pos: Array, k_pos: Array,
                  *, window: Optional[int] = None,
                  k_valid: Optional[Array] = None,
                  q_chunk: Optional[int] = None,
                  softmax_scale: Optional[float] = None) -> Array:
    """q (B, Sq, Hq, Dh), k/v (B, Sk, Hkv, Dh) -> (B, Sq, Hq, Dh).

    ``q_pos`` (Sq,) / ``k_pos`` (Sk,) are 1-D position ids shared by every
    batch lane.  ``q_chunk`` bounds the materialized score tile to
    (B, H, q_chunk, Sk) — the pure-JAX flash-style path for long prefill.
    ``k_valid`` (Sk,) masks cache slots (decode).
    """
    B, Sq, Hq, Dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    rep = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(Dh)
    if rep > 1:
        # Expand KV groups to full query heads (Megatron-style replication).
        # Keeping the grouped (Hkv, rep) einsum pins the shardable head dim
        # to Hkv, which is smaller than the "model" axis for every assigned
        # GQA config — the expanded form lets TP shard all Hq heads and
        # keeps the fp32 score tile fully partitioned (dry-run finding,
        # EXPERIMENTS.md §Perf).
        k = jnp.broadcast_to(k[:, :, :, None, :],
                             (B, Sk, Hkv, rep, Dh)).reshape(B, Sk, Hq, Dh)
        v = jnp.broadcast_to(v[:, :, :, None, :],
                             (B, Sk, Hkv, rep, Dh)).reshape(B, Sk, Hq, Dh)

    def attend(qc: Array, qp: Array) -> Array:
        # qc (B, Sc, Hq, Dh) -> (B, Sc, Hq, Dh); bf16 MXU, fp32 accumulate
        logits = jnp.einsum("bqhd,bkhd->bhqk", qc, k,
                            preferred_element_type=jnp.float32) * scale
        bias = _mask_bias(qp, k_pos, window, k_valid)        # (Sc, Sk)
        logits = logits + bias[None, None, :, :]
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(q.dtype), v,
                         preferred_element_type=jnp.float32)
        return out.astype(q.dtype)

    if q_chunk is None or q_chunk >= Sq:
        return attend(q, q_pos)
    n_chunks = (Sq + q_chunk - 1) // q_chunk
    pad = n_chunks * q_chunk - Sq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    pp = jnp.pad(q_pos, (0, pad), constant_values=-1)
    qs = qp.reshape(B, n_chunks, q_chunk, Hq, Dh).swapaxes(0, 1)
    ps = pp.reshape(n_chunks, q_chunk)
    out = jax.lax.map(lambda t: attend(*t), (qs, ps))
    out = out.swapaxes(0, 1).reshape(B, n_chunks * q_chunk, Hq, Dh)
    return out[:, :Sq]


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------
def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    dt = x.dtype
    g = jax.nn.silu(x @ w_gate.astype(dt))
    u = x @ w_up.astype(dt)
    return ((g * u) @ w_down.astype(dt))


def gelu_mlp(x: Array, w_up: Array, b_up: Array, w_down: Array,
             b_down: Array) -> Array:
    dt = x.dtype
    h = jax.nn.gelu(x @ w_up.astype(dt) + b_up.astype(dt))
    return h @ w_down.astype(dt) + b_down.astype(dt)


def mlp_tower(key, sizes: list[int], dtype=jnp.float32) -> dict:
    """Plain MLP parameter stack: sizes [in, h1, ..., out]."""
    keys = jax.random.split(key, len(sizes) - 1)
    return {
        f"w{i}": dense_init(keys[i], (sizes[i], sizes[i + 1]), dtype=dtype)
        for i in range(len(sizes) - 1)
    } | {
        f"b{i}": jnp.zeros((sizes[i + 1],), dtype) for i in range(len(sizes) - 1)
    }


def abs_mlp_tower(sizes: list[int], dtype=jnp.float32) -> dict:
    return {f"w{i}": abs_p(sizes[i], sizes[i + 1], dtype=dtype)
            for i in range(len(sizes) - 1)} | {
        f"b{i}": abs_p(sizes[i + 1], dtype=dtype)
        for i in range(len(sizes) - 1)}


def apply_mlp_tower(params: dict, x: Array, act=jax.nn.relu,
                    final_act=None) -> Array:
    n = len([k for k in params if k.startswith("w")])
    dt = x.dtype
    for i in range(n):
        x = x @ params[f"w{i}"].astype(dt) + params[f"b{i}"].astype(dt)
        if i < n - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x
