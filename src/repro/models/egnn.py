"""E(n)-Equivariant Graph Neural Network (Satorras et al., arXiv:2102.09844).

Message passing is implemented with ``jax.ops.segment_sum`` over an
edge-index array — the JAX-native scatter formulation the assignment
prescribes (no sparse formats needed).  Three input layouts map to the four
assigned shapes:

* ``egnn_forward``        — one (possibly huge) graph: nodes (N, F),
  coords (N, 3), edges (2, E).  Used by full_graph_sm / ogb_products and by
  the *sampled* minibatch_lg subgraphs (the sampler in data/graphs.py emits
  exactly this layout, padded to static shape).
* ``egnn_forward_batched``— vmapped over a batch of small dense graphs
  (molecule shape).

Equivariance: coordinate updates are linear combinations of relative
positions, so rotating/translating inputs rotates/translates outputs —
asserted as a property test (tests/test_models_egnn.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers import abs_mlp_tower, abs_p, apply_mlp_tower, mlp_tower

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str = "egnn"
    n_layers: int = 4
    d_hidden: int = 64
    d_feat: int = 1433          # input feature dim (dataset-dependent)
    d_edge: int = 0             # optional edge attribute dim
    n_classes: int = 40
    coord_agg: str = "mean"
    dtype: object = jnp.float32
    # SPMD: constrain node arrays (h, x) to this sharding at every layer
    # boundary (a §Perf variant: 256-way node sharding instead of DP-only).
    node_shard_axes: object = None


def abstract_params(cfg: EGNNConfig) -> dict:
    h = cfg.d_hidden
    msg_in = 2 * h + 1 + cfg.d_edge
    layer = {
        "phi_e": abs_mlp_tower([msg_in, h, h]),
        "phi_x": abs_mlp_tower([h, h, 1]),
        "phi_h": abs_mlp_tower([2 * h, h, h]),
    }
    return {
        "encoder": abs_p(cfg.d_feat, h),
        "layers": jax.tree.map(
            lambda s: abs_p(cfg.n_layers, *s.shape), layer),
        "decoder": abs_mlp_tower([h, h, cfg.n_classes]),
    }


def init_params(key: jax.Array, cfg: EGNNConfig) -> dict:
    from .layers import dense_init

    h = cfg.d_hidden
    msg_in = 2 * h + 1 + cfg.d_edge
    keys = jax.random.split(key, cfg.n_layers * 3 + 2)
    layers = []
    for i in range(cfg.n_layers):
        layers.append({
            "phi_e": mlp_tower(keys[3 * i], [msg_in, h, h]),
            "phi_x": mlp_tower(keys[3 * i + 1], [h, h, 1]),
            "phi_h": mlp_tower(keys[3 * i + 2], [2 * h, h, h]),
        })
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {
        "encoder": dense_init(keys[-2], (cfg.d_feat, h)),
        "layers": stacked,
        "decoder": mlp_tower(keys[-1], [h, h, cfg.n_classes]),
    }


def _egnn_layer(lp: dict, h: Array, x: Array, edges: Array,
                edge_attr, n_nodes: int, edge_valid, cfg: EGNNConfig):
    """h (N, F), x (N, 3), edges (2, E) int32 (src, dst)."""

    def _agg_wsc(t):
        # Pin scatter outputs to the node sharding: GSPMD then emits a
        # reduce-scatter of the per-edge-shard partial aggregates instead of
        # a full all-reduce (§Perf EGNN iteration 3).
        if cfg.node_shard_axes is None:
            return t
        return jax.lax.with_sharding_constraint(
            t, jax.sharding.PartitionSpec(tuple(cfg.node_shard_axes),
                                          *([None] * (t.ndim - 1))))

    src, dst = edges[0], edges[1]
    hv_s = jnp.take(h, src, axis=0)
    hv_d = jnp.take(h, dst, axis=0)
    xs = jnp.take(x, src, axis=0)
    xd = jnp.take(x, dst, axis=0)
    rel = xd - xs                                          # (E, 3)
    d2 = jnp.sum(rel * rel, axis=-1, keepdims=True)
    feats = [hv_d, hv_s, d2]
    if edge_attr is not None:
        feats.append(edge_attr)
    m = apply_mlp_tower(lp["phi_e"], jnp.concatenate(feats, axis=-1),
                        act=jax.nn.silu, final_act=jax.nn.silu)   # (E, F)
    if edge_valid is not None:
        m = m * edge_valid[:, None].astype(m.dtype)
    # coordinate update (equivariant): x_d += agg_e rel * phi_x(m)
    cw = apply_mlp_tower(lp["phi_x"], m, act=jax.nn.silu)          # (E, 1)
    if edge_valid is not None:
        cw = cw * edge_valid[:, None].astype(cw.dtype)
    coord_msg = _agg_wsc(jax.ops.segment_sum(rel * cw, dst,
                                             num_segments=n_nodes))
    if cfg.coord_agg == "mean":
        deg = _agg_wsc(jax.ops.segment_sum(
            jnp.ones_like(cw[:, 0]) if edge_valid is None
            else edge_valid.astype(cw.dtype), dst, num_segments=n_nodes))
        coord_msg = coord_msg / jnp.maximum(deg[:, None], 1.0)
    x = x + coord_msg
    agg = _agg_wsc(jax.ops.segment_sum(m, dst,
                                       num_segments=n_nodes))     # (N, F)
    upd = apply_mlp_tower(lp["phi_h"], jnp.concatenate([h, agg], -1),
                          act=jax.nn.silu)
    return h + upd, x


def _node_wsc(t: Array, cfg: EGNNConfig) -> Array:
    if cfg.node_shard_axes is None:
        return t
    spec = jax.sharding.PartitionSpec(tuple(cfg.node_shard_axes),
                                      *([None] * (t.ndim - 1)))
    return jax.lax.with_sharding_constraint(t, spec)


def egnn_forward(params: dict, feats: Array, coords: Array, edges: Array,
                 cfg: EGNNConfig, edge_attr=None, edge_valid=None):
    """Returns (node logits (N, n_classes), final coords (N, 3))."""
    n_nodes = feats.shape[0]
    h = feats.astype(cfg.dtype) @ params["encoder"].astype(cfg.dtype)
    x = coords.astype(cfg.dtype)

    def body(carry, lp):
        h, x = carry
        h, x = _node_wsc(h, cfg), _node_wsc(x, cfg)
        h, x = _egnn_layer(lp, h, x, edges, edge_attr, n_nodes, edge_valid,
                           cfg)
        return (h, x), None

    (h, x), _ = jax.lax.scan(body, (h, x), params["layers"])
    logits = apply_mlp_tower(params["decoder"], h, act=jax.nn.silu)
    return logits.astype(jnp.float32), x


def egnn_forward_batched(params, feats, coords, edges, cfg: EGNNConfig,
                         edge_valid=None):
    """feats (B, N, F), coords (B, N, 3), edges (B, 2, E)."""
    fn = lambda f, c, e, ev: egnn_forward(params, f, c, e, cfg,
                                          edge_valid=ev)
    if edge_valid is None:
        edge_valid = jnp.ones(edges[:, 0].shape, bool)
    return jax.vmap(fn)(feats, coords, edges, edge_valid)


# ---------------------------------------------------------------------------
# shard_map version: dst-partitioned edges (EXPERIMENTS.md §Perf, EGNN it. 4)
# ---------------------------------------------------------------------------
def make_sharded_loss(cfg: EGNNConfig, mesh, shard_axes) -> "Callable":
    """Locality-aware distributed EGNN loss.

    GSPMD lowers ``segment_sum`` over sharded edges into a full-size scatter
    + ALL-REDUCE of the node arrays per layer — it has no reduce-scatter
    strategy for scatters, and no way to exploit edge locality.  This
    shard_map version imposes a *data-layout contract* instead: device ``s``
    owns node rows ``[s*Nl, (s+1)*Nl)`` and exactly the edges whose dst lies
    in that range (``data.graphs.partition_edges_by_dst``).  Then every
    scatter is local, and the only collective is one all-gather of the
    (bf16) node arrays per layer for the src-side halo — whose transpose in
    backward is a reduce-scatter.  Wire bytes: one AG per layer vs. two+
    f32 ARs.
    """
    import functools as _ft

    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    axes = tuple(shard_axes)

    def local(p, feats, coords, edges, ev, labels):
        Nl = feats.shape[0]
        idx = jax.lax.axis_index(axes)
        src, dst = edges[0], edges[1]
        dst_local = jnp.clip(dst - idx * Nl, 0, Nl - 1)
        evf = ev.astype(cfg.dtype)
        h = feats.astype(cfg.dtype) @ p["encoder"].astype(cfg.dtype)
        x = coords.astype(cfg.dtype)

        def body(carry, lp):
            h, x = carry
            h_full = jax.lax.all_gather(h, axes, axis=0, tiled=True)
            x_full = jax.lax.all_gather(x, axes, axis=0, tiled=True)
            hv_s = jnp.take(h_full, src, axis=0)
            hv_d = jnp.take(h_full, dst, axis=0)
            rel = jnp.take(x_full, dst, axis=0) - jnp.take(x_full, src,
                                                           axis=0)
            d2 = jnp.sum(rel * rel, axis=-1, keepdims=True)
            m = apply_mlp_tower(lp["phi_e"],
                                jnp.concatenate([hv_d, hv_s, d2], -1),
                                act=jax.nn.silu, final_act=jax.nn.silu)
            m = m * evf[:, None]
            cw = apply_mlp_tower(lp["phi_x"], m, act=jax.nn.silu)
            cw = cw * evf[:, None]
            coord_msg = jax.ops.segment_sum(rel * cw, dst_local,
                                            num_segments=Nl)     # LOCAL
            if cfg.coord_agg == "mean":
                deg = jax.ops.segment_sum(evf, dst_local, num_segments=Nl)
                coord_msg = coord_msg / jnp.maximum(deg[:, None], 1.0)
            x = x + coord_msg
            agg = jax.ops.segment_sum(m, dst_local, num_segments=Nl)
            h = h + apply_mlp_tower(lp["phi_h"],
                                    jnp.concatenate([h, agg], -1),
                                    act=jax.nn.silu)
            return (h, x), None

        (h, x), _ = jax.lax.scan(body, (h, x), p["layers"])
        logits = apply_mlp_tower(p["decoder"], h,
                                 act=jax.nn.silu).astype(jnp.float32)
        mask = labels >= 0
        safe = jnp.clip(labels, 0, cfg.n_classes - 1)
        lse = jax.nn.logsumexp(logits, axis=-1)
        true = jnp.take_along_axis(logits, safe[:, None], axis=-1)[:, 0]
        mf = mask.astype(jnp.float32)
        num = jax.lax.psum(jnp.sum((lse - true) * mf), axes)
        den = jax.lax.psum(jnp.sum(mf), axes)
        loss = num / jnp.maximum(den, 1.0)
        return loss

    def loss_fn(params, batch):
        pspec = jax.tree.map(lambda _: P(), params)
        f = shard_map(
            local, mesh=mesh,
            in_specs=(pspec, P(axes, None), P(axes, None), P(None, axes),
                      P(axes), P(axes)),
            out_specs=P(), check_vma=False)
        loss = f(params, batch["feats"], batch["coords"], batch["edges"],
                 batch["edge_valid"], batch["labels"])
        return loss, {"nll": loss}

    return loss_fn


def loss_fn(params, batch, cfg: EGNNConfig):
    """Node classification cross-entropy (full-graph or sampled)."""
    if batch["feats"].ndim == 3:
        logits, _ = egnn_forward_batched(params, batch["feats"],
                                         batch["coords"], batch["edges"], cfg,
                                         batch.get("edge_valid"))
        logits = jnp.mean(logits, axis=1)        # graph-level: mean pool
    else:
        logits, _ = egnn_forward(params, batch["feats"], batch["coords"],
                                 batch["edges"], cfg,
                                 edge_valid=batch.get("edge_valid"))
    labels = batch["labels"]
    mask = (labels >= 0)
    safe = jnp.clip(labels, 0, cfg.n_classes - 1)
    lse = jax.nn.logsumexp(logits, axis=-1)
    true = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    m = mask.astype(jnp.float32)
    loss = jnp.sum((lse - true) * m) / jnp.maximum(jnp.sum(m), 1.0)
    return loss, {"nll": loss}


def node_embeddings(params, feats, coords, edges, cfg: EGNNConfig,
                    edge_valid=None):
    """Penultimate node embeddings — what DEG indexes for molecule retrieval."""
    n_nodes = feats.shape[0]
    h = feats.astype(cfg.dtype) @ params["encoder"].astype(cfg.dtype)
    x = coords.astype(cfg.dtype)

    def body(carry, lp):
        h, x = carry
        h, x = _egnn_layer(lp, h, x, edges, None, n_nodes, edge_valid, cfg)
        return (h, x), None

    (h, _), _ = jax.lax.scan(body, (h, x), params["layers"])
    return h.astype(jnp.float32)
