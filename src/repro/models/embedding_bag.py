"""EmbeddingBag built from JAX primitives (no native op exists).

Two layouts:

* ``embedding_bag_fixed``  — fixed fields (B, F): gather + (weighted) sum.
  This is the DLRM/DCN layout; the Pallas kernel ``kernels/bag_lookup`` is
  the fused version and is tested against this function.
* ``embedding_bag_ragged`` — ragged bags flattened to (total_ids,) with
  ``segment_ids``: ``jnp.take`` + ``jax.ops.segment_sum`` exactly as the
  assignment prescribes.

``sharded_embedding_lookup`` is the row-sharded distributed variant used
inside ``shard_map``: each shard owns a contiguous row range of the (stacked)
table, resolves local hits, and the partial results are psum'd over the
sharding axes.  See distributed/sharding.py for the axis layout and
EXPERIMENTS.md §Perf for the reduce-scatter optimization of this collective.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


def embedding_bag_fixed(table: Array, ids: Array,
                        weights: Optional[Array] = None,
                        combiner: str = "sum") -> Array:
    """table (V, E), ids (B, F) -> (B, E). INVALID (<0) ids contribute 0."""
    V = table.shape[0]
    valid = ids >= 0
    safe = jnp.clip(ids, 0, V - 1)
    rows = jnp.take(table, safe, axis=0)                 # (B, F, E)
    w = valid.astype(rows.dtype)
    if weights is not None:
        w = w * weights.astype(rows.dtype)
    out = jnp.sum(rows * w[..., None], axis=1)
    if combiner == "mean":
        out = out / jnp.maximum(w.sum(axis=1, keepdims=True), 1.0)
    elif combiner != "sum":
        raise ValueError(combiner)
    return out


def embedding_bag_ragged(table: Array, flat_ids: Array, segment_ids: Array,
                         num_bags: int,
                         weights: Optional[Array] = None,
                         combiner: str = "sum") -> Array:
    """Ragged bags: flat_ids (N,), segment_ids (N,) -> (num_bags, E)."""
    rows = jnp.take(table, flat_ids, axis=0)             # (N, E)
    if weights is not None:
        rows = rows * weights[:, None].astype(rows.dtype)
    out = jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)
    if combiner == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(flat_ids, rows.dtype),
                                  segment_ids, num_segments=num_bags)
        out = out / jnp.maximum(cnt[:, None], 1.0)
    return out


def embedding_bag_max(table: Array, flat_ids: Array, segment_ids: Array,
                      num_bags: int) -> Array:
    rows = jnp.take(table, flat_ids, axis=0)
    return jax.ops.segment_max(rows, segment_ids, num_segments=num_bags)


def sharded_embedding_lookup(local_table: Array, ids: Array, row_offset: Array,
                             axis_names: Sequence[str]) -> Array:
    """Row-sharded lookup inside shard_map.

    local_table (V_local, E): this shard's row range [row_offset,
    row_offset + V_local); ids (B, F) are *global* row indices.  Returns the
    full (B, F, E) gather, psum'd over ``axis_names``.
    """
    V_local = local_table.shape[0]
    local = ids - row_offset
    valid = (local >= 0) & (local < V_local)
    safe = jnp.clip(local, 0, V_local - 1)
    rows = jnp.take(local_table, safe, axis=0)           # (B, F, E)
    rows = jnp.where(valid[..., None], rows, 0.0)
    return jax.lax.psum(rows, axis_names)


def stack_vocab_offsets(vocab_sizes: Sequence[int]) -> tuple[int, jnp.ndarray]:
    """Stack per-field tables into one big table: returns (V_total, offsets)."""
    import numpy as np

    off = np.zeros(len(vocab_sizes), dtype=np.int32)
    total = 0
    for i, v in enumerate(vocab_sizes):
        off[i] = total
        total += int(v)
    return total, jnp.asarray(off)
