"""Decoder-only transformer covering the five assigned LM architectures.

One parameterized implementation: RMSNorm + RoPE + GQA + SwiGLU, optional
sliding-window layers (Mixtral: all layers; Gemma-3: 5 local : 1 global) and
optional MoE FFN (Qwen3-MoE, Mixtral).  Parameters are a pytree of fp32
arrays; per-layer weights carry a leading ``L`` dim and the *training*
forward runs ``jax.lax.scan`` over layers (compact HLO, fast multi-pod
compiles) with ``jax.checkpoint`` remat.  The *serving* path (prefill +
decode) runs a Python loop over layers so each layer can own a cache of its
natural size — sliding-window layers keep a ring buffer of ``window`` slots
instead of the full context (the reason gemma3 decode_32k fits on a v5e).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import (abs_p, apply_rope, dense_init, gqa_attention, rms_norm,
                     swiglu)
from .moe import MoEConfig, abs_moe_layer, init_moe_layer, moe_ffn

Array = jax.Array


def _wsc(x: Array, cfg: "TransformerConfig") -> Array:
    """Constrain (B, S, D) activations to the configured layout."""
    if cfg.act_batch_axes is None and cfg.act_seq_axis is None:
        return x
    spec = jax.sharding.PartitionSpec(cfg.act_batch_axes, cfg.act_seq_axis,
                                      None)
    return jax.lax.with_sharding_constraint(x, spec)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    rope_theta: float = 10000.0
    # sliding window: None = all layers full causal;
    # set + pattern None = every layer windowed (Mixtral SWA);
    # set + pattern p   = p local layers then 1 global, repeating (Gemma-3).
    sliding_window: Optional[int] = None
    local_global_pattern: Optional[int] = None
    moe: Optional[MoEConfig] = None
    tie_embeddings: bool = True
    dtype: Any = jnp.bfloat16
    remat: bool = True
    q_chunk: int = 1024          # query chunking for long prefill
    # MoE SPMD dispatch grouping: tokens are reshaped to (G, T/G) and
    # dispatched per group (vmapped), G = number of data shards.  This keeps
    # the capacity scatter *local to a shard* — the global-cumsum formulation
    # would make GSPMD materialize a cross-shard scatter (DESIGN.md §4).
    moe_groups: int = 1
    moe_shard_axes: Optional[tuple] = None  # mesh axes to pin groups to
    # Activation sharding constraint (mesh axis names for the batch dim of
    # (B, S, D) activations).  Without it the GSPMD solver is free to pick a
    # batch-replicated layout (observed on the 16x16 dry-run: bf16[256,4096,
    # 128] activations = batch all-gathered, d_model sharded -> 16x wasted
    # compute).  None = leave unconstrained (single-device tests).
    act_batch_axes: Optional[tuple] = None
    # Optional sequence-sharding axis for stored activations (sequence
    # parallelism, a §Perf iteration): shards the S dim of layer-boundary
    # activations; GSPMD inserts all-gather before attention and
    # reduce-scatter after the FFN.
    act_seq_axis: Optional[str] = None

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def is_global_layer(self) -> np.ndarray:
        """(L,) bool — which layers attend globally."""
        L = self.n_layers
        if self.sliding_window is None:
            return np.ones(L, bool)
        p = self.local_global_pattern
        if p is None:
            return np.zeros(L, bool)
        return np.array([(i + 1) % (p + 1) == 0 for i in range(L)])

    def layer_window(self, i: int) -> Optional[int]:
        return None if self.is_global_layer()[i] else self.sliding_window

    @property
    def param_count(self) -> int:
        return sum(int(np.prod(x.shape)) for x in
                   jax.tree.leaves(abstract_params(self)))

    @property
    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts)."""
        total = self.param_count
        if self.moe is None:
            return total
        e, k = self.moe.n_experts, self.moe.top_k
        expert_p = 3 * self.d_model * self.moe.d_ff_expert * self.n_layers * e
        return total - expert_p + expert_p * k // e


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------
def _layer_shapes(cfg: TransformerConfig) -> dict[str, tuple]:
    D, Dh = cfg.d_model, cfg.head_dim
    s = {
        "attn_norm": (cfg.n_layers, D),
        "mlp_norm": (cfg.n_layers, D),
        "wq": (cfg.n_layers, D, cfg.n_heads * Dh),
        "wk": (cfg.n_layers, D, cfg.n_kv_heads * Dh),
        "wv": (cfg.n_layers, D, cfg.n_kv_heads * Dh),
        "wo": (cfg.n_layers, cfg.n_heads * Dh, D),
    }
    if cfg.moe is None:
        s |= {
            "w_gate": (cfg.n_layers, D, cfg.d_ff),
            "w_up": (cfg.n_layers, D, cfg.d_ff),
            "w_down": (cfg.n_layers, cfg.d_ff, D),
        }
    return s


def abstract_params(cfg: TransformerConfig) -> dict:
    layers = {k: abs_p(*v) for k, v in _layer_shapes(cfg).items()}
    if cfg.moe is not None:
        layers |= abs_moe_layer(cfg.n_layers, cfg.d_model, cfg.moe)
    p = {
        "embed": abs_p(cfg.vocab, cfg.d_model),
        "final_norm": abs_p(cfg.d_model),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        p["head"] = abs_p(cfg.d_model, cfg.vocab)
    return p


def init_params(key: jax.Array, cfg: TransformerConfig) -> dict:
    ks = iter(jax.random.split(key, 64))
    layers = {}
    for name, shape in _layer_shapes(cfg).items():
        if "norm" in name:
            layers[name] = jnp.zeros(shape, jnp.float32)
        else:
            layers[name] = dense_init(next(ks), shape)
    if cfg.moe is not None:
        layers |= init_moe_layer(next(ks), cfg.n_layers, cfg.d_model, cfg.moe)
    p = {
        "embed": dense_init(next(ks), (cfg.vocab, cfg.d_model), scale=0.02),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        p["head"] = dense_init(next(ks), (cfg.d_model, cfg.vocab))
    return p


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------
def _attention_block(lp: dict, x: Array, q_pos: Array, cfg: TransformerConfig,
                     window_flag: Array, *, k_override=None, v_override=None,
                     k_pos=None, k_valid=None, q_chunk=None) -> Array:
    """One attention sub-block. window_flag: scalar bool (True = windowed).

    For the scanned train path the window decision must be a traced per-layer
    value, so the mask always computes both and selects — the windowed mask is
    an AND with the causal one, so we pass an *effective window* of either
    ``cfg.sliding_window`` or ``>= S`` (no-op).
    """
    B, S, D = x.shape
    dt = x.dtype
    Dh, Hq, Hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = (x @ lp["wq"].astype(dt)).reshape(B, S, Hq, Dh)
    k = (x @ lp["wk"].astype(dt)).reshape(B, S, Hkv, Dh)
    v = (x @ lp["wv"].astype(dt)).reshape(B, S, Hkv, Dh)
    q = apply_rope(q, q_pos, cfg.rope_theta)
    k = apply_rope(k, q_pos, cfg.rope_theta)
    if k_override is not None:
        k, v = k_override, v_override
    else:
        k_pos = q_pos
    if cfg.sliding_window is None:
        window = None
    else:
        # traced select: big window == unrestricted
        big = jnp.int32(1 << 30)
        window = jnp.where(window_flag, jnp.int32(cfg.sliding_window), big)
    out = gqa_attention(q, k, v, q_pos, k_pos, window=window,
                        k_valid=k_valid, q_chunk=q_chunk)
    return out.reshape(B, S, Hq * Dh) @ lp["wo"].astype(dt)


def _ffn_block(lp: dict, x: Array, cfg: TransformerConfig):
    """Returns (out, aux_loss)."""
    if cfg.moe is None:
        return swiglu(x, lp["w_gate"], lp["w_up"], lp["w_down"]), 0.0
    B, S, D = x.shape
    T = B * S
    G = cfg.moe_groups if T % max(cfg.moe_groups, 1) == 0 else 1
    if G <= 1:
        y, aux = moe_ffn(x.reshape(T, D), lp, cfg.moe)
        return y.reshape(B, S, D), aux
    xg = x.reshape(G, T // G, D)
    if cfg.moe_shard_axes is not None:
        xg = jax.lax.with_sharding_constraint(
            xg, jax.sharding.PartitionSpec(cfg.moe_shard_axes, None, None))
    # spmd_axis_name pins every vmapped intermediate (dispatch buffers,
    # expert activations) to the DP axes on the group dim — without it GSPMD
    # partial-contracts the FSDP-sharded d_model dim of the expert weights
    # and ALL-REDUCES the (E, G, C, F) expert activations (observed: 43 GB
    # per layer on mixtral train_4k; see EXPERIMENTS.md §Perf iteration 2).
    yg, auxg = jax.vmap(lambda t: moe_ffn(t, lp, cfg.moe),
                        spmd_axis_name=cfg.moe_shard_axes)(xg)
    return yg.reshape(B, S, D), jnp.mean(auxg)


def _layer(lp: dict, x: Array, q_pos: Array, cfg: TransformerConfig,
           windowed: Array, q_chunk=None, **attn_kw):
    x = _wsc(x, cfg)
    h = rms_norm(x, lp["attn_norm"])
    x = x + _attention_block(lp, h, q_pos, cfg, windowed, q_chunk=q_chunk,
                             **attn_kw)
    h = rms_norm(x, lp["mlp_norm"])
    f, aux = _ffn_block(lp, h, cfg)
    return x + f, aux


# --------------------------------------------------------------------------
# training forward + loss
# --------------------------------------------------------------------------
def forward_train(params: dict, tokens: Array, cfg: TransformerConfig) -> tuple:
    """tokens (B, S) -> (logits (B, S, V) fp32, aux_loss scalar)."""
    B, S = tokens.shape
    dt = cfg.dtype
    x = params["embed"].astype(dt)[tokens]
    q_pos = jnp.arange(S, dtype=jnp.int32)
    windowed = jnp.asarray(~cfg.is_global_layer())

    def body(x, scanned):
        lp, wflag = scanned
        fn = _layer
        if cfg.remat:
            fn = jax.checkpoint(_layer, static_argnums=(3, 5))
        x, aux = fn(lp, x, q_pos, cfg, wflag,
                    cfg.q_chunk if S > cfg.q_chunk else None)
        return x, aux

    x = _wsc(x, cfg)
    x, auxes = jax.lax.scan(body, x, (params["layers"], windowed))
    x = _wsc(x, cfg)
    x = rms_norm(x, params["final_norm"])
    head = params.get("head", None)
    if head is None:
        head = params["embed"].T
    logits = (x @ head.astype(dt)).astype(jnp.float32)
    if cfg.act_batch_axes is not None:
        logits = jax.lax.with_sharding_constraint(
            logits, jax.sharding.PartitionSpec(cfg.act_batch_axes, None,
                                               "model"))
    return logits, jnp.sum(auxes)


def loss_fn(params: dict, batch: dict, cfg: TransformerConfig) -> tuple:
    logits, aux = forward_train(params, batch["tokens"], cfg)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    true = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = jnp.sum((lse - true) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = nll + 0.01 * aux
    return loss, {"nll": nll, "aux": aux}


# --------------------------------------------------------------------------
# serving: per-layer KV caches (ring buffers on sliding-window layers)
# --------------------------------------------------------------------------
def init_cache(cfg: TransformerConfig, batch: int, max_len: int) -> dict:
    ks, vs = [], []
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
    for i in range(cfg.n_layers):
        w = cfg.layer_window(i)
        s = max_len if w is None else min(w, max_len)
        ks.append(jnp.zeros((batch, s, Hkv, Dh), cfg.dtype))
        vs.append(jnp.zeros((batch, s, Hkv, Dh), cfg.dtype))
    return {"k": ks, "v": vs, "pos": jnp.zeros((), jnp.int32)}


def abstract_cache(cfg: TransformerConfig, batch: int, max_len: int) -> dict:
    c = init_cache(cfg, batch, 0)  # cheap: zero-length, just for structure
    ks, vs = [], []
    for i in range(cfg.n_layers):
        w = cfg.layer_window(i)
        s = max_len if w is None else min(w, max_len)
        ks.append(abs_p(batch, s, cfg.n_kv_heads, cfg.head_dim,
                        dtype=cfg.dtype))
        vs.append(abs_p(batch, s, cfg.n_kv_heads, cfg.head_dim,
                        dtype=cfg.dtype))
    return {"k": ks, "v": vs, "pos": abs_p(dtype=jnp.int32)}


def _ring_slot_positions(cache_len: int, pos_next: Array) -> Array:
    """Absolute token position stored in each ring slot once ``pos_next``
    tokens have been written; slots not yet written get -1."""
    j = jnp.arange(cache_len, dtype=jnp.int32)
    last = pos_next - 1
    p = last - ((last - j) % cache_len)
    return jnp.where((p >= 0) & (p <= last), p, -1)


def serve_prefill(params: dict, tokens: Array, cfg: TransformerConfig,
                  max_len: Optional[int] = None) -> tuple:
    """Full forward over the prompt; returns (last-token logits (B, V), cache)."""
    B, S = tokens.shape
    max_len = max_len or S
    dt = cfg.dtype
    x = params["embed"].astype(dt)[tokens]
    q_pos = jnp.arange(S, dtype=jnp.int32)
    cache = init_cache(cfg, B, max_len)
    q_chunk = cfg.q_chunk if S > cfg.q_chunk else None
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        w = cfg.layer_window(i)

        def run_layer(lp, x):
            h = rms_norm(x, lp["attn_norm"])
            a = _attention_block(lp, h, q_pos, cfg,
                                 jnp.asarray(w is not None), q_chunk=q_chunk)
            x = x + a
            h = rms_norm(x, lp["mlp_norm"])
            f, _ = _ffn_block(lp, h, cfg)
            return x + f, h  # h unused; recompute kv below

        # kv for the cache (recomputed cheaply from the pre-attn norm)
        x = _wsc(x, cfg)
        h = rms_norm(x, lp["attn_norm"])
        k = (h @ lp["wk"].astype(dt)).reshape(B, S, cfg.n_kv_heads,
                                              cfg.head_dim)
        v = (h @ lp["wv"].astype(dt)).reshape(B, S, cfg.n_kv_heads,
                                              cfg.head_dim)
        k = apply_rope(k, q_pos, cfg.rope_theta)
        cl = cache["k"][i].shape[1]
        if w is None:
            cache["k"][i] = jax.lax.dynamic_update_slice(
                cache["k"][i], k[:, :cl], (0, 0, 0, 0))
            cache["v"][i] = jax.lax.dynamic_update_slice(
                cache["v"][i], v[:, :cl], (0, 0, 0, 0))
        else:
            take = min(cl, S)
            tail_k, tail_v = k[:, S - take:], v[:, S - take:]
            slots = (jnp.arange(S - take, S, dtype=jnp.int32)) % cl
            cache["k"][i] = cache["k"][i].at[:, slots].set(tail_k)
            cache["v"][i] = cache["v"][i].at[:, slots].set(tail_v)
        fn = jax.checkpoint(run_layer) if cfg.remat else run_layer
        x, _ = fn(lp, x)
    x = rms_norm(x, params["final_norm"])
    head = params.get("head", params["embed"].T if cfg.tie_embeddings else None)
    logits = (x[:, -1] @ head.astype(dt)).astype(jnp.float32)
    cache["pos"] = jnp.asarray(S, jnp.int32)
    return logits, cache


def serve_decode_step(params: dict, cache: dict, token: Array,
                      cfg: TransformerConfig) -> tuple:
    """One decode step. token (B, 1) int32 -> (logits (B, V) fp32, cache)."""
    B = token.shape[0]
    dt = cfg.dtype
    pos = cache["pos"]
    x = params["embed"].astype(dt)[token]                   # (B, 1, D)
    q_pos = pos[None].astype(jnp.int32)                     # (1,)
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        w = cfg.layer_window(i)
        cl = cache["k"][i].shape[1]
        x = _wsc(x, cfg)
        h = rms_norm(x, lp["attn_norm"])
        k_new = (h @ lp["wk"].astype(dt)).reshape(B, 1, cfg.n_kv_heads,
                                                  cfg.head_dim)
        v_new = (h @ lp["wv"].astype(dt)).reshape(B, 1, cfg.n_kv_heads,
                                                  cfg.head_dim)
        k_new = apply_rope(k_new, q_pos, cfg.rope_theta)
        slot = pos % cl if w is not None else pos
        ck = jax.lax.dynamic_update_slice(cache["k"][i], k_new,
                                          (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"][i], v_new,
                                          (0, slot, 0, 0))
        cache["k"][i], cache["v"][i] = ck, cv
        if w is None:
            k_pos = jnp.arange(cl, dtype=jnp.int32)
            k_valid = k_pos <= pos
        else:
            k_pos = _ring_slot_positions(cl, pos + 1)
            k_valid = k_pos >= 0
        a = _attention_block(
            lp, h, q_pos, cfg, jnp.asarray(w is not None),
            k_override=ck, v_override=cv, k_pos=k_pos, k_valid=k_valid)
        x = x + a
        h = rms_norm(x, lp["mlp_norm"])
        f, _ = _ffn_block(lp, h, cfg)
        x = x + f
    x = rms_norm(x, params["final_norm"])
    head = params.get("head", params["embed"].T if cfg.tie_embeddings else None)
    logits = (x[:, 0] @ head.astype(dt)).astype(jnp.float32)
    cache["pos"] = pos + 1
    return logits, cache


def embed_sequences(params: dict, tokens: Array, cfg: TransformerConfig):
    """Mean-pooled final hidden states — the embedding DEG indexes
    (kNN-LM-style retrieval examples)."""
    B, S = tokens.shape
    dt = cfg.dtype
    x = params["embed"].astype(dt)[tokens]
    q_pos = jnp.arange(S, dtype=jnp.int32)
    windowed = jnp.asarray(~cfg.is_global_layer())

    def body(x, scanned):
        lp, wflag = scanned
        x, _ = _layer(lp, x, q_pos, cfg, wflag)
        return x, None

    x, _ = jax.lax.scan(body, x, (params["layers"], windowed))
    x = rms_norm(x, params["final_norm"])
    return jnp.mean(x.astype(jnp.float32), axis=1)
