"""RecSys architectures: DLRM (MLPerf), DCN-v2, DeepFM, DIN.

Common skeleton: huge sparse embedding tables (stacked per-field into ONE
(V_total, E) table with static row offsets) -> a feature-interaction op
(dot / cross / FM / target-attention) -> a small MLP tower -> 1 logit.

The embedding lookup is the hot path.  Models take a ``lookup_fn`` so the
same forward runs (a) single-host with a plain gather, (b) under the
production mesh with the row-sharded shard_map lookup from
``models.embedding_bag.sharded_embedding_lookup``, or (c) through the
``bag_lookup`` Pallas kernel.

`retrieval_cand` serving (1 query x 1M candidates) uses ``user_embedding``
against the item-embedding rows — scored either brute-force via the
``l2_topk`` kernel or through a DEG index built over the item vectors (the
paper's technique serving the retrieval stage; see examples/).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .embedding_bag import stack_vocab_offsets
from .layers import abs_mlp_tower, abs_p, apply_mlp_tower, dense_init, mlp_tower

Array = jax.Array

# Criteo-Kaggle categorical cardinalities (widely published)
CRITEO_KAGGLE_VOCABS = (
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145, 5683,
    8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4, 7046547, 18, 15,
    286181, 105, 142572)
# Criteo-Terabyte cardinalities used by MLPerf DLRM (day 0-23 counts)
CRITEO_TB_VOCABS = (
    45833188, 36746, 17245, 7413, 20243, 3, 7114, 1441, 62, 29275261,
    1572176, 345138, 10, 2209, 11267, 128, 4, 974, 14, 48937457, 11316796,
    40094537, 452104, 12606, 104, 35)


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                       # 'dlrm' | 'dcn-v2' | 'deepfm' | 'din'
    n_dense: int
    n_sparse: int
    embed_dim: int
    vocab_sizes: tuple
    mlp: tuple                      # top tower hidden sizes
    bot_mlp: tuple = ()             # dlrm bottom tower
    n_cross: int = 0                # dcn-v2
    attn_mlp: tuple = ()            # din
    seq_len: int = 0                # din history length
    item_field: int = 0             # din: which field is the target item
    dtype: object = jnp.float32
    # stacked-table row padding: round total_rows up to a multiple, so the
    # row-sharded shard_map lookup divides evenly (distributed/collectives).
    # Padded rows are never addressed by real ids.
    table_pad_to: int = 1

    def __post_init__(self):
        assert len(self.vocab_sizes) == self.n_sparse, (
            len(self.vocab_sizes), self.n_sparse)

    @property
    def total_rows(self) -> int:
        n = int(sum(self.vocab_sizes))
        p = max(self.table_pad_to, 1)
        return -(-n // p) * p

    @property
    def x0_dim(self) -> int:
        """Input width of the interaction stage."""
        if self.kind == "dlrm":
            return self.embed_dim          # bottom-mlp output
        if self.kind == "dcn-v2":
            return self.n_dense + self.n_sparse * self.embed_dim
        if self.kind == "deepfm":
            return self.n_sparse * self.embed_dim
        if self.kind == "din":
            # target item + attention-pooled history + profile fields
            return (self.n_sparse + 1) * self.embed_dim
        raise ValueError(self.kind)


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------
def abstract_params(cfg: RecsysConfig) -> dict:
    E = cfg.embed_dim
    p: dict = {"table": abs_p(cfg.total_rows, E)}
    if cfg.kind == "dlrm":
        p["bot_mlp"] = abs_mlp_tower([cfg.n_dense, *cfg.bot_mlp])
        n_int = cfg.n_sparse + 1
        top_in = E + n_int * (n_int - 1) // 2
        p["top_mlp"] = abs_mlp_tower([top_in, *cfg.mlp])
    elif cfg.kind == "dcn-v2":
        d = cfg.x0_dim
        p["cross_w"] = abs_p(cfg.n_cross, d, d)
        p["cross_b"] = abs_p(cfg.n_cross, d)
        p["top_mlp"] = abs_mlp_tower([d, *cfg.mlp, 1])
    elif cfg.kind == "deepfm":
        p["fm_w"] = abs_p(cfg.total_rows)      # first-order weights
        p["fm_b"] = abs_p()
        p["top_mlp"] = abs_mlp_tower([cfg.x0_dim, *cfg.mlp, 1])
    elif cfg.kind == "din":
        E4 = 4 * E
        p["attn_mlp"] = abs_mlp_tower([E4, *cfg.attn_mlp, 1])
        p["top_mlp"] = abs_mlp_tower([cfg.x0_dim, *cfg.mlp, 1])
    return p


def init_params(key: jax.Array, cfg: RecsysConfig) -> dict:
    E = cfg.embed_dim
    ks = iter(jax.random.split(key, 16))
    p: dict = {"table": dense_init(next(ks), (cfg.total_rows, E), scale=0.01)}
    if cfg.kind == "dlrm":
        p["bot_mlp"] = mlp_tower(next(ks), [cfg.n_dense, *cfg.bot_mlp])
        n_int = cfg.n_sparse + 1
        top_in = E + n_int * (n_int - 1) // 2
        p["top_mlp"] = mlp_tower(next(ks), [top_in, *cfg.mlp])
    elif cfg.kind == "dcn-v2":
        d = cfg.x0_dim
        p["cross_w"] = dense_init(next(ks), (cfg.n_cross, d, d), scale=0.01)
        p["cross_b"] = jnp.zeros((cfg.n_cross, d), jnp.float32)
        p["top_mlp"] = mlp_tower(next(ks), [d, *cfg.mlp, 1])
    elif cfg.kind == "deepfm":
        p["fm_w"] = dense_init(next(ks), (cfg.total_rows,), scale=0.01)
        p["fm_b"] = jnp.zeros((), jnp.float32)
        p["top_mlp"] = mlp_tower(next(ks), [cfg.x0_dim, *cfg.mlp, 1])
    elif cfg.kind == "din":
        p["attn_mlp"] = mlp_tower(next(ks), [4 * E, *cfg.attn_mlp, 1])
        p["top_mlp"] = mlp_tower(next(ks), [cfg.x0_dim, *cfg.mlp, 1])
    return p


# --------------------------------------------------------------------------
# lookup plumbing
# --------------------------------------------------------------------------
def default_lookup(table: Array, flat_ids: Array) -> Array:
    """Plain gather: flat_ids (...,) global row ids -> (..., E)."""
    return jnp.take(table, flat_ids, axis=0)


def global_ids(cfg: RecsysConfig, sparse: Array) -> Array:
    """Per-field ids (B, F) -> global stacked-table rows (B, F)."""
    _, offsets = stack_vocab_offsets(cfg.vocab_sizes)
    return sparse + offsets[None, :]


# --------------------------------------------------------------------------
# forwards
# --------------------------------------------------------------------------
def _dlrm_interact(emb: Array, bot: Array) -> Array:
    """emb (B, F, E), bot (B, E) -> (B, E + F+1 choose 2) dot interactions."""
    z = jnp.concatenate([bot[:, None, :], emb], axis=1)    # (B, F+1, E)
    zz = jnp.einsum("bie,bje->bij", z, z)
    n = z.shape[1]
    iu = jnp.triu_indices(n, k=1)
    flat = zz[:, iu[0], iu[1]]                             # (B, n(n-1)/2)
    return jnp.concatenate([bot, flat], axis=1)


def forward(params: dict, batch: dict, cfg: RecsysConfig,
            lookup_fn: Callable = default_lookup) -> Array:
    """Returns logits (B,)."""
    dt = cfg.dtype
    if cfg.kind == "din":
        return _din_forward(params, batch, cfg, lookup_fn)
    gids = global_ids(cfg, batch["sparse"])
    emb = lookup_fn(params["table"], gids).astype(dt)      # (B, F, E)
    if cfg.kind == "dlrm":
        dense = jnp.log1p(jnp.maximum(batch["dense"].astype(dt), 0.0))
        bot = apply_mlp_tower(params["bot_mlp"], dense, act=jax.nn.relu,
                              final_act=jax.nn.relu)
        x = _dlrm_interact(emb, bot)
        out = apply_mlp_tower(params["top_mlp"], x, act=jax.nn.relu)
        return out[:, 0].astype(jnp.float32)
    if cfg.kind == "dcn-v2":
        dense = jnp.log1p(jnp.maximum(batch["dense"].astype(dt), 0.0))
        x0 = jnp.concatenate([dense, emb.reshape(emb.shape[0], -1)], axis=1)
        x = x0
        for i in range(cfg.n_cross):
            w = params["cross_w"][i].astype(dt)
            b = params["cross_b"][i].astype(dt)
            x = x0 * (x @ w + b) + x                       # DCN-v2 cross
        out = apply_mlp_tower(params["top_mlp"], x, act=jax.nn.relu)
        return out[:, 0].astype(jnp.float32)
    if cfg.kind == "deepfm":
        # FM second order: 0.5 * ((sum v)^2 - sum v^2), summed over E
        s = jnp.sum(emb, axis=1)
        s2 = jnp.sum(emb * emb, axis=1)
        fm2 = 0.5 * jnp.sum(s * s - s2, axis=1)
        fm1 = jnp.sum(jnp.take(params["fm_w"], gids, axis=0), axis=1)
        deep = apply_mlp_tower(params["top_mlp"],
                               emb.reshape(emb.shape[0], -1),
                               act=jax.nn.relu)[:, 0]
        return (fm1 + fm2 + deep + params["fm_b"]).astype(jnp.float32)
    raise ValueError(cfg.kind)


def _din_forward(params, batch, cfg, lookup_fn) -> Array:
    dt = cfg.dtype
    gids = global_ids(cfg, batch["sparse"])
    emb = lookup_fn(params["table"], gids).astype(dt)      # (B, F, E)
    target = emb[:, cfg.item_field]                        # (B, E)
    _, offsets = stack_vocab_offsets(cfg.vocab_sizes)
    hist_gids = batch["hist"] + offsets[cfg.item_field]
    hist = lookup_fn(params["table"], hist_gids).astype(dt)  # (B, S, E)
    valid = (batch["hist"] >= 0)[..., None].astype(dt)
    hist = hist * valid
    t = jnp.broadcast_to(target[:, None, :], hist.shape)
    af = jnp.concatenate([hist, t, hist - t, hist * t], axis=-1)
    scores = apply_mlp_tower(params["attn_mlp"], af, act=jax.nn.sigmoid)
    scores = jnp.where(valid > 0, scores, -1e30)
    w = jax.nn.softmax(scores, axis=1)                     # (B, S, 1)
    interest = jnp.sum(w * hist, axis=1)                   # (B, E)
    x = jnp.concatenate([emb.reshape(emb.shape[0], -1), interest], axis=1)
    out = apply_mlp_tower(params["top_mlp"], x, act=jax.nn.relu)
    return out[:, 0].astype(jnp.float32)


def loss_fn(params: dict, batch: dict, cfg: RecsysConfig,
            lookup_fn: Callable = default_lookup):
    logits = forward(params, batch, cfg, lookup_fn)
    y = batch["label"].astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return loss, {"bce": loss}


# --------------------------------------------------------------------------
# retrieval serving (the DEG integration point)
# --------------------------------------------------------------------------
def user_embedding(params: dict, batch: dict, cfg: RecsysConfig,
                   lookup_fn: Callable = default_lookup) -> Array:
    """A query-side vector in item-embedding space."""
    dt = cfg.dtype
    if cfg.kind == "din":
        gids = global_ids(cfg, batch["sparse"])
        emb = lookup_fn(params["table"], gids).astype(dt)
        _, offsets = stack_vocab_offsets(cfg.vocab_sizes)
        hist = lookup_fn(params["table"],
                         batch["hist"] + offsets[cfg.item_field]).astype(dt)
        valid = (batch["hist"] >= 0)[..., None].astype(dt)
        pooled = jnp.sum(hist * valid, 1) / jnp.maximum(valid.sum(1), 1.0)
        return pooled.astype(jnp.float32)
    gids = global_ids(cfg, batch["sparse"])
    emb = lookup_fn(params["table"], gids).astype(dt)
    return jnp.mean(emb, axis=1).astype(jnp.float32)


def serve_retrieval(params: dict, batch: dict, candidates: Array,
                    cfg: RecsysConfig, k: int = 100,
                    lookup_fn: Callable = default_lookup):
    """Score ``candidates`` (N, E) for each query; exact top-k (the
    brute-force path; the DEG path lives in serving/engine.py)."""
    u = user_embedding(params, batch, cfg, lookup_fn)      # (B, E)
    scores = u @ candidates.T.astype(u.dtype)              # (B, N)
    top, ids = jax.lax.top_k(scores, k)
    return top, ids


def item_vectors(params: dict, cfg: RecsysConfig, field: int,
                 n_items: Optional[int] = None) -> Array:
    """Rows of one field's embedding table = the candidate corpus."""
    _, offsets = stack_vocab_offsets(cfg.vocab_sizes)
    start = int(np.asarray(offsets)[field])
    n = n_items or int(cfg.vocab_sizes[field])
    return jax.lax.dynamic_slice_in_dim(params["table"], start, n, axis=0)
