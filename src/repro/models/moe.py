"""Mixture-of-Experts FFN with sort-free scatter dispatch.

Design notes (see DESIGN.md §4):

* **Dispatch** is linear-cost: top-k routing -> position-in-expert via a
  cumsum over one-hot assignments -> scatter into a static ``(E, C, D)``
  buffer (capacity ``C = ceil(T*k*cf/E)``, overflow tokens *dropped* like
  GShard/Switch) -> 3 batched expert GEMMs -> gather-combine weighted by the
  (renormalized) router probabilities.  No quadratic one-hot einsum.
* **Sharding**: expert-TP — every device holds all experts but a 1/TP slice
  of each expert's hidden dim (``we_* sharded on the F_e axis``).  Dispatch
  stays local to the device's tokens; the only collective is the standard
  row-parallel psum after ``we_down`` — identical schedule to the dense MLP,
  robust under GSPMD.  (Expert-parallel all-to-all is the alternative; noted
  as a perf iteration.)
* Aux load-balance loss (Switch-style): ``E * sum_e f_e * p_e``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers import abs_p, dense_init

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    # SPMD: constrain the expert-hidden activations to P(..., "model") so
    # GSPMD gathers the (small) FSDP-sharded expert weights instead of
    # partial-contracting d_model and ALL-REDUCING the (huge) expert
    # activations.  Only meaningful under a mesh; see transformer._ffn_block.
    shard_hidden: bool = False


def _capacity(T: int, moe: MoEConfig) -> int:
    c = int(T * moe.top_k * moe.capacity_factor / moe.n_experts) + 1
    return max(8, -(-c // 8) * 8)  # round up to 8


def abs_moe_layer(L: int, d_model: int, moe: MoEConfig) -> dict:
    E, F = moe.n_experts, moe.d_ff_expert
    return {
        "router": abs_p(L, d_model, E),
        "we_gate": abs_p(L, E, d_model, F),
        "we_up": abs_p(L, E, d_model, F),
        "we_down": abs_p(L, E, F, d_model),
    }


def init_moe_layer(key, L: int, d_model: int, moe: MoEConfig) -> dict:
    E, F = moe.n_experts, moe.d_ff_expert
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": dense_init(k1, (L, d_model, E)),
        "we_gate": dense_init(k2, (L, E, d_model, F)),
        "we_up": dense_init(k3, (L, E, d_model, F)),
        "we_down": dense_init(k4, (L, E, F, d_model)),
    }


def moe_ffn(x: Array, lp: dict, moe: MoEConfig) -> tuple[Array, Array]:
    """x (T, D) -> (y (T, D), aux_loss scalar)."""
    T, D = x.shape
    E, K = moe.n_experts, moe.top_k
    dt = x.dtype
    C = _capacity(T, moe)

    logits = (x @ lp["router"].astype(dt)).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_ids = jax.lax.top_k(probs, K)                     # (T, K)
    top_w = (top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
             ).astype(jnp.float32)

    flat_e = top_ids.reshape(-1)                                 # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_w = top_w.reshape(-1)

    # position of each assignment inside its expert's buffer
    oh = (flat_e[:, None] == jnp.arange(E, dtype=jnp.int32)[None, :]
          ).astype(jnp.int32)                                    # (T*K, E)
    pos_all = jnp.cumsum(oh, axis=0) - 1
    my_pos = jnp.take_along_axis(pos_all, flat_e[:, None], axis=1)[:, 0]
    keep = my_pos < C
    safe_e = jnp.where(keep, flat_e, E)                          # E = dump row
    safe_p = jnp.where(keep, my_pos, 0)

    buf = jnp.zeros((E + 1, C, D), dt)
    buf = buf.at[safe_e, safe_p].set(x[flat_t])
    xb = buf[:E]                                                 # (E, C, D)

    def wsc(t, spec):
        if not moe.shard_hidden:
            return t
        return jax.lax.with_sharding_constraint(
            t, jax.sharding.PartitionSpec(*spec))

    xb = wsc(xb, (None, None, None))
    g = jax.nn.silu(wsc(jnp.einsum("ecd,edf->ecf", xb,
                                   lp["we_gate"].astype(dt)),
                        (None, None, "model")))
    u = wsc(jnp.einsum("ecd,edf->ecf", xb, lp["we_up"].astype(dt)),
            (None, None, "model"))
    # yb left unconstrained: pinning it replicated forces the row-parallel
    # all-reduce at the (E, C, D) capacity buffer; unpinned, GSPMD may defer
    # the reduction to after the per-token gather (T < E*C rows).
    yb = jnp.einsum("ecf,efd->ecd", g * u, lp["we_down"].astype(dt))

    yb = jnp.concatenate([yb, jnp.zeros((1, C, D), dt)], axis=0)
    contrib = yb[safe_e, safe_p] * (flat_w * keep)[:, None].astype(dt)
    y = jax.ops.segment_sum(contrib, flat_t, num_segments=T)

    # Switch-style load-balance loss
    frac_tokens = jnp.mean(
        (top_ids[..., None] == jnp.arange(E)).any(axis=1).astype(jnp.float32),
        axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * mean_prob)
    return y.astype(dt), aux
