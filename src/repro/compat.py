"""Version compatibility shims for the installed jax.

The codebase targets the modern public API (``jax.shard_map`` with a
``check_vma`` flag, ``jax.set_mesh`` as a context manager).  Older jax
releases (<= 0.4.x) expose the same functionality as
``jax.experimental.shard_map.shard_map`` (flag spelled ``check_rep``) and
have no mesh context setter — entering the ``Mesh`` object itself is the
equivalent.  Import ``shard_map`` / ``set_mesh`` from here instead of from
``jax`` so both generations of the API work unchanged.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable

import jax

__all__ = ["shard_map", "set_mesh"]


if hasattr(jax, "shard_map"):
    _shard_map_impl: Callable[..., Any] = jax.shard_map
    _CHECK_KW = "check_vma"
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the new-API keyword spelling on any jax."""
    kwargs = {_CHECK_KW: check_vma}
    return _shard_map_impl(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    Falls back to entering the ``Mesh`` object (the pre-``jax.set_mesh``
    spelling) when the setter does not exist; a bare ``AbstractMesh`` (not
    a context manager) degrades to a no-op context.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext(mesh)
