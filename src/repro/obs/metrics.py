"""Metrics registry: counters, gauges, and log-bucketed histograms.

The serving stack's measurement layer.  Design constraints, in order:

* **cheap on the hot path** — ``Counter.inc`` / ``Histogram.observe`` are
  a lock, an index computation, and integer adds: no allocation, no
  string formatting, no wall-clock reads.  Metric objects are created
  once (``registry.counter(...)`` is get-or-create) and cached by the
  caller, so steady state never touches the registry dict;
* **mergeable** — two histograms with the same bucket layout add
  bucket-wise (:meth:`Histogram.merge_from`), so per-engine / per-shard
  registries roll up into one fleet view without losing percentile
  fidelity beyond the bucket width (``tests/test_obs_metrics.py`` pins
  merged percentiles against exact numpy over the concatenated samples);
* **reproducible percentiles** — p50/p99/p99.9 are a pure function of
  the bucket counts.  Feeding the same observations into a fresh
  histogram (e.g. replaying the query log, ``obs/querylog.py``)
  reproduces the registry's percentiles *exactly*, which is the
  round-trip the serving bench asserts;
* **exportable** — :meth:`MetricsRegistry.snapshot` is a JSON-able dict
  (the ``/metrics.json`` endpoint and the bench artifacts),
  :meth:`MetricsRegistry.to_prometheus` the text exposition format
  (``launch/serve.py --metrics-port``).

Histogram buckets are geometric (log-spaced): ``bounds[i+1] =
bounds[i] * growth``.  Relative quantile error is bounded by
``growth - 1`` per bucket, so the default ``growth=1.25`` holds every
percentile within 25% of the exact order statistic while covering
50 us .. 80 s of latency in ~54 buckets of int counts.
"""
from __future__ import annotations

import bisect
import json
import math
import threading
from typing import Optional, Sequence

#: default latency bucket layout (milliseconds): 0.05 ms .. ~80 s
DEFAULT_LATENCY_BOUNDS_MS = None   # filled below by log_buckets()


def log_buckets(lo: float, hi: float, growth: float = 1.25
                ) -> tuple[float, ...]:
    """Geometric bucket upper bounds: lo, lo*growth, ... >= hi."""
    if not (lo > 0 and hi > lo and growth > 1.0):
        raise ValueError(f"bad bucket spec lo={lo} hi={hi} growth={growth}")
    n = int(math.ceil(math.log(hi / lo) / math.log(growth))) + 1
    return tuple(lo * growth ** i for i in range(n))


DEFAULT_LATENCY_BOUNDS_MS = log_buckets(0.05, 80_000.0)

# Canonical metric names of the live-mutation subsystem (epoch publication
# + integrity scrubber).  One authoritative spelling shared by
# core/epoch.py, core/build.py, serving/scrub.py, launch/serve.py and the
# obs round-trip test — dashboards key on these strings.
EPOCH_GAUGE = "deg_epoch"
EPOCH_PUBLISH_TOTAL = "epoch_publish_total"
EPOCH_RETIRED_LAG_MS = "epoch_retired_lag_ms"
SCRUB_AUDITED_TOTAL = "scrub_vertices_audited_total"
SCRUB_QUARANTINED_TOTAL = "scrub_quarantined_total"
SCRUB_REPAIRED_TOTAL = "scrub_repaired_total"


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _prom_name(name: str) -> str:
    return "".join(c if (c.isalnum() or c == "_") else "_" for c in name)


def _prom_labels(labels: tuple, extra: str = "") -> str:
    parts = [f'{k}="{v}"' for k, v in labels]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


class Counter:
    """Monotone float counter."""

    __slots__ = ("name", "labels", "_lock", "value")

    kind = "counter"

    def __init__(self, name: str, labels: tuple):
        self.name, self.labels = name, labels
        self._lock = threading.Lock()
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self.value += v

    def merge_from(self, other: "Counter") -> None:
        with self._lock:
            self.value += other.value

    def state(self) -> dict:
        return {"value": self.value}

    def load_state(self, st: dict) -> None:
        self.value = float(st["value"])


class Gauge:
    """Last-write-wins instantaneous value (queue depth, occupancy)."""

    __slots__ = ("name", "labels", "_lock", "value")

    kind = "gauge"

    def __init__(self, name: str, labels: tuple):
        self.name, self.labels = name, labels
        self._lock = threading.Lock()
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self.value += v

    def merge_from(self, other: "Gauge") -> None:
        # merging point-in-time gauges across shards: sum (queue depths,
        # occupancies add; for averages export a counter pair instead)
        with self._lock:
            self.value += other.value

    def state(self) -> dict:
        return {"value": self.value}

    def load_state(self, st: dict) -> None:
        self.value = float(st["value"])


class Histogram:
    """Log-bucketed histogram with bucket-exact percentiles.

    ``bounds`` are *upper* bucket edges (``observe(v)`` lands in the first
    bucket with ``v <= bounds[i]``); one overflow bucket catches the rest.
    Percentiles interpolate within the winning bucket, so they are a pure
    function of the counts — replay-reproducible and merge-stable.
    """

    __slots__ = ("name", "labels", "bounds", "_lock", "counts", "sum",
                 "count")

    kind = "histogram"

    def __init__(self, name: str, labels: tuple,
                 bounds: Optional[Sequence[float]] = None):
        self.name, self.labels = name, labels
        b = tuple(bounds) if bounds is not None else DEFAULT_LATENCY_BOUNDS_MS
        if list(b) != sorted(b) or len(b) < 1:
            raise ValueError("histogram bounds must be sorted and non-empty")
        self.bounds = b
        self._lock = threading.Lock()
        self.counts = [0] * (len(b) + 1)       # +1 overflow
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self.counts[i] += 1
            self.sum += v
            self.count += 1

    def merge_from(self, other: "Histogram") -> None:
        if other.bounds != self.bounds:
            raise ValueError(
                f"cannot merge histogram {self.name!r}: bucket layouts "
                f"differ ({len(self.bounds)} vs {len(other.bounds)} bounds)")
        with self._lock:
            for i, c in enumerate(other.counts):
                self.counts[i] += c
            self.sum += other.sum
            self.count += other.count

    def percentile(self, q: float) -> float:
        """Bucket-interpolated q-th percentile (q in [0, 100]).  Returns
        nan when empty.  Deterministic in the counts alone."""
        total = self.count
        if total == 0:
            return float("nan")
        rank = (q / 100.0) * total
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            prev_cum = cum
            cum += c
            if cum >= rank:
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i] if i < len(self.bounds) \
                    else self.bounds[-1] * (self.bounds[-1] /
                                            self.bounds[-2])
                frac = (rank - prev_cum) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
        return self.bounds[-1]

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def percentiles(self) -> dict:
        return {"p50": self.percentile(50.0), "p99": self.percentile(99.0),
                "p999": self.percentile(99.9)}

    def state(self) -> dict:
        return {"bounds": list(self.bounds), "counts": list(self.counts),
                "sum": self.sum, "count": self.count,
                **self.percentiles()}

    def load_state(self, st: dict) -> None:
        if list(st["bounds"]) != list(self.bounds):
            raise ValueError("snapshot bucket layout differs")
        self.counts = [int(c) for c in st["counts"]]
        self.sum = float(st["sum"])
        self.count = int(st["count"])


class MetricsRegistry:
    """Thread-safe name+labels -> metric table (get-or-create)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[tuple, object] = {}

    def _get(self, cls, name: str, labels: dict, **kw):
        key = (name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, _label_key(labels), **kw)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None,
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels, bounds=bounds)

    def metrics(self) -> list:
        with self._lock:
            return list(self._metrics.values())

    def merge_from(self, other: "MetricsRegistry") -> None:
        """Fold another registry in (cross-engine / cross-shard rollup):
        same (name, labels) metrics add; new ones are copied."""
        for m in other.metrics():
            labels = dict(m.labels)
            if m.kind == "counter":
                mine = self.counter(m.name, **labels)
            elif m.kind == "gauge":
                mine = self.gauge(m.name, **labels)
            else:
                mine = self.histogram(m.name, bounds=m.bounds, **labels)
            mine.merge_from(m)

    # -- export ------------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able state: ``{"metrics": [{name, kind, labels, ...}]}``."""
        out = []
        for m in sorted(self.metrics(), key=lambda m: (m.name, m.labels)):
            out.append({"name": m.name, "kind": m.kind,
                        "labels": dict(m.labels), **m.state()})
        return {"metrics": out}

    def snapshot_json(self) -> str:
        return json.dumps(self.snapshot(), sort_keys=True, default=float)

    @classmethod
    def from_snapshot(cls, doc: dict) -> "MetricsRegistry":
        reg = cls()
        for m in doc["metrics"]:
            labels = dict(m["labels"])
            if m["kind"] == "counter":
                reg.counter(m["name"], **labels).load_state(m)
            elif m["kind"] == "gauge":
                reg.gauge(m["name"], **labels).load_state(m)
            else:
                reg.histogram(m["name"], bounds=m["bounds"],
                              **labels).load_state(m)
        return reg

    def to_prometheus(self) -> str:
        """Prometheus text exposition (one TYPE line per metric family,
        cumulative ``_bucket`` series with the ``le`` label)."""
        lines: list[str] = []
        typed: set[str] = set()
        for m in sorted(self.metrics(), key=lambda m: (m.name, m.labels)):
            pname = _prom_name(m.name)
            if m.kind in ("counter", "gauge"):
                if pname not in typed:
                    lines.append(f"# TYPE {pname} {m.kind}")
                    typed.add(pname)
                lines.append(f"{pname}{_prom_labels(m.labels)} "
                             f"{_fmt(m.value)}")
                continue
            if pname not in typed:
                lines.append(f"# TYPE {pname} histogram")
                typed.add(pname)
            cum = 0
            for i, b in enumerate(m.bounds):
                cum += m.counts[i]
                le = 'le="%s"' % _fmt(b)
                lines.append(
                    f"{pname}_bucket{_prom_labels(m.labels, le)} {cum}")
            cum += m.counts[-1]
            le_inf = 'le="+Inf"'
            lines.append(
                f"{pname}_bucket{_prom_labels(m.labels, le_inf)} {cum}")
            lines.append(f"{pname}_sum{_prom_labels(m.labels)} "
                         f"{_fmt(m.sum)}")
            lines.append(f"{pname}_count{_prom_labels(m.labels)} {m.count}")
        return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))
