"""Sampled, rotating JSONL query log — the serving-side record that feeds
continuous refinement.

The paper's signature claim is refinement that never stops; EnhanceGraph
(PAPERS.md, arxiv 2506.13144) shows the best refinement signal is
production traffic itself.  This module defines the record the mining
pass will consume (ROADMAP item 4: hard negatives, unreachable-in-L hops,
shortcut-edge proposals into the Alg. 5 swap machinery), so the schema
leads with the traversal facts that matter for graph quality, not just
latency:

    v                 schema version (1)
    qid               admission sequence number (engine-local, monotone)
    qhash             16-hex blake2b of the query vector bytes — joins
                      repeated queries across engines without storing the
                      vector itself
    k / seed / exclude_n   the request as dispatched (seed null = medoid)
    ids / dists       returned top-k (INVALID-padded ids dropped)
    hops / evals      per-lane traversal counters, surfaced from the beam
                      engine at zero extra device work (the search program
                      always computes them)
    visited_frac      visited-table occupancy in [0,1] (null when the
                      search ran the beam-broadcast dedup) — saturation
                      predicts dropped inserts / duplicate work
    budget_exhausted  lane ran under a hop budget (deadline shed)
    partial           completed flagged partial (best-so-far beam)
    flush_index / bucket   which flush served it, at what padded width
    latency_ms / spans     obs/trace.span_fields timings
    t_mono / t_wall_unix   submit instant — monotonic, plus a wall anchor
                      derived from one wall read per writer (never a
                      hot-path wall-clock call)

Sampling is decided *before* a record is built (``obs/trace.Sampler``):
a sampled-out query allocates nothing and appears nowhere in the log.

The reader side closes the loop: :func:`read_query_log` reloads a log
(rotated segments included, oldest first), :func:`replay_registry`
rebuilds the engine's latency histograms from it — bucket-for-bucket
identical to the live registry, which ``benchmarks/serving_load.py``
asserts — and :func:`recall_from_log` recomputes recall@k from the
recorded ids alone.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from typing import Callable, Optional, Sequence

import numpy as np

from . import clock
from .metrics import MetricsRegistry
from .trace import span_fields

SCHEMA_VERSION = 1

#: registry metric name the engines record request latency under; replay
#: rebuilds exactly this metric (see :func:`replay_registry`)
LATENCY_METRIC = "serving_request_latency_ms"


def query_hash(query: np.ndarray) -> str:
    """Stable 16-hex digest of the query vector bytes (float32 view)."""
    q = np.ascontiguousarray(np.asarray(query, np.float32))
    return hashlib.blake2b(q.tobytes(), digest_size=8).hexdigest()


def make_record(*, qid: int, query: np.ndarray, k: int,
                ids: np.ndarray, dists: np.ndarray,
                hops: int, evals: int,
                seed_vertex: Optional[int] = None,
                exclude_n: int = 0,
                visited_frac: Optional[float] = None,
                budget_exhausted: bool = False,
                partial: bool = False,
                flush_index: Optional[int] = None,
                bucket: Optional[int] = None,
                latency_ms: Optional[float] = None,
                result=None,
                t_mono: Optional[float] = None) -> dict:
    """One query-log record (a plain dict; the writer JSON-encodes it).
    ``result`` (an ``AsyncResult``-like with monotonic stamps) supplies
    the span timings when given."""
    keep = np.asarray(ids) >= 0
    rec = {
        "v": SCHEMA_VERSION,
        "qid": int(qid),
        "qhash": query_hash(query),
        "k": int(k),
        "seed": None if seed_vertex is None else int(seed_vertex),
        "exclude_n": int(exclude_n),
        "ids": [int(x) for x in np.asarray(ids)[keep]],
        "dists": [float(x) for x in np.asarray(dists)[keep]],
        "hops": int(hops),
        "evals": int(evals),
        "visited_frac": None if visited_frac is None else float(visited_frac),
        "budget_exhausted": bool(budget_exhausted),
        "partial": bool(partial),
        "flush_index": None if flush_index is None else int(flush_index),
        "bucket": None if bucket is None else int(bucket),
        "latency_ms": None if latency_ms is None else float(latency_ms),
        "spans": span_fields(result) if result is not None else {},
        "t_mono": float(t_mono) if t_mono is not None else None,
    }
    return rec


class QueryLogWriter:
    """Rotating JSONL writer.  One JSON object per line; when the active
    file exceeds ``max_bytes`` it is rotated to ``<path>.1`` (existing
    segments shift up, the oldest beyond ``max_files`` is dropped).

    Writes happen on the engine's extract thread; ``close()`` may race it
    from the caller's thread, hence the lock.  The writer stamps each
    record's ``t_wall_unix`` from a single wall-clock read taken at
    construction plus the record's monotonic offset — the hot path never
    reads the wall clock."""

    def __init__(self, path, *, max_bytes: int = 64 * 1024 * 1024,
                 max_files: int = 4):
        self.path = str(path)
        self.max_bytes = int(max_bytes)
        self.max_files = max(1, int(max_files))
        self._lock = threading.Lock()
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(self.path, "a", encoding="utf-8")
        self._bytes = self._f.tell()
        self._anchor_wall = clock.wall_unix()
        self._anchor_mono = clock.now()
        self.records_written = 0

    def write(self, rec: dict) -> None:
        if rec.get("t_mono") is not None:
            rec["t_wall_unix"] = (self._anchor_wall
                                  + (rec["t_mono"] - self._anchor_mono))
        line = json.dumps(rec, separators=(",", ":"), default=float) + "\n"
        data = line.encode("utf-8")
        with self._lock:
            if self._f is None:
                return
            if self._bytes and self._bytes + len(data) > self.max_bytes:
                self._rotate()
            self._f.write(line)
            self._bytes += len(data)
            self.records_written += 1

    def _rotate(self) -> None:
        self._f.close()
        oldest = f"{self.path}.{self.max_files}"
        if os.path.exists(oldest):
            os.remove(oldest)
        for i in range(self.max_files - 1, 0, -1):
            src = f"{self.path}.{i}"
            if os.path.exists(src):
                os.replace(src, f"{self.path}.{i + 1}")
        os.replace(self.path, f"{self.path}.1")
        self._f = open(self.path, "a", encoding="utf-8")
        self._bytes = 0

    def flush(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.flush()

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None


def read_query_log(path, *, include_rotated: bool = True) -> list[dict]:
    """Reload a query log: rotated segments first (oldest to newest), then
    the active file — chronological record order.  Unknown schema versions
    are rejected rather than silently misparsed."""
    path = str(path)
    files: list[str] = []
    if include_rotated:
        i = 1
        seen = []
        while os.path.exists(f"{path}.{i}"):
            seen.append(f"{path}.{i}")
            i += 1
        files.extend(reversed(seen))          # .N is oldest
    if os.path.exists(path):
        files.append(path)
    records: list[dict] = []
    for fp in files:
        with open(fp, encoding="utf-8") as f:
            for ln, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                rec = json.loads(line)
                v = rec.get("v")
                if v != SCHEMA_VERSION:
                    raise ValueError(
                        f"{fp}:{ln}: unknown query-log schema version {v!r} "
                        f"(reader supports {SCHEMA_VERSION})")
                records.append(rec)
    return records


def replay_registry(records: Sequence[dict],
                    registry: Optional[MetricsRegistry] = None
                    ) -> MetricsRegistry:
    """Rebuild the engine's request-latency histogram (and traversal
    counters) from log records.  With sample rate 1.0 the result is
    bucket-for-bucket identical to the live engine's registry — the
    round-trip ``benchmarks/serving_load.py`` asserts (p50/p99 equality is
    *exact*, not approximate: both sides are the same pure function of
    the same observations)."""
    reg = registry or MetricsRegistry()
    lat = reg.histogram(LATENCY_METRIC)
    hops = reg.counter("serving_hops_total")
    evals = reg.counter("serving_evals_total")
    partials = reg.counter("serving_deadline_partials_total")
    for rec in records:
        if rec.get("latency_ms") is not None:
            lat.observe(rec["latency_ms"])
        hops.inc(rec["hops"])
        evals.inc(rec["evals"])
        if rec["partial"]:
            partials.inc()
    return reg


def recall_from_log(records: Sequence[dict], gt_for_qid: Callable[[int],
                    Sequence[int]], k: int, *,
                    include_partial: bool = False) -> float:
    """recall@k over the recorded result ids.  ``gt_for_qid(qid)`` maps a
    record back to its exact ground-truth ids (the caller owns that
    mapping — e.g. the bench's submit-order index).  Partial
    (deadline-shed) results are load-shedding by design and excluded
    unless asked for."""
    hits = 0
    total = 0
    for rec in records:
        if rec["partial"] and not include_partial:
            continue
        gt = set(int(g) for g in list(gt_for_qid(rec["qid"]))[:k])
        got = set(rec["ids"][:k])
        hits += len(gt & got)
        total += len(gt)
    return hits / total if total else 0.0


def mining_view(records: Sequence[dict]) -> dict:
    """Aggregate traversal statistics by query hash — the shape of input
    ROADMAP item 4's learned-edges miner consumes: repeated queries
    (Zipfian traffic) grouped with their hop/eval costs and result sets,
    so expensive-but-frequent traversals stand out as shortcut-edge
    candidates."""
    by_hash: dict[str, dict] = {}
    for rec in records:
        agg = by_hash.setdefault(rec["qhash"], {
            "count": 0, "hops_sum": 0, "evals_sum": 0, "partials": 0,
            "ids": set()})
        agg["count"] += 1
        agg["hops_sum"] += rec["hops"]
        agg["evals_sum"] += rec["evals"]
        agg["partials"] += int(rec["partial"])
        agg["ids"].update(rec["ids"])
    return {h: {**a, "ids": sorted(a["ids"])} for h, a in by_hash.items()}
