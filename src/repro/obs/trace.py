"""Per-query and build-side span tracing.

A *span* here is two :func:`obs.clock.now` timestamps; the serving
engines stamp them directly onto the request future (``AsyncResult``
already carries ``submitted_at`` / ``dispatched_at`` / ``completed_at``;
this PR adds ``device_done_at``), so tracing a query allocates nothing
beyond the future that exists anyway.  The derived spans:

    admission ............ submitted_at            (queue entry)
    queue wait + linger .. dispatched_at - submitted_at
    device compute ....... device_done_at - dispatched_at
                           (async dispatch -> device->host readback done;
                           includes the rerank stage, which runs inside
                           the same compiled program)
    extract .............. completed_at - device_done_at
    total ................ completed_at - submitted_at

Ordering invariant (pinned by tests/test_obs_querylog.py):
``submitted_at <= dispatched_at <= device_done_at <= completed_at``.

:class:`Sampler` decides which queries produce a query-log record.  It is
deterministic (a fractional accumulator, not an RNG): rate 1.0 takes
every query, rate 0.25 every 4th, rate 0.0 nothing — and the 0.0 path is
a single attribute compare, so an untraced engine pays no per-query work
and allocates nothing.

:func:`span` is the build-side helper: a context manager that observes
``<name>_ms`` on a registry histogram (no-op when the registry is None),
used by ``core/build.py`` (wave stages) and ``core/optimize.py``
(refine-sweep chunks).
"""
from __future__ import annotations

import contextlib
from typing import Optional

from . import clock
from .metrics import MetricsRegistry


class Sampler:
    """Deterministic fractional sampler.  ``take()`` returns True for
    ``rate`` of calls, evenly spaced.  Not thread-safe by design: each
    engine owns one and calls it from a single thread (the scheduler)."""

    __slots__ = ("rate", "_acc")

    def __init__(self, rate: float):
        self.rate = min(max(float(rate), 0.0), 1.0)
        self._acc = 0.0

    @property
    def active(self) -> bool:
        return self.rate > 0.0

    def take(self) -> bool:
        if self.rate <= 0.0:
            return False
        if self.rate >= 1.0:
            return True
        self._acc += self.rate
        if self._acc >= 1.0:
            self._acc -= 1.0
            return True
        return False


@contextlib.contextmanager
def span(registry: Optional[MetricsRegistry], name: str, **labels):
    """Time a block into ``registry.histogram(name + '_ms')``.  With no
    registry the body runs bare (two None checks of overhead)."""
    if registry is None:
        yield
        return
    t0 = clock.now()
    try:
        yield
    finally:
        registry.histogram(name + "_ms", **labels).observe(
            (clock.now() - t0) * 1e3)


def span_fields(result) -> dict:
    """The per-query span timings (ms) derivable from an ``AsyncResult``'s
    monotonic stamps — the ``spans`` object of a query-log record.  Absent
    stamps (sync engine, which has no dispatch pipeline) yield a partial
    dict."""
    out: dict = {}
    sub = getattr(result, "submitted_at", None)
    dis = getattr(result, "dispatched_at", None)
    dev = getattr(result, "device_done_at", None)
    com = getattr(result, "completed_at", None)
    if sub is not None and dis is not None:
        out["queue_wait_ms"] = (dis - sub) * 1e3
    if dis is not None and dev is not None:
        out["device_ms"] = (dev - dis) * 1e3
    if dev is not None and com is not None:
        out["extract_ms"] = (com - dev) * 1e3
    if sub is not None and com is not None:
        out["total_ms"] = (com - sub) * 1e3
    return out
