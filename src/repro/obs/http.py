"""Tiny threaded metrics endpoint for ``launch/serve.py --metrics-port``.

Serves two read-only views of one :class:`~repro.obs.metrics.MetricsRegistry`:

    GET /metrics        Prometheus text exposition
    GET /metrics.json   JSON snapshot (same doc as ``registry.snapshot()``)

stdlib only (``http.server`` on a daemon thread) — a scrape every few
seconds reads registry state under its per-metric locks and never touches
the serving hot path.  Port 0 binds an ephemeral port (tests); the bound
port is on ``MetricsServer.port``.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from .metrics import MetricsRegistry


class _Handler(BaseHTTPRequestHandler):
    registry: MetricsRegistry = None  # set per-server via subclassing

    def do_GET(self):  # noqa: N802 (http.server API)
        if self.path.split("?")[0] == "/metrics":
            body = self.registry.to_prometheus().encode("utf-8")
            ctype = "text/plain; version=0.0.4; charset=utf-8"
        elif self.path.split("?")[0] == "/metrics.json":
            body = json.dumps(self.registry.snapshot(), sort_keys=True,
                              default=float).encode("utf-8")
            ctype = "application/json"
        else:
            self.send_error(404)
            return
        self.send_response(200)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, fmt, *args):  # silence per-request stderr spam
        pass


class MetricsServer:
    """Background scrape endpoint bound to ``host:port`` (port 0 = pick)."""

    def __init__(self, registry: MetricsRegistry, port: int,
                 host: str = "127.0.0.1"):
        handler = type("_BoundHandler", (_Handler,), {"registry": registry})
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="metrics-http",
            daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)


def serve_metrics(registry: MetricsRegistry, port: int,
                  host: str = "127.0.0.1") -> MetricsServer:
    return MetricsServer(registry, port, host=host)
