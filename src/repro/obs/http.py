"""Tiny threaded metrics endpoint for ``launch/serve.py --metrics-port``.

Serves two read-only views of one :class:`~repro.obs.metrics.MetricsRegistry`:

    GET /metrics        Prometheus text exposition
    GET /metrics.json   JSON snapshot (same doc as ``registry.snapshot()``)
    GET /healthz        liveness JSON from the server's ``health``
                        callable — 200 for ok/degraded, 503 for crashed
                        (the load balancer's eject signal)

stdlib only (``http.server`` on a daemon thread) — a scrape every few
seconds reads registry state under its per-metric locks and never touches
the serving hot path.  Port 0 binds an ephemeral port (tests); the bound
port is on ``MetricsServer.port``.  ``set_health`` may attach the health
callable after boot (serve.py binds the port before the engine exists so
scrapers can poll from t=0; until then /healthz reports ``booting``).
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from .metrics import MetricsRegistry


class _Handler(BaseHTTPRequestHandler):
    registry: MetricsRegistry = None  # set per-server via subclassing
    server_ref = None                 # the owning MetricsServer

    def do_GET(self):  # noqa: N802 (http.server API)
        status = 200
        if self.path.split("?")[0] == "/metrics":
            body = self.registry.to_prometheus().encode("utf-8")
            ctype = "text/plain; version=0.0.4; charset=utf-8"
        elif self.path.split("?")[0] == "/metrics.json":
            body = json.dumps(self.registry.snapshot(), sort_keys=True,
                              default=float).encode("utf-8")
            ctype = "application/json"
        elif self.path.split("?")[0] == "/healthz":
            health = getattr(self.server_ref, "health", None)
            doc = {"status": "booting"} if health is None else health()
            status = 503 if doc.get("status") == "crashed" else 200
            body = json.dumps(doc, sort_keys=True,
                              default=float).encode("utf-8")
            ctype = "application/json"
        else:
            self.send_error(404)
            return
        self.send_response(status)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, fmt, *args):  # silence per-request stderr spam
        pass


class MetricsServer:
    """Background scrape endpoint bound to ``host:port`` (port 0 = pick)."""

    def __init__(self, registry: MetricsRegistry, port: int,
                 host: str = "127.0.0.1", health=None):
        self.health = health          # () -> dict, e.g. engine.health
        handler = type("_BoundHandler", (_Handler,),
                       {"registry": registry, "server_ref": self})
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="metrics-http",
            daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def set_health(self, fn) -> None:
        """Attach (or swap) the /healthz source after boot."""
        self.health = fn

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)


def serve_metrics(registry: MetricsRegistry, port: int,
                  host: str = "127.0.0.1", health=None) -> MetricsServer:
    return MetricsServer(registry, port, host=host, health=health)
