"""The one clock source for every span, deadline, and latency figure.

Before this module existed the serving stack mixed three clocks:
``QueryEngine.flush`` timed itself with the wall clock (``time.time`` —
not monotonic; an NTP step mid-flush produces a negative or wildly
wrong latency), the async scheduler used ``time.monotonic``, and the
bucket precompiler used ``time.perf_counter``.  Cross-clock arithmetic
is a silent bug factory: two timestamps are only subtractable when they
came from the *same* clock.

Rules (enforced by a lint test and a CI grep — calling ``time.time`` is
banned under ``src/repro/serving/`` and ``src/repro/obs/``; this
docstring names it without the call parens for exactly that reason):

* every duration, span timestamp, and deadline instant comes from
  :func:`now` — ``time.perf_counter``, the highest-resolution monotonic
  clock CPython offers.  Values are only meaningful as *differences*
  within one process;
* wall-clock time appears exactly once per query-log file — the
  ``t_wall_unix`` / ``clock_origin`` anchor pair in each record lets an
  offline reader reconstruct absolute times without any hot-path
  wall-clock reads (see ``obs/querylog.py``);
* human-facing timestamps (bench JSON, log file headers) use
  :func:`wall_iso`, which goes through ``datetime`` so the banned-call
  lint stays a plain-text grep.
"""
from __future__ import annotations

import datetime as _datetime
import time as _time

#: THE span/deadline clock: monotonic, high resolution, ns-quantized by
#: the OS.  Alias (not a wrapper) so the hot path pays zero extra frames.
now = _time.perf_counter


def wall_unix() -> float:
    """Wall-clock seconds since the epoch (for log-record anchors only —
    never subtract this from a :func:`now` value)."""
    return _datetime.datetime.now(_datetime.timezone.utc).timestamp()


def wall_iso() -> str:
    """ISO-8601 UTC wall timestamp for human-facing metadata."""
    return _datetime.datetime.now(_datetime.timezone.utc).strftime(
        "%Y-%m-%dT%H:%M:%S%z")
