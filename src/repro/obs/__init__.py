"""Observability layer: one clock, a mergeable metrics registry, per-query
span tracing, and the structured query log that feeds continuous
refinement (ROADMAP item 4).  See ARCHITECTURE.md "Observability
layering" for the rules."""
from . import clock
from .metrics import (DEFAULT_LATENCY_BOUNDS_MS, EPOCH_GAUGE,
                      EPOCH_PUBLISH_TOTAL, EPOCH_RETIRED_LAG_MS,
                      SCRUB_AUDITED_TOTAL, SCRUB_QUARANTINED_TOTAL,
                      SCRUB_REPAIRED_TOTAL, Counter, Gauge, Histogram,
                      MetricsRegistry, log_buckets)
from .trace import Sampler, span, span_fields
from .querylog import (LATENCY_METRIC, QueryLogWriter, make_record,
                       mining_view, query_hash, read_query_log,
                       recall_from_log, replay_registry)
from .http import MetricsServer, serve_metrics

__all__ = [
    "clock",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "DEFAULT_LATENCY_BOUNDS_MS", "log_buckets",
    "EPOCH_GAUGE", "EPOCH_PUBLISH_TOTAL", "EPOCH_RETIRED_LAG_MS",
    "SCRUB_AUDITED_TOTAL", "SCRUB_QUARANTINED_TOTAL", "SCRUB_REPAIRED_TOTAL",
    "Sampler", "span", "span_fields",
    "QueryLogWriter", "LATENCY_METRIC", "make_record", "mining_view",
    "query_hash", "read_query_log", "recall_from_log", "replay_registry",
    "MetricsServer", "serve_metrics",
]
