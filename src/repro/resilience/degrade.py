"""Graceful degradation ladder: step search quality down under pressure.

The graph-ANNS trade-off space is a ladder (recall/latency pareto — see
PAPERS.md, arxiv 2101.12631), so overload has a better answer than
"queue grows" or "shed everything": serve cheaper.  Each rung is a
complete :class:`~repro.serving.buckets.ProgramConfig` derived from the
engine's base config:

====  ============  ====================================================
rung  name          change vs. previous rung
====  ============  ====================================================
0     ``base``      the engine's configured search program
1     ``slim-beam``  beam width L cut to ~3/4 (cost is ~linear in L)
2     ``hop-cap``    plus a hop budget of half the default allowance
                    (``(4L+64)/2`` — bounds worst-case walk tails
                    without truncating converged searches)
3     ``sq8``       plus sq8 traversal with a minimal 2k rerank — the
                    rerank touches only 2k exact rows per query, cheap
                    insurance that holds the recall@10 >= 0.95 floor the
                    overload bench enforces (a true no-rerank rung is
                    available via ``DegradePolicy(last_rung_rerank=None)``)
====  ============  ====================================================

:class:`LadderController` owns the transitions.  It observes the
admission-queue backlog once per flush and applies hysteresis: only
``down_after`` consecutive hot observations (backlog >= ``high_frac`` of
capacity) step down one rung, and only ``up_after`` consecutive cold
observations (backlog <= ``low_frac``) step back up — a single bursty
flush never flaps the ladder.  Every transition is reported through
``on_change(old, new, direction)`` so the engine can count it in the
metrics registry.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

from repro.core.beam import default_beam_width, default_max_hops

# NOTE: ProgramConfig (serving/buckets.py) appears only in annotations —
# importing it here would close an import cycle (serving/__init__ pulls
# async_engine, which pulls this module).  ``dataclasses.replace`` works
# on the instances without naming the class.


@dataclasses.dataclass(frozen=True)
class DegradePolicy:
    """Knobs for ladder construction and the hysteresis controller."""

    high_frac: float = 0.5        # backlog fraction of capacity = "hot"
    low_frac: float = 0.125       # backlog fraction of capacity = "cold"
    down_after: int = 3           # consecutive hot flushes to step down
    up_after: int = 8             # consecutive cold flushes to step up
    beam_frac: float = 0.75       # rung-1 L multiplier
    hop_frac: float = 0.5         # rung-2 budget as fraction of the
                                  # default hop allowance (4L+64)
    last_rung_codec: str = "sq8"
    last_rung_rerank: Optional[str] = "2k"   # "2k" | None (no rerank)
    max_rung: int = 3             # truncate the ladder (0 = never degrade)


@dataclasses.dataclass(frozen=True)
class LadderRung:
    """One degradation level: a compiled-program config plus an optional
    hop budget applied to every lane dispatched at this level."""

    name: str
    cfg: ProgramConfig
    hop_budget: Optional[int] = None


def build_ladder(base: ProgramConfig, degree: int,
                 policy: DegradePolicy = DegradePolicy()
                 ) -> List[LadderRung]:
    """Derive the degradation rungs from the engine's base program."""
    rungs = [LadderRung("base", base)]
    k = base.k
    base_l = base.beam_width if base.beam_width is not None else \
        default_beam_width(k, degree, 1)
    slim_l = max(k, int(base_l * policy.beam_frac))
    slim = dataclasses.replace(base, beam_width=slim_l)
    rungs.append(LadderRung("slim-beam", slim))
    # budget off the *default allowance* (4L+64), not L itself: a beam of
    # L needs ~L/expand_width hops just to fill, so a budget of L/2 would
    # truncate typical searches — the rung is meant to bound the
    # worst-case walk tail, not the converged common case
    budget = max(8, int(default_max_hops(slim_l) * policy.hop_frac))
    rungs.append(LadderRung("hop-cap", slim, hop_budget=budget))
    if base.codec == "float32":
        rerank = 2 * k if policy.last_rung_rerank == "2k" else None
        quant = dataclasses.replace(slim, codec=policy.last_rung_codec,
                                    rerank_k=rerank)
        rungs.append(LadderRung("sq8", quant, hop_budget=budget))
    return rungs[: policy.max_rung + 1]


class LadderController:
    """Hysteresis state machine mapping backlog observations to a rung.

    Not thread-safe by design: only the scheduler loop calls
    :meth:`observe` (once per flush, just after popping the batch), and
    only the scheduler reads :attr:`level`.
    """

    def __init__(self, n_rungs: int, capacity: int,
                 policy: DegradePolicy = DegradePolicy(),
                 on_change: Optional[Callable[[int, int, str], None]] = None):
        if capacity < 1:
            raise ValueError("LadderController needs a bounded queue "
                             "(capacity >= 1) to read pressure from")
        self.policy = policy
        self.n_rungs = max(1, n_rungs)
        self.high = max(1, int(capacity * policy.high_frac))
        self.low = int(capacity * policy.low_frac)
        self.on_change = on_change
        self.level = 0
        self._hot = 0
        self._cold = 0

    def observe(self, backlog: int) -> int:
        """Feed one backlog sample; returns the rung to dispatch at."""
        if backlog >= self.high:
            self._hot += 1
            self._cold = 0
        elif backlog <= self.low:
            self._cold += 1
            self._hot = 0
        else:                        # dead band: decay both streaks
            self._hot = 0
            self._cold = 0
        if self._hot >= self.policy.down_after and \
                self.level < self.n_rungs - 1:
            self._move(self.level + 1, "down")
            self._hot = 0
        elif self._cold >= self.policy.up_after and self.level > 0:
            self._move(self.level - 1, "up")
            self._cold = 0
        return self.level

    def _move(self, new: int, direction: str) -> None:
        old, self.level = self.level, new
        if self.on_change is not None:
            self.on_change(old, new, direction)

    def reset(self) -> None:
        self.level = 0
        self._hot = self._cold = 0
