"""Deterministic fault injection for chaos tests and the overload bench.

A :class:`FaultPlan` is a seeded list of rules, each bound to a named
*hook point* (``"scheduler.loop"``, ``"wal.append"``, ...).  Production
code calls :func:`fire` at those points; when no plan is installed the
call is a single global read + ``None`` check, so the hooks cost nothing
in normal operation.  When a plan is active, a matching rule can

- ``kill``  — raise :class:`FaultInjected` (simulates a thread crash /
  a process dying mid-write),
- ``delay`` — sleep for a fixed interval (simulates a slow device or a
  GC pause),
- ``call``  — run an arbitrary callable with the hook's context kwargs
  (corrupt a file, flip a byte, ...).

Rules trigger on the Nth visit to their point (``at=``, 1-based) and/or
with a seeded per-visit probability (``prob=``), so a chaos run is fully
reproducible from ``FaultPlan(seed=...)`` plus the schedule of hook
visits.  Install with ``with plan:`` (tests) or :func:`install`
(long-running processes); :meth:`FaultPlan.parse` builds a plan from the
CLI mini-language used by ``serve.py --faults``::

    scheduler.loop:kill@20;extract.loop:delay=0.05@3;wal.append:kill@7

Hook points currently wired in:

===================== ====================================================
``scheduler.loop``     top of each scheduler-loop iteration
``scheduler.dispatch`` just before a batch is padded + dispatched
``extract.loop``       top of each extract-loop iteration (before get)
``wal.append``         before a WAL record's bytes are written
``snapshot.mid_save``  between writing the tmp snapshot and the rename
``publish.swap``       inside ``DEGIndex.publish``, after the journal
                       record but before the epoch swap becomes visible
``scrub.audit``        before each scrubber audit chunk
``scrub.repair``       before the scrubber's repair stage
===================== ====================================================
"""
from __future__ import annotations

import contextlib
import random
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional


class FaultInjected(RuntimeError):
    """Raised by a ``kill`` rule.  Deliberate, not a bug."""

    def __init__(self, point: str, hit: int):
        super().__init__(f"fault injected at {point!r} (visit {hit})")
        self.point = point
        self.hit = hit


@dataclass
class _Rule:
    point: str
    op: str                               # "kill" | "delay" | "call"
    at: Optional[int] = None              # fire on the Nth visit (1-based)
    prob: float = 0.0                     # or: per-visit probability
    arg: Any = None                       # delay seconds / callable
    times: Optional[int] = 1              # max fires (None = unlimited)
    hits: int = 0
    fired: int = 0


class FaultPlan:
    """A seeded, reproducible schedule of faults keyed by hook point."""

    def __init__(self, seed: int = 0):
        self.seed = seed
        self._rng = random.Random(seed)
        self._rules: List[_Rule] = []
        self._lock = threading.Lock()

    # -- construction -----------------------------------------------------
    def kill(self, point: str, *, at: Optional[int] = None,
             prob: float = 0.0, times: Optional[int] = 1) -> "FaultPlan":
        self._rules.append(_Rule(point, "kill", at=at, prob=prob,
                                 times=times))
        return self

    def delay(self, point: str, seconds: float, *, at: Optional[int] = None,
              prob: float = 0.0,
              times: Optional[int] = None) -> "FaultPlan":
        self._rules.append(_Rule(point, "delay", at=at, prob=prob,
                                 arg=float(seconds), times=times))
        return self

    def call(self, point: str, fn: Callable[..., None], *,
             at: Optional[int] = None, prob: float = 0.0,
             times: Optional[int] = 1) -> "FaultPlan":
        self._rules.append(_Rule(point, "call", at=at, prob=prob, arg=fn,
                                 times=times))
        return self

    @classmethod
    def parse(cls, spec: str, seed: int = 0) -> "FaultPlan":
        """Build a plan from ``point:op[=arg][@n][%p][*times];...``."""
        plan = cls(seed=seed)
        for part in spec.split(";"):
            part = part.strip()
            if not part:
                continue
            try:
                point, action = part.split(":", 1)
                at = prob = None
                times: Optional[int] = None
                if "*" in action:
                    action, t = action.split("*", 1)
                    times = int(t)
                if "%" in action:
                    action, p = action.split("%", 1)
                    prob = float(p)
                if "@" in action:
                    action, n = action.split("@", 1)
                    at = int(n)
                if "=" in action:
                    op, arg = action.split("=", 1)
                else:
                    op, arg = action, None
                op = op.strip()
                if op == "kill":
                    plan.kill(point, at=at, prob=prob or 0.0,
                              times=times if times is not None else 1)
                elif op == "delay":
                    plan.delay(point, float(arg or 0.01), at=at,
                               prob=prob or 0.0, times=times)
                else:
                    raise ValueError(f"unknown fault op {op!r}")
            except ValueError as e:
                raise ValueError(
                    f"bad fault spec {part!r} "
                    "(want point:op[=arg][@n][%p][*times])") from e
        return plan

    # -- runtime ----------------------------------------------------------
    def fire(self, point: str, **ctx: Any) -> None:
        actions = []
        with self._lock:
            for r in self._rules:
                if r.point != point:
                    continue
                r.hits += 1
                if r.times is not None and r.fired >= r.times:
                    continue
                hit = (r.at is not None and r.hits == r.at) or \
                      (r.prob > 0.0 and self._rng.random() < r.prob)
                if hit:
                    r.fired += 1
                    actions.append((r, r.hits))
        for r, hit in actions:
            if r.op == "kill":
                raise FaultInjected(point, hit)
            if r.op == "delay":
                time.sleep(r.arg)
            elif r.op == "call":
                r.arg(**ctx)

    def counts(self) -> Dict[str, int]:
        """Fired-fault count per hook point (for test assertions)."""
        with self._lock:
            out: Dict[str, int] = {}
            for r in self._rules:
                out[r.point] = out.get(r.point, 0) + r.fired
            return out

    def __enter__(self) -> "FaultPlan":
        install(self)
        return self

    def __exit__(self, *exc: Any) -> None:
        clear()


# Module-level active plan.  A plain global (not a threading.local): the
# serving loops run on their own threads and must see the plan installed
# by the test thread.
_ACTIVE: Optional[FaultPlan] = None


def install(plan: FaultPlan) -> None:
    global _ACTIVE
    _ACTIVE = plan


def clear() -> None:
    global _ACTIVE
    _ACTIVE = None


def active() -> Optional[FaultPlan]:
    return _ACTIVE


def fire(point: str, **ctx: Any) -> None:
    """Hook entry point.  No-op (one global read) when no plan is active."""
    plan = _ACTIVE
    if plan is not None:
        plan.fire(point, **ctx)


@contextlib.contextmanager
def clock_skew(offset_s: float):
    """Shift the serving clock by ``offset_s`` within the block.

    Patches ``repro.obs.clock.now`` — the single time source for the
    serving path — so deadline math experiences a step change, the way a
    suspended VM or a long GC pause would look to the scheduler.
    """
    from repro.obs import clock

    real = clock.now
    clock.now = lambda: real() + offset_s
    try:
        yield
    finally:
        clock.now = real
