"""Serving + persistence resilience: typed failures, bounded admission
support, graceful degradation, and deterministic fault injection.

Import surface is deliberately light (numpy only) so that
``persist/wal.py`` and the schedulers can import fault hooks and error
types without pulling in jax.  The degradation ladder
(:mod:`repro.resilience.degrade`) imports the serving dispatch layer and
is imported explicitly by the async engine.
"""
from .errors import (EngineCrashedError, OverloadError, RequestValidationError,
                     ResilienceError)
from .faults import FaultInjected, FaultPlan, clock_skew
from .faults import active as active_faults
from .faults import clear as clear_faults
from .faults import fire as fire_fault
from .faults import install as install_faults
from .validate import validate_query

__all__ = [
    "ResilienceError", "OverloadError", "EngineCrashedError",
    "RequestValidationError", "FaultInjected", "FaultPlan", "clock_skew",
    "fire_fault", "install_faults", "clear_faults", "active_faults",
    "validate_query",
]
