"""Request validation at the admission boundary.

A NaN/Inf query must never reach a device batch: the lock-step beam
co-batches lanes, and while per-lane state is independent, a poisoned
lane still burns hops and produces garbage that callers may mistake for
results.  Validation turns that into a typed, synchronous rejection at
``submit`` — the request is never enqueued, never dispatched, and never
counted as served.
"""
from __future__ import annotations

import numpy as np

from .errors import RequestValidationError


def validate_query(query, dim: int) -> np.ndarray:
    """Coerce ``query`` to a finite float32 vector of length ``dim``.

    Raises :class:`RequestValidationError` on wrong dtype (complex /
    object / non-numeric), wrong shape (anything that doesn't squeeze to
    ``(dim,)``), or non-finite values — including Inf introduced by the
    float32 downcast itself.
    """
    try:
        arr = np.asarray(query)
    except Exception as e:                  # ragged lists etc.
        raise RequestValidationError(f"query is not array-like: {e}") from e
    if arr.dtype == object or np.issubdtype(arr.dtype, np.complexfloating) \
            or not np.issubdtype(arr.dtype, np.number):
        raise RequestValidationError(
            f"query dtype {arr.dtype} is not real-numeric")
    arr = np.squeeze(arr)
    if arr.shape != (dim,):
        raise RequestValidationError(
            f"query shape {np.asarray(query).shape} does not match "
            f"index dim ({dim},)")
    with np.errstate(over="ignore"):        # overflow -> Inf, caught below
        arr = np.ascontiguousarray(arr, dtype=np.float32)
    if not np.isfinite(arr).all():
        raise RequestValidationError(
            "query contains NaN/Inf after float32 cast")
    return arr
