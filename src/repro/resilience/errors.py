"""Typed failure vocabulary for the resilience subsystem.

Every way a request can fail to produce a search result maps to exactly
one exception type, so callers can route on ``except`` clauses instead of
string-matching messages:

- ``RequestValidationError`` — the query itself was malformed (NaN/Inf,
  wrong shape/dtype).  Raised at ``submit``; the request never reaches
  the admission queue, let alone a device batch.
- ``OverloadError`` — the bounded admission queue shed the request
  (either rejected at the door or evicted as the deadline-doomed victim).
- ``EngineCrashedError`` — a serving loop thread died; the watchdog
  fails every outstanding future with this instead of letting
  ``result()`` hang forever.

WAL errors live in :mod:`repro.persist.wal` (they are persistence-layer
concerns), ``FaultInjected`` in :mod:`repro.resilience.faults`.
"""
from __future__ import annotations


class ResilienceError(RuntimeError):
    """Base class for serving-resilience failures."""


class OverloadError(ResilienceError):
    """The admission queue was full and this request was shed.

    ``shed_at`` records which end lost: ``"submit"`` means the incoming
    request was rejected at the door, ``"queue"`` means it was admitted
    earlier and later evicted as the deadline-doomed victim.
    """

    def __init__(self, msg: str, *, depth: int = -1, capacity: int = -1,
                 shed_at: str = "submit"):
        super().__init__(msg)
        self.depth = depth
        self.capacity = capacity
        self.shed_at = shed_at


class EngineCrashedError(ResilienceError):
    """A serving loop thread died while this request was outstanding."""

    def __init__(self, msg: str, *, thread: str = "?"):
        super().__init__(msg)
        self.thread = thread


class RequestValidationError(ValueError):
    """The submitted query is malformed and was never enqueued."""
