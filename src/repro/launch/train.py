"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs a REDUCED config end-to-end on this container (the full configs only
lower via dryrun.py); on a real pod the same driver runs the full config —
the mesh, sharding rules and step functions are identical, only the config
object differs.  Demonstrates the fault-tolerance loop: checkpoints, resume,
failure injection, deterministic data replay.
"""
from __future__ import annotations

import argparse
import dataclasses


def build_reduced_trainer(arch: str, batch: int, seq: int, seed: int = 0):
    import jax
    import numpy as np

    from repro.configs import get_arch
    from repro.train.optimizer import adamw, cosine_schedule
    from repro.train.steps import make_train_step

    spec = get_arch(arch)
    cfg = spec.reduced()
    opt = adamw(cosine_schedule(3e-3, warmup=20, total=500))
    if spec.family == "lm":
        from repro.data.pipeline import lm_synthetic_batch_fn
        from repro.models import transformer as T

        params = T.init_params(jax.random.PRNGKey(seed), cfg)
        step = make_train_step(lambda p, b: T.loss_fn(p, b, cfg), opt)
        batch_fn = lm_synthetic_batch_fn(cfg.vocab, batch, seq, seed)
    elif spec.family == "recsys":
        from repro.data.recsys import CriteoLikeStream
        from repro.models import recsys as R

        params = R.init_params(jax.random.PRNGKey(seed), cfg)
        step = make_train_step(lambda p, b: R.loss_fn(p, b, cfg), opt)
        stream = CriteoLikeStream(cfg, seed=seed)
        batch_fn = lambda s: stream.batch(s, batch)
    elif spec.family == "gnn":
        from repro.data.graphs import (random_geometric_graph, subgraph_batch)
        from repro.models import egnn as E

        params = E.init_params(jax.random.PRNGKey(seed), cfg)
        step = make_train_step(lambda p, b: E.loss_fn(p, b, cfg), opt)
        g, coords = random_geometric_graph(2000, 8, seed=seed)
        rng = np.random.default_rng(seed)
        feats = rng.normal(size=(2000, cfg.d_feat)).astype(np.float32)
        labels = (coords[:, 0] > 0).astype(np.int32) + 2 * (
            coords[:, 1] > 0).astype(np.int32)

        def batch_fn(s):
            r = np.random.default_rng((seed, s))
            seeds = r.integers(0, 2000, size=batch).astype(np.int32)
            return subgraph_batch(g, feats, labels, seeds,
                                  jax.random.PRNGKey(s), (5, 5),
                                  coords=coords)
    else:
        raise ValueError(spec.family)
    opt_state = opt.init(params)
    return step, params, opt_state, batch_fn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    from repro.train.loop import LoopConfig, train_loop

    step, params, opt_state, batch_fn = build_reduced_trainer(
        args.arch, args.batch, args.seq)
    cfg = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every, fail_at=args.fail_at)
    (_, _), history = train_loop(step, params, opt_state, batch_fn, cfg)
    print(f"final loss: {history[-1]['loss']:.4f} "
          f"(first: {history[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
