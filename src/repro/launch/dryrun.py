import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before any other import: jax locks the
#   device count on first init, and the production meshes need 512
#   placeholder host devices (16x16 single-pod uses 256 of them).

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture x input shape) cell — the 40 assigned cells plus the
DEG production-search cells — lower + compile the step function on the
production mesh, and record:

* ``compiled.memory_analysis()``  — proves the cell fits per-device HBM;
* ``compiled.cost_analysis()``    — XLA's own FLOPs/bytes (loop bodies x1);
* the trip-count-scaled FLOPs / HBM bytes / collective bytes from
  ``repro.analysis.hlo`` — the numbers §Roofline consumes.

Usage:
    python -m repro.launch.dryrun --arch phi3-mini-3.8b --shape train_4k
    python -m repro.launch.dryrun --arch all [--multi-pod] [--out reports/]
    python -m repro.launch.dryrun --list

Each cell writes ``<out>/<mesh>/<arch>__<shape>.json``.  Use ``--hlo`` to
also dump the optimized HLO text next to it (input of the perf iterations).
"""
import argparse
import json
import sys
import time
import traceback


def _cells_for(arch: str) -> list:
    from repro.configs import all_cells
    from repro.launch.cells import DEG_CELLS

    cells = []
    if arch in ("all", "deg-ann"):
        cells += [("deg-ann", s) for s in DEG_CELLS]
    if arch == "all":
        cells += all_cells()
    elif arch != "deg-ann":
        cells += [(a, s) for a, s in all_cells() if a == arch]
    return cells


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             dump_hlo: bool = False, variant: str = "") -> dict:
    import jax
    from repro.analysis import hlo as H
    from repro.analysis import roofline as R
    from repro.configs import get_arch
    from repro.launch.cells import SkippedCell, build_cell
    from repro.launch.mesh import make_production_mesh, mesh_devices

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_name,
                 "variant": variant}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        prog = build_cell(arch, shape, mesh, variant=variant)
        lowered = prog.lower(mesh)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
        rec["devices"] = mesh_devices(mesh)

        # ---- memory analysis (proves it fits) -------------------------
        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(ma, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes")
                if hasattr(ma, k)}
        except Exception as e:  # CPU backend may not support it
            rec["memory_analysis"] = {"error": str(e)}

        # ---- XLA cost analysis (loop bodies counted once) --------------
        try:
            ca = compiled.cost_analysis()
            ca = ca[0] if isinstance(ca, (list, tuple)) else ca
            rec["xla_cost"] = {k: float(v) for k, v in ca.items()
                               if isinstance(v, (int, float))
                               and k in ("flops", "bytes accessed",
                                         "optimal_seconds")}
        except Exception as e:
            rec["xla_cost"] = {"error": str(e)}

        # ---- trip-scaled HLO cost + roofline ---------------------------
        text = compiled.as_text()
        rec["hlo_bytes"] = len(text)
        cost = H.analyze_text(text)
        est_hops = prog.meta.get("est_hops")
        if est_hops and cost["while_detail"]:
            # DEG search: the loop bound is max_hops (worst case); rescale
            # the dominant while with the measured expected hop count.
            main_body = max(cost["while_detail"], key=lambda w: w["hbm"])
            cost = H.analyze_text(
                text, trip_overrides={main_body["body"]: int(est_hops)})
            rec["trip_override"] = {main_body["body"]: int(est_hops)}
        rec["hlo_cost"] = {k: cost[k] for k in
                           ("flops", "hbm_bytes", "collective_bytes")}
        rec["per_collective"] = cost["per_collective"]
        rec["while_detail"] = cost["while_detail"][:12]

        kind = prog.kind
        dims = dict(prog.meta)
        cfg = dims.pop("cfg", None)
        cell_dims = (get_arch(arch).cell(shape).dims
                     if arch != "deg-ann" else prog.meta)
        mf = R.model_flops_for(prog.meta, kind, cell_dims)
        roof = R.from_costs(cost["flops"], cost["hbm_bytes"],
                            cost["collective_bytes"], model_flops=mf,
                            devices=rec["devices"])
        rec["roofline"] = roof.as_dict()
        rec["status"] = "ok"
        if dump_hlo:
            suffix = f".{variant}" if variant else ""
            hp = os.path.join(out_dir, mesh_name,
                              f"{arch}__{shape}{suffix}.hlo.txt")
            os.makedirs(os.path.dirname(hp), exist_ok=True)
            with open(hp, "w") as f:
                f.write(text)
    except SkippedCell as e:
        rec["status"] = "skipped"
        rec["reason"] = str(e)
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)

    suffix = f".{variant}" if variant else ""
    path = os.path.join(out_dir, mesh_name, f"{arch}__{shape}{suffix}.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--hlo", action="store_true", help="dump optimized HLO")
    ap.add_argument("--variant", default="", help="suffix for perf variants")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    cells = _cells_for(args.arch)
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]
    if args.list:
        for a, s in cells:
            print(f"{a:24s} {s}")
        return 0

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for mp in meshes:
        for arch, shape in cells:
            rec = run_cell(arch, shape, mp, args.out, dump_hlo=args.hlo,
                           variant=args.variant)
            roof = rec.get("roofline", {})
            print(f"[{rec['mesh']}] {arch}/{shape}: {rec['status']} "
                  f"lower={rec.get('lower_s', '-')}s "
                  f"compile={rec.get('compile_s', '-')}s "
                  f"bottleneck={roof.get('bottleneck', '-')}",
                  flush=True)
            if rec["status"] == "error":
                failures += 1
                print(rec.get("error"), file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
