"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* the first
jax device query, while smoke tests and benches must keep seeing 1 device.

Axes are *roles*, not sizes: everything downstream reads sizes from the mesh
object, so scaling to a 64-pod ``(64, 16, 16)`` mesh is config-only.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Tiny mesh with the same axis roles — for fast sharding-rule tests on
    CPU (requires >= 8 host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh (pod axis included when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis(mesh) -> str:
    return "model"


def mesh_devices(mesh) -> int:
    import numpy as np

    return int(np.prod(mesh.devices.shape))
