"""Index-building launcher: ``python -m repro.launch.build_index``.

Builds a DEG over a synthetic dataset (paper Table 3 parameters by default),
optionally runs continuous refinement, reports recall/QPS, and saves the
graph + vectors to an .npz file that serve.py can load.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--degree", type=int, default=20)
    ap.add_argument("--k-ext", type=int, default=40)
    ap.add_argument("--eps-ext", type=float, default=0.3)
    ap.add_argument("--wave", type=int, default=16,
                    help="bulk-build wave size (1 = paper-faithful)")
    ap.add_argument("--refine", type=int, default=0,
                    help="continuous-refinement iterations after build")
    ap.add_argument("--lid", choices=["low", "high"], default="low")
    ap.add_argument("--out", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.core.build import DEGParams, build_deg
    from repro.core.distances import exact_knn_batched
    from repro.core.invariants import check_invariants
    from repro.core.metrics import recall_at_k
    from repro.data.synthetic import gaussian_mixture, planted_manifold

    gen = gaussian_mixture if args.lid == "low" else planted_manifold
    vecs = gen(args.n + 500, args.dim, seed=args.seed)
    base, queries = vecs[: args.n], vecs[args.n:]

    params = DEGParams(degree=args.degree, k_ext=args.k_ext,
                       eps_ext=args.eps_ext,
                       scheme="C", rng_checks=True)
    t0 = time.time()
    idx = build_deg(base, params, wave_size=args.wave)
    build_s = time.time() - t0
    if args.refine:
        t0 = time.time()
        idx.refine(args.refine, seed=args.seed)
        print(f"refined {args.refine} iterations in {time.time()-t0:.1f}s "
              f"(avg neighbor dist {idx.builder.average_neighbor_distance():.4f})")
    ok, msgs = check_invariants(idx.builder)
    assert ok, msgs
    t0 = time.time()
    res = idx.search(queries, k=10, eps=0.1)
    qps = queries.shape[0] / (time.time() - t0)
    _, gt = exact_knn_batched(queries, base, 10)
    rec = recall_at_k(np.asarray(res.ids), gt)
    print(f"n={args.n} d={args.degree} wave={args.wave}: "
          f"build {build_s:.1f}s, recall@10 {rec:.4f}, {qps:.0f} qps, "
          f"avg-hops {float(np.mean(np.asarray(res.hops))):.1f}")
    if args.out:
        # versioned full-state snapshot (persist/): serve.py warm-starts
        # from this without rebuilding, and the restored index stays mutable
        idx.save(args.out)
        print(f"saved index snapshot to {args.out}")


if __name__ == "__main__":
    main()
