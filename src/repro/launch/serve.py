"""Serving launcher: ``python -m repro.launch.serve``.

Loads (or builds) a DEG index, then drives the batched QueryEngine through a
synthetic request trace mixing fresh ANN queries, exploration sessions, and
online inserts — the interactive-browsing workload the paper targets
(§1, §6.7).  Reports QPS and recall.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def _load_index(path):
    """Warm-start: the persist snapshot format, with a fallback for the
    legacy build_index archives (adjacency/weights/vectors/degree keys)."""
    from repro.core.build import DEGIndex, DEGParams

    with np.load(path) as z:
        legacy = "__meta__" not in z
        if legacy:
            adjacency = z["adjacency"]
            weights = z["weights"]
            vectors = z["vectors"]
            degree = int(z["degree"])
    if not legacy:
        return DEGIndex.load(path)
    params = DEGParams(degree=degree, k_ext=max(2 * degree, 20))
    idx = DEGIndex(vectors.shape[1], params, capacity=vectors.shape[0] + 1024)
    idx.vectors[: vectors.shape[0]] = vectors
    idx._put_rows(vectors, 0)
    from repro.core.graph import GraphBuilder

    b = GraphBuilder(idx.capacity, degree)
    b.load(adjacency, weights, adjacency.shape[0])
    idx.builder = b
    return idx


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--index", default=None,
                    help="warm-start from a persist snapshot (.npz from "
                    "build_index.py --out / DEGIndex.save); legacy "
                    "adjacency/vectors archives are still accepted")
    ap.add_argument("--save-index", default=None,
                    help="snapshot the (possibly mutated) index to this "
                    "path after serving — the restart loop: "
                    "--index X ... --save-index X")
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--degree", type=int, default=16)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--explore-sessions", type=int, default=8)
    ap.add_argument("--insert-every", type=int, default=0,
                    help="insert one new vector every N queries")
    ap.add_argument("--refine-budget", type=int, default=0)
    ap.add_argument("--build-refine", type=int, default=500,
                    help="refinement iterations after build (paper Alg. 5; "
                    "without it recall plateaus — see EXPERIMENTS.md)")
    from repro.configs.deg import QUANT_PRESETS

    ap.add_argument("--preset", default=None, choices=sorted(QUANT_PRESETS),
                    help="named store preset from configs/deg.py "
                    "(sets --codec/--rerank-k)")
    ap.add_argument("--codec", default="float32",
                    choices=("float32", "fp16", "sq8"),
                    help="vector store the beam traverses (compressed "
                    "codecs run the two-stage exact-rerank search)")
    ap.add_argument("--rerank-k", type=int, default=0,
                    help="exact-rerank width for compressed codecs "
                    "(0 = auto 4*k)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.preset:
        preset = QUANT_PRESETS[args.preset]
        args.codec, args.rerank_k = preset.codec, preset.rerank_k

    from repro.core.build import DEGIndex, DEGParams, build_deg
    from repro.core.distances import exact_knn_batched
    from repro.core.metrics import recall_at_k
    from repro.data.synthetic import make_dataset
    from repro.serving.engine import QueryEngine

    if args.index:
        idx = _load_index(args.index)
        base = idx.vectors[: idx.n].copy()
        rng = np.random.default_rng(args.seed)
        queries = base[rng.integers(0, base.shape[0], args.queries)] + \
            0.01 * rng.normal(size=(args.queries, base.shape[1])
                              ).astype(np.float32)
    else:
        base, queries = make_dataset("gaussian", args.n, args.queries,
                                     args.dim, seed=args.seed)
        idx = build_deg(base, DEGParams(degree=args.degree,
                                        k_ext=2 * args.degree),
                        wave_size=16,
                        refine_iterations=args.build_refine)
    engine = QueryEngine(idx, k=args.k, max_batch=args.batch,
                         refine_budget=args.refine_budget,
                         codec=args.codec,
                         rerank_k=args.rerank_k or None)
    if args.codec != "float32":
        ms = engine.memory_stats()
        print(f"codec={args.codec}: traversal store "
              f"{ms['serving_bytes']/1e6:.2f} MB "
              f"({ms['serving_ratio']:.2f}x smaller than float32)")

    futs = []
    t0 = time.time()
    for i, q in enumerate(queries):
        futs.append(engine.submit(q))
        if args.insert_every and i % args.insert_every == args.insert_every - 1:
            engine.insert(q + 0.05 * np.random.default_rng(i).normal(
                size=q.shape).astype(np.float32))
    engine.flush()
    wall = time.time() - t0
    found = np.stack([f["ids"] for f in futs])
    _, gt = exact_knn_batched(queries, base, args.k)
    rec = recall_at_k(found, gt)
    print(f"served {len(futs)} queries in {wall:.2f}s "
          f"({engine.stats.qps:.0f} qps device-time), recall@{args.k}={rec:.4f}, "
          f"{engine.stats.inserts} inserts, "
          f"{engine.stats.refine_iterations} refine edge improvements")

    # exploration sessions (paper §6.7): 4 hops each, no repeats
    for s in range(args.explore_sessions):
        v = int(np.random.default_rng(s).integers(0, idx.n))
        seen: set = set()
        for _ in range(4):
            fut = engine.explore(v, session=f"s{s}")
            engine.flush()
            ids = [int(x) for x in fut["ids"] if x >= 0]
            assert not (set(ids) & seen), "session exclusion violated"
            seen.update(ids)
            if ids:
                v = ids[0]
    print(f"ran {args.explore_sessions} exploration sessions "
          f"(4 hops each, exclusion verified)")
    if args.save_index:
        engine.save(args.save_index)
        print(f"saved index snapshot to {args.save_index} "
              f"(n={idx.n}; warm-start with --index)")


if __name__ == "__main__":
    main()
