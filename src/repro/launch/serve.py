"""Serving launcher: ``python -m repro.launch.serve``.

Loads (or builds) a DEG index, then serves a synthetic request trace.
Two front ends:

* ``--engine sync`` (default) — the batched ``QueryEngine`` driven
  closed-loop, mixing fresh ANN queries, exploration sessions, and
  online inserts — the interactive-browsing workload the paper targets
  (§1, §6.7).  Reports QPS and recall.
* ``--engine async`` — the continuous-batching ``AsyncQueryEngine``:
  single-query submits coalesced into bucketed fixed-shape programs
  with per-request deadlines (``--deadline-ms`` / ``--slo``).  Reports
  p50/p99 latency, sustained QPS, recall, and partial/forced-flush
  counts.

``--warmup`` precompiles every (bucket, preset) program at boot and logs
the compile time per bucket, so a warm-started snapshot (``--index``)
serves its first request at steady-state latency.

Observability (obs/): ``--metrics-port P`` serves the engine registry at
``http://127.0.0.1:P/metrics`` (Prometheus text), ``/metrics.json``, and
``/healthz`` (engine liveness: 503 once the engine is crashed) while the
process runs (``--hold-secs`` keeps it up after the trace for scrapers —
the CI smoke job's hook); ``--stats-every S`` prints a one-line registry
digest every S seconds; ``--trace-sample R`` + ``--query-log PATH``
write the sampled JSONL query log.

Resilience (resilience/, --engine async): ``--max-queue`` bounds
admission (overflow sheds with a typed ``OverloadError`` per
``--shed-policy``), ``--degrade`` arms the adaptive degradation ladder,
``--wal PATH`` journals every index mutation for crash-safe recovery,
and ``--faults SPEC`` installs a deterministic fault plan
(``point:op[=arg][@n]`` — the chaos-smoke CI job's hook).  Shed /
invalid / crashed submissions are counted, never silently dropped, and
the run ends with one greppable ``resilience:`` summary line.

Live mutation (--engine async): ``--refine-while-serving N`` runs a
background continuous-refinement writer that republishes a fresh epoch
per tick, ``--scrub-every S`` runs the online integrity scrubber
(audit / quarantine / repair / re-admit), and ``--inject-corruption K``
seeds adjacency damage the scrubber must heal (the scrub-smoke CI
hook).  Either flag enables epoch publication: readers serve immutable
published snapshots while writers mutate the live builder.  The run
ends with greppable ``scrub:`` and ``invariants:`` summary lines.
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np


def _load_index(path):
    """Warm-start: the persist snapshot format, with a fallback for the
    legacy build_index archives (adjacency/weights/vectors/degree keys)."""
    from repro.core.build import DEGIndex, DEGParams

    with np.load(path) as z:
        legacy = "__meta__" not in z
        if legacy:
            adjacency = z["adjacency"]
            weights = z["weights"]
            vectors = z["vectors"]
            degree = int(z["degree"])
    if not legacy:
        return DEGIndex.load(path)
    params = DEGParams(degree=degree, k_ext=max(2 * degree, 20))
    idx = DEGIndex(vectors.shape[1], params, capacity=vectors.shape[0] + 1024)
    idx.vectors[: vectors.shape[0]] = vectors
    idx._put_rows(vectors, 0)
    from repro.core.graph import GraphBuilder

    b = GraphBuilder(idx.capacity, degree)
    b.load(adjacency, weights, adjacency.shape[0])
    idx.builder = b
    return idx


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--index", default=None,
                    help="warm-start from a persist snapshot (.npz from "
                    "build_index.py --out / DEGIndex.save); legacy "
                    "adjacency/vectors archives are still accepted")
    ap.add_argument("--save-index", default=None,
                    help="snapshot the (possibly mutated) index to this "
                    "path after serving — the restart loop: "
                    "--index X ... --save-index X")
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--degree", type=int, default=16)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--explore-sessions", type=int, default=8)
    ap.add_argument("--insert-every", type=int, default=0,
                    help="insert one new vector every N queries")
    ap.add_argument("--refine-budget", type=int, default=0)
    ap.add_argument("--build-refine", type=int, default=500,
                    help="refinement iterations after build (paper Alg. 5; "
                    "without it recall plateaus — see EXPERIMENTS.md)")
    from repro.configs.deg import QUANT_PRESETS

    ap.add_argument("--preset", default=None, choices=sorted(QUANT_PRESETS),
                    help="named store preset from configs/deg.py "
                    "(sets --codec/--rerank-k/--eps)")
    ap.add_argument("--codec", default="float32",
                    choices=("float32", "fp16", "sq8", "pq"),
                    help="vector store the beam traverses (compressed "
                    "codecs run the two-stage exact-rerank search)")
    ap.add_argument("--rerank-k", type=int, default=0,
                    help="exact-rerank width for compressed codecs "
                    "(0 = auto 4*k)")
    ap.add_argument("--eps", type=float, default=0.1,
                    help="beam exploration slack (pq presets widen this — "
                    "ADC distances distort the stopping rule)")
    from repro.configs.deg import SEARCH_PRESETS, SLO_PRESETS

    ap.add_argument("--engine", default="sync", choices=("sync", "async"),
                    help="sync = closed-loop batched QueryEngine (golden "
                    "baseline); async = continuous-batching "
                    "AsyncQueryEngine with deadlines")
    ap.add_argument("--search-preset", default=None,
                    choices=sorted(SEARCH_PRESETS),
                    help="L/E search program preset from configs/deg.py "
                    "(bucketed programs are compiled per preset)")
    ap.add_argument("--slo", default="balanced", choices=sorted(SLO_PRESETS),
                    help="scheduler preset (max_batch/buckets/deadline/"
                    "linger) for --engine async")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request SLO override for --engine async "
                    "(negative = no deadline)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound the async admission queue at this depth; "
                    "overflow sheds with a typed OverloadError "
                    "(default: unbounded)")
    ap.add_argument("--shed-policy", default="reject",
                    choices=("reject", "drop"),
                    help="reject = refuse the incoming submit at "
                    "capacity; drop = evict the most-expired queued "
                    "request instead (needs deadlines)")
    ap.add_argument("--degrade", action="store_true",
                    help="arm the adaptive degradation ladder (slim "
                    "beam -> hop cap -> sq8) driven by queue backlog; "
                    "requires --max-queue")
    ap.add_argument("--wal", default=None,
                    help="journal every index mutation to this "
                    "write-ahead log; load_index(snapshot) + "
                    "replay_wal(wal) recovers bit-identically after a "
                    "crash")
    ap.add_argument("--faults", default=None,
                    help="deterministic fault plan spec, e.g. "
                    "'scheduler.loop:kill@5;wal.append:delay=0.01' "
                    "(see resilience.faults.FaultPlan.parse)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for probabilistic fault-plan rules")
    ap.add_argument("--refine-while-serving", type=int, default=0,
                    help="run N continuous-refinement iterations per "
                    "background tick while the async engine serves, "
                    "publishing a fresh epoch after each tick (0 = off; "
                    "enables epoch publication)")
    ap.add_argument("--scrub-every", type=float, default=0.0,
                    help="run the online integrity scrubber (audit / "
                    "quarantine / repair / re-admit) every S seconds "
                    "while serving (0 = off; enables epoch publication)")
    ap.add_argument("--inject-corruption", type=int, default=0,
                    help="flip this many adjacency entries (seeded) after "
                    "boot — the scrub-smoke hook: the scrubber must "
                    "detect, quarantine, and repair them")
    ap.add_argument("--warmup", action="store_true",
                    help="precompile all (bucket, preset) programs at boot "
                    "and log compile time per bucket")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve the metrics registry on this port "
                    "(/metrics Prometheus text, /metrics.json snapshot; "
                    "0 = ephemeral, the bound port is printed)")
    ap.add_argument("--stats-every", type=float, default=0.0,
                    help="print a one-line registry digest every N seconds "
                    "while serving (0 = off)")
    ap.add_argument("--trace-sample", type=float, default=0.0,
                    help="query-log sample rate in [0,1] (0 = tracing off, "
                    "no per-query work)")
    ap.add_argument("--query-log", default=None,
                    help="rotating JSONL query log path (needs "
                    "--trace-sample > 0)")
    ap.add_argument("--hold-secs", type=float, default=0.0,
                    help="keep the process (and --metrics-port endpoint) "
                    "alive this long after the trace finishes — for "
                    "external scrapers / the CI smoke job")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.preset:
        preset = QUANT_PRESETS[args.preset]
        args.codec, args.rerank_k = preset.codec, preset.rerank_k
        if preset.eps is not None:
            args.eps = preset.eps

    from repro import obs
    from repro.core.build import DEGIndex, DEGParams, build_deg
    from repro.core.distances import exact_knn_batched
    from repro.core.metrics import recall_at_k
    from repro.data.synthetic import make_dataset
    from repro.resilience import (EngineCrashedError, FaultPlan,
                                  OverloadError, RequestValidationError,
                                  install_faults)
    from repro.serving.async_engine import AsyncQueryEngine
    from repro.serving.engine import QueryEngine

    if args.faults:
        plan = FaultPlan.parse(args.faults, seed=args.fault_seed)
        install_faults(plan)
        print(f"faults: installed plan {args.faults!r} "
              f"(seed {args.fault_seed})")

    registry = obs.MetricsRegistry()
    metrics_srv = None
    if args.metrics_port is not None:
        metrics_srv = obs.serve_metrics(registry, args.metrics_port)
        print(f"metrics: {metrics_srv.url} (and /metrics.json)")
    qlog = None
    if args.query_log:
        qlog = obs.QueryLogWriter(args.query_log)
        print(f"query log: {args.query_log} "
              f"(sample rate {args.trace_sample})")
    stats_stop = threading.Event()
    if args.stats_every > 0:
        def _stats_loop():
            lat = registry.histogram(obs.LATENCY_METRIC)
            while not stats_stop.wait(args.stats_every):
                p = lat.percentiles()
                print(f"stats: requests="
                      f"{registry.counter('serving_requests_total').value:.0f} "
                      f"flushes="
                      f"{registry.counter('serving_flushes_total').value:.0f} "
                      f"queue={registry.gauge('serving_queue_depth').value:.0f} "
                      f"p50={p['p50']:.2f}ms p99={p['p99']:.2f}ms")
        threading.Thread(target=_stats_loop, name="stats-printer",
                         daemon=True).start()

    def _teardown():
        if args.hold_secs > 0:
            print(f"holding for {args.hold_secs}s "
                  f"(metrics endpoint stays up)")
            time.sleep(args.hold_secs)
        stats_stop.set()
        if qlog is not None:
            qlog.close()
        if metrics_srv is not None:
            metrics_srv.close()

    if args.index:
        idx = _load_index(args.index)
        base = idx.vectors[: idx.n].copy()
        rng = np.random.default_rng(args.seed)
        queries = base[rng.integers(0, base.shape[0], args.queries)] + \
            0.01 * rng.normal(size=(args.queries, base.shape[1])
                              ).astype(np.float32)
    else:
        base, queries = make_dataset("gaussian", args.n, args.queries,
                                     args.dim, seed=args.seed)
        idx = build_deg(base, DEGParams(degree=args.degree,
                                        k_ext=2 * args.degree),
                        wave_size=16,
                        refine_iterations=args.build_refine)
    # build-side spans (insert waves, refine chunks) land in the same
    # registry the serving metrics export from
    idx.metrics = registry
    if args.wal:
        idx.enable_wal(args.wal)
        print(f"wal: journaling mutations to {args.wal} "
              f"(cursor seq={idx._wal_seq})")
    live_mutation = bool(args.refine_while_serving or args.scrub_every > 0)
    if args.engine == "async":
        dl = args.deadline_ms
        if dl is not None and dl < 0:
            dl = None
        scrubber = None
        refine_stop = threading.Event()
        refine_thread = None
        refine_stats = {"ticks": 0, "errors": 0}
        if live_mutation:
            # epoch publication: writers mutate the live builder, readers
            # serve immutable published snapshots (see core/epoch.py)
            idx.enable_publishing()
            print(f"epochs: publication enabled "
                  f"(epoch {idx._epochs.current.epoch})")
        if args.inject_corruption:
            from repro.serving.scrub import corrupt_adjacency
            rows = corrupt_adjacency(idx, args.inject_corruption,
                                     seed=args.seed)
            print(f"corruption: flipped {args.inject_corruption} adjacency "
                  f"entries across rows {rows}")
        if args.scrub_every > 0:
            from repro.serving.scrub import IntegrityScrubber
            scrubber = IntegrityScrubber(idx, interval_s=args.scrub_every)
            scrubber.start()
            print(f"scrubber: auditing every {args.scrub_every}s")
        if args.refine_while_serving:
            def _refine_loop():
                # let the engine compile its first programs before the
                # writer starts competing for the mutation lock
                if refine_stop.wait(1.0):
                    return
                while not refine_stop.is_set():
                    try:
                        idx.refine(args.refine_while_serving,
                                   seed=refine_stats["ticks"])
                        idx.publish()
                        refine_stats["ticks"] += 1
                    except Exception:
                        # refinement may race injected corruption; the
                        # scrubber heals the graph and the next tick works
                        refine_stats["errors"] += 1
                    if refine_stop.wait(0.05):
                        return
            refine_thread = threading.Thread(
                target=_refine_loop, name="refine-while-serving",
                daemon=True)
            refine_thread.start()
            print(f"refine: {args.refine_while_serving} iterations per "
                  f"background tick, republishing each tick")
        aeng = AsyncQueryEngine(idx, k=args.k, eps=args.eps,
                                codec=args.codec,
                                rerank_k=args.rerank_k or None,
                                preset=args.search_preset, slo=args.slo,
                                max_batch=args.batch,
                                metrics=registry,
                                trace_sample=args.trace_sample,
                                query_log=qlog,
                                max_queue=args.max_queue,
                                shed_policy=args.shed_policy,
                                degrade=args.degrade,
                                **({} if args.deadline_ms is None
                                   else {"deadline_ms": dl}))
        if metrics_srv is not None:
            metrics_srv.set_health(aeng.health)
        if args.warmup:
            t0 = time.time()
            times = aeng.warmup()
            for (b, variant), secs in sorted(times.items()):
                print(f"warmup: bucket={b:4d} variant={variant:6s} "
                      f"compile+run {secs*1e3:8.1f} ms")
            print(f"warmup: {len(times)} programs in {time.time()-t0:.2f}s "
                  f"(buckets {list(aeng.buckets)})")
        # every submit ends in exactly one bucket: served, shed (typed
        # OverloadError), invalid (RequestValidationError), or crashed
        # (EngineCrashedError) — nothing hangs, nothing is silently lost
        t0 = time.time()
        served_q, served_fut = [], []
        shed = invalid = crashed = 0
        for q in queries:
            try:
                fut = aeng.submit(q)
            except OverloadError:
                shed += 1
                continue
            except RequestValidationError:
                invalid += 1
                continue
            except EngineCrashedError:
                crashed += 1
                continue
            served_q.append(q)
            served_fut.append(fut)
        futs, outs = [], []
        ok_q = []
        for q, f in zip(served_q, served_fut):
            try:
                outs.append(f.result(120.0))
            except OverloadError:
                shed += 1
                continue
            except EngineCrashedError:
                crashed += 1
                continue
            futs.append(f)
            ok_q.append(q)
        wall = time.time() - t0
        st = aeng.stats
        if futs:
            lats = np.array([f.latency_s for f in futs]) * 1e3
            found = np.stack([o[0] for o in outs])
            _, gt = exact_knn_batched(np.stack(ok_q), base, args.k)
            rec = recall_at_k(found, gt)
            print(f"served {len(futs)} queries in {wall:.2f}s "
                  f"({len(futs)/wall:.0f} qps sustained), "
                  f"recall@{args.k}={rec:.4f}, "
                  f"p50={np.percentile(lats, 50):.2f}ms "
                  f"p99={np.percentile(lats, 99):.2f}ms, "
                  f"{st.flushes} flushes {st.partials} partial "
                  f"{st.forced_flushes} deadline-forced, "
                  f"buckets={st.bucket_hist}")
        else:
            print(f"served 0 queries in {wall:.2f}s")
        print(f"resilience: served={len(futs)} shed={shed} "
              f"invalid={invalid} crashed={crashed} "
              f"degraded={st.degraded} restarts={st.restarts} "
              f"status={aeng.health()['status']}")
        if refine_thread is not None:
            refine_stop.set()
            refine_thread.join(timeout=60.0)
            print(f"refine: ticks={refine_stats['ticks']} "
                  f"errors={refine_stats['errors']}")
        if scrubber is not None:
            # one final synchronous pass so quarantined-but-unrepaired
            # damage from a late corruption never slips past the summary
            scrubber.stop()
            scrubber.run_pass()
            ss = scrubber.stats
            print(f"scrub: passes={ss.passes} audited={ss.audited} "
                  f"quarantined={ss.quarantined} repaired={ss.repaired} "
                  f"readmitted={ss.readmitted} unrepaired={ss.unrepaired} "
                  f"crashes={ss.crashes} errors={ss.errors} "
                  f"epoch={idx._epochs.current.epoch if idx.publishing else -1}")
        if live_mutation:
            from repro.core.invariants import check_invariants
            ok, problems = check_invariants(idx.builder)
            print(f"invariants: ok={ok}"
                  + ("" if ok else f" problems={problems}"))
        aeng.close()
        _teardown()
        if args.save_index:
            idx.save(args.save_index)
            print(f"saved index snapshot to {args.save_index} "
                  f"(n={idx.n}; warm-start with --index)")
        return

    engine = QueryEngine(idx, k=args.k, eps=args.eps, max_batch=args.batch,
                         refine_budget=args.refine_budget,
                         codec=args.codec,
                         rerank_k=args.rerank_k or None,
                         preset=args.search_preset,
                         metrics=registry,
                         trace_sample=args.trace_sample,
                         query_log=qlog)
    if args.warmup:
        t0 = time.time()
        times = engine.warmup()
        for (b, variant), secs in sorted(times.items()):
            print(f"warmup: bucket={b:4d} compile+run {secs*1e3:8.1f} ms")
        print(f"warmup: {len(times)} programs in {time.time()-t0:.2f}s "
              f"(buckets {list(engine.buckets)})")
    if args.codec != "float32":
        ms = engine.memory_stats()
        print(f"codec={args.codec}: traversal store "
              f"{ms['serving_bytes']/1e6:.2f} MB "
              f"({ms['serving_ratio']:.2f}x smaller than float32)")

    futs = []
    t0 = time.time()
    for i, q in enumerate(queries):
        futs.append(engine.submit(q))
        if args.insert_every and i % args.insert_every == args.insert_every - 1:
            engine.insert(q + 0.05 * np.random.default_rng(i).normal(
                size=q.shape).astype(np.float32))
    engine.flush()
    wall = time.time() - t0
    found = np.stack([f["ids"] for f in futs])
    _, gt = exact_knn_batched(queries, base, args.k)
    rec = recall_at_k(found, gt)
    print(f"served {len(futs)} queries in {wall:.2f}s "
          f"({engine.stats.qps:.0f} qps device-time), recall@{args.k}={rec:.4f}, "
          f"{engine.stats.inserts} inserts, "
          f"{engine.stats.refine_iterations} refine edge improvements")

    # exploration sessions (paper §6.7): 4 hops each, no repeats
    for s in range(args.explore_sessions):
        v = int(np.random.default_rng(s).integers(0, idx.n))
        seen: set = set()
        for _ in range(4):
            fut = engine.explore(v, session=f"s{s}")
            engine.flush()
            ids = [int(x) for x in fut["ids"] if x >= 0]
            assert not (set(ids) & seen), "session exclusion violated"
            seen.update(ids)
            if ids:
                v = ids[0]
    print(f"ran {args.explore_sessions} exploration sessions "
          f"(4 hops each, exclusion verified)")
    _teardown()
    if args.save_index:
        engine.save(args.save_index)
        print(f"saved index snapshot to {args.save_index} "
              f"(n={idx.n}; warm-start with --index)")


if __name__ == "__main__":
    main()
