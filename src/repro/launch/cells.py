"""Cell programs: one lowerable (step fn, abstract inputs, shardings) per
(architecture x input-shape x mesh) dry-run cell.

``build_cell(arch, shape, mesh)`` returns a :class:`CellProgram` whose
``lower()`` produces ``jax.stages.Lowered`` for the production mesh —
*every* array input is a ``jax.ShapeDtypeStruct`` (no allocation), which is
what lets the 91 GB DLRM table or the 141 B-param Mixtral lower on a CPU
container.

Shape policy: dims that must divide the mesh are padded here exactly the way
the data pipeline pads them at runtime (edge lists to the device count with
an ``edge_valid`` mask, node counts to the DP axes, recsys tables to the
"model" axis).  Padding constants are part of the cell metadata so the
roofline analysis can discount them.

Beyond the 40 assigned cells, the ``deg-ann`` pseudo-architecture lowers the
paper's own technique at production scale: the sharded-DEG search step
(distributed/index.py) over a 16.7M-vector index.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.distributed import sharding as SH
from repro.distributed.collectives import make_sharded_lookup, sharded_brute_topk
from repro.launch.mesh import batch_axes as mesh_batch_axes

Array = jax.Array


def sds(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def _pad_up(n: int, mult: int) -> int:
    return -(-n // mult) * mult


@dataclasses.dataclass
class CellProgram:
    arch: str
    shape: str
    kind: str
    fn: Callable
    args: tuple                   # abstract arg pytrees (ShapeDtypeStructs)
    in_specs: tuple               # PartitionSpec pytrees (or None = auto)
    out_specs: Any                # PartitionSpec pytree or None = auto
    donate: tuple = ()
    meta: dict = dataclasses.field(default_factory=dict)

    def jitted(self, mesh: Mesh):
        def shard(tree):
            if tree is None:
                return None
            return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                is_leaf=lambda x: isinstance(x, P))

        return jax.jit(
            self.fn,
            in_shardings=tuple(shard(s) for s in self.in_specs),
            out_shardings=shard(self.out_specs),
            donate_argnums=self.donate)

    def lower(self, mesh: Mesh):
        from repro.compat import set_mesh

        with set_mesh(mesh):
            return self.jitted(mesh).lower(*self.args)


# ===========================================================================
# LM family
# ===========================================================================
def _lm_cfg(spec, mesh: Mesh, seq_shard: bool = False):
    """Full config adapted to the mesh: activation-batch constraints (and
    optionally sequence parallelism), MoE dispatch groups = DP shards."""
    cfg = spec.model
    dp = mesh_batch_axes(mesh)
    cfg = dataclasses.replace(
        cfg, act_batch_axes=dp,
        act_seq_axis="model" if seq_shard else None)
    if cfg.moe is not None:
        g = int(np.prod([mesh.shape[a] for a in dp]))
        cfg = dataclasses.replace(
            cfg, moe_groups=g, moe_shard_axes=dp,
            moe=dataclasses.replace(cfg.moe, shard_hidden=True))
    return cfg


def _lm_train(spec, cell, mesh: Mesh, *, seq_shard=False,
              microbatches=1) -> CellProgram:
    from repro.models import transformer as T
    from repro.train.optimizer import adamw
    from repro.train.steps import make_train_step

    cfg = _lm_cfg(spec, mesh, seq_shard=seq_shard)
    B, S = cell["global_batch"], cell["seq_len"]
    params = T.abstract_params(cfg)
    opt = adamw(1e-4, weight_decay=0.1)
    opt_state = jax.eval_shape(opt.init, params)
    batch = {"tokens": sds(B, S, dtype=jnp.int32),
             "labels": sds(B, S, dtype=jnp.int32)}
    step = make_train_step(lambda p, b: T.loss_fn(p, b, cfg), opt, jit=False,
                           microbatches=microbatches)
    pspec = SH.lm_param_specs(cfg, mesh)
    ospec = SH.opt_state_specs(pspec, opt_state)
    bspec = SH.lm_batch_specs(mesh)
    mspec = {"loss": P(), "nll": P(), "aux": P()}
    return CellProgram(
        arch=spec.name, shape=cell.name, kind=cell.kind, fn=step,
        args=(params, opt_state, batch),
        in_specs=(pspec, ospec, bspec),
        out_specs=((pspec, ospec), mspec),
        donate=(0, 1),
        meta={"family": "lm", "tokens": B * S, "cfg": cfg})


def _lm_prefill(spec, cell, mesh: Mesh, *, seq_shard=False) -> CellProgram:
    from repro.models import transformer as T

    cfg = _lm_cfg(spec, mesh, seq_shard=seq_shard)
    B, S = cell["global_batch"], cell["seq_len"]
    params = T.abstract_params(cfg)
    tokens = sds(B, S, dtype=jnp.int32)
    fn = functools.partial(_prefill_fn, cfg=cfg, max_len=S)
    pspec = SH.lm_param_specs(cfg, mesh)
    bspec = P(SH.dp_axes(mesh), None)
    cspec = SH.lm_cache_specs(cfg, mesh, B)
    return CellProgram(
        arch=spec.name, shape=cell.name, kind=cell.kind, fn=fn,
        args=(params, tokens),
        in_specs=(pspec, bspec),
        out_specs=(P(SH.dp_axes(mesh), None), cspec),
        meta={"family": "lm", "tokens": B * S, "cfg": cfg})


def _prefill_fn(params, tokens, *, cfg, max_len):
    from repro.models import transformer as T

    return T.serve_prefill(params, tokens, cfg, max_len=max_len)


def _lm_decode(spec, cell, mesh: Mesh, *, seq_shard=False) -> CellProgram:
    from repro.models import transformer as T

    cfg = _lm_cfg(spec, mesh)
    B, S = cell["global_batch"], cell["seq_len"]
    dp = mesh_batch_axes(mesh)
    dp_n = int(np.prod([mesh.shape[a] for a in dp]))
    if B % dp_n != 0:               # long_500k: batch 1 is unshardable
        cfg = dataclasses.replace(cfg, act_batch_axes=None)
    if cfg.moe is not None and B % cfg.moe_groups != 0:
        cfg = dataclasses.replace(cfg, moe_groups=1, moe_shard_axes=None)
    params = T.abstract_params(cfg)
    cache = T.abstract_cache(cfg, B, S)
    token = sds(B, 1, dtype=jnp.int32)
    fn = functools.partial(_decode_fn, cfg=cfg)
    pspec = SH.lm_param_specs(cfg, mesh)
    cspec = SH.lm_cache_specs(cfg, mesh, B)
    bspec = P(SH._maybe(B, mesh, SH.dp_axes(mesh)), None)
    return CellProgram(
        arch=spec.name, shape=cell.name, kind=cell.kind, fn=fn,
        args=(params, cache, token),
        in_specs=(pspec, cspec, bspec),
        out_specs=(P(SH._maybe(B, mesh, SH.dp_axes(mesh)), None), cspec),
        donate=(1,),
        meta={"family": "lm", "tokens": B, "context": S, "cfg": cfg})


def _decode_fn(params, cache, token, *, cfg):
    from repro.models import transformer as T

    return T.serve_decode_step(params, cache, token, cfg)


# ===========================================================================
# EGNN family
# ===========================================================================
def _egnn_train_full(spec, cell, mesh: Mesh, *, gnn_bf16=False,
                     gnn_node_all_axes=False,
                     gnn_halo=False) -> CellProgram:
    from repro.models import egnn as E
    from repro.train.optimizer import adamw
    from repro.train.steps import make_train_step

    cfg = spec.model_for(cell.name)
    if gnn_bf16:
        cfg = dataclasses.replace(cfg, dtype=jnp.bfloat16)
    node_axes_cfg = tuple(mesh.axis_names) if gnn_node_all_axes else None
    if node_axes_cfg is not None:
        cfg = dataclasses.replace(cfg, node_shard_axes=node_axes_cfg)
    dev = int(np.prod(mesh.devices.shape))
    dp = SH.dp_axes(mesh)
    dp_n = int(np.prod([mesh.shape[a] for a in
                        ((dp,) if isinstance(dp, str) else dp)]))
    if cell.kind == "minibatch":
        from repro.data.graphs import subgraph_shapes

        n_nodes, n_edges = subgraph_shapes(cell["batch_nodes"],
                                           cell["fanouts"])
    else:
        n_nodes, n_edges = cell["n_nodes"], cell["n_edges"]
    n_pad = _pad_up(n_nodes, dev if gnn_node_all_axes else dp_n)
    e_pad = _pad_up(n_edges, dev)
    params = E.abstract_params(cfg)
    opt = adamw(1e-3)
    opt_state = jax.eval_shape(opt.init, params)
    batch = {
        "feats": sds(n_pad, cfg.d_feat),
        "coords": sds(n_pad, 3),
        "edges": sds(2, e_pad, dtype=jnp.int32),
        "edge_valid": sds(e_pad, dtype=jnp.bool_),
        "labels": sds(n_pad, dtype=jnp.int32),
    }
    if gnn_halo:
        loss = E.make_sharded_loss(cfg, mesh, tuple(mesh.axis_names))
    else:
        loss = lambda p, b: E.loss_fn(p, b, cfg)
    step = make_train_step(loss, opt, jit=False)
    pspec = jax.tree.map(lambda _: P(), params)
    ospec = SH.opt_state_specs(pspec, opt_state)
    edge_ax = tuple(mesh.axis_names)
    node_ax = edge_ax if gnn_node_all_axes else dp
    bspec = {
        "feats": P(node_ax, None), "coords": P(node_ax, None),
        "edges": P(None, edge_ax), "edge_valid": P(edge_ax),
        "labels": P(node_ax),
    }
    mspec = {"loss": P(), "nll": P()}
    return CellProgram(
        arch=spec.name, shape=cell.name, kind=cell.kind, fn=step,
        args=(params, opt_state, batch),
        in_specs=(pspec, ospec, bspec),
        out_specs=((pspec, ospec), mspec),
        donate=(0, 1),
        meta={"family": "gnn", "cfg": cfg, "n_nodes": n_nodes,
              "n_edges": n_edges, "n_nodes_pad": n_pad, "n_edges_pad": e_pad})


def _egnn_train_molecule(spec, cell, mesh: Mesh) -> CellProgram:
    from repro.models import egnn as E
    from repro.train.optimizer import adamw
    from repro.train.steps import make_train_step

    cfg = spec.model_for(cell.name)
    B, n, e = cell["batch"], cell["n_nodes"], cell["n_edges"]
    params = E.abstract_params(cfg)
    opt = adamw(1e-3)
    opt_state = jax.eval_shape(opt.init, params)
    batch = {
        "feats": sds(B, n, cfg.d_feat),
        "coords": sds(B, n, 3),
        "edges": sds(B, 2, e, dtype=jnp.int32),
        "edge_valid": sds(B, e, dtype=jnp.bool_),
        "labels": sds(B, dtype=jnp.int32),
    }
    step = make_train_step(lambda p, b: E.loss_fn(p, b, cfg), opt, jit=False)
    pspec = jax.tree.map(lambda _: P(), params)
    ospec = SH.opt_state_specs(pspec, opt_state)
    dp = SH.dp_axes(mesh)
    bspec = {"feats": P(dp, None, None), "coords": P(dp, None, None),
             "edges": P(dp, None, None), "edge_valid": P(dp, None),
             "labels": P(dp)}
    mspec = {"loss": P(), "nll": P()}
    return CellProgram(
        arch=spec.name, shape=cell.name, kind=cell.kind, fn=step,
        args=(params, opt_state, batch),
        in_specs=(pspec, ospec, bspec),
        out_specs=((pspec, ospec), mspec),
        donate=(0, 1),
        meta={"family": "gnn", "cfg": cfg, "batch": B})


# ===========================================================================
# RecSys family
# ===========================================================================
def _recsys_cfg(spec, mesh: Mesh):
    import dataclasses as dc

    return dc.replace(spec.model, table_pad_to=int(mesh.shape["model"]))


def _recsys_batch_abs(cfg, B: int) -> dict:
    b = {"sparse": sds(B, cfg.n_sparse, dtype=jnp.int32),
         "label": sds(B)}
    if cfg.n_dense:
        b["dense"] = sds(B, cfg.n_dense)
    if cfg.kind == "din":
        b["hist"] = sds(B, cfg.seq_len, dtype=jnp.int32)
    return b


def _recsys_train(spec, cell, mesh: Mesh) -> CellProgram:
    from repro.models import recsys as R
    from repro.train.optimizer import adamw, partitioned, sgd
    from repro.train.steps import make_train_step

    cfg = _recsys_cfg(spec, mesh)
    B = cell["batch"]
    params = R.abstract_params(cfg)
    # MLPerf DLRM optimizer split: stateless SGD on the embedding tables
    # (no moments for 100M+ rows), AdamW on the dense towers.
    label = lambda path, leaf: (
        "embed" if path and getattr(path[0], "key", None) in ("table", "fm_w")
        else "dense")
    opt = partitioned(label, {"embed": sgd(0.05), "dense": adamw(1e-3)})
    opt_state = jax.eval_shape(opt.init, params)
    batch = _recsys_batch_abs(cfg, B)
    dp = SH.dp_axes(mesh)
    lookup = make_sharded_lookup(mesh, table_axis="model", batch_axes=dp)
    step = make_train_step(
        lambda p, b: R.loss_fn(p, b, cfg, lookup_fn=lookup), opt, jit=False)
    pspec = SH.recsys_param_specs(cfg, mesh)
    ospec = SH.opt_state_specs(pspec, opt_state)
    bspec = SH.recsys_batch_specs(cfg, mesh, B)
    mspec = {"loss": P(), "bce": P()}
    return CellProgram(
        arch=spec.name, shape=cell.name, kind=cell.kind, fn=step,
        args=(params, opt_state, batch),
        in_specs=(pspec, ospec, bspec),
        out_specs=((pspec, ospec), mspec),
        donate=(0, 1),
        meta={"family": "recsys", "cfg": cfg, "batch": B})


def _recsys_serve(spec, cell, mesh: Mesh) -> CellProgram:
    from repro.models import recsys as R

    cfg = _recsys_cfg(spec, mesh)
    B = cell["batch"]
    params = R.abstract_params(cfg)
    batch = _recsys_batch_abs(cfg, B)
    del batch["label"]
    dp = SH.dp_axes(mesh)
    lookup = make_sharded_lookup(mesh, table_axis="model", batch_axes=dp)
    fn = functools.partial(_recsys_fwd, cfg=cfg, lookup=lookup)
    pspec = SH.recsys_param_specs(cfg, mesh)
    bspec = SH.recsys_batch_specs(cfg, mesh, B)
    del bspec["label"]
    return CellProgram(
        arch=spec.name, shape=cell.name, kind=cell.kind, fn=fn,
        args=(params, batch),
        in_specs=(pspec, bspec),
        out_specs=P(dp),
        meta={"family": "recsys", "cfg": cfg, "batch": B})


def _recsys_fwd(params, batch, *, cfg, lookup):
    from repro.models import recsys as R

    return R.forward(params, batch, cfg, lookup_fn=lookup)


def _recsys_retrieval(spec, cell, mesh: Mesh) -> CellProgram:
    from repro.models import recsys as R

    cfg = _recsys_cfg(spec, mesh)
    B, N = cell["batch"], cell["n_candidates"]
    dp = SH.dp_axes(mesh)
    dp_t = (dp,) if isinstance(dp, str) else dp
    dp_n = int(np.prod([mesh.shape[a] for a in dp_t]))
    N_pad = _pad_up(N, dp_n)
    params = R.abstract_params(cfg)
    batch = _recsys_batch_abs(cfg, B)
    del batch["label"]
    cands = sds(N_pad, cfg.embed_dim)
    lookup = make_sharded_lookup(mesh, table_axis="model", batch_axes=None)
    scorer = sharded_brute_topk(mesh, k=100, shard_axes=dp_t,
                                batch_axes=None, metric="ip")
    fn = functools.partial(_retrieval_fn, cfg=cfg, lookup=lookup,
                           scorer=scorer)
    pspec = SH.recsys_param_specs(cfg, mesh)
    bspec = SH.recsys_batch_specs(cfg, mesh, B)
    del bspec["label"]
    bspec = jax.tree.map(lambda s: P(*([None] * len(s))), bspec,
                         is_leaf=lambda x: isinstance(x, P))
    return CellProgram(
        arch=spec.name, shape=cell.name, kind=cell.kind, fn=fn,
        args=(params, batch, cands),
        in_specs=(pspec, bspec, P(dp, None)),
        out_specs=(P(None, None), P(None, None)),
        meta={"family": "recsys", "cfg": cfg, "batch": B,
              "n_candidates": N, "n_candidates_pad": N_pad})


def _retrieval_fn(params, batch, candidates, *, cfg, lookup, scorer):
    from repro.models import recsys as R

    u = R.user_embedding(params, batch, cfg, lookup_fn=lookup)
    return scorer(u, candidates)


# ===========================================================================
# DEG (the paper's technique at production scale — extra cells)
# ===========================================================================
DEG_CELLS = {
    # 16.7M vectors (2^24), dim 128, degree 30, sharded over "model".
    # est_hops: expected search length at 1M vectors/shard, from the
    # benchmarks.scalability log-fit (see EXPERIMENTS.md §Roofline) — the
    # compiled loop bound is max_hops (a worst case), so the roofline
    # rescales the search while-loop with this measured estimate.
    "search_16m": dict(n_total=1 << 24, dim=128, degree=30, batch=4096,
                       k=10, beam=64, kind="deg_search", est_hops=48),
    "explore_16m": dict(n_total=1 << 24, dim=128, degree=30, batch=4096,
                        k=100, beam=128, kind="deg_explore", exclude=16,
                        est_hops=130),
    "build_wave_16m": dict(n_total=1 << 24, dim=128, degree=30, batch=4096,
                           k=60, beam=90, kind="deg_search", est_hops=90),
}


def _deg_cell(shape_name: str, mesh: Mesh, *,
              deg_bf16=False) -> CellProgram:
    from repro.distributed.index import make_sharded_search

    c = DEG_CELLS[shape_name]
    S = int(mesh.shape["model"])
    Ns = c["n_total"] // S
    dp = SH.dp_axes(mesh)
    excl = c.get("exclude", 0)
    fn = make_sharded_search(mesh, k=c["k"], eps=0.1, beam_width=c["beam"],
                             batch_axes=dp, exclude_width=excl)
    vdt = jnp.bfloat16 if deg_bf16 else jnp.float32
    args = [
        sds(S, Ns, c["degree"], dtype=jnp.int32),     # adjacency
        sds(S, Ns, c["dim"], dtype=vdt),              # vectors
        sds(S, dtype=jnp.int32),                      # n
        sds(S, dtype=jnp.int32),                      # seeds
        sds(c["batch"], c["dim"], dtype=vdt),         # queries
    ]
    in_specs = [P("model", None, None), P("model", None, None), P("model"),
                P("model"), P(dp, None)]
    if excl:
        args.append(sds(c["batch"], excl, dtype=jnp.int32))
        in_specs.append(P(dp, None))
    return CellProgram(
        arch="deg-ann", shape=shape_name, kind=c["kind"], fn=fn,
        args=tuple(args), in_specs=tuple(in_specs),
        out_specs=(P(dp, None), P(dp, None)),
        meta={"family": "deg", **c, "n_shards": S, "n_per_shard": Ns})


# ===========================================================================
# dispatch + perf-iteration variants (EXPERIMENTS.md §Perf)
# ===========================================================================
# Each variant is a named, orthogonal change applied on top of the
# paper-faithful/baseline cell; the dry-run re-lowers and the roofline diff
# is the measurement.
VARIANTS = {
    "": {},
    # LM: sequence parallelism — layer-boundary activations sharded over
    # ("model",) on the seq dim; GSPMD turns per-layer TP all-reduces into
    # reduce-scatter/all-gather pairs and shards the norms + saved
    # activations.
    "seqpar": {"seq_shard": True},
    # EGNN: bf16 features/messages (halves HBM + collective payloads).
    "bf16msgs": {"gnn_bf16": True},
    # EGNN: shard node arrays over every mesh axis (256-way) instead of the
    # DP axes only — node-MLP compute and the aggregate all-reduce shrink.
    "nodeshard": {"gnn_node_all_axes": True},
    # EGNN: both.
    "bf16msgs+nodeshard": {"gnn_bf16": True, "gnn_node_all_axes": True},
    # EGNN: dst-partitioned edges + shard_map (local scatters, one halo
    # all-gather per layer; see models.egnn.make_sharded_loss).
    "halo": {"gnn_bf16": True, "gnn_node_all_axes": True, "gnn_halo": True},
    # DEG: bf16 vector payload (halves the gather traffic that dominates).
    "bf16vecs": {"deg_bf16": True},
    # LM train: gradient accumulation over 4 microbatches (live-activation
    # memory /4; XLA overlaps each microbatch's backward with the previous
    # one's gradient collectives on real hardware — straggler hiding).
    "microbatch4": {"microbatches": 4},
    "seqpar+microbatch4": {"seq_shard": True, "microbatches": 4},
    # DEG: bf16 + wider per-hop fanout batching (beam merge via top_k).
    "bf16vecs+topk": {"deg_bf16": True},
}


def build_cell(arch: str, shape: str, mesh: Mesh,
               variant: str = "") -> CellProgram:
    opts = VARIANTS[variant]
    if arch == "deg-ann":
        return _deg_cell(shape, mesh, **opts)
    spec = get_arch(arch)
    cell = spec.cell(shape)
    if shape in spec.skip:
        raise SkippedCell(spec.skip[shape])
    if spec.family == "lm":
        if cell.kind == "train":
            return _lm_train(spec, cell, mesh, **opts)
        if cell.kind == "prefill":
            return _lm_prefill(spec, cell, mesh, **opts)
        if cell.kind in ("decode", "long_decode"):
            return _lm_decode(spec, cell, mesh, **opts)
    if spec.family == "gnn":
        if cell.kind == "molecule":
            return _egnn_train_molecule(spec, cell, mesh)
        return _egnn_train_full(spec, cell, mesh, **opts)
    if spec.family == "recsys":
        if cell.kind == "recsys_train":
            return _recsys_train(spec, cell, mesh)
        if cell.kind == "recsys_serve":
            return _recsys_serve(spec, cell, mesh)
        if cell.kind == "retrieval":
            return _recsys_retrieval(spec, cell, mesh)
    raise ValueError(f"no cell builder for {arch}/{shape} ({cell.kind})")


class SkippedCell(Exception):
    """Raised for assigned cells documented as inapplicable (spec.skip)."""
