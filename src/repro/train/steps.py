"""Generic train-step factory: value_and_grad + optimizer, with optional
microbatch gradient accumulation (a lax.scan — the accumulation loop is also
where compute/reduce-scatter overlap happens on real hardware: XLA overlaps
the per-microbatch backward with the previous microbatch's gradient
collectives when latency hiding is enabled)."""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .optimizer import Optimizer, apply_updates


def make_train_step(loss_fn: Callable, opt: Optimizer,
                    microbatches: int = 1, donate: bool = True,
                    jit: bool = True):
    """loss_fn(params, batch) -> (loss, metrics dict).

    Returns step(params, opt_state, batch) -> ((params, opt_state), metrics).
    With microbatches > 1 the batch's leading dim is split and gradients are
    accumulated in fp32.
    """

    def _grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def step(params, opt_state, batch):
        if microbatches <= 1:
            loss, metrics, grads = _grads(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mb = jax.tree.map(split, batch)

            def body(acc, micro):
                loss, metrics, grads = _grads(params, micro)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / microbatches,
                    acc, grads)
                return acc, (loss, metrics)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, metricses) = jax.lax.scan(body, zeros, mb)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, metricses)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return (params, opt_state), metrics

    if jit:
        step = jax.jit(step, donate_argnums=(0, 1))if donate else jax.jit(step)
    return step


def make_eval_step(loss_fn: Callable, jit: bool = True):
    def step(params, batch):
        loss, metrics = loss_fn(params, batch)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return metrics

    return jax.jit(step) if jit else step
