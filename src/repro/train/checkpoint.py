"""Step-atomic sharded checkpointing with elastic resume (DESIGN.md §4).

Layout (one directory per step):

    <dir>/step_000123.tmp/        # written first
        manifest.json             # tree structure, shapes, dtypes, mesh info
        arr_00000.npy ...         # one file per leaf (per-host shard at scale)
    <dir>/step_000123/            # atomic rename when complete
    <dir>/LATEST                  # text file with the newest complete step

Crash-consistency: a half-written checkpoint never becomes visible because
the rename is the commit point; ``restore_latest`` only ever sees complete
directories.  The manifest records the mesh shape the state was saved under,
and restore re-shards to whatever mesh the *new* process runs — elastic
resume after scaling the pod count up or down.

On a real multi-host deployment each host writes only the shards it owns
(``jax.experimental.multihost_utils``); on this single-process container the
full array is written — the format and the commit protocol are identical.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np

_SEP = "/"
_UINT_FOR_SIZE = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _np_dtype(name: str) -> np.dtype:
    """Resolve 'bfloat16'/'float8_*' etc. through ml_dtypes."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _flatten(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((key, leaf))
    return out


def save(directory: str, step: int, state: Any, *,
         mesh_shape: Optional[tuple] = None, extra: Optional[dict] = None,
         keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:09d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    manifest = {"step": step, "mesh_shape": mesh_shape, "extra": extra or {},
                "leaves": []}
    for i, (key, leaf) in enumerate(flat):
        arr = np.asarray(leaf)
        fn = f"arr_{i:05d}.npy"
        dtype_name = str(arr.dtype)
        if arr.dtype.kind == "V":           # ml_dtypes (bf16, fp8, ...)
            dtype_name = arr.dtype.name
            arr = arr.view(_UINT_FOR_SIZE[arr.dtype.itemsize])
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append(
            {"key": key, "file": fn, "shape": list(arr.shape),
             "dtype": dtype_name})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                     # commit point
    latest = os.path.join(directory, "LATEST")
    with open(latest + ".tmp", "w") as f:
        f.write(name)
    os.replace(latest + ".tmp", latest)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
    for d in os.listdir(directory):           # orphaned partial writes
        if d.endswith(".tmp"):
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    latest = os.path.join(directory, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(directory, name)):
        return None
    return int(name.split("_")[1])


def restore(directory: str, step: int, like: Any, *,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings`` (optional pytree of NamedSharding)
    re-shards onto the current mesh — the elastic-resume path."""
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {l["key"]: l for l in manifest["leaves"]}
    flat_like = _flatten(like)
    leaves = []
    for key, leaf in flat_like:
        entry = by_key.get(key)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(os.path.join(path, entry["file"]))
        want_dtype = _np_dtype(entry["dtype"])
        if arr.dtype != want_dtype:             # bf16 etc. saved as uint view
            arr = arr.view(want_dtype)
        want = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: saved {arr.shape} != expected {want}")
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(like)
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings)
    else:
        state = jax.tree.map(jax.numpy.asarray, state)
    return state, manifest


def restore_latest(directory: str, like: Any, *, shardings: Any = None):
    step = latest_step(directory)
    if step is None:
        return None, None
    return restore(directory, step, like, shardings=shardings)
