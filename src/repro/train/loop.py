"""Fault-tolerant training loop (DESIGN.md §4).

Responsibilities:

* step-atomic checkpoint/restart via :mod:`repro.train.checkpoint`
  (write-to-temp + rename, resume from LATEST);
* deterministic data replay: the loop seeds the data iterator with
  ``(base_seed, step)`` so a restart replays the exact same batch order —
  no state beyond the step counter needs to be saved;
* failure injection for tests (``fail_at``): simulates a mid-run crash
  *after* the optimizer update but *before* (or after) the checkpoint,
  covering both loss-of-work and clean-resume paths;
* straggler mitigation hook: with ``microbatches > 1`` the train step
  accumulates gradients over microbatches (train/steps.py) — on real
  hardware XLA overlaps each microbatch's backward with the previous
  microbatch's gradient reduce-scatter, hiding slow-link stragglers inside
  the step.  The loop exposes ``metrics["step_time"]`` so per-step jitter
  is observable.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable, Optional

import jax
import numpy as np

from . import checkpoint as ckpt


class InjectedFailure(RuntimeError):
    """Raised by the failure-injection hook (tests / chaos drills)."""


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    keep: int = 3
    log_every: int = 10
    fail_at: Optional[int] = None      # inject a crash after this step
    fail_before_ckpt: bool = True      # crash before the step is saved


def train_loop(step_fn: Callable, params: Any, opt_state: Any,
               batch_fn: Callable[[int], Any], cfg: LoopConfig,
               *, mesh_shape: Optional[tuple] = None,
               log: Callable[[str], None] = print) -> tuple:
    """Run ``step_fn(params, opt_state, batch) -> ((params, opt_state),
    metrics)`` for ``cfg.total_steps``, resuming from the newest complete
    checkpoint if one exists.

    ``batch_fn(step)`` must be deterministic in ``step`` — that is the whole
    fault-tolerance contract: state = (params, opt_state, step).
    """
    start = 0
    if cfg.ckpt_dir:
        restored, manifest = ckpt.restore_latest(
            cfg.ckpt_dir, {"params": params, "opt": opt_state})
        if restored is not None:
            params, opt_state = restored["params"], restored["opt"]
            start = manifest["step"] + 1
            log(f"[loop] resumed from step {manifest['step']}")
    history = []
    for step in range(start, cfg.total_steps):
        t0 = time.time()
        batch = batch_fn(step)
        (params, opt_state), metrics = step_fn(params, opt_state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        metrics["step"] = step
        metrics["step_time"] = time.time() - t0
        history.append(metrics)
        if cfg.log_every and step % cfg.log_every == 0:
            log(f"[loop] step {step}: loss={metrics.get('loss', float('nan')):.4f} "
                f"({metrics['step_time']*1e3:.0f} ms)")
        if (cfg.fail_at is not None and step == cfg.fail_at
                and cfg.fail_before_ckpt):
            raise InjectedFailure(f"injected failure at step {step}")
        if cfg.ckpt_dir and (step % cfg.ckpt_every == 0
                             or step == cfg.total_steps - 1):
            ckpt.save(cfg.ckpt_dir, step,
                      {"params": params, "opt": opt_state},
                      mesh_shape=mesh_shape, keep=cfg.keep)
        if (cfg.fail_at is not None and step == cfg.fail_at
                and not cfg.fail_before_ckpt):
            raise InjectedFailure(f"injected failure at step {step}")
    return (params, opt_state), history
