"""Minimal optax-style optimizers (the container has no optax).

An Optimizer is (init, update):
  state  = opt.init(params)
  updates, state = opt.update(grads, state, params)
  params = apply_updates(params, updates)

``partitioned`` routes different param subtrees to different optimizers via
a label function — used by the recsys archs (embedding tables get stateless
SGD like MLPerf DLRM; dense towers get AdamW; see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(max_norm: float):
    def init(params):
        return ()

    def update(grads, state, params=None):
        g = global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
        return jax.tree.map(lambda x: x * scale, grads), state

    return Optimizer(init, update)


def sgd(lr: float | Callable = 1e-2, momentum: float = 0.0):
    def init(params):
        if momentum == 0.0:
            return {"count": jnp.zeros((), jnp.int32)}
        return {"count": jnp.zeros((), jnp.int32),
                "mom": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params=None):
        count = state["count"] + 1
        cur_lr = lr(count) if callable(lr) else lr
        if momentum == 0.0:
            upd = jax.tree.map(lambda g: -cur_lr * g, grads)
            return upd, {"count": count}
        mom = jax.tree.map(lambda m, g: momentum * m + g, state["mom"], grads)
        upd = jax.tree.map(lambda m: -cur_lr * m, mom)
        return upd, {"count": count, "mom": mom}

    return Optimizer(init, update)


def adamw(lr: float | Callable = 1e-3, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          clip_norm: Optional[float] = 1.0):
    """AdamW with optional fused global-norm clipping."""

    def init(params):
        return {
            "count": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(
                lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
            "nu": jax.tree.map(
                lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
        }

    def update(grads, state, params):
        if clip_norm is not None:
            g = global_norm(grads)
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(g, 1e-9))
            grads = jax.tree.map(lambda x: x * scale, grads)
        count = state["count"] + 1
        cur_lr = lr(count) if callable(lr) else lr
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2)
            * jnp.square(g.astype(jnp.float32)), state["nu"], grads)
        c = count.astype(jnp.float32)
        mu_hat_scale = 1.0 / (1 - b1 ** c)
        nu_hat_scale = 1.0 / (1 - b2 ** c)

        def upd(m, v, p):
            step = m * mu_hat_scale / (jnp.sqrt(v * nu_hat_scale) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (-cur_lr * step).astype(jnp.float32)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, {"count": count, "mu": mu, "nu": nu}

    return Optimizer(init, update)


def partitioned(label_fn: Callable, optimizers: dict[str, Optimizer]):
    """Route param subtrees to optimizers by label.

    label_fn(path_tuple, leaf) -> key into ``optimizers``.

    Non-selected leaves are masked to ``None`` (an empty pytree node), so a
    stateful optimizer keeps state *only* for its own leaves — this is what
    lets MLPerf-style recsys training hold no AdamW moments for the 100M-row
    embedding tables.
    """

    def _labels(params):
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: label_fn(path, leaf), params)

    def _mask(tree, labels, key):
        return jax.tree.map(lambda x, l: x if l == key else None, tree, labels)

    def init(params):
        labels = _labels(params)
        return {key: opt.init(_mask(params, labels, key))
                for key, opt in optimizers.items()}

    def update(grads, state, params):
        labels = _labels(grads)
        new_state, upds = {}, {}
        for key, opt in optimizers.items():
            upds[key], new_state[key] = opt.update(
                _mask(grads, labels, key), state[key],
                _mask(params, labels, key))
        # stitch per-leaf updates back together by path
        flat = {key: dict(jax.tree_util.tree_flatten_with_path(u)[0])
                for key, u in upds.items()}
        label_map = dict(jax.tree_util.tree_flatten_with_path(labels)[0])

        def pick(path, _leaf):
            return flat[label_map[path]][path]

        total = jax.tree_util.tree_map_with_path(pick, grads)
        return total, new_state

    return Optimizer(init, update)


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5
                         * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return lr
