"""Training substrate: optimizer, schedules, steps, checkpointing,
fault-tolerant loop."""
