"""Compiled-HLO cost model (DESIGN.md §7).

``compiled.cost_analysis()`` counts ``while`` bodies **once**, but every
interesting program here loops: ``scan`` over layers, ``lax.map`` over query
chunks, the DEG search loop.  This parser rebuilds the cost from the
optimized (post-SPMD) HLO text with loop bodies multiplied by their trip
counts:

* **FLOPs** — from ``dot`` / ``convolution`` ops (recursing into fusion
  subcomputations), 2 x prod(output) x contraction.
* **HBM bytes** — sum of operand+output bytes of *top-level* compute ops
  (fusions, dots, gathers, scatters, copies, DUS, collectives).  Fusion
  internals stay in registers/VMEM and are not traffic.  This is the
  standard "every materialized buffer crosses HBM once" approximation.
* **Collective bytes** — operand bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute, reported per category.

Trip counts come from the largest integer constant in the loop condition
computation (exact for scan/fori/map-style loops, an upper bound for
data-dependent loops like the DEG search — the roofline rescales those with
measured hop counts).

All shapes in post-SPMD HLO are already **per-device**, so every number this
module emits is per-device.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")
_NAME_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%?([\w\.\-]+)")
_CALL_ATTR_RE = re.compile(r"(calls|condition|body|to_apply)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "all-gather-start", "all-reduce-start",
               "collective-permute-start")

_TRAFFIC_OPS = ("fusion", "dot", "convolution", "gather", "scatter", "copy",
                "dynamic-update-slice", "dynamic-slice", "slice", "concatenate",
                "sort", "transpose", "reshape", "broadcast", "reduce", "rng",
                "iota", "pad", "custom-call", "select-and-scatter",
                "cholesky", "triangular-solve") + COLLECTIVES


def shape_bytes(type_str: str) -> float:
    """Total bytes of an HLO type string (tuples summed)."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * b
    return total


def shape_dims(type_str: str) -> Optional[tuple]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return tuple(int(d) for d in dims.split(",")) if dims else ()


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str
    operands: list
    calls: dict       # attr -> computation name
    trip: Optional[int] = None   # known_trip_count from backend_config


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    by_name: dict

    def out_bytes(self, name: str) -> float:
        i = self.by_name.get(name)
        return shape_bytes(i.type_str) if i else 0.0


def _split_operands(rest: str) -> tuple[str, str]:
    """Split 'operand-list), attrs...' respecting nesting."""
    depth = 0
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                return rest[:i], rest[i + 1:]
            depth -= 1
    return rest, ""


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for line in text.splitlines():
        if not line.strip():
            continue
        m = _COMP_RE.match(line)
        if m and line.rstrip().endswith("{"):
            cur = Computation(m.group(2), [], {})
            comps[cur.name] = cur
            if m.group(1):
                entry_name = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        ins = _parse_instruction(line)
        if ins is None:
            continue
        cur.instrs.append(ins)
        cur.by_name[ins.name] = ins
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _parse_operand_names(opsec: str) -> list:
    """Operand names from an operand list section.

    Newer XLA prints bare names (``%a, %b``); older releases print the full
    type inline (``f32[32,32]{1,0} %a``), so naive token matching picks up
    dtype/dim junk.  Split on top-level commas and keep the *last* token of
    each fragment — the operand name in both formats.
    """
    parts, depth, start = [], 0, 0
    for i, ch in enumerate(opsec):
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        elif ch == "," and depth == 0:
            parts.append(opsec[start:i])
            start = i + 1
    parts.append(opsec[start:])
    out = []
    for p in parts:
        toks = _OPERAND_RE.findall(p)
        if toks:
            out.append(toks[-1])
    return out


def _parse_instruction(line: str) -> Optional[Instr]:
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rhs = line[m.end():]
    # result type: either a balanced (tuple, ...) or one dtype[dims]{layout}
    if rhs.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        type_str, rhs = rhs[: end + 1], rhs[end + 1:]
    else:
        sp = rhs.find(" ")
        if sp == -1:
            return None
        type_str, rhs = rhs[:sp], rhs[sp:]
    mo = _OPCODE_RE.match(rhs)
    if not mo:
        return None
    opcode = mo.group(1)
    rest = rhs[mo.end():]
    opsec, attrs = _split_operands(rest)
    opsec = re.sub(r"/\*.*?\*/", "", opsec)   # strip /*index=N*/ comments
    operands = _parse_operand_names(opsec)
    calls = {k: v for k, v in _CALL_ATTR_RE.findall(attrs)}
    mt = _TRIP_RE.search(attrs)
    trip = int(mt.group(1)) if mt else None
    return Instr(name, type_str.strip(), opcode, attrs, operands, calls, trip)


@dataclasses.dataclass
class CostReport:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    per_collective: dict = dataclasses.field(default_factory=dict)
    while_detail: list = dataclasses.field(default_factory=list)

    def merged(self, other: "CostReport", scale: float = 1.0) -> "CostReport":
        pc = dict(self.per_collective)
        for k, v in other.per_collective.items():
            pc[k] = pc.get(k, 0.0) + v * scale
        return CostReport(
            flops=self.flops + other.flops * scale,
            hbm_bytes=self.hbm_bytes + other.hbm_bytes * scale,
            collective_bytes=self.collective_bytes
            + other.collective_bytes * scale,
            per_collective=pc,
            while_detail=self.while_detail + other.while_detail,
        )


class HloCost:
    """Whole-module cost with while-trip scaling."""

    def __init__(self, text: str,
                 trip_overrides: Optional[dict[str, int]] = None):
        self.text = text
        self.comps = parse_module(text)
        self.trip_overrides = trip_overrides or {}
        self._const_cache: dict[str, int] = {}
        self._memo: dict[str, CostReport] = {}

    # -- trip counts -----------------------------------------------------
    def trip_count(self, cond_name: str) -> int:
        if cond_name in self.trip_overrides:
            return self.trip_overrides[cond_name]
        if cond_name in self._const_cache:
            return self._const_cache[cond_name]
        # largest integer constant in the condition computation's text block
        block = self._comp_text(cond_name)
        consts = [int(x) for x in _CONST_RE.findall(block)]
        trip = max(consts) if consts else 1
        self._const_cache[cond_name] = trip
        return trip

    def _comp_text(self, name: str) -> str:
        # cheap: find the block by header
        pat = re.compile(r"^(ENTRY\s+)?%?" + re.escape(name) + r"\s+\(",
                         re.M)
        m = pat.search(self.text)
        if not m:
            return ""
        start = m.start()
        end = self.text.find("\n}", start)
        return self.text[start:end] if end != -1 else self.text[start:]

    # -- flops of a dot instruction --------------------------------------
    def _dot_flops(self, comp: Computation, ins: Instr) -> float:
        out = shape_dims(ins.type_str)
        if out is None:
            return 0.0
        n_out = 1
        for d in out:
            n_out *= d
        # contraction size: product of lhs contracting dims
        mdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
        lhs = comp.by_name.get(ins.operands[0]) if ins.operands else None
        k = 1
        if mdims and lhs is not None:
            ldims = shape_dims(lhs.type_str) or ()
            for ci in mdims.group(1).split(","):
                if ci and int(ci) < len(ldims):
                    k *= ldims[int(ci)]
        return 2.0 * n_out * k

    # -- per-computation cost --------------------------------------------
    def comp_cost(self, name: str, top_level: bool = True) -> CostReport:
        key = f"{name}|{top_level}"
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        rep = CostReport()
        if comp is None:
            self._memo[key] = rep
            return rep
        for ins in comp.instrs:
            out_b = shape_bytes(ins.type_str)
            if ins.opcode == "while":
                body = ins.calls.get("body")
                cond = ins.calls.get("condition")
                if body in self.trip_overrides:
                    trip = self.trip_overrides[body]
                elif ins.trip is not None:       # XLA known_trip_count
                    trip = ins.trip
                else:
                    trip = self.trip_count(cond) if cond else 1
                body_rep = self.comp_cost(body, True) if body else CostReport()
                rep = rep.merged(body_rep, scale=trip)
                rep.while_detail.append(
                    {"body": body, "trip": trip,
                     "flops": body_rep.flops, "hbm": body_rep.hbm_bytes,
                     "coll": body_rep.collective_bytes})
                continue
            if ins.opcode in ("call", "conditional", "async-start"):
                for cn in ins.calls.values():
                    rep = rep.merged(self.comp_cost(cn, True))
                continue
            if ins.opcode == "fusion":
                callee = ins.calls.get("calls")
                if callee:
                    inner = self._fusion_flops(callee)
                    rep.flops += inner
                if top_level:
                    rep.hbm_bytes += self._fusion_traffic(comp, ins)
                continue
            if ins.opcode in ("dynamic-slice", "gather"):
                if top_level:
                    rep.hbm_bytes += 2.0 * out_b    # rows read + write only
                continue
            if ins.opcode == "dynamic-update-slice":
                if top_level:
                    upd = (comp.out_bytes(ins.operands[1])
                           if len(ins.operands) > 1 else out_b)
                    rep.hbm_bytes += 2.0 * upd      # aliased accumulator
                continue
            if ins.opcode in ("dot", "convolution"):
                rep.flops += self._dot_flops(comp, ins)
                if top_level:
                    rep.hbm_bytes += out_b + self._operand_bytes(comp, ins)
                continue
            if ins.opcode in COLLECTIVES:
                b = self._operand_bytes(comp, ins)
                rep.collective_bytes += b
                cat = ins.opcode.replace("-start", "")
                rep.per_collective[cat] = rep.per_collective.get(cat, 0) + b
                if top_level:
                    rep.hbm_bytes += out_b + b
                continue
            if top_level and ins.opcode in _TRAFFIC_OPS:
                rep.hbm_bytes += out_b + self._operand_bytes(comp, ins)
        self._memo[key] = rep
        return rep

    def _operand_bytes(self, comp: Computation, ins: Instr) -> float:
        return sum(comp.out_bytes(o) for o in ins.operands
                   if o in comp.by_name)

    def _fusion_traffic(self, comp: Computation, ins: Instr) -> float:
        """Slice-aware HBM traffic of a fusion.

        A fusion parameter consumed *only* by dynamic-slice reads just the
        slice (scan stacks are read this way in backward bodies); a root
        dynamic-update-slice into an aliased accumulator writes just the
        update (scan stacks are written this way in forward bodies).
        Everything else reads/writes its full size.
        """
        out_b = shape_bytes(ins.type_str)
        callee = self.comps.get(ins.calls.get("calls", ""))
        if callee is None:
            return out_b + self._operand_bytes(comp, ins)
        # map parameter index -> callee instruction
        pname = {}
        for ci in callee.instrs:
            if ci.opcode == "parameter" and ci.operands:
                try:
                    pname[int(ci.operands[0])] = ci.name
                except ValueError:
                    pass
        read = 0.0
        for i, o in enumerate(ins.operands):
            full = comp.out_bytes(o)
            if full <= 0:
                continue
            par = pname.get(i)
            if par is None:
                read += full
                continue
            consumers = self._terminal_consumers(callee, par)
            sliced_ops = ("dynamic-slice", "dynamic-update-slice", "gather")
            if consumers and all(cj.opcode in sliced_ops
                                 for cj, _ in consumers):
                eff = 0.0
                for cj, via in consumers:
                    if cj.opcode in ("dynamic-slice", "gather"):
                        # reads only the addressed rows (gather traffic =
                        # output bytes; matters for embedding lookups and
                        # the DEG neighbor gathers, which otherwise count
                        # the whole table as read)
                        eff += shape_bytes(cj.type_str)
                    else:  # DUS: accumulator operand is aliased, updates
                        if cj.operands and cj.operands[0] == via:
                            eff += (callee.out_bytes(cj.operands[1])
                                    if len(cj.operands) > 1 else 0.0)
                        else:
                            eff += full
                read += min(eff, full)
            else:
                read += full
        # write side: if the fusion output is a DUS into a same-shape aliased
        # accumulator, only the update is written (compare dims, not bytes:
        # the CPU backend inserts dtype converts around the DUS)
        write = out_b
        out_dims = shape_dims(ins.type_str)
        for cj in callee.instrs:
            if (cj.opcode == "dynamic-update-slice"
                    and shape_dims(cj.type_str) == out_dims
                    and len(cj.operands) > 1):
                write = callee.out_bytes(cj.operands[1])
                break
        return read + write

    _PASSTHROUGH = ("convert", "bitcast", "copy")

    def _terminal_consumers(self, comp: Computation, name: str,
                            depth: int = 0) -> list:
        """Consumers of ``name`` inside ``comp``, looking through dtype
        converts/bitcasts (the CPU backend wraps scan-stack DUS/DS in
        converts).  Returns [(instr, via_operand_name)]."""
        if depth > 4:
            return []
        out = []
        for cj in comp.instrs:
            if cj.opcode == "parameter" or name not in cj.operands:
                continue
            if cj.opcode in self._PASSTHROUGH:
                nested = self._terminal_consumers(comp, cj.name, depth + 1)
                out += nested or [(cj, name)]
            else:
                out.append((cj, name))
        return out

    def _fusion_flops(self, callee: str) -> float:
        comp = self.comps.get(callee)
        if comp is None:
            return 0.0
        total = 0.0
        for ins in comp.instrs:
            if ins.opcode in ("dot", "convolution"):
                total += self._dot_flops(comp, ins)
            elif ins.opcode == "fusion" and "calls" in ins.calls:
                total += self._fusion_flops(ins.calls["calls"])
        return total

    def entry_cost(self) -> CostReport:
        return self.comp_cost("__entry__", True)


def analyze_text(text: str,
                 trip_overrides: Optional[dict[str, int]] = None) -> dict:
    """Convenience: HLO text -> plain-dict cost summary (per device)."""
    hc = HloCost(text, trip_overrides)
    rep = hc.entry_cost()
    return {
        "flops": rep.flops,
        "hbm_bytes": rep.hbm_bytes,
        "collective_bytes": rep.collective_bytes,
        "per_collective": rep.per_collective,
        "while_detail": rep.while_detail,
    }
