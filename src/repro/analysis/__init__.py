"""Compiled-HLO analysis: FLOPs / HBM bytes / collective bytes + roofline."""
