"""Markdown report generator for EXPERIMENTS.md §Dry-run / §Roofline.

    PYTHONPATH=src python -m repro.analysis.report [--root reports/dryrun]

Reads the per-cell JSON records written by repro.launch.dryrun and emits the
two tables; rerun after perf iterations to refresh the numbers.
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(root: str) -> dict[str, list[dict]]:
    out: dict[str, list[dict]] = {}
    for mesh_dir in sorted(glob.glob(os.path.join(root, "*"))):
        mesh = os.path.basename(mesh_dir)
        recs = []
        for p in sorted(glob.glob(os.path.join(mesh_dir, "*.json"))):
            with open(p) as f:
                recs.append(json.load(f))
        out[mesh] = recs
    return out


def _f(x, nd=3):
    if x is None:
        return "-"
    if isinstance(x, (int,)):
        return str(x)
    if abs(x) >= 1000 or (abs(x) < 0.001 and x != 0):
        return f"{x:.2e}"
    return f"{x:.{nd}f}"


def _gb(x):
    return f"{x/2**30:.2f}" if x is not None else "-"


def dryrun_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | status | lower s | compile s | arg GiB/dev | "
        "temp GiB/dev | collective mix |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("variant"):
            continue
        if r["status"] != "ok":
            reason = r.get("reason", r.get("error", ""))[:70]
            lines.append(f"| {r['arch']} | {r['shape']} | {r['status']}: "
                         f"{reason} | | | | | |")
            continue
        ma = r.get("memory_analysis", {})
        pc = r.get("per_collective", {})
        tot = sum(pc.values()) or 1.0
        mix = " ".join(f"{k.replace('all-','a').replace('reduce-scatter','rs').replace('collective-permute','cp')}:"
                       f"{100*v/tot:.0f}%" for k, v in
                       sorted(pc.items(), key=lambda kv: -kv[1])[:3])
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {r.get('lower_s','-')} | "
            f"{r.get('compile_s','-')} | {_gb(ma.get('argument_size_in_bytes'))} | "
            f"{_gb(ma.get('temp_size_in_bytes'))} | {mix or '-'} |")
    return "\n".join(lines)


def roofline_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | T_comp s | T_mem s | T_coll s | bottleneck | "
        "MODEL_FLOPS | useful | MFU-bound | one-line fix |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok" or r.get("variant"):
            continue
        rl = r["roofline"]
        fix = suggest_fix(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_f(rl['t_comp_s'])} | "
            f"{_f(rl['t_mem_s'])} | {_f(rl['t_coll_s'])} | "
            f"{rl['bottleneck']} | {_f(rl['model_flops'],2)} | "
            f"{_f(rl['useful_ratio'],2)} | {_f(rl['mfu_bound'],3)} | {fix} |")
    return "\n".join(lines)


def suggest_fix(r: dict) -> str:
    rl = r["roofline"]
    b = rl["bottleneck"]
    pc = r.get("per_collective", {})
    top = max(pc, key=pc.get) if pc else ""
    if b == "collective":
        if r["arch"] == "egnn":
            return ("partition edges by destination shard so segment_sum "
                    "scatters stay local (halo exchange instead of "
                    f"{top} of full node arrays)")
        if "train" in r["shape"]:
            return ("sequence-parallel activations: turn per-layer TP "
                    "all-reduces into reduce-scatter/all-gather pairs")
        return f"reduce {top} volume (bf16 payloads, fuse merges)"
    if b == "memory":
        if "decode" in r["shape"] or "500k" in r["shape"]:
            return ("decode is KV-bound by nature; quantize cache to int8 "
                    "and fuse the GQA expand into the attention kernel")
        if "prefill" in r["shape"]:
            return "flash-attention Pallas kernel (no HBM score tile)"
        return ("larger q_chunk / flash kernel; drop fp32 copies the CPU "
                "backend inserts (bf16 on TPU)")
    return "increase per-device work (larger batch) or cast GEMMs to bf16"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default="reports/dryrun")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    data = load(args.root)
    parts = []
    for mesh, recs in data.items():
        n_ok = sum(r["status"] == "ok" for r in recs)
        n_skip = sum(r["status"] == "skipped" for r in recs)
        n_err = len(recs) - n_ok - n_skip
        parts.append(f"\n### Mesh {mesh} — {n_ok} ok / {n_skip} skipped / "
                     f"{n_err} errors\n")
        parts.append(dryrun_table(recs))
    parts.append("\n\n### Roofline (single-pod 16x16)\n")
    if "pod16x16" in data:
        parts.append(roofline_table(data["pod16x16"]))
    text = "\n".join(parts)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    else:
        print(text)


if __name__ == "__main__":
    main()
