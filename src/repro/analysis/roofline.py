"""Three-term roofline model for TPU v5e (DESIGN.md §7).

    T_comp = FLOPs/device   / PEAK_FLOPS
    T_mem  = bytes/device   / HBM_BW
    T_coll = Σ_axis collective_bytes/device / ICI_BW   (ring (n-1)/n applied
             by GSPMD already being per-device operand bytes)

MODEL_FLOPS (the analytic 6·N·D / 6·N_active·D useful-work count) is
reported next to the HLO count so remat/dispatch waste is visible.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# TPU v5e, per chip (assignment-provided constants)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link


@dataclasses.dataclass
class Roofline:
    t_comp: float
    t_mem: float
    t_coll: float
    flops: float
    hbm_bytes: float
    collective_bytes: float
    model_flops: float = 0.0
    devices: int = 1

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_comp, "memory": self.t_mem,
                 "collective": self.t_coll}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Lower-bound step time: perfectly-overlapped terms."""
        return max(self.t_comp, self.t_mem, self.t_coll)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO FLOPs (global): remat/dispatch waste detector."""
        total = self.flops * self.devices
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Best-case MFU at the roofline: useful flops / (peak x step_time)."""
        t = self.step_time
        if t <= 0:
            return 0.0
        return self.model_flops / (self.devices * PEAK_FLOPS * t)

    def as_dict(self) -> dict:
        return {
            "t_comp_s": self.t_comp, "t_mem_s": self.t_mem,
            "t_coll_s": self.t_coll, "bottleneck": self.bottleneck,
            "flops_per_dev": self.flops, "hbm_bytes_per_dev": self.hbm_bytes,
            "collective_bytes_per_dev": self.collective_bytes,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "mfu_bound": self.mfu_bound,
            "step_time_s": self.step_time,
        }


def from_costs(flops: float, hbm_bytes: float, collective_bytes: float,
               *, model_flops: float = 0.0, devices: int = 1) -> Roofline:
    return Roofline(
        t_comp=flops / PEAK_FLOPS,
        t_mem=hbm_bytes / HBM_BW,
        t_coll=collective_bytes / ICI_BW,
        flops=flops, hbm_bytes=hbm_bytes,
        collective_bytes=collective_bytes,
        model_flops=model_flops, devices=devices)


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS per cell family (useful work, not compiled work)
# ---------------------------------------------------------------------------
def _tower_flops(sizes) -> float:
    return float(sum(2.0 * a * b for a, b in zip(sizes[:-1], sizes[1:])))


def lm_model_flops(cfg, kind: str, batch: int, seq: int) -> float:
    """6·N_active·tokens (+ attention) for train, 2·N_active·tokens for
    inference; attention term uses per-layer effective context."""
    n_active = cfg.active_param_count
    L, Hq, Dh = cfg.n_layers, cfg.n_heads, cfg.head_dim
    glob = cfg.is_global_layer()

    def attn(tok_s, ctx, causal):
        tot = 0.0
        for i in range(L):
            eff = ctx if glob[i] else min(ctx, cfg.sliding_window or ctx)
            f = 4.0 * batch * tok_s * eff * Hq * Dh
            tot += f / 2 if causal else f
        return tot

    if kind == "train":
        return 6.0 * n_active * batch * seq + 3.0 * attn(seq, seq, True)
    if kind == "prefill":
        return 2.0 * n_active * batch * seq + attn(seq, seq, True)
    # decode/long_decode: one token against a seq-length cache
    return 2.0 * n_active * batch + attn(1, seq, False)


def egnn_model_flops(cfg, n_nodes: int, n_edges: int, train: bool = True,
                     batch: int = 1) -> float:
    h = cfg.d_hidden
    per_layer = (
        n_edges * _tower_flops([2 * h + 1 + cfg.d_edge, h, h])      # phi_e
        + n_edges * _tower_flops([h, h, 1])                         # phi_x
        + n_nodes * _tower_flops([2 * h, h, h])                     # phi_h
    )
    total = (cfg.n_layers * per_layer
             + n_nodes * 2.0 * cfg.d_feat * h                       # encoder
             + n_nodes * _tower_flops([h, h, cfg.n_classes]))       # decoder
    total *= batch
    return 3.0 * total if train else total


def recsys_model_flops(cfg, kind: str, batch: int,
                       n_candidates: int = 0) -> float:
    E, F = cfg.embed_dim, cfg.n_sparse
    f = 0.0
    if cfg.kind == "dlrm":
        f += _tower_flops([cfg.n_dense, *cfg.bot_mlp])
        n_int = F + 1
        f += 2.0 * n_int * n_int * E                  # dot interactions
        f += _tower_flops([E + n_int * (n_int - 1) // 2, *cfg.mlp])
    elif cfg.kind == "dcn-v2":
        d = cfg.x0_dim
        f += cfg.n_cross * 2.0 * d * d
        f += _tower_flops([d, *cfg.mlp, 1])
    elif cfg.kind == "deepfm":
        f += 4.0 * F * E
        f += _tower_flops([cfg.x0_dim, *cfg.mlp, 1])
    elif cfg.kind == "din":
        f += cfg.seq_len * _tower_flops([4 * E, *cfg.attn_mlp, 1])
        f += _tower_flops([cfg.x0_dim, *cfg.mlp, 1])
    per_ex = f
    if kind == "retrieval":
        return batch * (per_ex + 2.0 * n_candidates * E)
    mult = 3.0 if kind == "recsys_train" else 1.0
    return mult * batch * per_ex


# ---------------------------------------------------------------------------
# structural Pallas-kernel tiles: HBM bytes + flops per tile at BlockSpec
# granularity — shared by benchmarks/kernels.py and the §Roofline report
# ---------------------------------------------------------------------------
# dims at the production-search cell scale (launch/cells.py DEG_CELLS):
# degree 30, dim 128, beam 64, k_ext 60; int8 codes for the sq8 store.
KERNEL_DIMS = {
    "gather_dist": dict(d=30, m=128),
    "gather_dist_q": dict(d=30, m=128),
    "beam_merge": dict(L=64, d=30),
    "mrng_occlusion": dict(K=60, d=30, m=128),
    "fused_hop": dict(E=4, d=30, m=128, V=2048),
}


def kernel_tile_costs(name: str, **dims) -> dict:
    """Structural per-tile costs of the named Pallas kernel.

    * ``gather_dist``     — d float32 rows + query + out;
    * ``gather_dist_q``   — d int8 code rows + f32 scale/query/out (the
      ~4x gather-traffic cut vs gather_dist);
    * ``beam_merge``      — the (L + d) bitonic partial merge over 4
      channels (dists f32, ids i32, checked/excluded bytes), in + out;
    * ``mrng_occlusion``  — K*d gathered f32 rows + query + candidate
      dists + neighbor weights in, distances + occlusion mask out; one
      distance (2m) plus the lune compare per gathered row.
    * ``fused_hop``       — one multi-expansion hop for one lane: E
      adjacency rows + the (1, V) visited table + query in, E*d gathered
      f32 vector rows (worst case: nothing filtered), compacted
      candidates + raw neighbor ids + eval count out; per gathered row
      one distance (2m) plus the E*d-lane seen/visited row compares.
    """
    if name == "gather_dist":
        d, m = dims["d"], dims["m"]
        return {"hbm_bytes": (d * m + m + d) * 4, "flops": 2.0 * d * m}
    if name == "gather_dist_q":
        d, m = dims["d"], dims["m"]
        return {"hbm_bytes": d * m + (m + m + d) * 4,
                "flops": 3.0 * d * m}
    if name == "beam_merge":
        L, d = dims["L"], dims["d"]
        n = L + d
        passes = max(1, int(np.ceil(np.log2(max(n, 2)))))
        return {"hbm_bytes": 2 * n * (4 + 4 + 1 + 1),
                "flops": float(n * passes)}
    if name == "mrng_occlusion":
        K, d, m = dims["K"], dims["d"], dims["m"]
        # f32: gathered rows + query + cand dists + weights + both outputs;
        # plus the K*d int32 neighbor-id array driving the gather
        return {"hbm_bytes": (K * d * m + m + K + 3 * K * d) * 4 + K * d * 4,
                "flops": K * d * (2.0 * m + 2.0)}
    if name == "fused_hop":
        E, d, m, V = dims["E"], dims["d"], dims["m"], dims["V"]
        # in: E i32 adjacency rows, (1, V) i32 visited table, f32 query;
        # E*d f32 vector rows DMA'd (worst case: visited filters nothing);
        # out: compacted ids+dists, raw neighbor ids, eval count.  Per
        # gathered row: one distance (2m) + the seen/visited row compares
        # (E*d + V lanes) + the keep/compaction select.
        return {"hbm_bytes": ((E * d + V + E * d) * 4 + m * 4
                              + E * d * m * 4
                              + (E * d * 2 + E * d + 1) * 4),
                "flops": E * d * (2.0 * m + E * d + V + 2.0)}
    raise ValueError(f"unknown kernel {name!r}; have {sorted(KERNEL_DIMS)}")


def kernel_roofline(name: str, **dims) -> Roofline:
    """Single-tile roofline of a Pallas kernel (no collectives)."""
    c = kernel_tile_costs(name, **(dims or KERNEL_DIMS[name]))
    return from_costs(c["flops"], c["hbm_bytes"], 0.0,
                      model_flops=c["flops"])


def attribute_kernel_time(total_s: float, tile_counts: dict) -> dict:
    """Split a *measured* wall-time total across Pallas kernels in
    proportion to their structural cost: weight(k) = tiles_k x the
    single-tile roofline step time (max of the compute/memory terms).

    This is the bridge between the serving telemetry (obs/ histograms
    measure how long flushes took, but a jitted program is opaque) and
    the structural model (which knows each kernel's relative expense but
    not the wall clock): tile counts come from the engine's hop/eval
    counters, the split from the model.  Returns
    ``{kernel: {"tiles", "weight_s", "seconds", "fraction"}}``; fractions
    sum to 1 when any weight is nonzero.
    """
    weights = {}
    for name, tiles in tile_counts.items():
        r = kernel_roofline(name, **KERNEL_DIMS[name])
        weights[name] = float(tiles) * r.step_time
    denom = sum(weights.values())
    out = {}
    for name, tiles in tile_counts.items():
        frac = weights[name] / denom if denom > 0 else 0.0
        out[name] = {"tiles": float(tiles), "weight_s": weights[name],
                     "seconds": frac * total_s, "fraction": frac}
    return out


def deg_model_flops(meta: dict, avg_hops: float) -> float:
    """Per-query useful work: hops x (d neighbor distances) + seed + merge.
    One distance = 2m flops (paper's SIMD L2 analogue)."""
    d, m, B = meta["degree"], meta["dim"], meta["batch"]
    per_hop = d * 2.0 * m
    return B * meta["n_shards"] * avg_hops * per_hop


def model_flops_for(meta: dict, kind: str, cell_dims: dict,
                    avg_hops: float = 48.0) -> float:
    fam = meta.get("family")
    cfg = meta.get("cfg")
    if fam == "lm":
        if kind == "train":
            return lm_model_flops(cfg, "train", cell_dims["global_batch"],
                                  cell_dims["seq_len"])
        if kind == "prefill":
            return lm_model_flops(cfg, "prefill", cell_dims["global_batch"],
                                  cell_dims["seq_len"])
        return lm_model_flops(cfg, "decode", cell_dims["global_batch"],
                              cell_dims["seq_len"])
    if fam == "gnn":
        if kind == "molecule":
            return egnn_model_flops(cfg, cell_dims["n_nodes"],
                                    cell_dims["n_edges"], True,
                                    cell_dims["batch"])
        return egnn_model_flops(cfg, meta["n_nodes"], meta["n_edges"], True)
    if fam == "recsys":
        return recsys_model_flops(cfg, kind, cell_dims["batch"],
                                  cell_dims.get("n_candidates", 0))
    if fam == "deg":
        return deg_model_flops(meta, meta.get("est_hops", avg_hops))
    return 0.0
