"""Distributed runtime: sharding rules, sharded DEG index, collectives."""
