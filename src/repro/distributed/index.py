"""Sharded Dynamic Exploration Graph (DESIGN.md §4).

The DB of N vectors is partitioned **round-robin** into S sub-DEGs, one per
``"model"``-axis shard (global id g lives on shard ``g % S`` at local row
``g // S``).  Each sub-DEG is an independent even-regular DEG built and
refined incrementally — DEG's incrementality is what makes per-shard
growth/rebalancing cheap at this scale.  Queries are sharded along the DP
axes (throughput) and replicated along ``"model"``; one search step is:

    local in-shard beam search  ->  all_gather(k best per shard, "model")
                                ->  exact top-k merge

Collective volume per query: ``S * k * 8`` bytes — independent of N.  Pods
replicate the index, so losing a pod degrades throughput, not recall; losing
one model shard degrades recall by ~1/S while the other shards keep serving
(fault-tolerance posture; simulated in tests/test_distributed.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import set_mesh, shard_map

from repro.core import beam
from repro.core.build import DEGIndex, DEGParams
from repro.core.graph import INVALID
from .collectives import topk_merge_allgather

Array = jax.Array


# ---------------------------------------------------------------------------
# the pure, lowerable search step
# ---------------------------------------------------------------------------
def make_sharded_search(mesh: Mesh, *, k: int, eps: float = 0.1,
                        beam_width: Optional[int] = None,
                        metric: str = "l2", shard_axis: str = "model",
                        batch_axes="data", exclude_width: int = 0,
                        codec: str = "float32",
                        rerank_k: int = 0, expand_width: int = 1,
                        visited_size: Optional[int] = None,
                        hop_backend: str = "jnp") -> Callable:
    """Build the jit-able sharded search step.

    f(adjacency (S, Ns, d) i32, vectors (S, Ns, m) f32, n (S,) i32,
      seeds (S,) i32, queries (B, m) f32[, exclude (B, X) i32])
      -> (ids (B, k) global i32, dists (B, k) f32)

    With a compressed ``codec``, f additionally takes
    ``codes (S, Ns, m)`` / ``scales (S, m)`` after ``vectors`` and runs the
    two-stage protocol: each shard's beam traverses its *quantized* store,
    ``rerank_k`` (default ``4 * k``) candidates per shard merge through
    ``topk_merge_allgather``, and the merged list is re-scored exactly
    AFTER the merge — each shard scores the merged rows it owns against its
    float store and a ``pmin`` over the shard axis fills every lane.  The
    extra collective volume is one (B, rerank_k) f32 pmin; the final top-k
    ordering is exactly the float ordering of the surviving candidates.

    ``expand_width`` / ``visited_size`` / ``hop_backend`` configure the
    shard-local multi-expansion engine (``visited_size=None`` auto-sizes
    like ``range_search``); the collective protocol is unchanged — multi-
    expansion only reshapes the per-shard ``while_loop``.
    """
    from repro.quant.store import VectorStore

    n_shards = int(mesh.shape[shard_axis])
    quantized = codec != "float32"
    rr = max(rerank_k, k) if quantized else k
    if quantized and rerank_k <= 0:
        rr = 4 * k

    def local(adj, vecs, codes, scales, books, n, seed, queries, exclude):
        adj, vecs = adj[0], vecs[0]              # strip leading shard dim
        from repro.core.graph import DEGraph

        store = (VectorStore(data=codes[0], scale=scales[0], codec=codec,
                             codebooks=None if books is None else books[0])
                 if quantized else beam.as_store(vecs))
        g = DEGraph(adjacency=adj, weights=jnp.zeros_like(adj, jnp.float32),
                    n=n[0])
        B = queries.shape[0]
        shard = jax.lax.axis_index(shard_axis)
        if exclude is None:
            seeds = jnp.broadcast_to(seed[0], (B, 1)).astype(jnp.int32)
            excl_local = None
        else:
            # exploration: global seed/exclude ids -> local rows where owned
            own = (exclude % n_shards) == shard
            local_rows = jnp.where(own, exclude // n_shards, INVALID)
            seeds = jnp.concatenate(
                [local_rows[:, :1],
                 jnp.broadcast_to(seed[0], (B, 1)).astype(jnp.int32)], axis=1)
            excl_local = local_rows
        # shard-local beam engine program (same primitives as range_search,
        # embedded directly in the shard_map body)
        n_ex = excl_local.shape[1] if excl_local is not None else 0
        L = (beam_width if beam_width is not None
             else beam.default_beam_width(rr, g.degree, seeds.shape[1],
                                          n_ex))
        L = max(L, rr, seeds.shape[1], rr + n_ex)
        vs = visited_size
        if vs is None:
            vs = (beam.default_visited_size(L, g.degree)
                  if hop_backend == "pallas" else 0)
        state = beam.beam_search(
            g, store, queries, seeds, k=rr, eps=eps, beam_width=L,
            max_hops=beam.default_max_hops(L), metric=metric,
            exclude=excl_local, expand_width=expand_width,
            visited_size=vs, hop_backend=hop_backend)
        lids, ldists = beam.extract(state, rr, dedup=vs > 0)
        gids = jnp.where(lids == INVALID, INVALID, lids * n_shards + shard)
        dists, ids = topk_merge_allgather(ldists, gids, rr, shard_axis)
        if quantized:
            ids, dists = _exact_rerank_owned(
                vecs, queries, ids, k=k, metric=metric,
                n_shards=n_shards, shard=shard, shard_axis=shard_axis)
        return ids, dists

    bspec = P(batch_axes, None)
    shspec3 = P(shard_axis, None, None)
    shspec1 = P(shard_axis)

    in_specs = [shspec3, shspec3]
    if quantized:
        in_specs += [shspec3, P(shard_axis, None)]
        if codec == "pq":                 # (S, m_sub, 256, dsub) codebooks
            in_specs += [P(shard_axis, None, None, None)]
    in_specs += [shspec1, shspec1, bspec]
    if exclude_width > 0:
        in_specs += [P(batch_axes, None)]

    def body(*a):
        books = None
        if quantized and codec == "pq":
            adj, vecs, codes, scales, books, n, seed, queries = a[:8]
            rest = a[8:]
        elif quantized:
            adj, vecs, codes, scales, n, seed, queries = a[:7]
            rest = a[7:]
        else:
            adj, vecs, n, seed, queries = a[:5]
            codes = scales = None
            rest = a[5:]
        exclude = rest[0] if rest else None
        return local(adj, vecs, codes, scales, books, n, seed, queries,
                     exclude)

    def f(*args):
        return shard_map(
            body, mesh=mesh, in_specs=tuple(in_specs),
            out_specs=(bspec, bspec), check_vma=False,
        )(*args)

    return f


def _exact_rerank_owned(vecs, queries, ids, *, k, metric, n_shards, shard,
                        shard_axis):
    """Exact rerank of merged global ids inside shard_map: each shard
    scores the rows it owns against its float store; pmin over the shard
    axis fills the unowned lanes; the exact top-k wins."""
    from repro.core.distances import get_metric

    own = (ids != INVALID) & ((ids % n_shards) == shard)
    rows = jnp.where(own, ids // n_shards, 0)
    ed = get_metric(metric).pair(queries[:, None, :],
                                 vecs[rows].astype(jnp.float32))
    ed = jnp.where(own, ed, jnp.inf)
    ed = jax.lax.pmin(ed, shard_axis)
    ed = jnp.where(ids == INVALID, jnp.inf, ed)
    order = jnp.argsort(ed, axis=1, stable=True)[:, :k]
    out_ids = jnp.take_along_axis(ids, order, axis=1)
    out_d = jnp.take_along_axis(ed, order, axis=1)
    out_ids = jnp.where(jnp.isinf(out_d), INVALID, out_ids)
    return out_ids, out_d


# ---------------------------------------------------------------------------
# host-side container
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ShardedDEG:
    """S independently built sub-DEGs + the stacked device arrays.

    ``quantize()`` attaches per-shard compressed stores (codes calibrated
    per shard from its live rows); ``search`` then runs the two-stage
    protocol of :func:`make_sharded_search` (quantized traversal, exact
    rerank after the all-gather merge)."""

    shards: list                     # list[DEGIndex]
    adjacency: Array                 # (S, Ns, d)
    vectors: Array                   # (S, Ns, m)
    n: Array                         # (S,)
    seeds: Array                     # (S,) per-shard medoid
    params: DEGParams
    codec: str = "float32"
    codes: Optional[Array] = None    # (S, Ns, m) — compressed rows
    scales: Optional[Array] = None   # (S, m) — per-shard sq8 scales
    codebooks: Optional[Array] = None  # (S, m_sub, 256, dsub) — pq books

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def n_total(self) -> int:
        return int(np.asarray(self.n).sum())

    def quantize(self, codec: str) -> "ShardedDEG":
        """Post-training: encode every shard's store under ``codec``
        (per-shard calibration over its live rows)."""
        from repro.quant import codec as qc

        if codec not in qc.CODECS:
            raise ValueError(f"unknown codec {codec!r} "
                             f"(have {sorted(qc.CODECS)})")
        if codec == "float32":
            return dataclasses.replace(self, codec=codec, codes=None,
                                       scales=None, codebooks=None)
        S, Ns, m = self.vectors.shape
        n_host = np.asarray(self.n)
        vecs = np.asarray(self.vectors)
        if codec == "pq":
            from repro.quant import pq as pqm

            m_sub, dsub = pqm.n_subspaces(m), pqm.subspace_dim(m)
            codes = np.zeros((S, Ns, m_sub), dtype=np.uint8)
            books = np.zeros((S, m_sub, pqm.PQ_K, dsub), dtype=np.float32)
            for s in range(S):
                books[s] = pqm.fit(vecs[s], int(n_host[s]), seed=s)
                codes[s] = np.asarray(pqm.encode(jnp.asarray(vecs[s]),
                                                 jnp.asarray(books[s])))
            return dataclasses.replace(
                self, codec=codec, codes=jnp.asarray(codes),
                scales=jnp.ones((S, m), jnp.float32),
                codebooks=jnp.asarray(books))
        codes = np.zeros((S, Ns, m),
                         dtype={"fp16": np.float16, "sq8": np.int8}[codec])
        scales = np.ones((S, m), dtype=np.float32)
        for s in range(S):
            sc = qc.calibrate_sq8_scale(jnp.asarray(vecs[s]), n_host[s]) \
                if codec == "sq8" else jnp.ones((m,), jnp.float32)
            scales[s] = np.asarray(sc)
            codes[s] = np.asarray(qc.encode(codec, jnp.asarray(vecs[s]), sc))
        return dataclasses.replace(self, codec=codec,
                                   codes=jnp.asarray(codes),
                                   scales=jnp.asarray(scales),
                                   codebooks=None)

    def memory_stats(self) -> dict:
        """Per-shard traversal-store bytes (live rows) under the attached
        codec vs the exact float32 store."""
        from repro.quant import codec as qc

        m = self.vectors.shape[2]
        per_shard = np.asarray(self.n)
        exact = sum(qc.store_bytes("float32", int(ns), m) for ns in per_shard)
        b = sum(qc.store_bytes(self.codec, int(ns), m) for ns in per_shard)
        return {"n": int(per_shard.sum()), "dim": m, "codec": self.codec,
                "exact_bytes": exact, "store_bytes": b,
                "ratio": exact / b if b else 0.0}

    def search(self, mesh: Mesh, queries: np.ndarray, k: int,
               eps: float = 0.1, batch_axes="data",
               rerank_k: int = 0, expand_width: Optional[int] = None,
               visited_size: Optional[int] = None,
               hop_backend: Optional[str] = None) -> tuple:
        f = make_sharded_search(
            mesh, k=k, eps=eps, metric=self.params.metric,
            batch_axes=batch_axes, codec=self.codec, rerank_k=rerank_k,
            expand_width=(self.params.expand_width if expand_width is None
                          else expand_width),
            visited_size=(self.params.visited_size if visited_size is None
                          else visited_size),
            hop_backend=(self.params.hop_backend if hop_backend is None
                         else hop_backend))
        args = [self.adjacency, self.vectors]
        if self.codec != "float32":
            args += [self.codes, self.scales]
            if self.codec == "pq":
                args += [self.codebooks]
        args += [self.n, self.seeds, jnp.asarray(queries)]
        with set_mesh(mesh):
            ids, dists = jax.jit(f)(*args)
        return np.asarray(ids), np.asarray(dists)

    def refine(self, iterations: int, seed: Optional[int] = None) -> int:
        """Shard-local continuous refinement (Alg. 5): each sub-DEG runs
        ``iterations`` of the batched refine path independently (sub-DEGs
        share no edges, so shard-local surgery is exact, not approximate),
        then the stacked device adjacency is refreshed from the builders.
        Returns the total number of improved edges."""
        improved = 0
        for s, sh in enumerate(self.shards):
            improved += sh.refine(
                iterations, seed=None if seed is None else seed + s)
        if improved:
            S, ns, d = self.adjacency.shape
            adj = np.full((S, ns, d), INVALID, dtype=np.int32)
            for s, sh in enumerate(self.shards):
                adj[s, : sh.n] = sh.builder.adjacency[: sh.n]
            self.adjacency = jnp.asarray(adj)
        return improved

    # -- persistence (persist/sharded.py owns the format) ------------------
    def save(self, path) -> None:
        """Snapshot every sub-DEG (full persist sections) behind one
        manifest; ``ShardedDEG.load`` restores exactly, or onto a different
        shard count via reshard-on-restore."""
        from repro.persist import save_sharded

        save_sharded(self, path)

    @classmethod
    def load(cls, path, n_shards: Optional[int] = None,
             wave_size: int = 8) -> "ShardedDEG":
        from repro.persist import load_sharded

        return load_sharded(path, n_shards=n_shards, wave_size=wave_size)

    def drop_shard(self, idx: int) -> "ShardedDEG":
        """Simulate losing one model shard: its sub-DEG serves nothing.
        (n=0 disables every vertex: recall degrades by ~1/S, service
        continues — the preemption-tolerance posture of DESIGN.md §4.)"""
        n = np.asarray(self.n).copy()
        n[idx] = 0
        return dataclasses.replace(self, n=jnp.asarray(n))


def build_sharded_deg(vectors: np.ndarray, n_shards: int,
                      params: Optional[DEGParams] = None,
                      wave_size: int = 8,
                      refine_iterations: int = 0,
                      codec: str = "float32") -> ShardedDEG:
    """Round-robin partition + per-shard incremental DEG build.
    ``codec`` != "float32" attaches post-training quantized shard stores."""
    params = params or DEGParams()
    vectors = np.asarray(vectors, dtype=np.float32)
    N, m = vectors.shape
    shards, id_rows = [], []
    for s in range(n_shards):
        rows = vectors[s::n_shards]
        idx = DEGIndex(m, params, capacity=rows.shape[0])
        idx.add(rows, wave_size=wave_size)
        if refine_iterations:
            idx.refine(refine_iterations)
        shards.append(idx)
    ns = max(sh.n for sh in shards)
    d = params.degree
    adj = np.full((n_shards, ns, d), INVALID, dtype=np.int32)
    vecs = np.zeros((n_shards, ns, m), dtype=np.float32)
    seeds = np.zeros((n_shards,), dtype=np.int32)
    n_arr = np.zeros((n_shards,), dtype=np.int32)
    for s, sh in enumerate(shards):
        adj[s, : sh.n] = sh.builder.adjacency[: sh.n]
        vecs[s, : sh.n] = sh.vectors[: sh.n]
        n_arr[s] = sh.n
        seeds[s] = sh.medoid()       # cached per-shard medoid entry
    sd = ShardedDEG(shards=shards, adjacency=jnp.asarray(adj),
                    vectors=jnp.asarray(vecs), n=jnp.asarray(n_arr),
                    seeds=jnp.asarray(seeds), params=params)
    return sd.quantize(codec) if codec != "float32" else sd
