"""Sharded Dynamic Exploration Graph (DESIGN.md §4).

The DB of N vectors is partitioned **round-robin** into S sub-DEGs, one per
``"model"``-axis shard (global id g lives on shard ``g % S`` at local row
``g // S``).  Each sub-DEG is an independent even-regular DEG built and
refined incrementally — DEG's incrementality is what makes per-shard
growth/rebalancing cheap at this scale.  Queries are sharded along the DP
axes (throughput) and replicated along ``"model"``; one search step is:

    local in-shard beam search  ->  all_gather(k best per shard, "model")
                                ->  exact top-k merge

Collective volume per query: ``S * k * 8`` bytes — independent of N.  Pods
replicate the index, so losing a pod degrades throughput, not recall; losing
one model shard degrades recall by ~1/S while the other shards keep serving
(fault-tolerance posture; simulated in tests/test_distributed.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import set_mesh, shard_map

from repro.core import beam
from repro.core.build import DEGIndex, DEGParams
from repro.core.graph import INVALID
from .collectives import topk_merge_allgather

Array = jax.Array


# ---------------------------------------------------------------------------
# the pure, lowerable search step
# ---------------------------------------------------------------------------
def make_sharded_search(mesh: Mesh, *, k: int, eps: float = 0.1,
                        beam_width: Optional[int] = None,
                        metric: str = "l2", shard_axis: str = "model",
                        batch_axes="data", exclude_width: int = 0) -> Callable:
    """Build the jit-able sharded search step.

    f(adjacency (S, Ns, d) i32, vectors (S, Ns, m) f32, n (S,) i32,
      seeds (S,) i32, queries (B, m) f32[, exclude (B, X) i32])
      -> (ids (B, k) global i32, dists (B, k) f32)
    """
    n_shards = int(mesh.shape[shard_axis])

    def local(adj, vecs, n, seed, queries, exclude):
        adj, vecs = adj[0], vecs[0]              # strip leading shard dim
        from repro.core.graph import DEGraph

        g = DEGraph(adjacency=adj, weights=jnp.zeros_like(adj, jnp.float32),
                    n=n[0])
        B = queries.shape[0]
        shard = jax.lax.axis_index(shard_axis)
        if exclude is None:
            seeds = jnp.broadcast_to(seed[0], (B, 1)).astype(jnp.int32)
            excl_local = None
        else:
            # exploration: global seed/exclude ids -> local rows where owned
            own = (exclude % n_shards) == shard
            local_rows = jnp.where(own, exclude // n_shards, INVALID)
            seeds = jnp.concatenate(
                [local_rows[:, :1],
                 jnp.broadcast_to(seed[0], (B, 1)).astype(jnp.int32)], axis=1)
            excl_local = local_rows
        # shard-local beam engine program (same primitives as range_search,
        # embedded directly in the shard_map body)
        n_ex = excl_local.shape[1] if excl_local is not None else 0
        L = (beam_width if beam_width is not None
             else beam.default_beam_width(k, g.degree, seeds.shape[1], n_ex))
        L = max(L, k, seeds.shape[1], k + n_ex)
        state = beam.beam_search(
            g, vecs, queries, seeds, k=k, eps=eps, beam_width=L,
            max_hops=beam.default_max_hops(L), metric=metric,
            exclude=excl_local)
        lids, ldists = beam.extract(state, k)
        gids = jnp.where(lids == INVALID, INVALID, lids * n_shards + shard)
        dists, ids = topk_merge_allgather(ldists, gids, k, shard_axis)
        return ids, dists

    bspec = P(batch_axes, None)
    shspec3 = P(shard_axis, None, None)
    shspec1 = P(shard_axis)

    if exclude_width > 0:
        def f(adj, vecs, n, seeds, queries, exclude):
            return shard_map(
                functools.partial(local),
                mesh=mesh,
                in_specs=(shspec3, shspec3, shspec1, shspec1, bspec,
                          P(batch_axes, None)),
                out_specs=(bspec, bspec), check_vma=False,
            )(adj, vecs, n, seeds, queries, exclude)
        return f

    def f(adj, vecs, n, seeds, queries):
        return shard_map(
            lambda a, v, nn, s, q: local(a, v, nn, s, q, None),
            mesh=mesh,
            in_specs=(shspec3, shspec3, shspec1, shspec1, bspec),
            out_specs=(bspec, bspec), check_vma=False,
        )(adj, vecs, n, seeds, queries)

    return f


# ---------------------------------------------------------------------------
# host-side container
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ShardedDEG:
    """S independently built sub-DEGs + the stacked device arrays."""

    shards: list                     # list[DEGIndex]
    adjacency: Array                 # (S, Ns, d)
    vectors: Array                   # (S, Ns, m)
    n: Array                         # (S,)
    seeds: Array                     # (S,) per-shard medoid
    params: DEGParams

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def n_total(self) -> int:
        return int(np.asarray(self.n).sum())

    def search(self, mesh: Mesh, queries: np.ndarray, k: int,
               eps: float = 0.1, batch_axes="data") -> tuple:
        f = make_sharded_search(mesh, k=k, eps=eps,
                                metric=self.params.metric,
                                batch_axes=batch_axes)
        with set_mesh(mesh):
            ids, dists = jax.jit(f)(self.adjacency, self.vectors, self.n,
                                    self.seeds, jnp.asarray(queries))
        return np.asarray(ids), np.asarray(dists)

    def drop_shard(self, idx: int) -> "ShardedDEG":
        """Simulate losing one model shard: its sub-DEG serves nothing.
        (n=0 disables every vertex: recall degrades by ~1/S, service
        continues — the preemption-tolerance posture of DESIGN.md §4.)"""
        n = np.asarray(self.n).copy()
        n[idx] = 0
        return dataclasses.replace(self, n=jnp.asarray(n))


def build_sharded_deg(vectors: np.ndarray, n_shards: int,
                      params: Optional[DEGParams] = None,
                      wave_size: int = 8,
                      refine_iterations: int = 0) -> ShardedDEG:
    """Round-robin partition + per-shard incremental DEG build."""
    params = params or DEGParams()
    vectors = np.asarray(vectors, dtype=np.float32)
    N, m = vectors.shape
    shards, id_rows = [], []
    for s in range(n_shards):
        rows = vectors[s::n_shards]
        idx = DEGIndex(m, params, capacity=rows.shape[0])
        idx.add(rows, wave_size=wave_size)
        if refine_iterations:
            idx.refine(refine_iterations)
        shards.append(idx)
    ns = max(sh.n for sh in shards)
    d = params.degree
    adj = np.full((n_shards, ns, d), INVALID, dtype=np.int32)
    vecs = np.zeros((n_shards, ns, m), dtype=np.float32)
    seeds = np.zeros((n_shards,), dtype=np.int32)
    n_arr = np.zeros((n_shards,), dtype=np.int32)
    for s, sh in enumerate(shards):
        adj[s, : sh.n] = sh.builder.adjacency[: sh.n]
        vecs[s, : sh.n] = sh.vectors[: sh.n]
        n_arr[s] = sh.n
        seeds[s] = sh.medoid()       # cached per-shard medoid entry
    return ShardedDEG(shards=shards, adjacency=jnp.asarray(adj),
                      vectors=jnp.asarray(vecs), n=jnp.asarray(n_arr),
                      seeds=jnp.asarray(seeds), params=params)
