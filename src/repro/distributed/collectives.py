"""Collective building blocks used by the distributed index and recsys.

Everything here is written for ``jax.shard_map`` over the production mesh
(launch/mesh.py) so the communication schedule is explicit and shows up
verbatim in the dry-run HLO for the roofline analysis.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

Array = jax.Array


# ---------------------------------------------------------------------------
# distributed exact top-k merge
# ---------------------------------------------------------------------------
def topk_merge_allgather(local_vals: Array, local_ids: Array, k: int,
                         axis_name) -> tuple[Array, Array]:
    """Inside shard_map: each shard holds (B, k) local top-k candidates with
    *global* ids; all-gather along ``axis_name`` and re-select top-k.

    Collective volume per query: shards * k * 8 bytes — the tiny merge path
    that makes sharded ANN search collective-light (DESIGN.md §4).
    """
    vals = jax.lax.all_gather(local_vals, axis_name, axis=1, tiled=True)
    ids = jax.lax.all_gather(local_ids, axis_name, axis=1, tiled=True)
    top, pos = jax.lax.top_k(-vals, k)          # distances: smaller is better
    return -top, jnp.take_along_axis(ids, pos, axis=1)


def sharded_brute_topk(mesh: Mesh, *, k: int, shard_axes: Sequence[str],
                       batch_axes=None, metric: str = "ip") -> Callable:
    """Returns f(queries (B, m), db (N, m)) -> (vals (B, k), ids (B, k)):
    DB rows sharded over ``shard_axes``; local scoring + exact global merge.

    ``metric='ip'`` scores by inner product (descending); ``'l2'`` by
    euclidean distance (ascending). Used by retrieval_cand and as the
    serial-scan baseline at scale.
    """
    shard_axes = tuple(shard_axes)
    q_spec = P(batch_axes, None)
    db_spec = P(shard_axes, None)

    def local(q, db):
        if metric == "ip":
            scores = -(q @ db.T)                # negate: unify to "smaller"
        else:
            q2 = jnp.sum(q * q, 1, keepdims=True)
            d2 = jnp.sum(db * db, 1)
            scores = q2 + d2[None, :] - 2.0 * (q @ db.T)
        n_local = db.shape[0]
        kk = min(k, n_local)
        neg, pos = jax.lax.top_k(-scores, kk)
        # global row ids: offset by this shard's position along shard_axes
        idx = jax.lax.axis_index(shard_axes)
        ids = pos + idx * n_local
        vals, ids = topk_merge_allgather(-neg, ids, k, shard_axes)
        if metric == "ip":
            vals = -vals
        return vals, ids

    f = shard_map(local, mesh=mesh, in_specs=(q_spec, db_spec),
                  out_specs=(q_spec, q_spec), check_vma=False)
    return f


# ---------------------------------------------------------------------------
# gradient compression (int8 all-reduce path)
# ---------------------------------------------------------------------------
def int8_compress(x: Array) -> tuple[Array, Array]:
    """Per-tensor symmetric int8 quantization: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def int8_decompress(q: Array, scale: Array, dtype=jnp.float32) -> Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(x: Array, axis_name) -> Array:
    """All-reduce with int8 payload: agree on a *global* scale (scalar pmax
    — per-shard scales cannot be mixed after the sum), quantize, psum the
    int8 payload (as int32 to avoid overflow at >127 shards), dequantize.
    ~4x wire-bytes reduction on the DP all-reduce path for the cost of one
    extra scalar collective."""
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)).astype(jnp.float32), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale


def make_compressed_grad_allreduce(mesh: Mesh, dp_axis) -> Callable:
    """tree -> tree: int8-compressed mean-all-reduce over the DP axes.

    Drop-in for the implicit GSPMD gradient all-reduce when gradients are
    computed per-shard inside shard_map (launch/train.py --compress-grads).
    """

    def reduce_tree(grads):
        def one(g):
            s = compressed_psum(g, dp_axis)
            n = jax.lax.psum(jnp.ones((), jnp.float32), dp_axis)
            return (s / n).astype(g.dtype)

        return jax.tree.map(one, grads)

    return reduce_tree


# ---------------------------------------------------------------------------
# sharded embedding lookup factory (recsys hot path)
# ---------------------------------------------------------------------------
def make_sharded_lookup(mesh: Mesh, *, table_axis: str = "model",
                        batch_axes=None) -> Callable:
    """Returns lookup(table, ids) for a row-sharded table under jit.

    table: (V, E) sharded P(table_axis, None); ids: (B, ...) global rows,
    sharded over ``batch_axes``.  Each shard resolves local hits and psums
    over the table axis (models/embedding_bag.sharded_embedding_lookup).
    """
    from repro.models.embedding_bag import sharded_embedding_lookup

    def local(table, ids):
        n_local = table.shape[0]
        shard = jax.lax.axis_index(table_axis)
        offset = shard * n_local
        return sharded_embedding_lookup(table, ids, offset, (table_axis,))

    def lookup(table, ids):
        f = shard_map(
            local, mesh=mesh,
            in_specs=(P(table_axis, None), P(batch_axes, *([None] * (ids.ndim - 1)))),
            out_specs=P(batch_axes, *([None] * ids.ndim)),
            check_vma=False)
        return f(table, ids)

    return lookup
