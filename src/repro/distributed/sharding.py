"""Per-architecture parameter / activation PartitionSpec rules.

Layout summary (DESIGN.md §4) for the production mesh
``("pod",) + ("data", "model")``:

* **LM transformers** — batch over the DP axes ``("pod","data")``; params
  FSDP-sharded over ``"data"`` on the d_model axis and tensor-parallel over
  ``"model"`` on heads / FFN-hidden / vocab.  MoE experts use *expert-TP*:
  every device holds all experts but a 1/TP slice of each expert's hidden
  dim, so dispatch stays device-local and the only collective matches the
  dense MLP's psum.
* **KV caches (decode)** — cache length sharded over ``"model"``
  (flash-decode style sequence parallelism: each model shard holds 1/TP of
  the context, computes partial attention, GSPMD inserts the softmax
  all-reduce), batch over the DP axes.
* **EGNN** — params replicated (tiny); edge arrays sharded over
  ``("data","model")`` and node arrays over ``"data"``.
* **RecSys** — embedding-table rows sharded over ``"model"`` (lookup =
  mask + psum inside shard_map, see models/embedding_bag.py), dense towers
  replicated, batch over DP axes.  (The reduce-scatter/all-to-all row layout
  over all axes is the §Perf iteration.)

All functions return *pytrees of PartitionSpec* with the exact structure of
the matching ``abstract_params``/input trees, ready to wrap in
``NamedSharding``.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh) -> Any:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if len(axes) > 1 else axes[0]


def _divisible(n: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    names = (axis,) if isinstance(axis, str) else tuple(axis)
    size = int(np.prod([mesh.shape[a] for a in names]))
    return n % size == 0


def _maybe(n: int, mesh: Mesh, axis):
    """Shard axis only if the dim divides evenly (GSPMD supports uneven but
    padding wastes memory and muddies the roofline numbers)."""
    return axis if _divisible(n, mesh, axis) else None


# ---------------------------------------------------------------------------
# LM transformer
# ---------------------------------------------------------------------------
def lm_param_specs(cfg, mesh: Mesh) -> dict:
    """PartitionSpec tree matching models.transformer.abstract_params(cfg)."""
    D, Dh = cfg.d_model, cfg.head_dim
    Hq, Hkv = cfg.n_heads * Dh, cfg.n_kv_heads * Dh
    fsdp = "data" if "data" in mesh.axis_names else None

    def mat(rows: int, cols: int, row_ax, col_ax):
        return P(None, _maybe(rows, mesh, row_ax), _maybe(cols, mesh, col_ax))

    layers = {
        "attn_norm": P(None, None),
        "mlp_norm": P(None, None),
        "wq": mat(D, Hq, fsdp, "model"),
        "wk": mat(D, Hkv, fsdp, "model"),
        "wv": mat(D, Hkv, fsdp, "model"),
        "wo": mat(Hq, D, "model", fsdp),
    }
    if cfg.moe is None:
        layers |= {
            "w_gate": mat(D, cfg.d_ff, fsdp, "model"),
            "w_up": mat(D, cfg.d_ff, fsdp, "model"),
            "w_down": mat(cfg.d_ff, D, "model", fsdp),
        }
    else:
        F = cfg.moe.d_ff_expert
        layers |= {
            "router": P(None, _maybe(D, mesh, fsdp), None),
            "we_gate": P(None, None, _maybe(D, mesh, fsdp),
                         _maybe(F, mesh, "model")),
            "we_up": P(None, None, _maybe(D, mesh, fsdp),
                       _maybe(F, mesh, "model")),
            "we_down": P(None, None, _maybe(F, mesh, "model"),
                         _maybe(D, mesh, fsdp)),
        }
    p = {
        "embed": P(_maybe(cfg.vocab, mesh, "model"), None),
        "final_norm": P(None),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        p["head"] = P(None, _maybe(cfg.vocab, mesh, "model"))
    return p


def lm_batch_specs(mesh: Mesh) -> dict:
    b = dp_axes(mesh)
    return {"tokens": P(b, None), "labels": P(b, None)}


def lm_cache_specs(cfg, mesh: Mesh, batch: int) -> dict:
    """KV-cache specs matching transformer.abstract_cache.

    Cache length over "model" (sequence-parallel decode); batch over DP axes
    when it divides, else replicated (long_500k has batch 1).
    """
    b = _maybe(batch, mesh, dp_axes(mesh))
    ks, vs = [], []
    for i in range(cfg.n_layers):
        w = cfg.layer_window(i)
        # ring caches (sliding-window layers) are small; shard only full ones
        seq_ax = "model" if w is None else None
        ks.append(P(b, seq_ax, None, None))
        vs.append(P(b, seq_ax, None, None))
    return {"k": ks, "v": vs, "pos": P()}


# ---------------------------------------------------------------------------
# EGNN
# ---------------------------------------------------------------------------
def egnn_param_specs(params_tree) -> Any:
    return jax.tree.map(lambda _: P(), params_tree)


def egnn_batch_specs(mesh: Mesh, kind: str, dims: dict) -> dict:
    all_ax = tuple(mesh.axis_names)  # edges spread over every device
    if kind == "molecule":
        b = dp_axes(mesh)
        return {"feats": P(b, None, None), "coords": P(b, None, None),
                "edges": P(b, None, None), "labels": P(b, None)}
    edge_ax = all_ax if dims["n_edges"] % int(
        np.prod(mesh.devices.shape)) == 0 else None
    return {
        "feats": P(None, None),          # node arrays replicated (psum'd agg)
        "coords": P(None, None),
        "edges": P(None, edge_ax),
        "labels": P(None),
    }


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------
def recsys_param_specs(cfg, mesh: Mesh) -> dict:
    """Row-shard the stacked embedding table over "model"; small towers
    replicated."""
    from repro.models import recsys as R

    tree = R.abstract_params(cfg)

    def spec(path, leaf):
        name = path[0].key if hasattr(path[0], "key") else str(path[0])
        if name == "table":
            return P(_maybe(leaf.shape[0], mesh, "model"), None)
        if name == "fm_w":
            return P(_maybe(leaf.shape[0], mesh, "model"))
        return P(*([None] * len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(spec, tree)


def recsys_batch_specs(cfg, mesh: Mesh, batch: int) -> dict:
    b = _maybe(batch, mesh, dp_axes(mesh))
    s = {"sparse": P(b, None), "label": P(b)}
    if cfg.n_dense:
        s["dense"] = P(b, None)
    if cfg.kind == "din":
        s["hist"] = P(b, None)
    return s


# ---------------------------------------------------------------------------
# generic helpers
# ---------------------------------------------------------------------------
def named(mesh: Mesh, spec_tree) -> Any:
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _is_spec(x) -> bool:
    return isinstance(x, P)


def _prune_to(specs, tree) -> Any:
    """Select from the full param-spec tree the leaves present in ``tree``
    (which may be a masked subtree with None nodes — see
    train.optimizer.partitioned)."""
    spec_map = dict(jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=_is_spec)[0])
    return jax.tree_util.tree_map_with_path(
        lambda path, _: spec_map[path], tree)


def opt_state_specs(param_specs, opt_state_tree) -> Any:
    """Optimizer-state specs: moment leaves inherit the matching param spec;
    counts/scalars replicate.  Handles adamw/sgd/partitioned state dicts."""

    def build(st):
        if isinstance(st, dict):
            out = {}
            for k, v in st.items():
                if k in ("mu", "nu", "mom"):
                    out[k] = _prune_to(param_specs, v)
                elif isinstance(v, dict):
                    out[k] = build(v)
                else:
                    out[k] = jax.tree.map(lambda _: P(), v)
            return out
        return jax.tree.map(lambda _: P(), st)

    return build(opt_state_tree)
