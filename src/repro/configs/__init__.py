"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from .base import ArchSpec, ShapeCell
from .deg import DEG_PAPER_CONFIGS, QUANT_PRESETS, QuantPreset
from .gnn_archs import EGNN
from .lm_archs import (GEMMA3_12B, GRANITE_3_2B, MIXTRAL_8X22B, PHI3_MINI,
                       QWEN3_MOE)
from .recsys_archs import DCN_V2, DEEPFM, DIN, DLRM_MLPERF

_ARCHS = {
    s.name: s for s in (
        PHI3_MINI, GRANITE_3_2B, GEMMA3_12B, QWEN3_MOE, MIXTRAL_8X22B,
        EGNN, DCN_V2, DEEPFM, DIN, DLRM_MLPERF,
    )
}


def get_arch(name: str) -> ArchSpec:
    try:
        return _ARCHS[name]
    except KeyError:
        raise ValueError(
            f"unknown arch {name!r}; available: {sorted(_ARCHS)}") from None


def list_archs() -> list[str]:
    return sorted(_ARCHS)


def all_cells() -> list[tuple[str, str]]:
    """All 40 (arch, shape) dry-run cells."""
    out = []
    for name in list_archs():
        for cell in _ARCHS[name].shapes:
            out.append((name, cell.name))
    return out


__all__ = ["ArchSpec", "ShapeCell", "get_arch", "list_archs", "all_cells",
           "DEG_PAPER_CONFIGS"]
