"""The five assigned LM-family architectures (exact public configs).

Sources are the assignment table entries; d_head is derived as
d_model // n_heads where the table does not pin it.
"""
from __future__ import annotations

import dataclasses

from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

from .base import ArchSpec, LM_SHAPES

_FULL_ATTN_SKIP = ("long_500k needs sub-quadratic attention; this arch is "
                   "pure full attention (assignment rule: skip + note)")


def _reduced_lm(moe: bool = False, window=None, pattern=None):
    return TransformerConfig(
        name="reduced", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256,
        sliding_window=window, local_global_pattern=pattern,
        # capacity_factor 4.0: smoke tests assert prefill/decode consistency,
        # which requires no capacity drops (drop behavior is covered by
        # test_moe_capacity_drops_tokens); full configs keep 1.25.
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                      capacity_factor=4.0) if moe else None,
        remat=False, q_chunk=32)


PHI3_MINI = ArchSpec(
    name="phi3-mini-3.8b", family="lm",
    model=TransformerConfig(
        name="phi3-mini-3.8b", n_layers=32, d_model=3072, n_heads=32,
        n_kv_heads=32, d_ff=8192, vocab=32064, rope_theta=10000.0,
        tie_embeddings=False),
    shapes=LM_SHAPES,
    reduced=lambda: _reduced_lm(),
    skip={"long_500k": _FULL_ATTN_SKIP},
    notes="arXiv:2404.14219 — RoPE SwiGLU, MHA (GQA kv=32 == heads)")

GRANITE_3_2B = ArchSpec(
    name="granite-3-2b", family="lm",
    model=TransformerConfig(
        name="granite-3-2b", n_layers=40, d_model=2048, n_heads=32,
        n_kv_heads=8, d_ff=8192, vocab=49155, tie_embeddings=True),
    shapes=LM_SHAPES,
    reduced=lambda: _reduced_lm(),
    skip={"long_500k": _FULL_ATTN_SKIP},
    notes="hf:ibm-granite/granite-3.0-2b-base — GQA kv=8")

GEMMA3_12B = ArchSpec(
    name="gemma3-12b", family="lm",
    model=TransformerConfig(
        name="gemma3-12b", n_layers=48, d_model=3840, n_heads=16,
        n_kv_heads=8, d_ff=15360, vocab=262144, sliding_window=1024,
        local_global_pattern=5, tie_embeddings=True),
    shapes=LM_SHAPES,
    reduced=lambda: _reduced_lm(window=8, pattern=1),
    notes=("hf:google/gemma-3 family — 5 local(window 1024):1 global; "
           "long_500k RUNS: 40/48 layers hold a 1024-slot ring cache, the 8 "
           "global layers hold the full 500k cache (sharded)"))

QWEN3_MOE = ArchSpec(
    name="qwen3-moe-30b-a3b", family="lm",
    model=TransformerConfig(
        name="qwen3-moe-30b-a3b", n_layers=48, d_model=2048, n_heads=32,
        n_kv_heads=4, d_ff=768, vocab=151936,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768),
        tie_embeddings=True),
    shapes=LM_SHAPES,
    reduced=lambda: _reduced_lm(moe=True),
    skip={"long_500k": _FULL_ATTN_SKIP},
    notes="hf:Qwen/Qwen3-30B-A3B — 128 experts top-8, GQA kv=4")

MIXTRAL_8X22B = ArchSpec(
    name="mixtral-8x22b", family="lm",
    model=TransformerConfig(
        name="mixtral-8x22b", n_layers=56, d_model=6144, n_heads=48,
        n_kv_heads=8, d_head=128, d_ff=16384, vocab=32768,
        sliding_window=4096,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384),
        tie_embeddings=False),
    shapes=LM_SHAPES,
    reduced=lambda: _reduced_lm(moe=True, window=8),
    notes=("arXiv:2401.04088 — 8 experts top-2, SWA window 4096 on all "
           "layers; long_500k RUNS with the 4096-slot ring cache"))


def _post_init_checks():
    for spec in (PHI3_MINI, GRANITE_3_2B, GEMMA3_12B, QWEN3_MOE,
                 MIXTRAL_8X22B):
        m = spec.model
        assert m.n_heads % m.n_kv_heads == 0, spec.name


_post_init_checks()
