"""The four assigned recsys architectures (exact public configs)."""
from __future__ import annotations

from repro.models.recsys import (CRITEO_KAGGLE_VOCABS, CRITEO_TB_VOCABS,
                                 RecsysConfig)

from .base import ArchSpec, RECSYS_SHAPES, ShapeCell


def _reduced_recsys(kind: str):
    if kind == "din":
        return RecsysConfig(
            name=f"{kind}-reduced", kind="din", n_dense=0, n_sparse=3,
            embed_dim=8, vocab_sizes=(50, 20, 30), mlp=(32, 16),
            attn_mlp=(16, 8), seq_len=10, item_field=0)
    if kind == "deepfm":
        return RecsysConfig(
            name=f"{kind}-reduced", kind="deepfm", n_dense=0, n_sparse=6,
            embed_dim=6, vocab_sizes=(40,) * 6, mlp=(32, 16))
    n_cross = 2 if kind == "dcn-v2" else 0
    bot = (16, 8) if kind == "dlrm" else ()
    return RecsysConfig(
        name=f"{kind}-reduced", kind=kind, n_dense=4, n_sparse=5,
        embed_dim=8, vocab_sizes=(30,) * 5, mlp=(32, 16), bot_mlp=bot,
        n_cross=n_cross)


DCN_V2 = ArchSpec(
    name="dcn-v2", family="recsys",
    model=RecsysConfig(
        name="dcn-v2", kind="dcn-v2", n_dense=13, n_sparse=26, embed_dim=16,
        vocab_sizes=CRITEO_KAGGLE_VOCABS, mlp=(1024, 1024, 512), n_cross=3),
    shapes=RECSYS_SHAPES,
    reduced=lambda: _reduced_recsys("dcn-v2"),
    notes="arXiv:2008.13535 — 3 cross layers, Criteo-Kaggle vocabularies")

DEEPFM = ArchSpec(
    name="deepfm", family="recsys",
    model=RecsysConfig(
        name="deepfm", kind="deepfm", n_dense=0, n_sparse=39, embed_dim=10,
        # 26 categorical + 13 bucketized-dense fields (64 buckets each)
        vocab_sizes=CRITEO_KAGGLE_VOCABS + (64,) * 13,
        mlp=(400, 400, 400)),
    shapes=RECSYS_SHAPES,
    reduced=lambda: _reduced_recsys("deepfm"),
    notes="arXiv:1703.04247 — FM + deep tower, 39 fields")

DIN = ArchSpec(
    name="din", family="recsys",
    model=RecsysConfig(
        name="din", kind="din", n_dense=0, n_sparse=3, embed_dim=18,
        # fields: item (63001), category (801), user segment (192403)
        vocab_sizes=(63001, 801, 192403), mlp=(200, 80),
        attn_mlp=(80, 40), seq_len=100, item_field=0),
    shapes=RECSYS_SHAPES,
    reduced=lambda: _reduced_recsys("din"),
    notes="arXiv:1706.06978 — target attention over 100-item history "
          "(Amazon-Electronics-scale vocabularies)")

DLRM_MLPERF = ArchSpec(
    name="dlrm-mlperf", family="recsys",
    model=RecsysConfig(
        name="dlrm-mlperf", kind="dlrm", n_dense=13, n_sparse=26,
        embed_dim=128, vocab_sizes=CRITEO_TB_VOCABS,
        bot_mlp=(512, 256, 128), mlp=(1024, 1024, 512, 256, 1)),
    shapes=RECSYS_SHAPES,
    reduced=lambda: _reduced_recsys("dlrm"),
    notes="arXiv:1906.00091 + MLPerf config — Criteo-1TB vocabularies "
          f"({sum(CRITEO_TB_VOCABS):,} rows x 128)")
