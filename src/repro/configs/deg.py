"""DEG hyperparameters from the paper (Table 3) keyed by dataset analogue,
plus the defaults used by the offline benchmarks."""
from __future__ import annotations

from repro.core.build import DEGParams

# paper Table 3 (d, k_ext, eps_ext, k_opt, eps_opt, i_opt)
DEG_PAPER_CONFIGS = {
    "audio": DEGParams(degree=20, k_ext=40, eps_ext=0.3, k_opt=20,
                       eps_opt=0.001, i_opt=5),
    "enron": DEGParams(degree=30, k_ext=60, eps_ext=0.3, k_opt=30,
                       eps_opt=0.001, i_opt=5),
    "sift1m": DEGParams(degree=30, k_ext=60, eps_ext=0.2, k_opt=30,
                        eps_opt=0.001, i_opt=5),
    "glove": DEGParams(degree=30, k_ext=30, eps_ext=0.2, k_opt=30,
                       eps_opt=0.001, i_opt=5),
    # CPU-scale default for the offline benchmarks in this container
    "bench-small": DEGParams(degree=16, k_ext=32, eps_ext=0.3, k_opt=16,
                             eps_opt=0.001, i_opt=5),
}
