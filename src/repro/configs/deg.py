"""DEG hyperparameters from the paper (Table 3) keyed by dataset analogue,
plus the defaults used by the offline benchmarks and the serving-side
quantized-store presets."""
from __future__ import annotations

import dataclasses

from repro.core.build import DEGParams

# paper Table 3 (d, k_ext, eps_ext, k_opt, eps_opt, i_opt)
DEG_PAPER_CONFIGS = {
    "audio": DEGParams(degree=20, k_ext=40, eps_ext=0.3, k_opt=20,
                       eps_opt=0.001, i_opt=5),
    "enron": DEGParams(degree=30, k_ext=60, eps_ext=0.3, k_opt=30,
                       eps_opt=0.001, i_opt=5),
    "sift1m": DEGParams(degree=30, k_ext=60, eps_ext=0.2, k_opt=30,
                        eps_opt=0.001, i_opt=5),
    "glove": DEGParams(degree=30, k_ext=30, eps_ext=0.2, k_opt=30,
                       eps_opt=0.001, i_opt=5),
    # CPU-scale default for the offline benchmarks in this container
    "bench-small": DEGParams(degree=16, k_ext=32, eps_ext=0.3, k_opt=16,
                             eps_opt=0.001, i_opt=5),
}


@dataclasses.dataclass(frozen=True)
class QuantPreset:
    """Serving-side store configuration (post-training; orthogonal to the
    build params above).  ``codec`` is what the beam traverses, ``rerank_k``
    how many candidates the exact second stage re-scores (0 = auto 4*k,
    ignored for the exact codec)."""

    codec: str = "float32"
    rerank_k: int = 0


# serving presets: exact baseline, the 2x half-precision store, and two
# SQ8 points trading rerank width for recall headroom (the
# benchmarks/quantization.py frontier quantifies the trade on bench-small)
QUANT_PRESETS = {
    "exact": QuantPreset(),
    "fp16": QuantPreset(codec="fp16", rerank_k=20),
    "sq8-compact": QuantPreset(codec="sq8", rerank_k=20),
    "sq8-serving": QuantPreset(codec="sq8", rerank_k=40),
}


@dataclasses.dataclass(frozen=True)
class SearchPreset:
    """Query-engine configuration (orthogonal to both the build params and
    the store codec): how many beam entries each hop expands
    (``expand_width``), which hop implementation runs (``hop_backend``:
    "jnp" composed | "pallas" fused ``kernels/fused_hop``), and the
    per-lane visited-filter size (``visited_size``; None = auto — the
    broadcast dedup unless the fused kernel, which requires the filter,
    is selected)."""

    expand_width: int = 1
    hop_backend: str = "jnp"
    visited_size: int | None = None


# search-engine presets swept by benchmarks/search_pareto.py.  "classic"
# (E=1, jnp, beam-broadcast dedup) is the seed program bit for bit and
# stays the default everywhere; the multi-expansion points trade hop-count
# for per-hop width (the sweep shows multi-e2 beating the strongest E=1
# config at the saturated-recall tier on bench-small), "visited" variants
# swap the broadcast dedup for the O(probes) hash filter, and "fused"
# routes the hop body through the fused Pallas kernel (TPU-targeted).
SEARCH_PRESETS = {
    "classic": SearchPreset(),
    "visited-e1": SearchPreset(expand_width=1, visited_size=1024),
    "multi-e2": SearchPreset(expand_width=2),
    "multi-e4": SearchPreset(expand_width=4),
    "multi-e2-visited": SearchPreset(expand_width=2, visited_size=2048),
    "multi-e4-fused": SearchPreset(expand_width=4, hop_backend="pallas"),
}
