"""DEG hyperparameters from the paper (Table 3) keyed by dataset analogue,
plus the defaults used by the offline benchmarks and the serving-side
quantized-store presets."""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.build import DEGParams

# paper Table 3 (d, k_ext, eps_ext, k_opt, eps_opt, i_opt)
DEG_PAPER_CONFIGS = {
    "audio": DEGParams(degree=20, k_ext=40, eps_ext=0.3, k_opt=20,
                       eps_opt=0.001, i_opt=5),
    "enron": DEGParams(degree=30, k_ext=60, eps_ext=0.3, k_opt=30,
                       eps_opt=0.001, i_opt=5),
    "sift1m": DEGParams(degree=30, k_ext=60, eps_ext=0.2, k_opt=30,
                        eps_opt=0.001, i_opt=5),
    "glove": DEGParams(degree=30, k_ext=30, eps_ext=0.2, k_opt=30,
                       eps_opt=0.001, i_opt=5),
    # CPU-scale default for the offline benchmarks in this container
    "bench-small": DEGParams(degree=16, k_ext=32, eps_ext=0.3, k_opt=16,
                             eps_opt=0.001, i_opt=5),
}


@dataclasses.dataclass(frozen=True)
class QuantPreset:
    """Serving-side store configuration (post-training; orthogonal to the
    build params above).  ``codec`` is what the beam traverses, ``rerank_k``
    how many candidates the exact second stage re-scores (0 = auto 4*k,
    ignored for the exact codec), ``eps`` the beam's relative exploration
    slack (None = the engine's default)."""

    codec: str = "float32"
    rerank_k: int = 0
    eps: Optional[float] = None


# serving presets: exact baseline, the 2x half-precision store, two SQ8
# points trading rerank width for recall headroom, and two PQ points for
# the >=8x memory tier.  PQ's coarser per-row error distorts the beam's
# stopping rule, not just the final ordering, so its presets widen BOTH
# knobs: eps=0.2 keeps candidates in the beam that exact distances would
# have admitted, and the wider exact second stage recovers the order
# (the benchmarks/quantization.py frontier quantifies the trade on
# bench-small: rerank width alone plateaus ~0.89 recall@10 at eps=0.1,
# eps=0.2 + rerank_k=120 clears 0.95).
QUANT_PRESETS = {
    "exact": QuantPreset(),
    "fp16": QuantPreset(codec="fp16", rerank_k=20),
    "sq8-compact": QuantPreset(codec="sq8", rerank_k=20),
    "sq8-serving": QuantPreset(codec="sq8", rerank_k=40),
    "pq-compact": QuantPreset(codec="pq", rerank_k=80, eps=0.2),
    "pq-serving": QuantPreset(codec="pq", rerank_k=120, eps=0.2),
}


@dataclasses.dataclass(frozen=True)
class SearchPreset:
    """Query-engine configuration (orthogonal to both the build params and
    the store codec): how many beam entries each hop expands
    (``expand_width``), which hop implementation runs (``hop_backend``:
    "jnp" composed | "pallas" fused ``kernels/fused_hop``), the per-lane
    visited-filter size (``visited_size``; None = auto — the broadcast
    dedup unless the fused kernel, which requires the filter, is
    selected), and the beam length L (``beam_width``; None = the engine
    heuristic).  The serving bucket table precompiles one program per
    (batch bucket, preset), so L/E live here rather than ad hoc per
    call."""

    expand_width: int = 1
    hop_backend: str = "jnp"
    visited_size: int | None = None
    beam_width: int | None = None


# search-engine presets swept by benchmarks/search_pareto.py.  "classic"
# (E=1, jnp, beam-broadcast dedup) is the seed program bit for bit and
# stays the default everywhere; the multi-expansion points trade hop-count
# for per-hop width (the sweep shows multi-e2 beating the strongest E=1
# config at the saturated-recall tier on bench-small), "visited" variants
# swap the broadcast dedup for the O(probes) hash filter, and "fused"
# routes the hop body through the fused Pallas kernel (TPU-targeted).
SEARCH_PRESETS = {
    "classic": SearchPreset(),
    "visited-e1": SearchPreset(expand_width=1, visited_size=1024),
    "multi-e2": SearchPreset(expand_width=2),
    # the search_pareto headline point: E=2/L=64 beats the strongest E=1
    # config at the saturated-recall tier on bench-small (PR 4)
    "multi-e2-l64": SearchPreset(expand_width=2, beam_width=64),
    "multi-e4": SearchPreset(expand_width=4),
    "multi-e2-visited": SearchPreset(expand_width=2, visited_size=2048),
    "multi-e4-fused": SearchPreset(expand_width=4, hop_backend="pallas"),
}


@dataclasses.dataclass(frozen=True)
class ServingPreset:
    """Continuous-batching scheduler configuration (serving/async_engine).

    ``max_batch`` bounds one flush; batches are padded to power-of-two
    buckets from ``bucket_floor`` up (``serving/buckets.py``), so the jit
    cache stays at ``len(buckets)`` programs per search preset.
    ``deadline_ms`` is the default per-request SLO (None = no deadline):
    a request whose deadline minus ``slack_ms`` (plus the measured flush
    latency) is near forces a flush; one whose deadline has already
    expired at dispatch is searched under ``partial_hops`` expansions and
    returned flagged partial instead of being dropped.  ``linger_ms`` is
    the max time the scheduler holds an underfull batch waiting for
    coalescing."""

    max_batch: int = 64
    bucket_floor: int = 8
    deadline_ms: float | None = 50.0
    slack_ms: float = 3.0
    linger_ms: float = 2.0
    partial_hops: int = 8
    pipeline_depth: int = 2


# SLO presets for the async serving front end (launch/serve.py --slo,
# benchmarks/serving_load.py): interactive trades batch occupancy for
# latency, throughput the reverse; ci-quick is the deterministic smoke
# configuration the CI gate runs.
SLO_PRESETS = {
    "interactive": ServingPreset(max_batch=32, bucket_floor=4,
                                 deadline_ms=15.0, linger_ms=1.0,
                                 partial_hops=6),
    "balanced": ServingPreset(),
    "throughput": ServingPreset(max_batch=128, bucket_floor=16,
                                deadline_ms=None, linger_ms=5.0),
    "ci-quick": ServingPreset(max_batch=16, bucket_floor=4,
                              deadline_ms=500.0, linger_ms=1.0),
}
