"""DEG hyperparameters from the paper (Table 3) keyed by dataset analogue,
plus the defaults used by the offline benchmarks and the serving-side
quantized-store presets."""
from __future__ import annotations

import dataclasses

from repro.core.build import DEGParams

# paper Table 3 (d, k_ext, eps_ext, k_opt, eps_opt, i_opt)
DEG_PAPER_CONFIGS = {
    "audio": DEGParams(degree=20, k_ext=40, eps_ext=0.3, k_opt=20,
                       eps_opt=0.001, i_opt=5),
    "enron": DEGParams(degree=30, k_ext=60, eps_ext=0.3, k_opt=30,
                       eps_opt=0.001, i_opt=5),
    "sift1m": DEGParams(degree=30, k_ext=60, eps_ext=0.2, k_opt=30,
                        eps_opt=0.001, i_opt=5),
    "glove": DEGParams(degree=30, k_ext=30, eps_ext=0.2, k_opt=30,
                       eps_opt=0.001, i_opt=5),
    # CPU-scale default for the offline benchmarks in this container
    "bench-small": DEGParams(degree=16, k_ext=32, eps_ext=0.3, k_opt=16,
                             eps_opt=0.001, i_opt=5),
}


@dataclasses.dataclass(frozen=True)
class QuantPreset:
    """Serving-side store configuration (post-training; orthogonal to the
    build params above).  ``codec`` is what the beam traverses, ``rerank_k``
    how many candidates the exact second stage re-scores (0 = auto 4*k,
    ignored for the exact codec)."""

    codec: str = "float32"
    rerank_k: int = 0


# serving presets: exact baseline, the 2x half-precision store, and two
# SQ8 points trading rerank width for recall headroom (the
# benchmarks/quantization.py frontier quantifies the trade on bench-small)
QUANT_PRESETS = {
    "exact": QuantPreset(),
    "fp16": QuantPreset(codec="fp16", rerank_k=20),
    "sq8-compact": QuantPreset(codec="sq8", rerank_k=20),
    "sq8-serving": QuantPreset(codec="sq8", rerank_k=40),
}
