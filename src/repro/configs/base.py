"""Config system: one ArchSpec per assigned architecture.

An ArchSpec bundles the model config, the architecture family (which picks
the train/serve step implementations), the assigned input shapes, and a
``reduced()`` factory for CPU smoke tests.  ``skip`` documents assigned
cells that are inapplicable (e.g. long_500k on pure full-attention archs)
per the assignment rules — they are *reported*, not silently dropped.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (architecture x input-shape) dry-run cell."""
    name: str
    kind: str           # train | prefill | decode | long_decode |
                        # full_graph | minibatch | molecule |
                        # recsys_train | recsys_serve | retrieval
    dims: dict

    def __getitem__(self, k):
        return self.dims[k]


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str                       # 'lm' | 'gnn' | 'recsys'
    model: Any                        # family-specific config object
    shapes: tuple                     # tuple[ShapeCell, ...]
    reduced: Callable[[], Any]        # small config for smoke tests
    skip: dict = dataclasses.field(default_factory=dict)  # shape -> reason
    notes: str = ""
    # per-shape model overrides (e.g. EGNN d_feat differs per dataset)
    shape_overrides: dict = dataclasses.field(default_factory=dict)

    def cell(self, shape_name: str) -> ShapeCell:
        for c in self.shapes:
            if c.name == shape_name:
                return c
        raise KeyError(f"{self.name} has no shape {shape_name}")

    def model_for(self, shape_name: str):
        ov = self.shape_overrides.get(shape_name)
        if not ov:
            return self.model
        return dataclasses.replace(self.model, **ov)


LM_SHAPES = (
    ShapeCell("train_4k", "train", dict(seq_len=4096, global_batch=256)),
    ShapeCell("prefill_32k", "prefill", dict(seq_len=32768, global_batch=32)),
    ShapeCell("decode_32k", "decode", dict(seq_len=32768, global_batch=128)),
    ShapeCell("long_500k", "long_decode", dict(seq_len=524288, global_batch=1)),
)

RECSYS_SHAPES = (
    ShapeCell("train_batch", "recsys_train", dict(batch=65536)),
    ShapeCell("serve_p99", "recsys_serve", dict(batch=512)),
    ShapeCell("serve_bulk", "recsys_serve", dict(batch=262144)),
    ShapeCell("retrieval_cand", "retrieval",
              dict(batch=1, n_candidates=1_000_000)),
)

GNN_SHAPES = (
    ShapeCell("full_graph_sm", "full_graph",
              dict(n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7)),
    ShapeCell("minibatch_lg", "minibatch",
              dict(n_nodes=232_965, n_edges=114_615_892, batch_nodes=1024,
                   fanouts=(15, 10), d_feat=602, n_classes=41)),
    ShapeCell("ogb_products", "full_graph",
              dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100,
                   n_classes=47)),
    ShapeCell("molecule", "molecule",
              dict(n_nodes=30, n_edges=64, batch=128, d_feat=16,
                   n_classes=16)),
)
