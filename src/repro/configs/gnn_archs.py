"""EGNN architecture spec (arXiv:2102.09844): 4 layers, d_hidden 64, E(n)."""
from __future__ import annotations

import dataclasses

from repro.models.egnn import EGNNConfig

from .base import ArchSpec, GNN_SHAPES

EGNN = ArchSpec(
    name="egnn", family="gnn",
    model=EGNNConfig(name="egnn", n_layers=4, d_hidden=64, d_feat=1433,
                     n_classes=7),
    shapes=GNN_SHAPES,
    reduced=lambda: EGNNConfig(name="egnn-reduced", n_layers=2, d_hidden=16,
                               d_feat=24, n_classes=5),
    shape_overrides={
        "full_graph_sm": dict(d_feat=1433, n_classes=7),
        "minibatch_lg": dict(d_feat=602, n_classes=41),
        "ogb_products": dict(d_feat=100, n_classes=47),
        "molecule": dict(d_feat=16, n_classes=16),
    },
    notes=("message passing via jax.ops.segment_sum over an edge index "
           "(assignment: GNN regime = scatter message passing); "
           "minibatch_lg uses the real fanout sampler in data/graphs.py"))
