"""Crash-safe mutation journal (write-ahead log) for :class:`DEGIndex`.

Checkpoints only capture wave boundaries; any ``add``/``remove``/
``refine`` issued between ``enable_checkpoints`` ticks was simply lost
on crash.  The WAL closes that window: with ``index.enable_wal(path)``
every mutation *unit* is journaled **before** it is applied, so recovery
is::

    idx = load_index(snapshot)          # restores graph + RNG stream
    replay_wal(idx, wal_path)           # re-applies ops >= snapshot cursor

and the result is bit-identical to the uninterrupted build — the RNG
stream is part of the snapshot payload, every mutation is deterministic
given the stream (deletes derive their RNG from the vertex id, a
``refine`` under WAL resolves its seed by drawing from the persisted
stream), and the journal replays in admission order.

On-disk format (little-endian)::

    file   := header record*
    header := b"DEGWAL01"                              (8 bytes)
    record := magic:u32  seq:u64  op:u8  len:u32  crc:u32  payload[len]

``payload`` is an npz (the same container as snapshots) holding a
``__meta__`` JSON blob plus the op's arrays, and ``crc`` is the CRC-32
of the payload bytes.  Failure modes are distinguished deliberately:

* an **incomplete trailing record** (the process died mid-append) is a
  *torn tail* — expected after a crash; :func:`read_wal` truncates it
  and replay proceeds with the complete prefix;
* a **complete record whose payload fails its CRC** (bit rot, a seek
  scribble) is *corruption* — :class:`WALCorruptionError`, never
  silently skipped.

Ops journaled (one record per *unit* so mid-``add`` checkpoints see a
consistent cursor): ``add`` — one bootstrap take or one insert wave
(points array + wave_size); ``remove`` — the id list + refine_after;
``refine`` — iterations + resolved seed; ``epoch_publish`` — an epoch
boundary marker (epoch number, n, builder generation, quarantine set)
written by ``DEGIndex.publish()``.

Publish markers change recovery semantics: when the journal contains
``epoch_publish`` records past the snapshot cursor, :func:`replay_wal`
stops at the **last** one and truncates the unpublished tail — readers of
a publishing index only ever observed published epochs, so recovering to
a half-applied mutation batch beyond the last publish would materialize a
state no reader (and no result the service returned) ever saw.  Journals
without publish markers (the pre-epoch format, and non-serving builds)
replay in full, unchanged.
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
import struct
import zlib
from typing import Any, Dict, List, Optional

import numpy as np

from repro.resilience import faults as _faults

FILE_MAGIC = b"DEGWAL01"
_REC_MAGIC = 0x57414C52            # "RLAW" little-endian = b"RLAW"
_REC_HEADER = struct.Struct("<IQBII")   # magic, seq, op, len, crc
_META_KEY = "__meta__"

OPS = {"add": 1, "remove": 2, "refine": 3, "epoch_publish": 4}
_OP_NAMES = {v: k for k, v in OPS.items()}


class WALError(ValueError):
    """Structural WAL problem (bad header, op/seq mismatch on replay)."""


class WALCorruptionError(WALError):
    """A complete record's payload fails its CRC — data corruption, as
    opposed to the expected torn tail of a crash mid-append."""


@dataclasses.dataclass
class WALRecord:
    seq: int
    op: str
    meta: Dict[str, Any]
    arrays: Dict[str, np.ndarray]
    end_off: int = 0        # file offset just past this record (replay
    #                         truncates the unpublished tail at this point)


def _encode_payload(meta: Dict[str, Any],
                    arrays: Dict[str, np.ndarray]) -> bytes:
    blob = np.frombuffer(json.dumps(meta).encode("utf-8"), dtype=np.uint8)
    buf = io.BytesIO()
    np.savez(buf, **{_META_KEY: blob}, **arrays)
    return buf.getvalue()


def _decode_payload(data: bytes) -> tuple[Dict[str, Any],
                                          Dict[str, np.ndarray]]:
    with np.load(io.BytesIO(data)) as z:
        meta = json.loads(bytes(z[_META_KEY]).decode("utf-8"))
        arrays = {k: z[k] for k in z.files if k != _META_KEY}
    return meta, arrays


class WALWriter:
    """Append-only journal writer.  ``sync=True`` (the default) fsyncs
    every append — a record the caller saw ``append`` return for
    survives the process.  Attaching to an existing journal validates
    the header and appends after the last record."""

    def __init__(self, path, sync: bool = True):
        self.path = os.fspath(path)
        self.sync = sync
        exists = os.path.exists(self.path) and \
            os.path.getsize(self.path) > 0
        if exists:
            with open(self.path, "rb") as f:
                head = f.read(len(FILE_MAGIC))
            if head != FILE_MAGIC:
                raise WALError(
                    f"{self.path}: not a DEG WAL (bad file magic)")
        self._f = open(self.path, "ab")
        if not exists:
            self._f.write(FILE_MAGIC)
            self._flush()

    def append(self, seq: int, op: str,
               meta: Optional[Dict[str, Any]] = None,
               arrays: Optional[Dict[str, np.ndarray]] = None) -> None:
        payload = _encode_payload(meta or {}, arrays or {})
        _faults.fire("wal.append", seq=seq, op=op, path=self.path)
        self._f.write(_REC_HEADER.pack(
            _REC_MAGIC, seq, OPS[op], len(payload),
            zlib.crc32(payload) & 0xFFFFFFFF))
        self._f.write(payload)
        self._flush()

    def _flush(self) -> None:
        self._f.flush()
        if self.sync:
            os.fsync(self._f.fileno())

    def close(self) -> None:
        if not self._f.closed:
            self._flush()
            self._f.close()

    def __enter__(self) -> "WALWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_wal(path, *, truncate_torn: bool = True) -> List[WALRecord]:
    """Read every complete record.  A torn tail (crash mid-append) is
    truncated in place when ``truncate_torn`` so a writer can re-attach;
    a complete-but-CRC-failing record raises
    :class:`WALCorruptionError`."""
    path = os.fspath(path)
    with open(path, "rb") as f:
        data = f.read()
    if len(data) == 0:
        return []                      # crashed before the header landed
    if len(data) < len(FILE_MAGIC):
        return _torn(path, 0, truncate_torn)
    if data[: len(FILE_MAGIC)] != FILE_MAGIC:
        raise WALError(f"{path}: not a DEG WAL (bad file magic)")
    records: List[WALRecord] = []
    off = len(FILE_MAGIC)
    while off < len(data):
        if off + _REC_HEADER.size > len(data):
            return records + _torn(path, off, truncate_torn)
        magic, seq, op_code, length, crc = _REC_HEADER.unpack_from(data, off)
        if magic != _REC_MAGIC:
            raise WALCorruptionError(
                f"{path}: bad record magic at offset {off} "
                "(overwritten or corrupted journal)")
        body_start = off + _REC_HEADER.size
        if body_start + length > len(data):
            return records + _torn(path, off, truncate_torn)
        payload = data[body_start: body_start + length]
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            raise WALCorruptionError(
                f"{path}: CRC mismatch in record seq={seq} at offset "
                f"{off} — corrupted record (not a torn tail)")
        if op_code not in _OP_NAMES:
            raise WALCorruptionError(
                f"{path}: unknown op code {op_code} in record seq={seq}")
        meta, arrays = _decode_payload(payload)
        records.append(WALRecord(seq=seq, op=_OP_NAMES[op_code],
                                 meta=meta, arrays=arrays,
                                 end_off=body_start + length))
        off = body_start + length
    return records


def _torn(path: str, good_end: int, truncate: bool) -> list:
    if truncate:
        with open(path, "r+b") as f:
            f.truncate(good_end)
    return []


def replay_wal(index, path, *,
               to_last_publish: Optional[bool] = None) -> int:
    """Re-apply journaled ops past the index's snapshot cursor.

    Records with ``seq`` below ``index._wal_seq`` predate the snapshot
    and are skipped; the rest must be contiguous from the cursor (a gap
    means snapshot and journal don't belong together).  Each op runs
    through the index's *public* mutation methods with the replay guard
    set, so the exact build code paths execute — the guard verifies each
    op against its record (op kind and, for ``refine``, the re-drawn
    seed) instead of re-appending it.  Returns the number of ops
    applied.

    ``to_last_publish`` controls the crash-consistent-publish contract:
    ``None`` (auto, the default) stops at the last ``epoch_publish``
    record **iff any exists past the cursor** and truncates the journal
    tail beyond it, so recovery lands exactly on the last state a reader
    could have observed and re-enabled logging continues from a matching
    cursor.  ``False`` forces a full replay (pre-epoch behavior);
    ``True`` demands a publish marker and raises if none is found past
    the cursor.  Each publish marker is verified against the replayed
    state (``n`` must match) — a mismatch means the snapshot and journal
    diverged."""
    records = read_wal(path, truncate_torn=True)
    start_seq = index._wal_seq
    pub_idx = None
    for i, rec in enumerate(records):
        if rec.op == "epoch_publish" and rec.seq >= start_seq:
            pub_idx = i
    if to_last_publish is None:
        to_last_publish = pub_idx is not None
    elif to_last_publish and pub_idx is None:
        raise WALError(
            f"{path}: to_last_publish=True but no epoch_publish record "
            f"past cursor {start_seq}")
    stop = pub_idx if to_last_publish else len(records) - 1
    applied = 0
    for rec in records[: stop + 1] if stop is not None else records:
        if rec.seq < index._wal_seq:
            continue
        if rec.seq != index._wal_seq:
            raise WALError(
                f"{path}: journal gap — snapshot cursor is "
                f"{index._wal_seq} but next record is seq={rec.seq}; "
                "this WAL does not continue that snapshot")
        if rec.op == "epoch_publish":
            if int(rec.meta["n"]) != index.n:
                raise WALError(
                    f"{path}: epoch_publish seq={rec.seq} expects "
                    f"n={rec.meta['n']} but replay reached n={index.n} — "
                    "snapshot and journal diverged")
            index._wal_seq += 1
            applied += 1
            continue
        index._wal_replay = rec
        try:
            if rec.op == "add":
                index.add(rec.arrays["points"],
                          wave_size=int(rec.meta["wave_size"]))
            elif rec.op == "remove":
                index.remove([int(x) for x in rec.arrays["ids"]],
                             refine_after=int(rec.meta["refine_after"]))
            else:                      # "refine"
                index.refine(int(rec.meta["iterations"]),
                             seed=None if rec.meta["drew"]
                             else rec.meta["seed"])
        finally:
            index._wal_replay = None
        applied += 1
    if to_last_publish and pub_idx is not None \
            and pub_idx < len(records) - 1:
        # discard the unpublished tail: no reader ever saw those
        # mutations, and a re-enabled writer must append at the
        # recovered cursor without seq collisions
        with open(os.fspath(path), "r+b") as f:
            f.truncate(records[pub_idx].end_off)
    return applied


def recover(snapshot_path, wal_path, params: Optional[object] = None,
            capacity: Optional[int] = None,
            to_last_publish: Optional[bool] = None):
    """``load_index(snapshot) + replay_wal(wal)`` in one call.  The WAL
    (if present) is replayed and re-enabled on the returned index, so
    mutation logging continues at the recovered cursor.  When the journal
    holds ``epoch_publish`` markers, recovery lands exactly on the last
    published epoch (see :func:`replay_wal`); ``to_last_publish`` forces
    either behavior."""
    from .snapshot import load_index

    index = load_index(snapshot_path, params=params, capacity=capacity)
    if wal_path is not None and os.path.exists(wal_path):
        replay_wal(index, wal_path, to_last_publish=to_last_publish)
        index.enable_wal(wal_path)
    return index
