"""Versioned snapshot/restore of the full DEG index state.

Layering (see ARCHITECTURE.md "Persistence layering"):

* :mod:`repro.persist.format` — the self-describing npz envelope
  (``format_version``, per-section CRC-32 checksums, typed load errors);
* :mod:`repro.persist.snapshot` — one :class:`DEGIndex`: graph + vectors +
  materialized quant stores + params + RNG/build counters + medoid cache,
  plus the mid-build checkpoint contract;
* :mod:`repro.persist.sharded` — :class:`ShardedDEG`: per-shard sections
  behind a manifest, exact restore or reshard-on-restore;
* :mod:`repro.persist.wal` — the crash-safe mutation journal between
  checkpoints: CRC-framed append-only records, torn-tail truncation on
  read, ``recover(snapshot, wal)`` = bit-identical resume.

The index classes expose the ergonomic face (``DEGIndex.save/load``,
``ShardedDEG.save/load``, ``QueryEngine.from_snapshot``); everything
funnels through the functions here.
"""
from .format import (FORMAT_VERSION, SUPPORTED_VERSIONS, SnapshotChecksumError,
                     SnapshotFormatError, read_snapshot, write_snapshot)
from .sharded import load_sharded, save_sharded
from .snapshot import load_index, save_index
from .wal import (WALCorruptionError, WALError, WALRecord, WALWriter,
                  read_wal, recover, replay_wal)

__all__ = [
    "FORMAT_VERSION", "SUPPORTED_VERSIONS",
    "SnapshotFormatError", "SnapshotChecksumError",
    "read_snapshot", "write_snapshot",
    "save_index", "load_index", "save_sharded", "load_sharded",
    "WALError", "WALCorruptionError", "WALRecord", "WALWriter",
    "read_wal", "replay_wal", "recover",
]
