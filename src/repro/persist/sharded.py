"""Snapshot/restore of a :class:`repro.distributed.index.ShardedDEG`.

One npz holds a **manifest** (shard count, params, attached codec, per-shard
payloads) plus the full per-shard sections of ``persist/snapshot.py`` under
``shard{i}/...`` prefixes — each sub-DEG round-trips exactly like a single
index, including its build RNG stream (so post-restore incremental growth
of any shard stays bit-identical to a never-persisted one).

Restore semantics (ARCHITECTURE.md "Persistence layering"):

* **same shard count** — exact restore: every sub-DEG is rebuilt from its
  sections, then the stacked device arrays (adjacency / vectors / n /
  seeds) are refreshed from the restored builders — the same refresh
  ``ShardedDEG.refine`` runs after shard-local surgery — and the attached
  codec is re-encoded per shard (deterministic: same rows -> same
  calibration -> same codes).
* **different shard count** — the round-robin partition (global id ``g``
  on shard ``g % S`` at row ``g // S``) is partition-specific, so graph
  topology cannot be reused: the global vector set is reassembled in
  global-id order and the sub-DEGs are *rebuilt* at the new count.
  Vectors, params and codec survive; per-shard topology and build RNG
  streams do not (they describe partitions that no longer exist).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .format import SnapshotFormatError, read_snapshot, write_snapshot
from .snapshot import index_sections, restore_into

KIND = "sharded_deg"


def save_sharded(sharded, path) -> None:
    sections: dict = {}
    shard_payloads = []
    for i, sh in enumerate(sharded.shards):
        secs, payload = index_sections(sh)
        for sec, entries in secs.items():
            sections[f"shard{i}/{sec}"] = entries
        shard_payloads.append(payload)
    manifest = {
        "n_shards": sharded.n_shards,
        "params": dataclasses.asdict(sharded.params),
        "codec": sharded.codec,
        "shards": shard_payloads,
    }
    write_snapshot(path, KIND, sections, manifest)


def load_sharded(path, n_shards: Optional[int] = None, wave_size: int = 8):
    """Restore a ShardedDEG.  ``n_shards=None`` (or the saved count) is the
    exact restore; a different count triggers reshard-on-restore (rebuild
    from the persisted vectors — see module docstring)."""
    from repro.core.build import DEGIndex, DEGParams
    from repro.core.graph import INVALID
    from repro.distributed.index import ShardedDEG, build_sharded_deg
    import jax.numpy as jnp

    manifest, sections = read_snapshot(path, expected_kind=KIND)
    S = int(manifest["n_shards"])
    params = DEGParams(**manifest["params"])
    codec = manifest["codec"]

    shards = []
    for i, payload in enumerate(manifest["shards"]):
        prefix = f"shard{i}/"
        secs = {sec[len(prefix):]: entries
                for sec, entries in sections.items()
                if sec.startswith(prefix)}
        if "vectors" not in secs:
            raise SnapshotFormatError(
                f"{path}: manifest names shard {i} but its sections are "
                "missing")
        sh = DEGIndex(int(payload["dim"]), params,
                      capacity=int(payload["capacity"]))
        restore_into(sh, payload, secs)
        shards.append(sh)

    if n_shards is not None and int(n_shards) != S:
        # reshard-on-restore: reassemble the global id order and rebuild
        n_per = [sh.n for sh in shards]
        total = sum(n_per)
        dim = shards[0].dim
        vectors = np.zeros((total, dim), np.float32)
        for s, sh in enumerate(shards):
            vectors[s: s + S * sh.n: S] = sh.vectors[: sh.n]
        return build_sharded_deg(vectors, int(n_shards), params=params,
                                 wave_size=wave_size, codec=codec)

    # exact restore: stacked-adjacency refresh from the restored builders
    ns = max(sh.n for sh in shards)
    d = params.degree
    m = shards[0].dim
    adj = np.full((S, ns, d), INVALID, dtype=np.int32)
    vecs = np.zeros((S, ns, m), dtype=np.float32)
    seeds = np.zeros((S,), dtype=np.int32)
    n_arr = np.zeros((S,), dtype=np.int32)
    for s, sh in enumerate(shards):
        adj[s, : sh.n] = sh.builder.adjacency[: sh.n]
        vecs[s, : sh.n] = sh.vectors[: sh.n]
        n_arr[s] = sh.n
        seeds[s] = sh.medoid()
    sd = ShardedDEG(shards=shards, adjacency=jnp.asarray(adj),
                    vectors=jnp.asarray(vecs), n=jnp.asarray(n_arr),
                    seeds=jnp.asarray(seeds), params=params)
    return sd.quantize(codec) if codec != "float32" else sd
