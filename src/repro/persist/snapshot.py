"""Full-state snapshot/restore of one :class:`repro.core.build.DEGIndex`.

What a ``deg_index`` snapshot carries (sections sized by ``n``, the live
vertices — the paper's "predictable index size" claim extends to disk):

* ``graph``   — dense adjacency + weights rows of the live vertices;
* ``vectors`` — the float32 rows the index serves from;
* ``store_{codec}`` — every *materialized* quantized store: encoded rows
  plus the codec's calibration state (the sq8 per-dimension scale), so a
  restored index serves compressed searches bit-identically without
  re-encoding (re-encoding would re-calibrate and shift codes);
* ``pending`` — points buffered before the ``K_{d+1}`` bootstrap exists;
* payload — ``DEGParams``, the build RNG stream state (bit-identical
  resume), ``build_stats``, the checkpoint wave counter, and the cached
  medoid seed.

The restored index is *immediately mutable*: restore funnels through
``GraphBuilder.load`` which drops the device cache, so the first
post-restore ``device_graph()`` re-uploads and every later mutation goes
back through the normal dirty-row scatter path.  Nothing device-side is
serialized — device state is always rebuilt lazily from the host arrays.

Checkpoints are ordinary snapshots taken at wave boundaries (the only
points where the graph satisfies its invariants mid-build), written by
``DEGIndex._checkpoint_tick`` from ``_insert_wave`` / ``refine_sweep``.
Resuming = ``load_index(ckpt)`` + ``add(points[idx.n:])`` with the same
wave size: the RNG stream and wave partitioning line up, so the resumed
build is bit-identical to an uninterrupted one.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from .format import read_snapshot, write_snapshot

KIND = "deg_index"


def index_sections(index) -> tuple[dict, dict]:
    """The (sections, payload) pair for one DEGIndex — shared by the
    single-index snapshot and the per-shard sections of persist/sharded.py."""
    n = index.n
    sections: dict = {
        "vectors": {"data": np.asarray(index.vectors[:n], np.float32)},
    }
    if index.builder is not None:
        sections["graph"] = {
            "adjacency": np.asarray(index.builder.adjacency[:n], np.int32),
            "weights": np.asarray(index.builder.weights[:n], np.float32),
        }
    if index._pending:
        sections["pending"] = {"data": np.stack(index._pending).astype(
            np.float32)}
    for codec, store in index._stores.items():
        sec = {
            "data": np.asarray(store.data[:n]),
            "scale": np.asarray(store.scale, np.float32),
        }
        if store.codebooks is not None:      # pq: shared k-means codebooks
            sec["codebooks"] = np.asarray(store.codebooks, np.float32)
        sections[f"store_{codec}"] = sec
    payload = {
        "dim": int(index.dim),
        "capacity": int(index.capacity),
        "n": int(n),
        "params": dataclasses.asdict(index.params),
        "rng_state": index._rng.bit_generator.state,
        "build_stats": {k: (int(v) if isinstance(v, (int, np.integer))
                            else float(v))
                        for k, v in index.build_stats.items()},
        "wave_counter": int(index._wave_counter),
        "medoid": None if index._medoid is None else int(index._medoid),
        "stores": sorted(index._stores),
        "has_builder": index.builder is not None,
        # WAL cursor: ops with seq >= wal_seq postdate this snapshot and
        # are re-applied by persist.wal.replay_wal on recovery
        "wal_seq": int(getattr(index, "_wal_seq", 0)),
    }
    return sections, payload


def restore_into(index, payload: dict, sections: dict) -> None:
    """Rebuild ``index``'s state (graph, vectors, stores, counters) from a
    verified (payload, sections) pair.  ``index`` must be freshly
    constructed with the payload's dim/params/capacity."""
    from repro.core.graph import GraphBuilder
    from repro.quant.store import VectorStore

    n = int(payload["n"])
    vec = sections["vectors"]["data"]
    if n:
        index.vectors[:n] = vec
        index._put_rows(vec, 0)
    if payload["has_builder"]:
        b = GraphBuilder(index.capacity, index.params.degree)
        g = sections["graph"]
        b.load(g["adjacency"], g["weights"], n)
        index.builder = b
    index._pending = ([row.copy() for row in sections["pending"]["data"]]
                      if "pending" in sections else [])
    for codec in payload["stores"]:
        s = sections[f"store_{codec}"]
        # row width comes from the section, not index.dim — pq rows hold
        # m_sub code bytes, not dim elements
        data = np.zeros((index.capacity,) + s["data"].shape[1:],
                        dtype=s["data"].dtype)
        data[:n] = s["data"]
        books = (jnp.asarray(s["codebooks"]) if "codebooks" in s else None)
        index._stores[codec] = VectorStore(
            data=jnp.asarray(data), scale=jnp.asarray(s["scale"]),
            codec=codec, codebooks=books)
    rng = np.random.default_rng()
    rng.bit_generator.state = payload["rng_state"]
    index._rng = rng
    index.build_stats = dict(payload["build_stats"])
    index._wave_counter = int(payload["wave_counter"])
    index._medoid = payload["medoid"]
    index._wal_seq = int(payload.get("wal_seq", 0))   # pre-WAL snapshots: 0


def save_index(index, path) -> None:
    """Serialize the complete index state to one versioned npz snapshot."""
    sections, payload = index_sections(index)
    write_snapshot(path, KIND, sections, payload)


def load_index(path, params: Optional[object] = None,
               capacity: Optional[int] = None):
    """Restore a :class:`DEGIndex` from ``path``.

    ``params`` overrides the persisted *search* knobs (a restored index may
    serve a different engine config); the structural fields (``degree``,
    ``metric``) must match the snapshot — a mismatched graph would be
    silently wrong, so it raises.  ``capacity`` may only grow the index.
    """
    from repro.core.build import DEGIndex, DEGParams

    payload, sections = read_snapshot(path, expected_kind=KIND)
    saved = DEGParams(**payload["params"])
    if params is None:
        params = saved
    elif (params.degree != saved.degree or params.metric != saved.metric):
        raise ValueError(
            f"params override (degree={params.degree}, "
            f"metric={params.metric!r}) is structurally incompatible with "
            f"the snapshot (degree={saved.degree}, metric={saved.metric!r})")
    cap = int(payload["capacity"])
    if capacity is not None:
        cap = max(cap, int(capacity))
    index = DEGIndex(int(payload["dim"]), params, capacity=cap)
    restore_into(index, payload, sections)
    return index
