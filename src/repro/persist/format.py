"""The on-disk snapshot container (ARCHITECTURE.md "Persistence layering").

One ``.npz`` file holds a *versioned, self-describing* snapshot:

* ``__meta__`` — a UTF-8 JSON blob (stored as a uint8 array, the only way
  to put structured metadata inside an npz without pickling) carrying
  ``format_version``, the snapshot ``kind``, a free-form ``payload`` dict,
  and the **section table**: for every stored array its dtype, shape and
  CRC-32 checksum.
* ``{section}/{name}`` — the arrays themselves, grouped into named
  sections ("graph", "vectors", "store_sq8", "shard0/graph", ...).

Readers verify, in order: the meta blob parses, ``format_version`` is one
we understand (unknown versions are *rejected*, never guessed at), every
array named by the section table is present with the recorded dtype/shape,
and its bytes hash to the recorded checksum.  Failures raise typed errors
(:class:`SnapshotFormatError` / :class:`SnapshotChecksumError`) with the
offending section in the message — a truncated or bit-flipped snapshot
fails loudly at load, not as a corrupt search three layers up.

This module knows nothing about DEG semantics: ``persist/snapshot.py``
(single index) and ``persist/sharded.py`` (manifest + per-shard sections)
decide *what* goes into the sections; this layer owns the envelope.
"""
from __future__ import annotations

import json
import os
import zlib

import numpy as np

#: the one format this code writes; readers accept exactly the versions in
#: SUPPORTED_VERSIONS and reject everything else with a clear error.
FORMAT_VERSION = 1
SUPPORTED_VERSIONS = (1,)

_META_KEY = "__meta__"


class SnapshotFormatError(ValueError):
    """Structurally unusable snapshot (bad envelope, unknown version,
    missing section, dtype/shape mismatch)."""


class SnapshotChecksumError(SnapshotFormatError):
    """A section's bytes do not hash to the recorded checksum."""


def _crc32(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def write_snapshot(path, kind: str, sections: dict, payload: dict) -> None:
    """Write ``sections`` ({section: {name: ndarray}}) + ``payload`` (any
    JSON-able dict) to ``path`` as one compressed npz."""
    table: dict = {}
    arrays: dict = {}
    for sec, entries in sections.items():
        table[sec] = {}
        for name, arr in entries.items():
            arr = np.ascontiguousarray(arr)
            key = f"{sec}/{name}"
            arrays[key] = arr
            table[sec][name] = {
                "dtype": arr.dtype.str,
                "shape": list(arr.shape),
                "crc32": _crc32(arr),
            }
    meta = {
        "format_version": FORMAT_VERSION,
        "kind": kind,
        "sections": table,
        "payload": payload,
    }
    blob = np.frombuffer(json.dumps(meta).encode("utf-8"), dtype=np.uint8)
    # tmp + fsync + atomic rename: checkpoints overwrite their
    # predecessor, and a crash mid-write (or a power cut with the page
    # cache still dirty) must not destroy the only resumable snapshot
    # (the same commit protocol as train/checkpoint.py).  The directory
    # fsync makes the rename itself durable.
    path = os.fspath(path)
    tmp = f"{path}.tmp-{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            np.savez_compressed(f, **{_META_KEY: blob}, **arrays)
            f.flush()
            os.fsync(f.fileno())
        from repro.resilience import faults as _faults

        _faults.fire("snapshot.mid_save", path=path, tmp=tmp)
        os.replace(tmp, path)
        _fsync_dir(os.path.dirname(path) or ".")
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def _fsync_dir(dirpath: str) -> None:
    fd = os.open(dirpath, os.O_RDONLY)
    try:
        os.fsync(fd)
    except OSError:        # some filesystems refuse directory fsync
        pass
    finally:
        os.close(fd)


def read_snapshot(path, expected_kind=None) -> tuple[dict, dict]:
    """Read + verify a snapshot.  Returns ``(payload, sections)`` where
    ``sections`` maps {section: {name: ndarray}}."""
    with np.load(path) as z:
        if _META_KEY not in z:
            raise SnapshotFormatError(
                f"{path}: not a repro snapshot (no {_META_KEY} entry); "
                "was this written by persist.write_snapshot?")
        try:
            meta = json.loads(bytes(z[_META_KEY]).decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise SnapshotFormatError(f"{path}: corrupt meta blob: {e}")
        version = meta.get("format_version")
        if version not in SUPPORTED_VERSIONS:
            raise SnapshotFormatError(
                f"{path}: unknown snapshot format_version {version!r}; this "
                f"build reads versions {list(SUPPORTED_VERSIONS)}. Re-save "
                "the index with a matching build or upgrade this one.")
        if expected_kind is not None and meta.get("kind") != expected_kind:
            raise SnapshotFormatError(
                f"{path}: snapshot kind {meta.get('kind')!r}, "
                f"expected {expected_kind!r}")
        sections: dict = {}
        for sec, entries in meta["sections"].items():
            sections[sec] = {}
            for name, info in entries.items():
                key = f"{sec}/{name}"
                if key not in z:
                    raise SnapshotFormatError(
                        f"{path}: section array {key!r} named by the meta "
                        "table is missing from the archive")
                arr = z[key]
                if arr.dtype.str != info["dtype"] \
                        or list(arr.shape) != info["shape"]:
                    raise SnapshotFormatError(
                        f"{path}: {key!r} is {arr.dtype.str}{arr.shape}, "
                        f"meta table says {info['dtype']}"
                        f"{tuple(info['shape'])}")
                if _crc32(arr) != info["crc32"]:
                    raise SnapshotChecksumError(
                        f"{path}: checksum mismatch in section {key!r} "
                        "(truncated or corrupted snapshot)")
                sections[sec][name] = arr
    return meta["payload"], sections
