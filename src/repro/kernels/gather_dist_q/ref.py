"""Pure-jnp oracle for the gather+dequant+distance kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("squared",))
def gather_dist_q_ref(codes: jax.Array, scale: jax.Array, ids: jax.Array,
                      queries: jax.Array, squared: bool = False):
    """codes (N, m) int8, scale (m,) f32, ids (B, d), queries (B, m)."""
    g = codes[ids].astype(jnp.float32) * scale[None, None, :]   # (B, d, m)
    diff = g - queries.astype(jnp.float32)[:, None, :]
    d2 = jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0)
    return d2 if squared else jnp.sqrt(d2)
