"""Fused neighbor-gather + dequantize + distance Pallas TPU kernel.

The quantized sibling of ``kernels/gather_dist``: one hop of the DEG range
search over an SQ8 store needs ``dist(q_b, deq(codes[ids[b, j]]))`` for
``j < d``.  A naive XLA lowering gathers the int8 rows, materializes the
dequantized ``(B, d, m)`` float32 tensor in HBM (8x the code bytes!), then
reduces.  Here each int8 row is DMA'd HBM->VMEM directly by the BlockSpec
index_map using the *scalar-prefetched* ``ids`` and dequantized in VMEM —
the float32 intermediate never exists outside the register file, so the HBM
traffic per hop is the ``d * m`` code bytes plus the query row: a ~4x cut of
the term that dominates the search roofline.

grid = (B, d): step (i, j) pulls code row ids[i, j], the shared per-dimension
scale row, and query row i into VMEM, computes one dequantized distance, and
stores it at out[i, j].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, codes_ref, scale_ref, q_ref, out_ref, *, squared: bool):
    j = pl.program_id(1)
    row = codes_ref[0, :].astype(jnp.float32) * scale_ref[0, :]
    diff = row - q_ref[0, :].astype(jnp.float32)
    d2 = jnp.maximum(jnp.sum(diff * diff), 0.0)
    dist = d2 if squared else jnp.sqrt(d2)
    out_ref[0, pl.dslice(j, 1)] = dist[None]


@functools.partial(jax.jit, static_argnames=("squared", "interpret"))
def gather_dist_q_pallas(codes: jax.Array, scale: jax.Array, ids: jax.Array,
                         queries: jax.Array, *, squared: bool = False,
                         interpret: bool = True):
    """codes (N, m) int8, scale (1, m) f32, ids (B, d) int32 in [0, N),
    queries (B, m) f32 -> (B, d) f32 distances."""
    N, m = codes.shape
    B, d = ids.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, d),
        in_specs=[
            pl.BlockSpec((1, m), lambda i, j, ids: (ids[i, j], 0)),
            pl.BlockSpec((1, m), lambda i, j, ids: (0, 0)),
            pl.BlockSpec((1, m), lambda i, j, ids: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, j, ids: (i, 0)),
    )
    kernel = functools.partial(_kernel, squared=squared)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, d), jnp.float32),
        interpret=interpret,
    )(ids, codes, scale, queries)
