"""Public wrapper for the gather+dequant+distance kernel: clamps
out-of-range ids (INVALID = -1 slots are masked by the caller), pads the
feature dim to the 128-lane boundary (zero code x zero scale x zero query
padding contributes nothing to the distance)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .gather_dist_q import gather_dist_q_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("squared", "interpret"))
def gather_dist_q(codes: jax.Array, scale: jax.Array, ids: jax.Array,
                  queries: jax.Array, *, squared: bool = False,
                  interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    N, m = codes.shape
    pad_m = (-m) % 128
    c = jnp.pad(codes.astype(jnp.int8), ((0, 0), (0, pad_m)))
    s = jnp.pad(scale.astype(jnp.float32), (0, pad_m))[None, :]
    q = jnp.pad(queries.astype(jnp.float32), ((0, 0), (0, pad_m)))
    safe_ids = jnp.clip(ids, 0, N - 1).astype(jnp.int32)
    return gather_dist_q_pallas(c, s, safe_ids, q, squared=squared,
                                interpret=interpret)
