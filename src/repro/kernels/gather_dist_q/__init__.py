from .ops import gather_dist_q
from .ref import gather_dist_q_ref

__all__ = ["gather_dist_q", "gather_dist_q_ref"]
