"""Public dispatch for the fused multi-expansion hop.

Backends:

* ``"jnp"``    — the pure-jnp oracle (``ref.fused_hop_ref``); also the
                 documentation of the hop semantics.
* ``"pallas"`` — the fused kernel (interpret mode off-TPU).  Clamps
                 out-of-range selection ids (INVALID lanes carry an
                 explicit activity flag into SMEM), pads the feature dim
                 to the 128-lane boundary (zero row x zero query padding
                 contributes nothing to the distance), and normalizes the
                 scalar operands to the (B, 1)/(1,) shapes the kernel's
                 BlockSpecs expect.

A ``visited=None`` call runs without the filter: the kernel receives a
one-slot all-INVALID dummy table whose whole-row compare never hits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.graph import INVALID

from .fused_hop import fused_hop_pallas
from .ref import fused_hop_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit,
                   static_argnames=("squared", "backend", "interpret"))
def fused_hop(adjacency: jax.Array, vectors: jax.Array, sel_ids: jax.Array,
              queries: jax.Array, dmax: jax.Array,
              visited: jax.Array | None = None, *, n_valid: jax.Array,
              squared: bool = False, backend: str = "jnp",
              interpret: bool | None = None):
    """One multi-expansion hop for B lanes — see ``ref.fused_hop_ref`` for
    the argument/return contract (both backends are exact-parity)."""
    if backend == "jnp":
        return fused_hop_ref(adjacency, vectors, sel_ids, queries,
                             jnp.asarray(dmax, jnp.float32), visited,
                             n_valid=n_valid, squared=squared)
    if backend != "pallas":
        raise ValueError(f"unknown fused_hop backend {backend!r}")
    if interpret is None:
        interpret = _default_interpret()
    N, d = adjacency.shape
    B, E = sel_ids.shape
    m = vectors.shape[1]
    pad_m = (-m) % 128
    # bf16 rows stay bf16 on the HBM->VMEM DMA path (same policy as
    # gather_dist); the kernel accumulates distances in f32 regardless.
    # At aligned production dims (m % 128 == 0, f32/bf16 store) both
    # branches below are no-ops, so the store is passed through untouched
    # — only an unaligned store pays a loop-invariant pad+copy per jitted
    # search program.
    dt = vectors.dtype if vectors.dtype == jnp.bfloat16 else jnp.float32
    v = vectors if vectors.dtype == dt else vectors.astype(dt)
    q = queries if queries.dtype == dt else queries.astype(dt)
    if pad_m:
        v = jnp.pad(v, ((0, 0), (0, pad_m)))
        q = jnp.pad(q, ((0, 0), (0, pad_m)))
    act = (sel_ids != INVALID).astype(jnp.int32)
    safe_sel = jnp.clip(sel_ids, 0, N - 1).astype(jnp.int32)
    vis = (visited if visited is not None
           else jnp.full((B, 1), INVALID, jnp.int32))
    cand_ids, cand_d, nbr_ids, evals = fused_hop_pallas(
        adjacency, v, safe_sel, act, q,
        jnp.asarray(dmax, jnp.float32).reshape(B, 1), vis,
        jnp.asarray(n_valid, jnp.int32).reshape(1,),
        squared=squared, interpret=interpret)
    return cand_ids, cand_d, nbr_ids, evals[:, 0]
