"""Pure-jnp oracle for the fused multi-expansion hop kernel.

This is the batch formulation of exactly what the Pallas kernel computes
sequentially per lane, and (by construction) exactly what the beam
engine's jnp hop path computes when the visited filter is active — so the
kernel, this oracle, and the engine's composed path are mutually
bit-identical.  See ``fused_hop.py`` for the op-by-op correspondence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.graph import INVALID
from repro.core.visited import DEFAULT_PROBES, contains, first_occurrence_mask


@functools.partial(jax.jit, static_argnames=("squared", "n_probes"))
def fused_hop_ref(adjacency: jax.Array, vectors: jax.Array,
                  sel_ids: jax.Array, queries: jax.Array, dmax: jax.Array,
                  visited: jax.Array | None = None, *, n_valid: jax.Array,
                  squared: bool = False, n_probes: int = DEFAULT_PROBES):
    """One multi-expansion hop for B lanes.

    Args:
      adjacency: (N, d) int32, INVALID-padded rows.
      vectors: (Nv, m) float — the store rows.
      sel_ids: (B, E) int32 — vertices to expand (INVALID = inactive lane
        slot; nothing of that slot is gathered or scored).
      queries: (B, m) float.
      dmax: (B,) float32 — keep threshold (candidates with dist > dmax are
        dropped; the engine passes ``radius * (1 + eps)``).
      visited: (B, V) int32 visited table or None (no filtering).
      n_valid: () int32 — neighbors >= n_valid are invalid.
    Returns:
      cand_ids (B, E*d) int32 — kept candidates *compacted* to the front in
        discovery order (e-major, j-minor), INVALID-padded;
      cand_dists (B, E*d) float32 — matching distances, inf-padded;
      nbr_ids (B, E*d) int32 — the raw gathered neighbor ids, valid-masked
        (for the caller's visited-set insertion);
      evals (B,) int32 — distance evaluations performed (post-filter).
    """
    B, E = sel_ids.shape
    d = adjacency.shape[1]
    Ed = E * d
    act = sel_ids != INVALID
    nbrs = adjacency[jnp.where(act, sel_ids, 0)]             # (B, E, d)
    valid = act[:, :, None] & (nbrs != INVALID) & (nbrs < n_valid)
    flat = nbrs.reshape(B, Ed)
    vmask = valid.reshape(B, Ed)

    # first occurrence among valid ids (two expanded vertices may share a
    # neighbor) — the same shared mask the engine's jnp hop applies
    scored = vmask & first_occurrence_mask(flat, vmask)
    if visited is not None:
        scored &= ~contains(visited, flat, n_probes=n_probes)

    safe = jnp.where(scored, flat, 0)
    g = vectors[safe].astype(jnp.float32)                    # (B, Ed, m)
    diff = g - queries.astype(jnp.float32)[:, None, :]
    d2 = jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0)
    nd = d2 if squared else jnp.sqrt(d2)
    nd = jnp.where(scored, nd, jnp.inf)
    keep = scored & (nd <= dmax[:, None])

    # stable compaction: kept candidates first, discovery order preserved
    order = jnp.argsort(~keep, axis=1, stable=True)
    cand_ids = jnp.take_along_axis(jnp.where(keep, flat, INVALID), order,
                                   axis=1)
    cand_d = jnp.take_along_axis(jnp.where(keep, nd, jnp.inf), order, axis=1)
    nbr_out = jnp.where(vmask, flat, INVALID)
    return (cand_ids, cand_d, nbr_out,
            scored.sum(axis=1).astype(jnp.int32))
