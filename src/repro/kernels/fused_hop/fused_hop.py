"""Fused multi-expansion hop Pallas TPU kernel.

One hop of the multi-expansion beam engine (``core/beam.py``,
``expand_width=E``) is, per query lane: gather the adjacency rows of the E
selected vertices, drop neighbors already visited, gather the surviving
vector rows, score them against the query, and keep those inside the range
radius.  In plain XLA that is four HBM round-trips per hop (adjacency
gather, visited-table gather, ``(B, E*d, m)`` vector gather, compare) with
the gathered tensors materialized between them.  Here the whole hop body
runs in one kernel:

* **adjacency-row gather** — the selected vertex ids are *scalar-prefetched*
  (SMEM before the grid starts), so the BlockSpec index_map DMAs row
  ``sel[b, e]`` HBM->VMEM directly (the idiomatic Pallas TPU gather);
* **visited filter** — the lane's (1, V) visited table sits in VMEM; a
  neighbor is dropped on a whole-row compare (an id can only ever be stored
  at one of its own probe slots — see ``core/visited.py`` — so row
  membership equals probe membership, branch-free);
* **vector gather** — ``vectors`` stays in HBM (``ANY`` memory space) and
  each *surviving* row (the DMA is gated on the filter verdict, so
  filtered neighbors cost no HBM traffic or flops) is pulled by a manual
  ``make_async_copy`` whose source index is the neighbor id just read
  from the adjacency row in VMEM — the data-dependent gather BlockSpecs
  cannot express;
* **distance + compaction** — the distance folds into a keep test against
  the per-lane radius bound, and kept candidates are written through a
  monotone SMEM write pointer: the output block is *compacted* (kept
  candidates first, discovery order), so the beam merge consumes a dense
  prefix.  Compaction is stable, which makes the merged beam bit-identical
  to merging the uncompacted candidate block (rank ties preserve relative
  order).

grid = (B, E): step (b, e) walks the d neighbors of selection e, revisiting
the lane-wide output block (index_map pins it to (b, 0)) so the write
pointer and eval counter accumulate across the E selections of a lane; a
lane-private ``seen`` scratch row dedups neighbors shared by two selections
of the *same hop* (matching the oracle's first-occurrence mask).

Outputs: compacted (cand_ids, cand_dists), the valid-masked raw neighbor
ids (for the caller's visited-set insertion), and the per-lane count of
distance evaluations.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_INVALID = -1


def _kernel(sel_ref, act_ref, nv_ref, adj_ref, vis_ref, q_ref, dmax_ref,
            vec_hbm, cid_ref, cd_ref, nbr_ref, ev_ref,
            seen_ref, row_ref, ptr_ref, sem, *, squared: bool):
    b = pl.program_id(0)
    e = pl.program_id(1)
    d = adj_ref.shape[1]
    n_rows = vec_hbm.shape[0]

    @pl.when(e == 0)
    def _reset():
        cid_ref[...] = jnp.full(cid_ref.shape, _INVALID, jnp.int32)
        cd_ref[...] = jnp.full(cd_ref.shape, jnp.inf, jnp.float32)
        seen_ref[...] = jnp.full(seen_ref.shape, _INVALID, jnp.int32)
        ev_ref[0, 0] = jnp.int32(0)
        ptr_ref[0] = jnp.int32(0)

    act = act_ref[b, e] != 0
    nv = nv_ref[0]
    dmax = dmax_ref[0, 0]
    q = q_ref[0, :].astype(jnp.float32)

    def body(j, _):
        nid = adj_ref[0, j]
        valid = act & (nid != _INVALID) & (nid < nv)
        nbr_ref[0, pl.dslice(j, 1)] = jnp.where(valid, nid, _INVALID)[None]
        dup = (seen_ref[0, :] == nid).any()
        vis = (vis_ref[0, :] == nid).any()
        scored = valid & ~dup & ~vis

        # only surviving rows are DMA'd and scored — this gate is where
        # the visited filter actually saves HBM traffic and flops
        @pl.when(scored)
        def _score():
            cp = pltpu.make_async_copy(
                vec_hbm.at[pl.dslice(jnp.clip(nid, 0, n_rows - 1), 1), :],
                row_ref, sem)
            cp.start()
            cp.wait()
            diff = row_ref[0, :].astype(jnp.float32) - q
            d2 = jnp.maximum(jnp.sum(diff * diff), 0.0)
            dist = d2 if squared else jnp.sqrt(d2)
            seen_ref[0, pl.dslice(e * d + j, 1)] = nid[None]
            ev_ref[0, 0] = ev_ref[0, 0] + 1
            keep = dist <= dmax
            ptr = ptr_ref[0]

            @pl.when(keep)
            def _write():
                cid_ref[0, pl.dslice(ptr, 1)] = nid[None]
                cd_ref[0, pl.dslice(ptr, 1)] = dist[None]

            ptr_ref[0] = ptr + keep.astype(jnp.int32)

        return 0

    jax.lax.fori_loop(0, d, body, 0)


@functools.partial(jax.jit, static_argnames=("squared", "interpret"))
def fused_hop_pallas(adjacency: jax.Array, vectors: jax.Array,
                     sel_ids: jax.Array, act: jax.Array, queries: jax.Array,
                     dmax: jax.Array, visited: jax.Array,
                     n_valid: jax.Array, *, squared: bool = False,
                     interpret: bool = True):
    """adjacency (N, d) i32, vectors (Nv, m) float, sel_ids (B, E) i32 in
    [0, N), act (B, E) i32 flags, queries (B, m) float, dmax (B, 1) f32,
    visited (B, V) i32, n_valid (1,) i32
    -> (cand_ids (B, E*d) i32, cand_dists (B, E*d) f32,
        nbr_ids (B, E*d) i32, evals (B, 1) i32)."""
    N, d = adjacency.shape
    B, E = sel_ids.shape
    m = vectors.shape[1]
    V = visited.shape[1]
    Ed = E * d
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, E),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, e, sel, act, nv: (sel[b, e], 0)),
            pl.BlockSpec((1, V), lambda b, e, sel, act, nv: (b, 0)),
            pl.BlockSpec((1, m), lambda b, e, sel, act, nv: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, e, sel, act, nv: (b, 0)),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
        ],
        out_specs=[
            pl.BlockSpec((1, Ed), lambda b, e, sel, act, nv: (b, 0)),
            pl.BlockSpec((1, Ed), lambda b, e, sel, act, nv: (b, 0)),
            pl.BlockSpec((1, d), lambda b, e, sel, act, nv: (b, e)),
            pl.BlockSpec((1, 1), lambda b, e, sel, act, nv: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, Ed), jnp.int32),      # seen: scored ids this hop
            pltpu.VMEM((1, m), vectors.dtype),   # DMA landing row
            pltpu.SMEM((1,), jnp.int32),         # compaction write pointer
            pltpu.SemaphoreType.DMA(()),
        ],
    )
    kernel = functools.partial(_kernel, squared=squared)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Ed), jnp.int32),
            jax.ShapeDtypeStruct((B, Ed), jnp.float32),
            jax.ShapeDtypeStruct((B, Ed), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        interpret=interpret,
    )(sel_ids, act, n_valid, adjacency, visited, queries, dmax, vectors)
