from .ops import fused_hop
from .ref import fused_hop_ref

__all__ = ["fused_hop", "fused_hop_ref"]
