"""Fused neighbor-gather + distance + MRNG-occlusion Pallas TPU kernel.

The inner decision of DEG construction (Alg. 2/3) and continuous refinement
(Alg. 5) is the *lune test*: a candidate edge (v, c) at distance ``delta`` is
occluded by a vertex ``u`` adjacent to ``c`` iff

    delta > max(d(v, u), w(c, u))

i.e. ``u`` lies inside the lune of the candidate edge.  Answering it for a
batch of candidates needs, per candidate, the distances from the query
vertex to every neighbor of the candidate — a gather of ``d`` vector rows
followed by ``d`` distance reductions and a compare.  A naive XLA lowering
materializes the gathered ``(B, K, d, m)`` float32 tensor in HBM before
reducing; here each neighbor row is DMA'd HBM->VMEM directly by the
BlockSpec index_map using the *scalar-prefetched* neighbor ids, reduced to
a distance, and folded into the occlusion compare in one pass — the gathered
rows never exist outside VMEM.

grid = (B, K, d): step (b, i, j) pulls vector row ``nbr_ids[b, i, j]`` and
query row ``b`` into VMEM, computes ``dist = delta(q_b, row)`` and
``occl = cand_d[b, i] > max(dist, nbr_w[b, i, j])``, and stores both at
``[b, i, j]``.  Both the extension path (candidates = search results,
query = the new vertex) and the refinement path (candidates = a vertex's
own neighbors, cand_d = its edge weights) consume the same program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, vec_ref, q_ref, cd_ref, w_ref, dist_ref, occ_ref, *,
            squared: bool):
    j = pl.program_id(2)
    row = vec_ref[0, :].astype(jnp.float32)
    diff = row - q_ref[0, :].astype(jnp.float32)
    d2 = jnp.maximum(jnp.sum(diff * diff), 0.0)
    dist = d2 if squared else jnp.sqrt(d2)
    w = w_ref[0, 0, pl.dslice(j, 1)][0]
    occ = (cd_ref[0, 0] > jnp.maximum(dist, w)).astype(jnp.float32)
    dist_ref[0, 0, pl.dslice(j, 1)] = dist[None]
    occ_ref[0, 0, pl.dslice(j, 1)] = occ[None]


@functools.partial(jax.jit, static_argnames=("squared", "interpret"))
def mrng_occlusion_pallas(vectors: jax.Array, nbr_ids: jax.Array,
                          queries: jax.Array, cand_dists: jax.Array,
                          nbr_weights: jax.Array, *, squared: bool = False,
                          interpret: bool = True):
    """vectors (N, m) f32, nbr_ids (B, K, d) int32 in [0, N), queries (B, m)
    f32, cand_dists (B, K) f32, nbr_weights (B, K, d) f32
    -> (nbr_dist (B, K, d) f32, occl (B, K, d) f32 in {0, 1})."""
    N, m = vectors.shape
    B, K, d = nbr_ids.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K, d),
        in_specs=[
            pl.BlockSpec((1, m), lambda b, i, j, ids: (ids[b, i, j], 0)),
            pl.BlockSpec((1, m), lambda b, i, j, ids: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, i, j, ids: (b, i)),
            pl.BlockSpec((1, 1, d), lambda b, i, j, ids: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, d), lambda b, i, j, ids: (b, i, 0)),
            pl.BlockSpec((1, 1, d), lambda b, i, j, ids: (b, i, 0)),
        ],
    )
    kernel = functools.partial(_kernel, squared=squared)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, K, d), jnp.float32),
                   jax.ShapeDtypeStruct((B, K, d), jnp.float32)],
        interpret=interpret,
    )(nbr_ids, vectors, queries, cand_dists, nbr_weights)
