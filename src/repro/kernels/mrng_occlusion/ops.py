"""Public dispatch for the fused gather + distance + occlusion kernel.

Backends:

* ``"jnp"``    — the pure-jnp oracle (any metric); the default inside the
                 jitted construction programs off-TPU.
* ``"pallas"`` — the Pallas kernel (l2 / sqeuclidean; interpret mode
                 off-TPU).  Clamps out-of-range ids (INVALID = -1 slots are
                 masked by the caller) and pads the feature dim to the
                 128-lane boundary (zero vector x zero query padding
                 contributes nothing to the distance).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .mrng_occlusion import mrng_occlusion_pallas
from .ref import mrng_occlusion_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit,
                   static_argnames=("metric", "backend", "interpret"))
def mrng_occlusion(vectors: jax.Array, nbr_ids: jax.Array,
                   queries: jax.Array, cand_dists: jax.Array,
                   nbr_weights: jax.Array, *, metric: str = "l2",
                   backend: str = "jnp", interpret: bool | None = None):
    """-> (nbr_dist (B, K, d) f32, occl (B, K, d) bool).  ``occl[b, i, j]``
    answers: does neighbor j of candidate i occlude the candidate edge
    (lune test, Alg. 2)?  Callers mask INVALID id lanes themselves."""
    if backend == "jnp" or metric not in ("l2", "sqeuclidean"):
        return mrng_occlusion_ref(vectors, nbr_ids, queries, cand_dists,
                                  nbr_weights, metric=metric)
    if backend != "pallas":
        raise ValueError(f"unknown mrng_occlusion backend {backend!r}")
    if interpret is None:
        interpret = _default_interpret()
    N, m = vectors.shape
    pad_m = (-m) % 128
    v = jnp.pad(vectors.astype(jnp.float32), ((0, 0), (0, pad_m)))
    q = jnp.pad(queries.astype(jnp.float32), ((0, 0), (0, pad_m)))
    safe_ids = jnp.clip(nbr_ids, 0, N - 1).astype(jnp.int32)
    nd, occ = mrng_occlusion_pallas(
        v, safe_ids, q, cand_dists.astype(jnp.float32),
        nbr_weights.astype(jnp.float32),
        squared=(metric == "sqeuclidean"), interpret=interpret)
    return nd, occ.astype(bool)
