"""Pure-jnp oracle for the gather + distance + MRNG-occlusion kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("metric",))
def mrng_occlusion_ref(vectors: jax.Array, nbr_ids: jax.Array,
                       queries: jax.Array, cand_dists: jax.Array,
                       nbr_weights: jax.Array, *, metric: str = "l2"):
    """vectors (N, m), nbr_ids (B, K, d), queries (B, m), cand_dists (B, K),
    nbr_weights (B, K, d) -> (nbr_dist (B, K, d), occl (B, K, d) bool)."""
    from repro.core.distances import get_metric

    g = vectors[nbr_ids].astype(jnp.float32)               # (B, K, d, m)
    nd = get_metric(metric).pair(
        queries.astype(jnp.float32)[:, None, None, :], g)
    occ = cand_dists[:, :, None] > jnp.maximum(nd, nbr_weights)
    return nd, occ
