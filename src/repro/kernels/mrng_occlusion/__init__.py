from .ops import mrng_occlusion
from .ref import mrng_occlusion_ref

__all__ = ["mrng_occlusion", "mrng_occlusion_ref"]
