"""Public wrapper for the embedding-bag kernel: INVALID (-1) ids get weight
zero (ragged bags are padded to the fixed field count), embedding dim padded
to the 128-lane boundary."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .bag_lookup import bag_lookup_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def bag_lookup(table: jax.Array, ids: jax.Array,
               weights: jax.Array | None = None, *,
               interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    V, E = table.shape
    B, F = ids.shape
    if weights is None:
        weights = jnp.ones((B, F), jnp.float32)
    weights = jnp.where(ids < 0, 0.0, weights.astype(jnp.float32))
    safe_ids = jnp.clip(ids, 0, V - 1).astype(jnp.int32)
    pad_e = (-E) % 128
    t = jnp.pad(table.astype(jnp.float32), ((0, 0), (0, pad_e)))
    out = bag_lookup_pallas(t, safe_ids, weights, interpret=interpret)
    return out[:, :E]
