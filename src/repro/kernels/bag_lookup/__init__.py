from .ops import bag_lookup
from .ref import bag_lookup_ref

__all__ = ["bag_lookup", "bag_lookup_ref"]
