"""EmbeddingBag (gather-reduce) Pallas TPU kernel.

JAX has no native ``nn.EmbeddingBag``; the recsys substrate implements it as
``take + segment_sum`` (see repro.models.embedding_bag).  This kernel is the
fused TPU version for the *fixed-fields* layout used by DLRM/DCN-style
models: ``out[b] = sum_f w[b,f] * table[idx[b,f]]``.

Like gather_dist, the table rows are DMA'd HBM->VMEM via a scalar-prefetched
index map; the accumulation lives in the revisited output block (grid is
(B, F) with F innermost, so out[i] stays resident in VMEM across the F
steps — one init at f==0, one accumulate per field, no HBM round-trips).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, tab_ref, w_ref, out_ref):
    f = pl.program_id(1)
    w = w_ref[0, pl.dslice(f, 1)].astype(jnp.float32)    # (1,)
    row = tab_ref[0, :].astype(jnp.float32) * w          # (E,)

    @pl.when(f == 0)
    def _init():
        out_ref[0, :] = row

    @pl.when(f != 0)
    def _acc():
        out_ref[0, :] = out_ref[0, :] + row


@functools.partial(jax.jit, static_argnames=("interpret",))
def bag_lookup_pallas(table: jax.Array, ids: jax.Array, weights: jax.Array, *,
                      interpret: bool = True):
    """table (V, E), ids (B, F) int32, weights (B, F) -> (B, E) weighted sum."""
    V, E = table.shape
    B, F = ids.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, F),
        in_specs=[
            pl.BlockSpec((1, E), lambda i, f, ids: (ids[i, f], 0)),
            pl.BlockSpec((1, F), lambda i, f, ids: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, E), lambda i, f, ids: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, E), jnp.float32),
        interpret=interpret,
    )(ids, table, weights)
