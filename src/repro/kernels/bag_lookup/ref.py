"""Pure-jnp oracle for the embedding-bag kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def bag_lookup_ref(table: jax.Array, ids: jax.Array, weights: jax.Array):
    rows = table[ids].astype(jnp.float32)               # (B, F, E)
    return jnp.sum(rows * weights[..., None].astype(jnp.float32), axis=1)
