from .ops import gather_dist
from .ref import gather_dist_ref

__all__ = ["gather_dist", "gather_dist_ref"]
