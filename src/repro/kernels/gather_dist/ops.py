"""Public wrapper for the gather+distance kernel: clamps out-of-range ids
(INVALID = -1 slots are masked by the caller), pads the feature dim to the
128-lane boundary."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .gather_dist import gather_dist_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("squared", "interpret"))
def gather_dist(vectors: jax.Array, ids: jax.Array, queries: jax.Array, *,
                squared: bool = False, interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    N, m = vectors.shape
    pad_m = (-m) % 128
    # bf16 vectors stay bf16 on the HBM->VMEM path (halves the gather
    # traffic that dominates the DEG search roofline — §Perf DEG it. 2);
    # the kernel accumulates distances in f32 regardless.
    dt = vectors.dtype if vectors.dtype == jnp.bfloat16 else jnp.float32
    v = jnp.pad(vectors.astype(dt), ((0, 0), (0, pad_m)))
    q = jnp.pad(queries.astype(dt), ((0, 0), (0, pad_m)))
    safe_ids = jnp.clip(ids, 0, N - 1).astype(jnp.int32)
    return gather_dist_pallas(v, safe_ids, q, squared=squared,
                              interpret=interpret)
