"""Public wrapper for the gather+distance kernel: clamps out-of-range ids
(INVALID = -1 slots are masked by the caller), pads the feature dim to the
128-lane boundary."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .gather_dist import gather_dist_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("squared", "interpret"))
def gather_dist(vectors: jax.Array, ids: jax.Array, queries: jax.Array, *,
                squared: bool = False, interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    N, m = vectors.shape
    pad_m = (-m) % 128
    # Half-width vectors (bf16 AND f16) stay half-width on the HBM->VMEM
    # path — halving the gather traffic that dominates the DEG search
    # roofline (§Perf DEG it. 2).  Upcasting the fp16 store here used to
    # materialize a full-size f32 copy every hop, defeating the 2x codec;
    # the kernel upcasts per-tile instead.  Queries stay f32 for f16
    # stores (f16->f32 is exact, so results are bit-identical to the old
    # upcast-everything path); bf16 keeps its historical
    # query-in-store-dtype behavior.
    dt = (vectors.dtype
          if vectors.dtype in (jnp.bfloat16, jnp.float16) else jnp.float32)
    qt = dt if dt == jnp.bfloat16 else jnp.float32
    v = jnp.pad(vectors.astype(dt), ((0, 0), (0, pad_m)))
    q = jnp.pad(queries.astype(qt), ((0, 0), (0, pad_m)))
    safe_ids = jnp.clip(ids, 0, N - 1).astype(jnp.int32)
    return gather_dist_pallas(v, safe_ids, q, squared=squared,
                              interpret=interpret)
