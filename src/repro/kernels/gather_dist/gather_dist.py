"""Fused neighbor-gather + distance Pallas TPU kernel.

One hop of the DEG range search needs ``dist(q_b, vectors[ids[b, j]])`` for
``j < d`` — a random gather of ``d`` rows per query followed by a reduction.
In plain XLA this materializes the gathered ``(B, d, m)`` tensor in HBM; here
each gathered row is DMA'd HBM->VMEM directly by the BlockSpec index_map
using the *scalar-prefetched* ``ids`` (the idiomatic Pallas TPU gather: the
index arrays arrive in SMEM before the grid starts so the DMA pipeline can
compute source addresses).

grid = (B, d): step (i, j) pulls row ids[i, j] and the query row i into VMEM,
computes one distance, and stores it at out[i, j].  The op is memory-bound by
construction (the roofline term is the d*m*4 bytes of gathered rows per
query); fusing away the (B, d, m) intermediate is the win.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, vec_ref, q_ref, out_ref, *, squared: bool):
    j = pl.program_id(1)
    diff = vec_ref[0, :].astype(jnp.float32) - q_ref[0, :].astype(jnp.float32)
    d2 = jnp.maximum(jnp.sum(diff * diff), 0.0)
    dist = d2 if squared else jnp.sqrt(d2)
    out_ref[0, pl.dslice(j, 1)] = dist[None]


@functools.partial(jax.jit, static_argnames=("squared", "interpret"))
def gather_dist_pallas(vectors: jax.Array, ids: jax.Array, queries: jax.Array,
                       *, squared: bool = False, interpret: bool = True):
    """vectors (N, m), ids (B, d) int32 in [0, N), queries (B, m) -> (B, d)."""
    N, m = vectors.shape
    B, d = ids.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, d),
        in_specs=[
            pl.BlockSpec((1, m), lambda i, j, ids: (ids[i, j], 0)),
            pl.BlockSpec((1, m), lambda i, j, ids: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, j, ids: (i, 0)),
    )
    kernel = functools.partial(_kernel, squared=squared)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, d), jnp.float32),
        interpret=interpret,
    )(ids, vectors, queries)
