"""Pallas TPU kernels for the compute hot spots of the ANN system.

Each kernel package contains:

* ``<name>.py`` — the ``pl.pallas_call`` kernel with explicit BlockSpec VMEM
  tiling (TPU is the *target*; on this CPU container they run with
  ``interpret=True``, which executes the kernel body in Python);
* ``ops.py``    — the jit'd public wrapper (padding, alignment, dispatch);
* ``ref.py``    — the pure-jnp oracle used by tests and benchmarks.

Kernels:

* ``l2_topk``     — tiled query x base L2 distance matrix fused with a
                    streaming top-k (the brute-force scorer / re-ranker and
                    the `retrieval_cand` scorer for the recsys archs);
* ``gather_dist`` — scalar-prefetched neighbor-row gather fused with the
                    per-hop distance computation of the graph search;
* ``beam_merge``  — fused bitonic partial merge folding the per-hop scored
                    candidates into the sorted search beam (bit-identical
                    to a stable argsort of the concatenation; the beam
                    engine's per-hop workhorse — see core/beam.py);
* ``gather_dist_q`` — the SQ8 sibling of ``gather_dist``: gathers int8 code
                    rows, dequantizes them in VMEM against the shared
                    per-dimension scale, and reduces to distances in one
                    pass (the quantized store's hot path — see
                    quant/store.py);
* ``mrng_occlusion`` — gather each candidate's neighbor rows via
                    scalar-prefetched ids, reduce to query distances in
                    VMEM, and fold in the Alg. 2 lune test in one pass (the
                    construction/refinement hot path — see core/extend.py);
* ``bag_lookup``  — embedding-bag gather-reduce (recsys embedding tables).
"""
