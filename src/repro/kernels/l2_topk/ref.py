"""Pure-jnp oracle for the fused L2 + top-k kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("k", "squared"))
def l2_topk_ref(queries: jax.Array, base: jax.Array, k: int,
                squared: bool = False):
    q = queries.astype(jnp.float32)
    x = base.astype(jnp.float32)
    qn = jnp.sum(q * q, axis=1, keepdims=True)
    xn = jnp.sum(x * x, axis=1)
    d2 = jnp.maximum(qn - 2.0 * (q @ x.T) + xn[None, :], 0.0)
    d = d2 if squared else jnp.sqrt(d2)
    neg, ids = jax.lax.top_k(-d, k)
    return -neg, ids.astype(jnp.int32)
