from .ops import l2_topk
from .ref import l2_topk_ref

__all__ = ["l2_topk", "l2_topk_ref"]
