"""Fused L2-distance + streaming top-k Pallas TPU kernel.

The brute-force scan of an ANN system is one big ``(B, m) x (m, N)`` matmul
(the ``|q|^2 - 2 q.x + |x|^2`` expansion) followed by a row-wise top-k.  A
naive implementation materializes the full ``(B, N)`` distance matrix in HBM
(N can be 10^6+); this kernel keeps a running per-query top-k in VMEM scratch
and never writes the matrix out:

* grid = (B/TB, N/TN), N innermost so the scratch accumulates across tiles;
* the query tile (TB, m) and base tile (TN, m) live in VMEM; the distance
  tile is one MXU matmul (2*TB*TN*m FLOPs) plus rank-1 corrections;
* the top-k merge is k extraction steps of pure VPU ops (min / where /
  broadcasted_iota one-hots — no in-kernel sort or scatter, both of which
  are TPU-hostile).

VMEM budget at defaults (TB=8, TN=512, m<=1024, fp32):
  base tile 2 MB + query tile 32 KB + scratch ~ (TB*(K+TN)) -> well under
  the ~16 MB/core budget; TN can be raised to 2048 for small m.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_LIMIT = jnp.float32(3.0e38)


def _extract_topk(cand_d, cand_i, k: int):
    """k extraction steps over (TB, C) candidates, TPU-safe (no sort/scatter).

    Returns (TB, k) best distances/ids, ascending."""
    TB, C = cand_d.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (TB, C), 1)

    def step(t, carry):
        bd, bi, cd = carry
        pos = jnp.argmin(cd, axis=1)                      # (TB,)
        sel = col == pos[:, None]                         # one-hot (TB, C)
        val = jnp.min(cd, axis=1)                         # (TB,)
        vid = jnp.max(jnp.where(sel, cand_i, -1), axis=1)
        tcol = jax.lax.broadcasted_iota(jnp.int32, bd.shape, 1) == t
        bd = jnp.where(tcol, val[:, None], bd)
        bi = jnp.where(tcol, vid[:, None], bi)
        cd = jnp.where(sel, jnp.inf, cd)
        return bd, bi, cd

    bd0 = jnp.full((TB, k), jnp.inf, cand_d.dtype)
    bi0 = jnp.full((TB, k), -1, jnp.int32)
    bd, bi, _ = jax.lax.fori_loop(0, k, step, (bd0, bi0, cand_d))
    return bd, bi


def _kernel(q_ref, x_ref, od_ref, oi_ref, sd_ref, si_ref, *, k: int,
            tn: int, squared: bool):
    n_idx = pl.program_id(1)
    n_tiles = pl.num_programs(1)

    @pl.when(n_idx == 0)
    def _init():
        sd_ref[...] = jnp.full_like(sd_ref, jnp.inf)
        si_ref[...] = jnp.full_like(si_ref, -1)

    q = q_ref[...].astype(jnp.float32)                    # (TB, m)
    x = x_ref[...].astype(jnp.float32)                    # (TN, m)
    qn = jnp.sum(q * q, axis=1, keepdims=True)            # (TB, 1)
    xn = jnp.sum(x * x, axis=1)                           # (TN,)
    dot = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    d2 = jnp.maximum(qn - 2.0 * dot + xn[None, :], 0.0)   # (TB, TN)
    dist = d2 if squared else jnp.sqrt(d2)

    gids = n_idx * tn + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
    cand_d = jnp.concatenate([sd_ref[...], dist], axis=1)
    cand_i = jnp.concatenate([si_ref[...], gids], axis=1)
    bd, bi = _extract_topk(cand_d, cand_i, k)
    sd_ref[...] = bd
    si_ref[...] = bi

    @pl.when(n_idx == n_tiles - 1)
    def _flush():
        od_ref[...] = sd_ref[...]
        oi_ref[...] = si_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("k", "tb", "tn", "squared", "interpret"),
)
def l2_topk_pallas(queries: jax.Array, base: jax.Array, k: int, *,
                   tb: int = 8, tn: int = 512, squared: bool = False,
                   interpret: bool = True):
    """queries (B, m), base (N, m) -> (dists (B, k), ids (B, k)).

    B must be a multiple of tb and N of tn (ops.py pads)."""
    B, m = queries.shape
    N, _ = base.shape
    assert B % tb == 0 and N % tn == 0, (B, tb, N, tn)
    grid = (B // tb, N // tn)
    kernel = functools.partial(_kernel, k=k, tn=tn, squared=squared)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, m), lambda i, n: (i, 0)),
            pl.BlockSpec((tn, m), lambda i, n: (n, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tb, k), lambda i, n: (i, 0)),
            pl.BlockSpec((tb, k), lambda i, n: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tb, k), jnp.float32),
            pltpu.VMEM((tb, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, base)
