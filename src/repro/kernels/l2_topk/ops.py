"""Public jit'd wrapper for the fused L2 + top-k kernel: pads to tile
boundaries, dispatches to the Pallas kernel, slices back."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .l2_topk import l2_topk_pallas

_PAD_VAL = 1.0e19  # distance to padded base rows overflows to ~inf after square


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("k", "tb", "tn", "squared",
                                             "interpret"))
def l2_topk(queries: jax.Array, base: jax.Array, k: int, *, tb: int = 8,
            tn: int = 512, squared: bool = False,
            interpret: bool | None = None):
    """Top-k nearest rows of ``base`` for each query, fused in one kernel.

    queries (B, m), base (N, m) -> (dists (B, k), ids (B, k)); padded rows
    can never appear in results because their distance is ~inf.
    """
    if interpret is None:
        interpret = _default_interpret()
    B, m = queries.shape
    N, _ = base.shape
    if k > N:
        raise ValueError(f"k={k} > N={N}")
    tn = min(tn, _round_up(N, 128))
    pad_b = _round_up(B, tb) - B
    pad_n = _round_up(N, tn) - N
    pad_m = _round_up(m, 128) - m
    q = jnp.pad(queries.astype(jnp.float32), ((0, pad_b), (0, pad_m)))
    x = jnp.pad(base.astype(jnp.float32), ((0, pad_n), (0, pad_m)),
                constant_values=0.0)
    if pad_n:
        # push padded rows to +inf distance
        mask = jnp.arange(x.shape[0]) >= N
        x = jnp.where(mask[:, None], _PAD_VAL, x)
    d, i = l2_topk_pallas(q, x, k, tb=tb, tn=tn, squared=squared,
                          interpret=interpret)
    return d[:B], i[:B]


def _round_up(v: int, mult: int) -> int:
    return (v + mult - 1) // mult * mult
