from .beam_merge import beam_merge_pallas, merge_beam_candidates
from .ops import beam_merge
from .ref import beam_merge_ref

__all__ = ["beam_merge", "beam_merge_pallas", "beam_merge_ref",
           "merge_beam_candidates"]
