"""Fused beam-merge Pallas TPU kernel (bitonic partial merge).

One hop of the DEG range search must fold ``d`` freshly scored neighbor
candidates into the distance-sorted beam of static width ``L``.  The seed
implementation re-sorted the whole ``(B, L+d)`` concatenation with
``argsort`` every hop — an O((L+d) log^2 (L+d)) comparator sort that ignores
the fact that ``L`` of the entries are *already sorted*.  This kernel
exploits that invariant:

1. the ``d`` candidates are bitonic-sorted (a log^2 d network over lanes —
   cheap: d is the graph degree, 8..32);
2. ``[beam asc | +inf pads | candidates desc]`` is a bitonic sequence of
   power-of-two length T, so one *bitonic merge* (log T compare-exchange
   stages of pure VPU selects — no gather, no scatter, no sort primitive)
   produces the fully sorted T-vector;
3. the first ``L`` lanes are the new beam.

Every compare-exchange is keyed on the pair ``(distance, rank)`` where
``rank`` is the position in the virtual ``[beam | candidates]``
concatenation.  Ranks are unique, so the network computes a *total* order
that coincides exactly with a stable argsort of the concatenation — the
kernel is bit-identical to the seed merge, not merely equivalent up to
ties.  The same property makes the network deterministic on all backends.

The compare-exchange helpers are plain jnp on ``(..., T)`` arrays: the
Pallas kernel body calls them on VMEM-resident blocks, and
``ops.beam_merge(backend="jnp")`` calls them directly as the XLA fast path
(the form the jitted search loop uses on CPU/GPU, and the baseline the
microbenchmark compares against argsort).

Payload layout: distances f32 + rank i32 + three payload channels
(vertex id i32, checked flag, excluded flag).  Flags travel as int32 inside
the kernel — TPU has no 1-bit vregs; ``ops.py`` converts at the boundary.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INF = float("inf")


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _cmp_swap(fields, j: int, desc=None):
    """One compare-exchange stage at stride ``j`` over the last axis.

    ``fields[0]`` is the distance key, ``fields[1]`` the rank tie-break; the
    rest are payload.  Partner lanes are exchanged via a reshape to
    ``(..., c, 2, j)`` — a pure relayout, no gather.  ``desc`` (broadcast
    over ``(..., c, j)``) flips the direction per chunk for the sort
    network; ``None`` means ascending everywhere (the merge network).
    """
    d = fields[0]
    lead, T = d.shape[:-1], d.shape[-1]
    c = T // (2 * j)

    def halves(x):
        v = x.reshape(*lead, c, 2, j)
        return v[..., 0, :], v[..., 1, :]

    lo_d, hi_d = halves(fields[0])
    lo_r, hi_r = halves(fields[1])
    # (dist, rank) lexicographic: does the high lane belong before the low?
    swap = (hi_d < lo_d) | ((hi_d == lo_d) & (hi_r < lo_r))
    if desc is not None:
        swap = swap != desc            # XOR: descending chunks invert
    out = []
    for x in fields:
        lo, hi = halves(x)
        new_lo = jnp.where(swap, hi, lo)
        new_hi = jnp.where(swap, lo, hi)
        out.append(jnp.stack([new_lo, new_hi], axis=-2).reshape(*lead, T))
    return tuple(out)


def _bitonic_sort(fields):
    """Full bitonic sort (ascending by (dist, rank)) over the last axis."""
    T = fields[0].shape[-1]
    k = 2
    while k <= T:
        j = k // 2
        while j >= 1:
            c = T // (2 * j)
            chunk_start = jnp.arange(c) * (2 * j)
            desc = ((chunk_start // k) % 2 == 1)[:, None]
            fields = _cmp_swap(fields, j, desc)
            j //= 2
        k *= 2
    return fields


def _bitonic_merge(fields):
    """Merge network: bitonic input -> ascending by (dist, rank)."""
    T = fields[0].shape[-1]
    j = T // 2
    while j >= 1:
        fields = _cmp_swap(fields, j)
        j //= 2
    return fields


def merge_beam_candidates(beam_dists, beam_payload, cand_dists, cand_payload,
                          *, out_width: int | None = None,
                          presort: str = "auto"):
    """The fused merge on plain arrays (shared by kernel body and jnp path).

    Args:
      beam_dists: (..., L) f32, ascending (stable order — the beam
        invariant).
      beam_payload: tuple of (..., L) arrays carried through the permutation.
      cand_dists: (..., d) f32, arbitrary order (masked lanes = +inf).
      cand_payload: tuple of (..., d) arrays (same arity as beam_payload).
      presort: how the candidate block is sorted before the merge network.
        "network" = the bitonic sort (the only form a Pallas TPU kernel
        body can lower); "argsort" = one stable XLA sort + gathers — a
        stable sort by distance IS the (dist, rank) order, so the two are
        bit-identical; "auto" = argsort for wide multi-expansion blocks
        (d >= 32, where log^2 d network passes lose to one sort on CPU),
        network otherwise.
    Returns:
      (dists, payload...) each (..., out_width or L) — the first entries of
      the stable-sorted [beam | candidates] concatenation.
    """
    lead = beam_dists.shape[:-1]
    L = beam_dists.shape[-1]
    d = cand_dists.shape[-1]
    out_width = L if out_width is None else out_width
    dp = _next_pow2(d)
    T = _next_pow2(L + dp)
    i32 = jnp.int32
    if presort == "auto":
        presort = "argsort" if d >= 32 else "network"

    # --- candidates: pad to dp, sort asc by (dist, rank), reverse -> desc --
    pad_c = dp - d
    c_dists = jnp.concatenate(
        [cand_dists, jnp.full((*lead, pad_c), _INF, cand_dists.dtype)], -1)
    c_pay = tuple(
        jnp.concatenate([p, jnp.zeros((*lead, pad_c), p.dtype)], -1)
        for p in cand_payload)
    if presort == "argsort":
        order = jnp.argsort(c_dists, axis=-1, stable=True)
        take = functools.partial(jnp.take_along_axis, indices=order, axis=-1)
        c_fields = ((take(c_dists), (L + order).astype(i32))
                    + tuple(take(p) for p in c_pay))
    else:
        c_rank = jnp.broadcast_to(L + jnp.arange(dp, dtype=i32), (*lead, dp))
        c_fields = _bitonic_sort((c_dists, c_rank) + c_pay)
    c_fields = tuple(x[..., ::-1] for x in c_fields)

    # --- bitonic sequence: [beam asc | +inf pads | candidates desc] --------
    mid = T - L - dp
    b_rank = jnp.broadcast_to(jnp.arange(L, dtype=i32), (*lead, L))
    pad_dists = jnp.full((*lead, mid), _INF, beam_dists.dtype)
    pad_rank = jnp.broadcast_to(T + jnp.arange(mid, dtype=i32), (*lead, mid))

    def cat(b, pad, c):
        return jnp.concatenate([b, pad, c], -1)

    fields = (cat(beam_dists, pad_dists, c_fields[0]),
              cat(b_rank, pad_rank, c_fields[1]))
    for bp, cp in zip(beam_payload, c_fields[2:]):
        fields += (cat(bp, jnp.zeros((*lead, mid), bp.dtype), cp),)

    merged = _bitonic_merge(fields)
    return (merged[0][..., :out_width],) + tuple(
        x[..., :out_width] for x in merged[2:])


def _kernel(bd_ref, bi_ref, bc_ref, bx_ref, cd_ref, ci_ref, cc_ref, cx_ref,
            od_ref, oi_ref, oc_ref, ox_ref):
    out = merge_beam_candidates(
        bd_ref[...], (bi_ref[...], bc_ref[...], bx_ref[...]),
        cd_ref[...], (ci_ref[...], cc_ref[...], cx_ref[...]),
        presort="network")       # sort primitives don't lower in Pallas TPU
    od_ref[...], oi_ref[...], oc_ref[...], ox_ref[...] = out


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def beam_merge_pallas(beam_dists, beam_ids, beam_chk, beam_exc,
                      cand_dists, cand_ids, cand_chk, cand_exc,
                      *, tb: int = 8, interpret: bool = True):
    """Pallas dispatch: (B, L) beam + (B, d) candidates -> merged (B, L).

    Flag channels are int32.  B must be a multiple of ``tb`` (ops.py pads).
    The whole (tb, T<=2*(L+d)) working set lives in VMEM: at production
    shapes (L<=512, d<=32, tb=8) that is ~170 KB across the seven channels —
    far under budget, so the grid tiles the batch only.
    """
    B, L = beam_dists.shape
    d = cand_dists.shape[1]
    assert B % tb == 0, (B, tb)
    grid = (B // tb,)
    bspec = pl.BlockSpec((tb, L), lambda i: (i, 0))
    cspec = pl.BlockSpec((tb, d), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[bspec, bspec, bspec, bspec, cspec, cspec, cspec, cspec],
        out_specs=[bspec, bspec, bspec, bspec],
        out_shape=[
            jax.ShapeDtypeStruct((B, L), jnp.float32),
            jax.ShapeDtypeStruct((B, L), jnp.int32),
            jax.ShapeDtypeStruct((B, L), jnp.int32),
            jax.ShapeDtypeStruct((B, L), jnp.int32),
        ],
        interpret=interpret,
    )(beam_dists, beam_ids, beam_chk, beam_exc,
      cand_dists, cand_ids, cand_chk, cand_exc)
