"""Pure-jnp oracle for the fused beam merge: the seed implementation's
stable argsort over the ``[beam | candidates]`` concatenation."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@jax.jit
def beam_merge_ref(beam_dists, beam_ids, beam_chk, beam_exc,
                   cand_dists, cand_ids, cand_chk, cand_exc):
    """(B, L) sorted beam + (B, d) candidates -> merged (B, L) 4-tuple.

    Returns (dists, ids, checked, excluded) — the first L entries of the
    stable sort of the concatenation, i.e. ties keep beam-before-candidate
    and original-lane order.  This IS the pre-beam-engine merge, kept as the
    golden semantics every other backend must reproduce bit-exactly.
    """
    L = beam_dists.shape[-1]
    all_d = jnp.concatenate([beam_dists, cand_dists], axis=-1)
    order = jnp.argsort(all_d, axis=-1)[..., :L]

    def take(b, c):
        return jnp.take_along_axis(jnp.concatenate([b, c], -1), order, -1)

    return (jnp.take_along_axis(all_d, order, -1),
            take(beam_ids, cand_ids),
            take(beam_chk, cand_chk),
            take(beam_exc, cand_exc))
