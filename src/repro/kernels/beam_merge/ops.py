"""Public dispatch for the fused beam merge.

Backends (all bit-identical outputs — see beam_merge.py for why):

* ``"jnp"``     — the bitonic partial-merge network inlined as plain XLA
                  ops; the default inside the jitted search loop off-TPU.
* ``"pallas"``  — the Pallas kernel (interpret mode off-TPU).
* ``"argsort"`` — the seed stable-argsort merge (oracle; also the baseline
                  the ``beam_merge`` microbenchmark compares against).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .beam_merge import beam_merge_pallas, merge_beam_candidates
from .ref import beam_merge_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(v: int, mult: int) -> int:
    return (v + mult - 1) // mult * mult


@functools.partial(jax.jit, static_argnames=("backend", "tb", "interpret"))
def beam_merge(beam_dists, beam_ids, beam_chk, beam_exc,
               cand_dists, cand_ids, cand_exc, *, cand_chk=None,
               backend: str = "jnp", tb: int = 8,
               interpret: bool | None = None):
    """Merge ``d`` candidates into the sorted width-``L`` beam.

    beam_* : (B, L) — dists f32 ascending (stable order), ids i32,
             checked/excluded bool.
    cand_* : (B, d) — masked lanes carry dist=+inf / id=INVALID.
    ``cand_chk`` is keyword-only (defaults to all-False — fresh candidates
    are unexpanded) so the 7-positional-arg surface cannot be confused
    with the 8-positional (…, cand_chk, cand_exc) channel order of
    ``beam_merge_ref`` / ``beam_merge_pallas``.
    Returns (dists, ids, checked, excluded), each (B, L): the first L
    entries of the stable sort of ``[beam | candidates]``.
    """
    if cand_chk is None:
        cand_chk = jnp.zeros_like(cand_ids, dtype=bool)
    if backend == "argsort":
        return beam_merge_ref(beam_dists, beam_ids, beam_chk, beam_exc,
                              cand_dists, cand_ids, cand_chk, cand_exc)
    if backend == "jnp":
        d, ids, chk, exc = merge_beam_candidates(
            beam_dists, (beam_ids, beam_chk, beam_exc),
            cand_dists, (cand_ids, cand_chk, cand_exc))
        return d, ids, chk, exc
    if backend != "pallas":
        raise ValueError(f"unknown beam_merge backend {backend!r}")
    if interpret is None:
        interpret = _default_interpret()
    B, L = beam_dists.shape
    pad_b = _round_up(max(B, 1), tb) - B

    def pad(x, fill):
        return jnp.pad(x, ((0, pad_b), (0, 0)), constant_values=fill)

    i32 = jnp.int32
    out = beam_merge_pallas(
        pad(beam_dists, jnp.inf), pad(beam_ids, 0),
        pad(beam_chk.astype(i32), 0), pad(beam_exc.astype(i32), 0),
        pad(cand_dists, jnp.inf), pad(cand_ids, 0),
        pad(cand_chk.astype(i32), 0), pad(cand_exc.astype(i32), 0),
        tb=tb, interpret=interpret)
    d, ids, chk, exc = out
    return (d[:B], ids[:B], chk[:B].astype(bool), exc[:B].astype(bool))
