"""Pure-jnp oracle for the PQ gather + LUT-ADC distance kernel.

Operates on the kernel's padded operand layout (``ops.padded_operands``)
with the kernel's exact formulation — squared-diff matmul against the
subspace selector for the LUT, one-hot masked sum for the per-row
accumulate — so interpret-mode parity is bitwise, matching the house
``gather_dist_q`` test idiom.  The mathematical identity (ADC l2 ==
exact l2 to the decoded vector) is pinned separately in the tests via
``quant.pq.decode``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("squared",))
def pq_adc_ref(codes: jax.Array, cb2: jax.Array, sel: jax.Array,
               ids: jax.Array, queries: jax.Array, squared: bool = False):
    """codes (N, S) uint8, cb2 (256, mp) f32, sel (mp, S) f32, ids (B, d)
    int32 in [0, N), queries (B, mp) f32 -> (B, d) f32."""
    K = cb2.shape[0]
    diff = cb2[None] - queries[:, None, :]                  # (B, 256, mp)
    lut = jnp.matmul(diff * diff, sel,
                     preferred_element_type=jnp.float32,
                     precision=jax.lax.Precision.HIGHEST)   # (B, 256, S)
    g = codes[ids].astype(jnp.int32)                        # (B, d, S)
    hit = jnp.arange(K)[None, None, :, None] == g[:, :, None, :]
    vals = jnp.where(hit, lut[:, None], 0.0)                # (B, d, 256, S)
    d2 = jnp.maximum(jnp.sum(vals, axis=(2, 3)), 0.0)
    return d2 if squared else jnp.sqrt(d2)
