"""Public wrapper for the PQ gather + LUT-ADC distance kernel: clamps
out-of-range ids (INVALID = -1 slots are masked by the caller), lane-pads
the code rows to :data:`SUBSPACE_LANES` and the flattened codebook /
query to the 128-lane boundary, and builds the 0/1 subspace selector the
in-kernel LUT matmul contracts against.  All padding is
zero-contributing: padded query/codebook lanes difference to 0, and
selector columns past ``m_sub`` are zero so padded code lanes (code 0)
read a LUT column that is identically 0."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .pq_adc import PQ_K, SUBSPACE_LANES, pq_adc_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=())
def padded_operands(codes: jax.Array, codebooks: jax.Array,
                    queries: jax.Array):
    """Natural operands -> the kernel's padded layout.

    codes (N, m_sub) uint8, codebooks (m_sub, 256, dsub) f32, queries
    (B, dim) f32 -> (codes (N, S) uint8, cb2 (256, mp) f32, sel (mp, S)
    f32, queries (B, mp) f32) with ``cb2[c, s*dsub+k] = codebooks[s, c, k]``
    and ``sel[s*dsub+k, s] = 1``.  Exposed so the exact-parity tests can
    feed the jnp oracle the very operands the kernel sees.
    """
    N, m_sub = codes.shape
    cb = jnp.asarray(codebooks, jnp.float32)
    ms, K, dsub = cb.shape
    if ms != m_sub or K != PQ_K:
        raise ValueError(f"codes/codebooks disagree: codes m_sub={m_sub}, "
                         f"codebooks {cb.shape}")
    S = SUBSPACE_LANES
    if m_sub > S:
        raise ValueError(f"m_sub={m_sub} exceeds the kernel's {S} "
                         "subspace lanes")
    dim = m_sub * dsub
    pad_m = (-dim) % 128
    mp = dim + pad_m
    c = jnp.pad(codes.astype(jnp.uint8), ((0, 0), (0, S - m_sub)))
    cb2 = jnp.pad(jnp.transpose(cb, (1, 0, 2)).reshape(K, dim),
                  ((0, 0), (0, pad_m)))
    lane = jnp.arange(mp)
    sel = ((lane[:, None] // dsub == jnp.arange(S)[None, :])
           & (lane < dim)[:, None]).astype(jnp.float32)
    q = jnp.pad(queries.astype(jnp.float32), ((0, 0), (0, pad_m)))
    return c, cb2, sel, q


@functools.partial(jax.jit, static_argnames=("squared", "interpret"))
def pq_adc(codes: jax.Array, codebooks: jax.Array, ids: jax.Array,
           queries: jax.Array, *, squared: bool = False,
           interpret: bool | None = None):
    """codes (N, m_sub) uint8, codebooks (m_sub, 256, dsub) f32, ids (B, d)
    int32, queries (B, dim) f32 -> (B, d) f32 ADC l2 distances."""
    if interpret is None:
        interpret = _default_interpret()
    N = codes.shape[0]
    c, cb2, sel, q = padded_operands(codes, codebooks, queries)
    safe_ids = jnp.clip(ids, 0, N - 1).astype(jnp.int32)
    return pq_adc_pallas(c, cb2, sel, safe_ids, q, squared=squared,
                         interpret=interpret)
