from .ops import padded_operands, pq_adc
from .ref import pq_adc_ref

__all__ = ["pq_adc", "pq_adc_ref", "padded_operands"]
