"""Fused PQ code-gather + LUT-ADC distance Pallas TPU kernel.

The product-quantized sibling of ``kernels/gather_dist_q``: one hop of the
DEG range search over a PQ store needs ``dist(q_b, decode(codes[ids[b,j]]))``
for ``j < d``.  Decoding in XLA would materialize the gathered ``(B, d, m)``
float32 tensor — 4 * dsub x the code bytes — before reducing.  Asymmetric
distance computation never decodes: for l2,

    ||q - decode(x)||^2 = sum_s ||q_s - C[s, code_s(x)]||^2,

so a per-query ``(256, S)`` table of squared sub-distances (S = padded
subspace lanes) built ONCE in VMEM turns every gathered code row into
table lookups + adds.  The HBM traffic per hop is the ``d * m_sub`` code
*bytes* plus the query row — at dsub = 8 a ~32x cut of the gather term vs
the float32 kernel.

grid = (B, d), d minormost: step (i, 0) builds query i's LUT in the VMEM
scratch (``@pl.when`` — scratch persists across the sequential grid steps
of that query); every step (i, j) pulls code row ids[i, j] into VMEM via
the scalar-prefetched ids, one-hot-selects its ``m_sub`` LUT entries, and
stores the accumulated distance at out[i, j].

Operand layout (prepared by ``ops.padded_operands``): codes are lane-padded
to ``(N, S)`` uint8 (pad code 0 is harmless — see below); the codebooks
arrive transposed/flattened as ``cb2 (256, mp)`` with
``cb2[c, s*dsub + k] = C[s, c, k]`` so the LUT build is one elementwise
square plus one ``(256, mp) @ (mp, S)`` MXU matmul against the 0/1
subspace-selector ``sel (mp, S)``; selector columns ``s >= m_sub`` are
zero, so LUT columns for padded code lanes are identically 0 and padded
lanes contribute nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: subspace-lane width of the padded code rows / LUT (one VREG of lanes);
#: bounds m_sub — dsub = 8 supports stores up to 1024 dims
SUBSPACE_LANES = 128

#: centroids per subspace (uint8 code byte)
PQ_K = 256


def _kernel(ids_ref, codes_ref, cb2_ref, sel_ref, q_ref, out_ref, lut_ref,
            *, squared: bool):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _build_lut():
        diff = cb2_ref[...] - q_ref[0, :][None, :]          # (256, mp)
        lut_ref[...] = jnp.dot(diff * diff, sel_ref[...],
                               preferred_element_type=jnp.float32,
                               precision=jax.lax.Precision.HIGHEST)

    code = codes_ref[0, :].astype(jnp.int32)[None, :]       # (1, S)
    hit = jax.lax.broadcasted_iota(jnp.int32, lut_ref.shape, 0) == code
    d2 = jnp.maximum(jnp.sum(jnp.where(hit, lut_ref[...], 0.0)), 0.0)
    dist = d2 if squared else jnp.sqrt(d2)
    out_ref[0, pl.dslice(j, 1)] = dist[None]


@functools.partial(jax.jit, static_argnames=("squared", "interpret"))
def pq_adc_pallas(codes: jax.Array, cb2: jax.Array, sel: jax.Array,
                  ids: jax.Array, queries: jax.Array, *,
                  squared: bool = False, interpret: bool = True):
    """codes (N, S) uint8, cb2 (256, mp) f32, sel (mp, S) f32, ids (B, d)
    int32 in [0, N), queries (B, mp) f32 -> (B, d) f32 distances."""
    N, S = codes.shape
    K, mp = cb2.shape
    B, d = ids.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, d),
        in_specs=[
            pl.BlockSpec((1, S), lambda i, j, ids: (ids[i, j], 0)),
            pl.BlockSpec((K, mp), lambda i, j, ids: (0, 0)),
            pl.BlockSpec((mp, S), lambda i, j, ids: (0, 0)),
            pl.BlockSpec((1, mp), lambda i, j, ids: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, j, ids: (i, 0)),
        scratch_shapes=[pltpu.VMEM((K, S), jnp.float32)],
    )
    kernel = functools.partial(_kernel, squared=squared)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, d), jnp.float32),
        interpret=interpret,
    )(ids, codes, cb2, sel, queries)
