"""Admission queue + request futures for the continuous-batching engine.

The scheduler side of ``AsyncQueryEngine``: single-query submits land in
an :class:`AdmissionQueue` as :class:`Request` records and are handed out
strictly FIFO (queue-order fairness — a burst that overfills one bucket
is served oldest-first across consecutive flushes, never reordered by
deadline or arrival jitter).  Each request carries an
:class:`AsyncResult`, a thread-safe future the extract stage completes;
cancellation is resolved at dispatch time (a cancelled request still in
the queue is dropped before it costs a lane).

Deadlines are absolute :func:`repro.obs.clock.now` instants (the one
monotonic clock every serving timestamp comes from — see obs/clock.py).
The queue only *accounts* for them (``next_deadline`` feeds the engine's
flush-timing decision); the policy itself — force a flush when a request
nears its deadline, search an already-expired request under a partial hop
budget — lives in ``serving/async_engine.py``.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import threading
from typing import Callable, Optional, Sequence

from repro.obs import clock
from repro.resilience.errors import OverloadError


class CancelledError(RuntimeError):
    """Raised by :meth:`AsyncResult.result` for a cancelled request."""


class AsyncResult:
    """Thread-safe future for one submitted query.

    States: pending -> dispatched -> done, or pending -> cancelled, or
    (pending | dispatched) -> failed.  A *failed* future carries a typed
    exception in ``error`` (:class:`~repro.resilience.OverloadError` when
    the bounded queue shed it, :class:`~repro.resilience.EngineCrashedError`
    when a serving thread died while it was outstanding) which
    :meth:`result` re-raises — callers never hang on a request the engine
    can no longer serve.  ``ids``/``dists`` are the per-request result
    rows; ``partial`` is True when the request's deadline expired before
    dispatch and the engine returned the best-so-far beam under the
    partial hop budget instead of dropping it; ``degraded``/
    ``degrade_level`` record whether the ladder served it below the base
    search program; ``epoch`` is the published-epoch number the flush
    searched (None when the index is not publishing) — replaying the
    query against that epoch's snapshot must reproduce ``ids``/``dists``
    bit for bit, the no-torn-reads contract of live mutation.

    The future doubles as the request's trace record: ``submitted_at`` /
    ``dispatched_at`` / ``device_done_at`` / ``completed_at`` are
    :func:`repro.obs.clock.now` stamps set as the request moves through
    the pipeline (ordering invariant: each <= the next), ``seq`` its
    admission order, ``sampled`` whether the engine's query-log sampler
    took it.  Tracing therefore allocates nothing per query beyond this
    object, which exists anyway."""

    __slots__ = ("_event", "_lock", "_state", "ids", "dists", "partial",
                 "submitted_at", "dispatched_at", "device_done_at",
                 "completed_at", "deadline", "flush_index", "seq", "sampled",
                 "error", "degraded", "degrade_level", "epoch")

    def __init__(self, deadline: Optional[float] = None):
        self._event = threading.Event()
        self._lock = threading.Lock()
        self._state = "pending"
        self.ids = None
        self.dists = None
        self.partial = False
        self.error: Optional[BaseException] = None
        self.degraded = False
        self.degrade_level = 0
        self.epoch: Optional[int] = None
        self.submitted_at = clock.now()
        self.dispatched_at: Optional[float] = None
        self.device_done_at: Optional[float] = None
        self.completed_at: Optional[float] = None
        self.deadline = deadline
        self.flush_index: Optional[int] = None
        self.seq: Optional[int] = None
        self.sampled = False

    # -- state transitions (engine-side) -----------------------------------
    def _mark_dispatched(self, flush_index: int) -> None:
        with self._lock:
            self._state = "dispatched"
            self.dispatched_at = clock.now()
            self.flush_index = flush_index

    def _complete(self, ids, dists, *, partial: bool) -> None:
        with self._lock:
            self.ids, self.dists = ids, dists
            self.partial = partial
            self.completed_at = clock.now()
            self._state = "done"
        self._event.set()

    def _try_cancel(self) -> bool:
        with self._lock:
            if self._state != "pending":
                return False
            self._state = "cancelled"
        self._event.set()
        return True

    def _fail(self, exc: BaseException) -> bool:
        """Resolve the future with a typed error (shed / engine crash).

        Valid from *pending* (queue shed it) and *dispatched* (a loop
        thread died while the batch was in flight).  Returns False if the
        future already resolved — completion wins races with failure."""
        with self._lock:
            if self._state not in ("pending", "dispatched"):
                return False
            self._state = "failed"
            self.error = exc
            self.completed_at = clock.now()
        self._event.set()
        return True

    # -- caller side -------------------------------------------------------
    @property
    def cancelled(self) -> bool:
        return self._state == "cancelled"

    @property
    def failed(self) -> bool:
        return self._state == "failed"

    def done(self) -> bool:
        return self._event.is_set()

    def cancel(self) -> bool:
        """Cancel if still queued.  Returns False once dispatched — the
        lane is already paid for and the result will arrive."""
        return self._try_cancel()

    def result(self, timeout: Optional[float] = None):
        """Block for (ids, dists).  Raises :class:`CancelledError` for a
        cancelled request, the stored typed error for a failed one
        (overload shed / engine crash), TimeoutError if the wait
        expires."""
        if not self._event.wait(timeout):
            raise TimeoutError("result not ready")
        if self._state == "cancelled":
            raise CancelledError("request was cancelled before dispatch")
        if self._state == "failed":
            raise self.error
        return self.ids, self.dists

    @property
    def latency_s(self) -> Optional[float]:
        if self.completed_at is None:
            return None
        return self.completed_at - self.submitted_at


@dataclasses.dataclass
class Request:
    """One admitted query: operands plus scheduling metadata."""

    query: "object"                      # (m,) float32 np.ndarray
    result: AsyncResult
    seq: int                             # admission order (FIFO key)
    exclude: Sequence[int] = ()
    seed_vertex: Optional[int] = None

    @property
    def deadline(self) -> Optional[float]:
        return self.result.deadline


class AdmissionQueue:
    """FIFO admission queue shared by the submit side and the scheduler
    thread.  All waits go through one condition variable.

    Pushes are cheap by design — the serving host shares cores with the
    device program (single-process jax), so per-request overhead on the
    submit path is stolen straight from search compute.  ``push`` only
    wakes the scheduler on the transitions it actually acts on: queue
    went non-empty (start the linger clock) or reached ``notify_at``
    (= the engine's ``max_batch``: a full bucket should flush now, not at
    linger expiry).  In between, the scheduler's own timed waits poll the
    flush instant.  Deadlines are tracked in a lazy min-heap so
    :meth:`next_deadline` is O(log n) amortized, not a deque scan per
    scheduler pass.

    With ``capacity`` set the queue is bounded and sheds under pressure
    (``capacity=None`` keeps the historical unbounded behavior).  Two
    policies:

    - ``"reject"`` — a push that would exceed capacity raises
      :class:`~repro.resilience.OverloadError`; queued work is never
      disturbed.
    - ``"drop"`` — deadline-aware: the request shed is the one that
      would miss its SLO anyway — the *earliest-deadline* live request,
      the incoming one included (a request with no deadline is never the
      victim).  A queued victim's future fails with ``OverloadError``
      (``shed_at="queue"``); if the incoming request is the most doomed,
      the push itself raises (``shed_at="submit"``).  With no deadlines
      anywhere the policy degenerates to reject.

    The live count excludes requests already cancelled or shed (they
    still occupy deque slots until ``pop_ready`` discards them), so the
    recount is only paid on the already-slow overload path."""

    def __init__(self, notify_at: Optional[int] = None,
                 capacity: Optional[int] = None, shed_policy: str = "reject",
                 on_shed: Optional[Callable[[Request], None]] = None):
        if capacity is not None and capacity < 1:
            raise ValueError(f"capacity must be >= 1 or None, got {capacity}")
        if shed_policy not in ("reject", "drop"):
            raise ValueError(f"unknown shed_policy {shed_policy!r}")
        self._dq: collections.deque[Request] = collections.deque()
        self._cv = threading.Condition()
        self._seq = 0
        self._head = 0            # seq of the oldest request still queued
        self._deadlines: list[tuple[float, int]] = []   # (deadline, seq)
        self.notify_at = notify_at
        self.capacity = capacity
        self.shed_policy = shed_policy
        self.on_shed = on_shed

    def __len__(self) -> int:
        with self._cv:
            return len(self._dq)

    def push(self, query, *, exclude: Sequence[int] = (),
             seed_vertex: Optional[int] = None,
             deadline: Optional[float] = None) -> AsyncResult:
        res = AsyncResult(deadline=deadline)
        victim: Optional[Request] = None
        with self._cv:
            if self.capacity is not None and \
                    len(self._dq) >= self.capacity:
                victim = self._shed_for(deadline)
                if victim is not None:
                    # fail under _cv so pop_ready can't dispatch the
                    # victim between selection and the state flip (the
                    # result lock nests inside _cv, never the reverse)
                    victim.result._fail(OverloadError(
                        "shed from queue: a fuller queue arrived before "
                        "your deadline", depth=self.capacity,
                        capacity=self.capacity, shed_at="queue"))
            req = Request(query=query, result=res, seq=self._seq,
                          exclude=exclude, seed_vertex=seed_vertex)
            res.seq = req.seq
            self._seq += 1
            self._dq.append(req)
            if deadline is not None:
                heapq.heappush(self._deadlines, (deadline, req.seq))
            n = len(self._dq)
            if n == 1 or (self.notify_at is not None
                          and n >= self.notify_at):
                self._cv.notify_all()
        if victim is not None and self.on_shed is not None:
            # callback outside the lock; the victim stays in the deque
            # (pop_ready discards it) so the seq-contiguity that
            # next_deadline's lazy heap relies on is preserved
            self.on_shed(victim)
        return res

    def _shed_for(self, incoming_deadline: Optional[float]
                  ) -> Optional[Request]:
        """Called under ``_cv`` when the deque is at/over capacity.
        Returns a queued victim to fail (admitting the incoming request),
        or raises :class:`OverloadError` to reject the incoming one."""
        live = [r for r in self._dq if r.result._state == "pending"]
        if len(live) < self.capacity:
            return None               # slack was cancelled/shed slots
        depth = len(live)
        if self.shed_policy == "drop":
            with_dl = [r for r in live if r.deadline is not None]
            if with_dl:
                victim = min(with_dl, key=lambda r: r.deadline)
                if incoming_deadline is None \
                        or incoming_deadline > victim.deadline:
                    return victim
                # the incoming request is the most doomed: fall through
        raise OverloadError(
            f"admission queue full ({depth}/{self.capacity})",
            depth=depth, capacity=self.capacity, shed_at="submit")

    def pop_ready(self, max_n: int) -> list[Request]:
        """Up to ``max_n`` oldest live requests, strict FIFO.  Requests
        cancelled or shed while queued are discarded here (their futures
        are already set), so they never occupy a lane."""
        out: list[Request] = []
        with self._cv:
            while self._dq and len(out) < max_n:
                req = self._dq.popleft()
                self._head = req.seq + 1
                if req.result._state != "pending":
                    continue
                out.append(req)
        return out

    def oldest_submit_t(self) -> Optional[float]:
        with self._cv:
            for req in self._dq:
                if req.result._state == "pending":
                    return req.result.submitted_at
        return None

    def next_deadline(self) -> Optional[float]:
        """Earliest live deadline currently queued (None if none carry
        one) — the input to the engine's deadline-aware flush timing.
        Stale heap entries (dispatched or cancelled requests) are
        discarded lazily here."""
        with self._cv:
            h = self._deadlines
            while h and h[0][1] < self._head:
                heapq.heappop(h)
            # a cancelled/shed-but-still-queued request: O(dead entries),
            # and only when the earliest deadline is a dead one
            while h and h[0][1] >= self._head:
                dl, seq = h[0]
                req = self._dq[seq - self._head] \
                    if seq - self._head < len(self._dq) else None
                if req is not None and req.seq == seq \
                        and req.result._state != "pending":
                    heapq.heappop(h)
                    continue
                return dl
        return None

    def wait(self, timeout: Optional[float] = None) -> None:
        """Sleep until a push (or timeout).  Spurious wakeups are fine —
        the engine recomputes its flush decision every pass."""
        with self._cv:
            self._cv.wait(timeout)

    def notify(self) -> None:
        with self._cv:
            self._cv.notify_all()
