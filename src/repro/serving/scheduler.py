"""Admission queue + request futures for the continuous-batching engine.

The scheduler side of ``AsyncQueryEngine``: single-query submits land in
an :class:`AdmissionQueue` as :class:`Request` records and are handed out
strictly FIFO (queue-order fairness — a burst that overfills one bucket
is served oldest-first across consecutive flushes, never reordered by
deadline or arrival jitter).  Each request carries an
:class:`AsyncResult`, a thread-safe future the extract stage completes;
cancellation is resolved at dispatch time (a cancelled request still in
the queue is dropped before it costs a lane).

Deadlines are absolute :func:`repro.obs.clock.now` instants (the one
monotonic clock every serving timestamp comes from — see obs/clock.py).
The queue only *accounts* for them (``next_deadline`` feeds the engine's
flush-timing decision); the policy itself — force a flush when a request
nears its deadline, search an already-expired request under a partial hop
budget — lives in ``serving/async_engine.py``.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import threading
from typing import Optional, Sequence

from repro.obs import clock


class CancelledError(RuntimeError):
    """Raised by :meth:`AsyncResult.result` for a cancelled request."""


class AsyncResult:
    """Thread-safe future for one submitted query.

    States: pending -> dispatched -> done, or pending -> cancelled.
    ``ids``/``dists`` are the per-request result rows; ``partial`` is True
    when the request's deadline expired before dispatch and the engine
    returned the best-so-far beam under the partial hop budget instead of
    dropping it.

    The future doubles as the request's trace record: ``submitted_at`` /
    ``dispatched_at`` / ``device_done_at`` / ``completed_at`` are
    :func:`repro.obs.clock.now` stamps set as the request moves through
    the pipeline (ordering invariant: each <= the next), ``seq`` its
    admission order, ``sampled`` whether the engine's query-log sampler
    took it.  Tracing therefore allocates nothing per query beyond this
    object, which exists anyway."""

    __slots__ = ("_event", "_lock", "_state", "ids", "dists", "partial",
                 "submitted_at", "dispatched_at", "device_done_at",
                 "completed_at", "deadline", "flush_index", "seq", "sampled")

    def __init__(self, deadline: Optional[float] = None):
        self._event = threading.Event()
        self._lock = threading.Lock()
        self._state = "pending"
        self.ids = None
        self.dists = None
        self.partial = False
        self.submitted_at = clock.now()
        self.dispatched_at: Optional[float] = None
        self.device_done_at: Optional[float] = None
        self.completed_at: Optional[float] = None
        self.deadline = deadline
        self.flush_index: Optional[int] = None
        self.seq: Optional[int] = None
        self.sampled = False

    # -- state transitions (engine-side) -----------------------------------
    def _mark_dispatched(self, flush_index: int) -> None:
        with self._lock:
            self._state = "dispatched"
            self.dispatched_at = clock.now()
            self.flush_index = flush_index

    def _complete(self, ids, dists, *, partial: bool) -> None:
        with self._lock:
            self.ids, self.dists = ids, dists
            self.partial = partial
            self.completed_at = clock.now()
            self._state = "done"
        self._event.set()

    def _try_cancel(self) -> bool:
        with self._lock:
            if self._state != "pending":
                return False
            self._state = "cancelled"
        self._event.set()
        return True

    # -- caller side -------------------------------------------------------
    @property
    def cancelled(self) -> bool:
        return self._state == "cancelled"

    def done(self) -> bool:
        return self._event.is_set()

    def cancel(self) -> bool:
        """Cancel if still queued.  Returns False once dispatched — the
        lane is already paid for and the result will arrive."""
        return self._try_cancel()

    def result(self, timeout: Optional[float] = None):
        """Block for (ids, dists).  Raises :class:`CancelledError` for a
        cancelled request, TimeoutError if the wait expires."""
        if not self._event.wait(timeout):
            raise TimeoutError("result not ready")
        if self._state == "cancelled":
            raise CancelledError("request was cancelled before dispatch")
        return self.ids, self.dists

    @property
    def latency_s(self) -> Optional[float]:
        if self.completed_at is None:
            return None
        return self.completed_at - self.submitted_at


@dataclasses.dataclass
class Request:
    """One admitted query: operands plus scheduling metadata."""

    query: "object"                      # (m,) float32 np.ndarray
    result: AsyncResult
    seq: int                             # admission order (FIFO key)
    exclude: Sequence[int] = ()
    seed_vertex: Optional[int] = None

    @property
    def deadline(self) -> Optional[float]:
        return self.result.deadline


class AdmissionQueue:
    """FIFO admission queue shared by the submit side and the scheduler
    thread.  All waits go through one condition variable.

    Pushes are cheap by design — the serving host shares cores with the
    device program (single-process jax), so per-request overhead on the
    submit path is stolen straight from search compute.  ``push`` only
    wakes the scheduler on the transitions it actually acts on: queue
    went non-empty (start the linger clock) or reached ``notify_at``
    (= the engine's ``max_batch``: a full bucket should flush now, not at
    linger expiry).  In between, the scheduler's own timed waits poll the
    flush instant.  Deadlines are tracked in a lazy min-heap so
    :meth:`next_deadline` is O(log n) amortized, not a deque scan per
    scheduler pass."""

    def __init__(self, notify_at: Optional[int] = None):
        self._dq: collections.deque[Request] = collections.deque()
        self._cv = threading.Condition()
        self._seq = 0
        self._head = 0            # seq of the oldest request still queued
        self._deadlines: list[tuple[float, int]] = []   # (deadline, seq)
        self.notify_at = notify_at

    def __len__(self) -> int:
        with self._cv:
            return len(self._dq)

    def push(self, query, *, exclude: Sequence[int] = (),
             seed_vertex: Optional[int] = None,
             deadline: Optional[float] = None) -> AsyncResult:
        res = AsyncResult(deadline=deadline)
        with self._cv:
            req = Request(query=query, result=res, seq=self._seq,
                          exclude=exclude, seed_vertex=seed_vertex)
            res.seq = req.seq
            self._seq += 1
            self._dq.append(req)
            if deadline is not None:
                heapq.heappush(self._deadlines, (deadline, req.seq))
            n = len(self._dq)
            if n == 1 or (self.notify_at is not None
                          and n >= self.notify_at):
                self._cv.notify_all()
        return res

    def pop_ready(self, max_n: int) -> list[Request]:
        """Up to ``max_n`` oldest live requests, strict FIFO.  Requests
        cancelled while queued are discarded here (their futures are
        already set), so they never occupy a lane."""
        out: list[Request] = []
        with self._cv:
            while self._dq and len(out) < max_n:
                req = self._dq.popleft()
                self._head = req.seq + 1
                if req.result.cancelled:
                    continue
                out.append(req)
        return out

    def oldest_submit_t(self) -> Optional[float]:
        with self._cv:
            for req in self._dq:
                if not req.result.cancelled:
                    return req.result.submitted_at
        return None

    def next_deadline(self) -> Optional[float]:
        """Earliest live deadline currently queued (None if none carry
        one) — the input to the engine's deadline-aware flush timing.
        Stale heap entries (dispatched or cancelled requests) are
        discarded lazily here."""
        with self._cv:
            h = self._deadlines
            while h and h[0][1] < self._head:
                heapq.heappop(h)
            # a cancelled-but-still-queued request: O(cancellations), and
            # only when the earliest deadline is the cancelled one
            while h and h[0][1] >= self._head:
                dl, seq = h[0]
                req = self._dq[seq - self._head] \
                    if seq - self._head < len(self._dq) else None
                if req is not None and req.seq == seq \
                        and req.result.cancelled:
                    heapq.heappop(h)
                    continue
                return dl
        return None

    def wait(self, timeout: Optional[float] = None) -> None:
        """Sleep until a push (or timeout).  Spurious wakeups are fine —
        the engine recomputes its flush decision every pass."""
        with self._cv:
            self._cv.wait(timeout)

    def notify(self) -> None:
        with self._cv:
            self._cv.notify_all()
