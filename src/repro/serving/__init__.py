"""Serving: batched ANN query engine over (sharded) DEG indexes."""
