"""Serving: batched ANN query engines over (sharded) DEG indexes.

* ``engine.QueryEngine`` — the synchronous batch engine (the golden
  bit-identical baseline; sessions, online inserts, refinement);
* ``async_engine.AsyncQueryEngine`` — the continuous-batching online
  engine (admission queue, deadline-aware flush, pipelined bucketed
  programs);
* ``buckets`` — the bucketed fixed-shape program table both flush
  through; ``scheduler`` — the admission queue + request futures.
"""
from repro.serving.async_engine import AsyncEngineStats, AsyncQueryEngine  # noqa: F401
from repro.serving.engine import EngineStats, QueryEngine  # noqa: F401
from repro.serving.scheduler import AsyncResult, CancelledError  # noqa: F401
