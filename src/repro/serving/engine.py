"""Batched ANN query engine (the production face of the paper's system).

``QueryEngine`` fronts a :class:`repro.core.build.DEGIndex` (single host) or
a :class:`repro.distributed.index.ShardedDEG` (mesh) with:

* **request batching**: incoming queries are buffered and flushed as one
  fixed-shape device call (lane padding keeps the jit cache to one entry);
* **exploration sessions**: per-user exclude lists implement the paper's
  browsing protocol (§6.7) — results the user has seen never reappear, while
  navigation may still pass through them;
* **online inserts**: new vectors are added through the incremental build
  path (Alg. 3) and are searchable on the next flush — the "time between
  insertion and findability" requirement of paper §1.1;
* **continuous refinement**: ``refine_budget`` edge-optimization iterations
  (Alg. 5) run between flushes — the paper's central idea, as a background
  serving-loop activity;
* **quantized serving**: ``codec="sq8"|"fp16"|"pq"`` makes every flush
  traverse the compressed vector store (two-stage search: exact rerank of
  ``rerank_k`` candidates restores recall) — the paper's predictable-index-
  size claim extended to a ~4x (sq8) or >= 8x (pq, LUT-based ADC
  traversal) smaller hot store; ``memory_stats()`` reports the footprint.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.build import DEGIndex
from repro.core.graph import INVALID
from repro.obs import clock
from repro.obs.metrics import MetricsRegistry
from repro.obs.querylog import LATENCY_METRIC, QueryLogWriter, make_record
from repro.obs.trace import Sampler
from repro.serving import buckets as _buckets


@dataclasses.dataclass
class EngineStats:
    flushes: int = 0
    queries: int = 0
    inserts: int = 0
    refine_iterations: int = 0   # improved EDGES (refine's return unit)
    total_search_s: float = 0.0

    @property
    def qps(self) -> float:
        return self.queries / self.total_search_s if self.total_search_s else 0.0


class QueryEngine:
    def __init__(self, index: DEGIndex, *, k: int = 10, eps: float = 0.1,
                 max_batch: int = 64, bucket_floor: int = 8,
                 refine_budget: int = 0,
                 beam_width: Optional[int] = None, exclude_width: int = 8,
                 codec: str = "float32", rerank_k: Optional[int] = None,
                 expand_width: Optional[int] = None,
                 visited_size: Optional[int] = None,
                 hop_backend: Optional[str] = None,
                 preset: Optional[str] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 trace_sample: float = 0.0,
                 query_log: Optional[QueryLogWriter] = None):
        """``codec`` picks the vector store the beam traverses for THIS
        engine ("float32" exact | "fp16" | "sq8" | "pq"); compressed
        codecs run the two-stage search (exact rerank of ``rerank_k``
        candidates, default ``4 * k`` — pq wants a wider stage, see
        ``configs.deg.QUANT_PRESETS``).  Engines over the same index may
        choose different codecs — the index caches one store per codec.

        ``expand_width`` / ``visited_size`` / ``hop_backend`` configure the
        multi-expansion engine for this engine's flushes (None = inherit
        the index's ``DEGParams`` knobs); engines over one index may serve
        different (E, backend) points of the Pareto sweep.  ``preset``
        names a ``configs.deg.SEARCH_PRESETS`` entry supplying those knobs
        (plus ``beam_width``) wholesale; explicit arguments win.

        Flushes of fewer than ``max_batch`` queries are padded to the
        power-of-two bucket >= ``bucket_floor`` that fits them
        (``serving/buckets.py``), so the jit cache holds at most one
        program per bucket instead of one per batch size — and a
        single-query flush no longer pays a ``max_batch``-wide program."""
        from repro.quant.codec import CODECS

        if codec not in CODECS:
            raise ValueError(f"unknown codec {codec!r} "
                             f"(have {sorted(CODECS)})")
        if preset is not None:
            from repro.configs.deg import SEARCH_PRESETS

            p = SEARCH_PRESETS[preset]
            expand_width = p.expand_width if expand_width is None \
                else expand_width
            hop_backend = p.hop_backend if hop_backend is None \
                else hop_backend
            visited_size = p.visited_size if visited_size is None \
                else visited_size
            beam_width = p.beam_width if beam_width is None else beam_width
        self.index = index
        self.k, self.eps, self.beam_width = k, eps, beam_width
        self.codec, self.rerank_k = codec, rerank_k
        self.expand_width = expand_width
        self.visited_size = visited_size
        self.hop_backend = hop_backend
        self.max_batch = max_batch
        self.refine_budget = refine_budget
        self.stats = EngineStats()
        # observability (obs/): a registry is always present (own one by
        # default) so flush-level metrics are free to keep on; per-query
        # log records are written only for sampled queries.  Metric
        # objects are resolved once here — flush() never touches the
        # registry dict.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._sampler = Sampler(trace_sample)
        self._query_log = query_log
        self._qid = 0                     # submit order, the log's qid key
        self._m_queries = self.metrics.counter("serving_requests_total")
        self._m_flushes = self.metrics.counter("serving_flushes_total")
        self._m_hops = self.metrics.counter("serving_hops_total")
        self._m_evals = self.metrics.counter("serving_evals_total")
        # request latency for the closed-loop engine is the flush that
        # served it (no admission queue): observed per request so the
        # stats digest and a query-log replay see the same metric the
        # async engine reports
        self._m_latency = self.metrics.histogram(LATENCY_METRIC)
        self._m_flush_lat: dict = {}      # bucket -> flush-latency histogram
        self._pending: list = []          # (query_vec, exclude_ids, future)
        self._sessions: dict[str, set] = {}
        # minimum exclude-lane width: per-flush widths are bucketed to
        # powers of two above this floor, so flushes with comparable
        # session history reuse the same jitted program (bounded entries)
        # without one long session permanently widening every later flush.
        self._exclude_width = max(1, exclude_width)
        self.cfg = _buckets.ProgramConfig(
            k=k, eps=eps, beam_width=beam_width, codec=codec,
            rerank_k=rerank_k, expand_width=expand_width,
            visited_size=visited_size, hop_backend=hop_backend)
        self.buckets = _buckets.bucket_sizes(max_batch, bucket_floor)

    def warmup(self, *, with_budget: bool = False) -> dict:
        """Precompile every (bucket, variant) program this engine can
        dispatch (boot-time, so no request ever pays a trace).  Returns
        ``{(bucket, variant): seconds}`` compile wall times."""
        view = self.index.acquire_view()
        try:
            return _buckets.precompile(view, self.cfg, self.buckets,
                                       with_budget=with_budget)
        finally:
            self.index.release_view(view)

    # -- lifecycle ---------------------------------------------------------
    @classmethod
    def from_snapshot(cls, path, **engine_kwargs) -> "QueryEngine":
        """Warm-start an engine from a persisted index (persist/snapshot):
        no rebuild on boot — the restored index serves on the first flush
        and stays fully mutable (online inserts/deletes/refinement)."""
        return cls(DEGIndex.load(path), **engine_kwargs)

    def save(self, path) -> None:
        """Flush pending queries, then snapshot the backing index (session
        exclude-sets are serving-process state, not index state, and are
        deliberately not persisted)."""
        self.flush()
        self.index.save(path)

    # -- request paths ----------------------------------------------------
    def submit(self, query: np.ndarray, session: Optional[str] = None,
               seed_vertex: Optional[int] = None) -> dict:
        """Queue one query; returns a 'future' dict filled at flush()."""
        fut = {"done": False, "ids": None, "dists": None}
        excl = sorted(self._sessions.get(session, ())) if session else []
        qid = self._qid
        self._qid += 1
        sampled = self._sampler.take() if self._sampler.active else False
        self._pending.append((np.asarray(query, np.float32), excl, fut,
                              session, seed_vertex, qid, sampled))
        if len(self._pending) >= self.max_batch:
            self.flush()
        return fut

    def search(self, queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Synchronous batched search (no sessions)."""
        futs = [self.submit(q) for q in np.atleast_2d(queries)]
        self.flush()
        return (np.stack([f["ids"] for f in futs]),
                np.stack([f["dists"] for f in futs]))

    def explore(self, vertex: int, session: str) -> dict:
        """Exploration query: seed = an indexed vertex; session exclusions
        accumulate (paper §6.7 protocol)."""
        self._sessions.setdefault(session, set()).add(int(vertex))
        q = self.index.vectors[int(vertex)]
        return self.submit(q, session=session, seed_vertex=int(vertex))

    def memory_stats(self) -> dict:
        """Vector-store footprint of this engine's traversal path: the
        index-wide per-codec table plus the bytes/ratio for the codec this
        engine actually serves with."""
        stats = self.index.memory_stats()
        stats["codec"] = self.codec
        stats["serving_bytes"] = stats[f"{self.codec}_bytes"]
        stats["serving_ratio"] = stats[f"{self.codec}_ratio"]
        return stats

    def insert(self, vectors: np.ndarray, wave_size: int = 8) -> None:
        vectors = np.atleast_2d(np.asarray(vectors, np.float32))
        self.index.add(vectors, wave_size=wave_size)
        self.stats.inserts += vectors.shape[0]

    def delete(self, vertex: int) -> bool:
        """Online delete (beyond-paper fully-dynamic path).  Deletion
        compacts slots (the last vertex moves into the freed slot), so
        pending queries are flushed first and session exclude-sets are
        remapped."""
        self.flush()
        last = self.index.n - 1
        ok = bool(self.index.remove([int(vertex)]))
        if ok:
            for seen in self._sessions.values():
                seen.discard(int(vertex))
                if last in seen and vertex != last:
                    seen.discard(last)
                    seen.add(int(vertex))    # the moved vertex's new id
        return ok

    # -- the device call ---------------------------------------------------
    def flush(self) -> int:
        """One fixed-shape beam-engine call for the whole pending batch.

        Seed and exclude lanes go straight into ``DEGIndex.search_batch``
        through the shared bucket table (``serving/buckets.py``): the
        batch is padded to the smallest bucket that fits it, plain queries
        get the cached medoid seed, exploration queries their seed vertex
        plus session history.  A flush with no exclusions at all passes
        ``exclude=None`` (identical program to ``index.search``,
        configured beam_width honored); otherwise the exclude width is the
        batch's need bucketed to a power of two, so widths — and the beam
        widening ``L >= k + X`` that comes with them — never outlive the
        sessions that required them."""
        if not self._pending:
            return 0
        batch = self._pending[: self.max_batch]
        self._pending = self._pending[self.max_batch:]
        B = len(batch)
        # epoch capture (same contract as the async engine): with
        # publishing on, the whole flush searches one immutable snapshot
        # and quarantined vertices are excluded from results and seeds
        view = self.index.acquire_view()
        try:
            quarantine = tuple(getattr(view, "quarantine", ()) or ())
            qset = set(quarantine)
            items = [
                _buckets.BatchItem(
                    query=q,
                    # an exploration seed never reappears in its own results
                    exclude=list(dict.fromkeys(
                        ([sv] if sv is not None else [])
                        + list(ex) + list(quarantine))),
                    seed_vertex=(None if sv is not None and sv in qset
                                 else sv))
                for (q, ex, _, _, sv, _, _) in batch]
            bucket = next(b for b in self.buckets if b >= B)
            qs, seeds, excl = _buckets.pad_batch(items, bucket,
                                                 view.medoid(),
                                                 self._exclude_width)
            t0 = clock.now()
            res = _buckets.dispatch(view, self.cfg, qs, seeds, excl)
            ids, dists = np.asarray(res.ids), np.asarray(res.dists)
        finally:
            self.index.release_view(view)
        flush_s = clock.now() - t0
        self.stats.total_search_s += flush_s
        flush_index = self.stats.flushes
        self.stats.flushes += 1
        self.stats.queries += B
        hops = np.asarray(res.hops)
        evals = np.asarray(res.evals)
        vfrac = None if res.visited_frac is None \
            else np.asarray(res.visited_frac)
        self._m_flushes.inc()
        self._m_queries.inc(B)
        self._m_hops.inc(int(hops[:B].sum()))
        self._m_evals.inc(int(evals[:B].sum()))
        h = self._m_flush_lat.get(bucket)
        if h is None:
            h = self._m_flush_lat[bucket] = self.metrics.histogram(
                "serving_flush_latency_ms", bucket=str(bucket))
        h.observe(flush_s * 1e3)
        for _ in range(B):
            self._m_latency.observe(flush_s * 1e3)
        for i, (q, _, fut, session, sv, qid, sampled) in enumerate(batch):
            fut["ids"], fut["dists"] = ids[i], dists[i]
            fut["done"] = True
            if session:
                self._sessions.setdefault(session, set()).update(
                    int(x) for x in ids[i] if x != INVALID)
            if sampled and self._query_log is not None:
                self._query_log.write(make_record(
                    qid=qid, query=q, k=self.k, ids=ids[i], dists=dists[i],
                    hops=int(hops[i]), evals=int(evals[i]),
                    seed_vertex=sv,
                    exclude_n=len(items[i].exclude),
                    visited_frac=None if vfrac is None else float(vfrac[i]),
                    flush_index=flush_index, bucket=bucket,
                    latency_ms=flush_s * 1e3))
        # continuous refinement between flushes (the paper's core idea);
        # refine() counts improved EDGES (can exceed the vertex budget)
        if self.refine_budget:
            self.stats.refine_iterations += self.index.refine(
                self.refine_budget, seed=self.stats.flushes)
        return B
