"""Online integrity scrubber: audit, quarantine, repair, re-admit — live.

The paper's Table-1 guarantees (even regularity, undirectedness with
equal weights, no self loops / duplicates, single connected component)
were historically asserted only in tests.  This module audits them
continuously on a *serving* index and heals violations without taking
traffic down:

1. **Audit** — each pass sweeps the adjacency rows in chunks through the
   vectorized ``invariants.audit_rows`` (plus one frontier-sweep
   reachability check), under the index mutation lock so a concurrent
   writer's half-applied surgery is never mistaken for damage.
2. **Quarantine** — flagged vertices enter ``index.quarantine`` and the
   damaged rows are sanitized immediately (invalid half-edges dropped, so
   the live graph stays safely traversable); a ``publish()`` makes the
   quarantine visible to serving at the next flush — quarantined ids are
   excluded from results and session seeds, and the published medoid
   avoids them.
3. **Repair** — ``core.repair.repair_vertices`` re-completes the
   deficient rows (delete-repair pairing + edge splits), reconnects any
   split component, and polishes with an Alg.-5 refinement sweep.
4. **Re-admit** — repaired vertices leave quarantine only after a clean
   re-audit (row bitmask 0 *and* reachable); the follow-up ``publish()``
   restores them to serving.  Vertices that fail re-audit stay
   quarantined and are retried next pass.

The loop is wired like the async engine's supervisor: a daemon thread
with deterministic fault hooks (``scrub.audit`` per chunk,
``scrub.repair`` before surgery) so chaos tests can delay or kill it at
decision points; a crashed pass is counted and the next pass starts
clean — the scrubber never takes the serving path down with it.

Known limit: a concurrent delete compacts slots, and although the
quarantine set tracks the remap (core/delete.py), a vertex flagged in an
earlier chunk of the *same pass* may have moved by repair time.  The
repair re-audits whatever currently sits at those ids, so the worst case
is a healthy vertex briefly quarantined — excluded, never corrupted —
and the next pass converges.

WAL interaction: repairs are deliberately *not* journaled.  Corruption is
an in-RAM event the journal never saw, so ``recover(snapshot, wal)``
reconstructs the uncorrupted timeline directly — journaling the repair
would bake the damage into an otherwise clean recovery.  The cost is that
after a repair the live graph may differ bit-wise from a fresh replay
(the repaired edges are not necessarily the original ones); structural
validity and the publish protocol hold either way.

``corrupt_adjacency`` is the seeded fault injector used by tests and the
CI ``scrub-smoke`` job: it simulates in-range bit flips (wrong neighbor
id, scribbled weight) that a search can traverse without crashing but
the audit must catch.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional

import numpy as np

from repro.core import invariants as _inv
from repro.obs import clock
from repro.obs.metrics import (SCRUB_AUDITED_TOTAL, SCRUB_QUARANTINED_TOTAL,
                               SCRUB_REPAIRED_TOTAL)
from repro.resilience import faults as _faults


@dataclasses.dataclass
class ScrubStats:
    passes: int = 0
    audited: int = 0        # row audits performed (rows x passes)
    quarantined: int = 0    # vertices that entered quarantine
    repaired: int = 0       # vertices that passed a clean re-audit
    readmitted: int = 0     # == repaired (kept separate for the summary)
    unrepaired: int = 0     # still quarantined after the latest pass
    crashes: int = 0        # passes killed by injected faults
    errors: int = 0         # passes that died on an unexpected exception
    last_pass_s: float = 0.0


class IntegrityScrubber:
    """Background Table-1 auditor with quarantine-and-repair.

    ``start()`` spawns the daemon loop (one pass every ``interval_s``);
    ``run_pass()`` is the synchronous unit the loop calls — tests drive
    it directly for determinism.  Metrics flow through the owning index's
    registry when one is attached (``scrub_vertices_audited_total``,
    ``scrub_quarantined_total``, ``scrub_repaired_total``)."""

    def __init__(self, index, *, chunk: int = 256, interval_s: float = 0.5,
                 refine_repaired: bool = True, publish: bool = True):
        self.index = index
        self.chunk = int(chunk)
        self.interval_s = float(interval_s)
        self.refine_repaired = bool(refine_repaired)
        # publish quarantine/repair transitions as new epochs (requires
        # enable_publishing(); off = pure audit/repair, e.g. sync mode)
        self.publish = bool(publish)
        self.stats = ScrubStats()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._loop, name="deg-scrubber", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        t, self._thread = self._thread, None
        if t is not None:
            t.join(timeout=30.0)

    close = stop

    def __enter__(self) -> "IntegrityScrubber":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    def _loop(self) -> None:
        from repro.resilience.faults import FaultInjected

        while not self._stop.wait(self.interval_s):
            try:
                self.run_pass()
            except FaultInjected:
                self.stats.crashes += 1      # chaos kill: next pass restarts
            except Exception:
                self.stats.errors += 1       # never take serving down

    # -- one pass ----------------------------------------------------------
    def run_pass(self) -> dict:
        """Audit the whole graph once, quarantine + repair + re-admit.
        Returns a summary dict (also folded into ``self.stats``)."""
        idx = self.index
        t0 = clock.now()
        summary = {"audited": 0, "flagged": 0, "quarantined": 0,
                   "repaired": 0, "readmitted": 0, "unrepaired": 0}
        if idx.builder is None:
            return summary
        metrics = idx.metrics
        flagged: list[int] = []
        # 1. chunked row audit (lock per chunk: writers interleave freely)
        start = 0
        while start < idx.n:
            _faults.fire("scrub.audit", start=start)
            with idx.mutation_lock:
                hi = min(start + self.chunk, idx.n)
                rows = np.arange(start, hi)
                mask = _inv.audit_rows(idx.builder, rows)
                bad = rows[mask != 0]
            flagged.extend(int(v) for v in bad)
            summary["audited"] += int(rows.size)
            start = hi
        # reachability: one frontier sweep from the published entry point
        with idx.mutation_lock:
            if idx.n > 0:
                entry = idx.medoid()
                unreached = _inv.unreachable_vertices(idx.builder, entry)
                flagged.extend(int(v) for v in unreached)
        flagged = sorted(set(flagged))
        summary["flagged"] = len(flagged)
        self.stats.passes += 1
        self.stats.audited += summary["audited"]
        if metrics is not None:
            metrics.counter(SCRUB_AUDITED_TOTAL).inc(summary["audited"])
        # 2. quarantine + sanitize + publish (serving is protected from
        # the damage one flush after this swap)
        if flagged:
            from repro.core.repair import sanitize_rows

            with idx.mutation_lock:
                fresh = [v for v in flagged if v not in idx.quarantine]
                idx.quarantine.update(flagged)
                sanitize_rows(idx, flagged)
                if self.publish and idx.publishing:
                    idx.publish()
            summary["quarantined"] = len(fresh)
            self.stats.quarantined += len(fresh)
            if metrics is not None and fresh:
                metrics.counter(SCRUB_QUARANTINED_TOTAL).inc(len(fresh))
        # 3. repair everything currently quarantined (incl. carry-overs
        # from earlier passes), re-audit, re-admit what came back clean
        if idx.quarantine:
            _faults.fire("scrub.repair", quarantined=len(idx.quarantine))
            from repro.core.repair import repair_vertices

            with idx.mutation_lock:
                work = sorted(idx.quarantine)
                candidates, _failed = repair_vertices(
                    idx, work, refine_after=self.refine_repaired)
                # re-admission gate: clean row audit AND reachable
                clean: list[int] = []
                if candidates:
                    mask = _inv.audit_rows(
                        idx.builder, np.asarray(candidates, np.int64))
                    entry = idx.medoid()
                    unreached = set(
                        int(v) for v in _inv.unreachable_vertices(
                            idx.builder, entry))
                    clean = [v for v, m in zip(candidates, mask)
                             if m == 0 and v not in unreached]
                for v in clean:
                    idx.quarantine.discard(v)
                # drop quarantined ids that no longer exist (deletes)
                idx.quarantine = {v for v in idx.quarantine if v < idx.n}
                if self.publish and idx.publishing:
                    idx.publish()
            summary["repaired"] = len(clean)
            summary["readmitted"] = len(clean)
            self.stats.repaired += len(clean)
            self.stats.readmitted += len(clean)
            if metrics is not None and clean:
                metrics.counter(SCRUB_REPAIRED_TOTAL).inc(len(clean))
        summary["unrepaired"] = len(idx.quarantine)
        self.stats.unrepaired = len(idx.quarantine)
        self.stats.last_pass_s = clock.now() - t0
        return summary


def corrupt_adjacency(index, n_flips: int, seed: int = 0) -> list[int]:
    """Seeded corruption injector (tests / CI ``scrub-smoke``): flip
    ``n_flips`` adjacency entries to wrong in-range neighbor ids and
    scribble their weights — the damage class a memory fault or a buggy
    surgery leaves behind.  In-range ids keep the beam traversal safe
    (gathers stay in bounds) while breaking undirectedness / weights, so
    serving survives until the scrubber heals the graph.  Returns the
    corrupted row ids."""
    b = index.builder
    if b is None or b.n < 3:
        return []
    rng = np.random.default_rng(seed)
    rows: list[int] = []
    with index.mutation_lock:
        for _ in range(int(n_flips)):
            r = int(rng.integers(0, b.n))
            s = int(rng.integers(0, b.degree))
            wrong = int(rng.integers(0, b.n))
            b.adjacency[r, s] = wrong
            b.weights[r, s] = float(abs(b.weights[r, s]) * 2.0 + 1.0)
            b.mark_dirty(r)
            rows.append(r)
    return sorted(set(rows))
