"""Continuous-batching async serving engine.

``AsyncQueryEngine`` turns the synchronous ``QueryEngine.flush`` batch
call into an online serving loop:

* **admission queue** — ``submit`` returns immediately with an
  :class:`~repro.serving.scheduler.AsyncResult`; a scheduler thread
  coalesces queued singles into dynamic batches, padded into the same
  power-of-two **bucketed fixed-shape programs** the sync engine flushes
  through (``serving/buckets.py``), so steady state never retraces and a
  light load never pays the ``max_batch``-wide program;
* **deadline-aware flush** — a request nearing its deadline (minus the
  measured flush latency and a safety ``slack_ms``) forces a flush
  before the batch fills; a request whose deadline already expired at
  dispatch is searched under a ``partial_hops`` per-lane hop budget
  (the beam engine's early-extract operand) and completes flagged
  ``partial=True`` — best-so-far results instead of a drop;
* **host↔device pipelining** — dispatch is asynchronous (jax enqueues
  the program and returns), so while flush *i* computes on device, the
  scheduler thread stages and transfers flush *i+1* and the extract
  thread blocks on flush *i-1*'s device→host readback; a bounded
  in-flight queue (``pipeline_depth``) is the double buffer and the
  backpressure;
* **bit-identity** — with no deadline fired, a flush runs the *same
  program on the same operands* as ``QueryEngine.flush`` (both go
  through ``buckets.dispatch``), and per-lane results are independent of
  batch composition, so async results are bit-identical to a sync flush
  of the same queries no matter how the scheduler grouped them (pinned
  by tests/test_serving_async.py against the golden fixture).

The engine serves a read-only view of the index: run mutations (insert /
delete / refine) through the owning ``QueryEngine`` or the index itself
while no async engine is live, or between ``close()``/construction.
"""
from __future__ import annotations

import dataclasses
import queue as _queue
import threading
from typing import Optional, Sequence

import numpy as np

from repro.obs import clock
from repro.obs.metrics import MetricsRegistry
from repro.obs.querylog import LATENCY_METRIC, QueryLogWriter, make_record
from repro.obs.trace import Sampler
from repro.serving import buckets as _buckets
from repro.serving.scheduler import AdmissionQueue, AsyncResult, Request


@dataclasses.dataclass
class AsyncEngineStats:
    flushes: int = 0
    queries: int = 0
    partials: int = 0           # deadline-expired, served best-so-far
    forced_flushes: int = 0     # flushed early for a nearing deadline
    ema_flush_s: float = 0.0    # smoothed dispatch->extracted wall time
    bucket_hist: dict = dataclasses.field(default_factory=dict)


class AsyncQueryEngine:
    def __init__(self, index, *, k: int = 10, eps: float = 0.1,
                 beam_width: Optional[int] = None,
                 codec: str = "float32", rerank_k: Optional[int] = None,
                 expand_width: Optional[int] = None,
                 visited_size: Optional[int] = None,
                 hop_backend: Optional[str] = None,
                 preset: Optional[str] = None,
                 slo: "str | object | None" = None,
                 max_batch: Optional[int] = None,
                 bucket_floor: Optional[int] = None,
                 deadline_ms: "float | None" = "unset",
                 slack_ms: Optional[float] = None,
                 linger_ms: Optional[float] = None,
                 partial_hops: Optional[int] = None,
                 pipeline_depth: Optional[int] = None,
                 exclude_width: int = 8,
                 metrics: Optional[MetricsRegistry] = None,
                 trace_sample: float = 0.0,
                 query_log: Optional[QueryLogWriter] = None,
                 start: bool = True):
        """``preset`` names a ``configs.deg.SEARCH_PRESETS`` entry (the
        L/E search program); ``slo`` a ``configs.deg.SLO_PRESETS`` entry
        (or a ``ServingPreset`` instance) supplying the scheduler knobs —
        explicit keyword arguments win over both.  ``deadline_ms`` is the
        default per-request SLO (None = no deadline; requests may
        override per ``submit``).

        ``metrics`` is the engine's :class:`MetricsRegistry` (own one by
        default — pass a shared registry to roll several engines into one
        export).  Flush-level metrics and the request-latency histogram
        are always on (allocation-free observes).  ``trace_sample`` in
        [0, 1] picks which queries get a ``query_log`` JSONL record
        (obs/querylog.py); at 0.0 the per-query cost is one attribute
        compare per flush — no record is built, nothing allocated."""
        from repro.configs.deg import SLO_PRESETS, ServingPreset

        if preset is not None:
            from repro.configs.deg import SEARCH_PRESETS

            p = SEARCH_PRESETS[preset]
            expand_width = p.expand_width if expand_width is None \
                else expand_width
            hop_backend = p.hop_backend if hop_backend is None \
                else hop_backend
            visited_size = p.visited_size if visited_size is None \
                else visited_size
            beam_width = p.beam_width if beam_width is None else beam_width
        s = SLO_PRESETS[slo] if isinstance(slo, str) else \
            (slo or ServingPreset())
        self.index = index
        self.cfg = _buckets.ProgramConfig(
            k=k, eps=eps, beam_width=beam_width, codec=codec,
            rerank_k=rerank_k, expand_width=expand_width,
            visited_size=visited_size, hop_backend=hop_backend)
        self.max_batch = max_batch if max_batch is not None else s.max_batch
        self.buckets = _buckets.bucket_sizes(
            self.max_batch,
            bucket_floor if bucket_floor is not None else s.bucket_floor)
        self.default_deadline_ms = (s.deadline_ms if deadline_ms == "unset"
                                    else deadline_ms)
        self.slack_s = (slack_ms if slack_ms is not None else s.slack_ms) \
            / 1e3
        self.linger_s = (linger_ms if linger_ms is not None else s.linger_ms) \
            / 1e3
        self.partial_hops = (partial_hops if partial_hops is not None
                             else s.partial_hops)
        depth = pipeline_depth if pipeline_depth is not None \
            else s.pipeline_depth
        self._exclude_width = max(1, exclude_width)
        self.stats = AsyncEngineStats()
        # observability: resolve every metric object once here so the
        # scheduler / extract threads never touch the registry dict.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._sampler = Sampler(trace_sample)
        self._query_log = query_log
        self._m_queries = self.metrics.counter("serving_requests_total")
        self._m_flushes = self.metrics.counter("serving_flushes_total")
        self._m_forced = self.metrics.counter("serving_forced_flushes_total")
        self._m_partials = self.metrics.counter(
            "serving_deadline_partials_total")
        self._m_hops = self.metrics.counter("serving_hops_total")
        self._m_evals = self.metrics.counter("serving_evals_total")
        self._m_queue_depth = self.metrics.gauge("serving_queue_depth")
        self._m_latency = self.metrics.histogram(LATENCY_METRIC)
        self._m_flush_lat = {
            b: self.metrics.histogram("serving_flush_latency_ms",
                                      bucket=str(b))
            for b in self.buckets}
        self._queue = AdmissionQueue(notify_at=self.max_batch)
        # late-binding pipeline: the scheduler takes a dispatch slot
        # BEFORE popping the queue, so a batch is formed at the instant
        # the pipeline can absorb it (pop early and requests arriving
        # while the staged flush waits would miss the bus — the
        # small-flush oscillation).  The semaphore holds ``depth`` slots
        # (the double buffer); extract releases one per drained flush.
        self._slots = threading.Semaphore(max(1, depth))
        self._inflight: _queue.Queue = _queue.Queue()
        self._stop = False
        self._threads: list[threading.Thread] = []
        if start:
            self.start()

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> None:
        if self._threads:
            return
        self._stop = False
        self._threads = [
            threading.Thread(target=self._scheduler_loop,
                             name="deg-serve-scheduler", daemon=True),
            threading.Thread(target=self._extract_loop,
                             name="deg-serve-extract", daemon=True),
        ]
        for t in self._threads:
            t.start()

    def close(self) -> None:
        """Drain the queue (every accepted request completes), stop the
        threads.  Idempotent."""
        self._stop = True
        self._queue.notify()
        for t in self._threads:
            t.join()
        self._threads = []
        # a submit that raced close() past the running check: cancel its
        # future rather than leave it forever pending
        for req in self._queue.pop_ready(self.max_batch):
            req.result._try_cancel()
        if self._query_log is not None:
            self._query_log.flush()

    def __enter__(self) -> "AsyncQueryEngine":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def warmup(self) -> dict:
        """Boot-time precompile of every (bucket, {plain, budget})
        program this engine can dispatch — no live request ever pays a
        trace.  Returns ``{(bucket, variant): seconds}`` compile times."""
        return _buckets.precompile(self.index, self.cfg, self.buckets,
                                   with_budget=True)

    # -- request path ------------------------------------------------------
    def submit(self, query: np.ndarray, *,
               deadline_ms: "float | None" = "unset",
               exclude: Sequence[int] = (),
               seed_vertex: Optional[int] = None) -> AsyncResult:
        """Queue one query; returns immediately.  ``deadline_ms`` is
        relative to now ("unset" = the engine default; None = no SLO).
        ``seed_vertex`` replaces the medoid seed (exploration-style
        callers add it to ``exclude`` themselves when the protocol hides
        it)."""
        if self._stop or not self._threads:
            raise RuntimeError("engine is not running (closed or never "
                               "started)")
        dl_ms = self.default_deadline_ms if deadline_ms == "unset" \
            else deadline_ms
        deadline = None if dl_ms is None else clock.now() + dl_ms / 1e3
        res = self._queue.push(np.asarray(query, np.float32),
                               exclude=list(exclude),
                               seed_vertex=seed_vertex, deadline=deadline)
        self._m_queue_depth.set(len(self._queue))
        return res

    def search(self, queries: np.ndarray, timeout: Optional[float] = 60.0
               ) -> tuple[np.ndarray, np.ndarray]:
        """Submit a batch and block for all results (convenience — the
        closed-loop face of the async engine, used by the bit-identity
        tests)."""
        futs = [self.submit(q) for q in np.atleast_2d(queries)]
        outs = [f.result(timeout) for f in futs]
        return (np.stack([o[0] for o in outs]),
                np.stack([o[1] for o in outs]))

    # -- scheduler thread --------------------------------------------------
    def _flush_at(self) -> tuple[Optional[float], bool]:
        """(instant the current queue content must flush, whether a
        deadline pulled it earlier): the oldest request's linger expiry,
        pulled forward if a queued deadline (minus slack and the measured
        flush latency) is nearer."""
        oldest = self._queue.oldest_submit_t()
        if oldest is None:
            return None, False
        at = oldest + self.linger_s
        nd = self._queue.next_deadline()
        if nd is not None:
            dl_at = nd - self.slack_s - self.stats.ema_flush_s
            if dl_at < at:
                return dl_at, True
        return at, False

    def _scheduler_loop(self) -> None:
        while True:
            if self._stop:
                while True:           # drain: accepted requests complete
                    reqs = self._queue.pop_ready(self.max_batch)
                    if not reqs:
                        break
                    self._dispatch(reqs)
                self._inflight.put(None)
                return
            if len(self._queue) == 0:
                self._queue.wait(0.02)
                continue
            if not self._slots.acquire(timeout=0.02):
                continue              # pipeline full; recheck stop flag
            deadline_forced = False
            while (not self._stop
                   and len(self._queue) < self.max_batch):
                at, forced = self._flush_at()
                now = clock.now()
                if at is None or now >= at:
                    deadline_forced = forced and at is not None
                    break
                self._queue.wait(min(at - now, 0.02))
                if len(self._queue) == 0:
                    break
            reqs = self._queue.pop_ready(self.max_batch)
            if reqs:
                if deadline_forced:
                    self.stats.forced_flushes += 1
                    self._m_forced.inc()
                self._dispatch(reqs)
            else:
                self._slots.release()

    def _dispatch(self, reqs: list[Request]) -> None:
        """Stage one bucketed flush and enqueue it (asynchronously — jax
        returns before the device finishes) for the extract thread."""
        B = len(reqs)
        bucket = next(b for b in self.buckets if b >= B)
        now = clock.now()
        expired = [r.deadline is not None and now > r.deadline for r in reqs]
        budget = None
        if any(expired):
            # expired lanes run the partial-hop early extract; the rest
            # (and the padding) are uncapped.  One budgeted program per
            # bucket regardless of which lanes expired (traced operand).
            budget = np.full(bucket, _buckets.NO_BUDGET, np.int32)
            for i, ex in enumerate(expired):
                if ex:
                    budget[i] = self.partial_hops
        items = [_buckets.BatchItem(query=r.query, exclude=r.exclude,
                                    seed_vertex=r.seed_vertex) for r in reqs]
        qs, seeds, excl = _buckets.pad_batch(items, bucket,
                                             self.index.medoid(),
                                             self._exclude_width)
        res = _buckets.dispatch(self.index, self.cfg, qs, seeds, excl,
                                hop_budget=budget)
        flush_index = self.stats.flushes
        self.stats.flushes += 1
        self.stats.queries += B
        self.stats.bucket_hist[bucket] = \
            self.stats.bucket_hist.get(bucket, 0) + 1
        self._m_flushes.inc()
        self._m_queries.inc(B)
        self._m_queue_depth.set(len(self._queue))
        if self._sampler.active:          # one compare per flush at 0.0
            for r in reqs:                # single-threaded sampler use
                r.result.sampled = self._sampler.take()
        for r in reqs:
            r.result._mark_dispatched(flush_index)
        # in-flight count is bounded by the dispatch-slot semaphore
        # (acquired before the batch was popped), so this never blocks;
        # extract releases the slot once the flush is drained
        self._inflight.put((reqs, res, expired, bucket, clock.now()))

    # -- extract thread ----------------------------------------------------
    def _extract_loop(self) -> None:
        while True:
            item = self._inflight.get()
            if item is None:
                return
            reqs, res, expired, bucket, t0 = item
            B = len(reqs)
            ids = np.asarray(res.ids)      # device->host: blocks until the
            dists = np.asarray(res.dists)  # async dispatch finished
            t_dev = clock.now()
            dt = t_dev - t0
            self.stats.ema_flush_s = dt if not self.stats.ema_flush_s \
                else 0.8 * self.stats.ema_flush_s + 0.2 * dt
            self._m_flush_lat[bucket].observe(dt * 1e3)
            # traversal counters ride the same result the flush computed
            # anyway — surfacing them costs two tiny transfers, zero
            # extra device work
            hops = np.asarray(res.hops)
            evals = np.asarray(res.evals)
            self._m_hops.inc(int(hops[:B].sum()))
            self._m_evals.inc(int(evals[:B].sum()))
            vfrac = None if res.visited_frac is None \
                else np.asarray(res.visited_frac)
            log = self._query_log
            any_sampled = log is not None and any(
                r.result.sampled for r in reqs)
            for i, r in enumerate(reqs):
                if expired[i]:
                    self.stats.partials += 1
                    self._m_partials.inc()
                r.result.device_done_at = t_dev
                r.result._complete(ids[i].copy(), dists[i].copy(),
                                   partial=expired[i])
                # observe AFTER _complete so the histogram sees the same
                # completed_at the future exposes (log replay matches)
                self._m_latency.observe(
                    (r.result.completed_at - r.result.submitted_at) * 1e3)
                if any_sampled and r.result.sampled:
                    log.write(make_record(
                        qid=r.seq, query=r.query, k=self.cfg.k,
                        ids=ids[i], dists=dists[i],
                        hops=int(hops[i]), evals=int(evals[i]),
                        seed_vertex=r.seed_vertex,
                        exclude_n=len(r.exclude),
                        visited_frac=None if vfrac is None
                        else float(vfrac[i]),
                        budget_exhausted=bool(
                            expired[i] and self.partial_hops is not None
                            and hops[i] >= self.partial_hops),
                        partial=expired[i],
                        flush_index=r.result.flush_index, bucket=bucket,
                        latency_ms=(r.result.completed_at
                                    - r.result.submitted_at) * 1e3,
                        result=r.result,
                        t_mono=r.result.submitted_at))
            self._slots.release()     # free the dispatch slot last, so a
            # newly formed batch sees this flush's arrivals in the queue
