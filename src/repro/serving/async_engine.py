"""Continuous-batching async serving engine.

``AsyncQueryEngine`` turns the synchronous ``QueryEngine.flush`` batch
call into an online serving loop:

* **admission queue** — ``submit`` returns immediately with an
  :class:`~repro.serving.scheduler.AsyncResult`; a scheduler thread
  coalesces queued singles into dynamic batches, padded into the same
  power-of-two **bucketed fixed-shape programs** the sync engine flushes
  through (``serving/buckets.py``), so steady state never retraces and a
  light load never pays the ``max_batch``-wide program;
* **deadline-aware flush** — a request nearing its deadline (minus the
  measured flush latency and a safety ``slack_ms``) forces a flush
  before the batch fills; a request whose deadline already expired at
  dispatch is searched under a ``partial_hops`` per-lane hop budget
  (the beam engine's early-extract operand) and completes flagged
  ``partial=True`` — best-so-far results instead of a drop;
* **host↔device pipelining** — dispatch is asynchronous (jax enqueues
  the program and returns), so while flush *i* computes on device, the
  scheduler thread stages and transfers flush *i+1* and the extract
  thread blocks on flush *i-1*'s device→host readback; a bounded
  in-flight queue (``pipeline_depth``) is the double buffer and the
  backpressure;
* **bit-identity** — with no deadline fired, a flush runs the *same
  program on the same operands* as ``QueryEngine.flush`` (both go
  through ``buckets.dispatch``), and per-lane results are independent of
  batch composition, so async results are bit-identical to a sync flush
  of the same queries no matter how the scheduler grouped them (pinned
  by tests/test_serving_async.py against the golden fixture).

Resilience (all opt-in, defaults preserve the historical behavior; see
``src/repro/resilience/``): a bounded admission queue (``max_queue`` +
``shed_policy``) sheds with typed ``OverloadError`` instead of growing
latency without bound; a degradation ladder (``degrade=True``) steps the
search program down rungs (slimmer beam -> hop cap -> sq8 traversal)
under sustained queue pressure with hysteresis and back up when the
queue drains; ``submit`` validates queries (NaN/Inf never reach a
batch); and a watchdog/supervisor turns a dying loop thread into typed
``EngineCrashedError`` futures plus (``max_restarts`` budget allowing) a
restarted pipeline — ``result()`` never hangs on a dead engine.

Live mutation: when the index has epoch publication enabled
(``DEGIndex.enable_publishing()``), every flush acquires the current
published epoch (``acquire_view``) and searches *its* frozen buffers —
writers are free to insert / delete / refine the live builder
concurrently and ``publish()`` at batch boundaries; a flush never
observes mid-surgery state, and each result is stamped with the epoch it
searched (``AsyncResult.epoch``) so a replay against that snapshot is
bit-identical.  Quarantined vertices (the integrity scrubber's set,
carried on the epoch) are appended to each lane's exclude list and
dropped as session seeds.  Without publishing the engine behaves as
before: it serves the index's own device cache and the index must stay
read-only while the engine is live.
"""
from __future__ import annotations

import dataclasses
import queue as _queue
import threading
from typing import Optional, Sequence

import numpy as np

from repro.obs import clock
from repro.obs.metrics import MetricsRegistry
from repro.obs.querylog import LATENCY_METRIC, QueryLogWriter, make_record
from repro.obs.trace import Sampler
from repro.resilience import faults as _faults
from repro.resilience.degrade import (DegradePolicy, LadderController,
                                      LadderRung, build_ladder)
from repro.resilience.errors import (EngineCrashedError, OverloadError,
                                     RequestValidationError)
from repro.resilience.validate import validate_query
from repro.serving import buckets as _buckets
from repro.serving.scheduler import AdmissionQueue, AsyncResult, Request


@dataclasses.dataclass
class AsyncEngineStats:
    flushes: int = 0
    queries: int = 0
    partials: int = 0           # deadline-expired, served best-so-far
    forced_flushes: int = 0     # flushed early for a nearing deadline
    ema_flush_s: float = 0.0    # smoothed dispatch->extracted wall time
    bucket_hist: dict = dataclasses.field(default_factory=dict)
    shed: int = 0               # overload-shed requests (queue + submit)
    invalid: int = 0            # rejected at validation, never enqueued
    degraded: int = 0           # requests served below the base rung
    crashes: int = 0            # loop-thread deaths observed
    restarts: int = 0           # successful supervisor restarts


class AsyncQueryEngine:
    def __init__(self, index, *, k: int = 10, eps: float = 0.1,
                 beam_width: Optional[int] = None,
                 codec: str = "float32", rerank_k: Optional[int] = None,
                 expand_width: Optional[int] = None,
                 visited_size: Optional[int] = None,
                 hop_backend: Optional[str] = None,
                 preset: Optional[str] = None,
                 slo: "str | object | None" = None,
                 max_batch: Optional[int] = None,
                 bucket_floor: Optional[int] = None,
                 deadline_ms: "float | None" = "unset",
                 slack_ms: Optional[float] = None,
                 linger_ms: Optional[float] = None,
                 partial_hops: Optional[int] = None,
                 pipeline_depth: Optional[int] = None,
                 exclude_width: int = 8,
                 metrics: Optional[MetricsRegistry] = None,
                 trace_sample: float = 0.0,
                 query_log: Optional[QueryLogWriter] = None,
                 max_queue: Optional[int] = None,
                 shed_policy: str = "reject",
                 degrade: "bool | DegradePolicy" = False,
                 validate: bool = True,
                 max_restarts: int = 3,
                 start: bool = True):
        """``preset`` names a ``configs.deg.SEARCH_PRESETS`` entry (the
        L/E search program); ``slo`` a ``configs.deg.SLO_PRESETS`` entry
        (or a ``ServingPreset`` instance) supplying the scheduler knobs —
        explicit keyword arguments win over both.  ``deadline_ms`` is the
        default per-request SLO (None = no deadline; requests may
        override per ``submit``).

        ``metrics`` is the engine's :class:`MetricsRegistry` (own one by
        default — pass a shared registry to roll several engines into one
        export).  Flush-level metrics and the request-latency histogram
        are always on (allocation-free observes).  ``trace_sample`` in
        [0, 1] picks which queries get a ``query_log`` JSONL record
        (obs/querylog.py); at 0.0 the per-query cost is one attribute
        compare per flush — no record is built, nothing allocated.

        Resilience knobs (all default to the historical behavior):
        ``max_queue`` bounds the admission queue (None = unbounded) with
        ``shed_policy`` ("reject" | "drop", see AdmissionQueue) deciding
        who gets the typed ``OverloadError``; ``degrade=True`` (or a
        :class:`DegradePolicy`) arms the graceful-degradation ladder —
        requires a bounded queue, since queue pressure is its input;
        ``validate`` screens NaN/Inf/shape at submit; ``max_restarts``
        caps how many times the supervisor revives crashed loop threads
        (0 = fail fast: the first crash is terminal)."""
        from repro.configs.deg import SLO_PRESETS, ServingPreset

        if preset is not None:
            from repro.configs.deg import SEARCH_PRESETS

            p = SEARCH_PRESETS[preset]
            expand_width = p.expand_width if expand_width is None \
                else expand_width
            hop_backend = p.hop_backend if hop_backend is None \
                else hop_backend
            visited_size = p.visited_size if visited_size is None \
                else visited_size
            beam_width = p.beam_width if beam_width is None else beam_width
        s = SLO_PRESETS[slo] if isinstance(slo, str) else \
            (slo or ServingPreset())
        self.index = index
        self.cfg = _buckets.ProgramConfig(
            k=k, eps=eps, beam_width=beam_width, codec=codec,
            rerank_k=rerank_k, expand_width=expand_width,
            visited_size=visited_size, hop_backend=hop_backend)
        self.max_batch = max_batch if max_batch is not None else s.max_batch
        self.buckets = _buckets.bucket_sizes(
            self.max_batch,
            bucket_floor if bucket_floor is not None else s.bucket_floor)
        self.default_deadline_ms = (s.deadline_ms if deadline_ms == "unset"
                                    else deadline_ms)
        self.slack_s = (slack_ms if slack_ms is not None else s.slack_ms) \
            / 1e3
        self.linger_s = (linger_ms if linger_ms is not None else s.linger_ms) \
            / 1e3
        self.partial_hops = (partial_hops if partial_hops is not None
                             else s.partial_hops)
        depth = pipeline_depth if pipeline_depth is not None \
            else s.pipeline_depth
        self._exclude_width = max(1, exclude_width)
        self.stats = AsyncEngineStats()
        # observability: resolve every metric object once here so the
        # scheduler / extract threads never touch the registry dict.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._sampler = Sampler(trace_sample)
        self._query_log = query_log
        self._m_queries = self.metrics.counter("serving_requests_total")
        self._m_flushes = self.metrics.counter("serving_flushes_total")
        self._m_forced = self.metrics.counter("serving_forced_flushes_total")
        self._m_partials = self.metrics.counter(
            "serving_deadline_partials_total")
        self._m_hops = self.metrics.counter("serving_hops_total")
        self._m_evals = self.metrics.counter("serving_evals_total")
        self._m_queue_depth = self.metrics.gauge("serving_queue_depth")
        self._m_latency = self.metrics.histogram(LATENCY_METRIC)
        self._m_flush_lat = {
            b: self.metrics.histogram("serving_flush_latency_ms",
                                      bucket=str(b))
            for b in self.buckets}
        self._m_shed = self.metrics.counter("serving_shed_total")
        self._m_invalid = self.metrics.counter(
            "serving_invalid_requests_total")
        self._m_degraded = self.metrics.counter("serving_degraded_total")
        self._m_level = self.metrics.gauge("serving_degrade_level")
        self._m_trans = {
            d: self.metrics.counter("serving_degrade_transitions_total",
                                    direction=d)
            for d in ("down", "up")}
        self._m_crashes = self.metrics.counter("serving_engine_crashes_total")
        self._m_restarts = self.metrics.counter(
            "serving_thread_restarts_total")
        # -- resilience: bounded admission + degradation ladder ------------
        self._validate = validate
        self.max_restarts = max_restarts
        self._queue = AdmissionQueue(notify_at=self.max_batch,
                                     capacity=max_queue,
                                     shed_policy=shed_policy,
                                     on_shed=self._on_shed)
        self._ladder: list[LadderRung] = [LadderRung("base", self.cfg)]
        self._ladder_ctl: Optional[LadderController] = None
        if degrade:
            if max_queue is None:
                raise ValueError("degrade needs a bounded queue "
                                 "(max_queue): queue pressure is the "
                                 "ladder's input signal")
            policy = degrade if isinstance(degrade, DegradePolicy) \
                else DegradePolicy()
            self._ladder = build_ladder(self.cfg, index.params.degree,
                                        policy)
            self._ladder_ctl = LadderController(
                len(self._ladder), max_queue, policy,
                on_change=self._on_ladder_change)
        # late-binding pipeline: the scheduler takes a dispatch slot
        # BEFORE popping the queue, so a batch is formed at the instant
        # the pipeline can absorb it (pop early and requests arriving
        # while the staged flush waits would miss the bus — the
        # small-flush oscillation).  The semaphore holds ``depth`` slots
        # (the double buffer); extract releases one per drained flush.
        self._depth = max(1, depth)
        self._slots = threading.Semaphore(self._depth)
        self._inflight: _queue.Queue = _queue.Queue()
        self._stop = False
        self._halt = False              # crash path: exit without drain
        self._crashed: Optional[EngineCrashedError] = None
        self._generation = 0
        self._staging: Optional[list[Request]] = None
        self._extracting: Optional[tuple] = None
        self._events: _queue.Queue = _queue.Queue()
        self._threads: list[threading.Thread] = []
        self._sup_thread: Optional[threading.Thread] = None
        if start:
            self.start()

    # -- resilience callbacks ----------------------------------------------
    def _on_shed(self, req: Request) -> None:
        self.stats.shed += 1
        self._m_shed.inc()

    def _on_ladder_change(self, old: int, new: int, direction: str) -> None:
        self._m_trans[direction].inc()
        self._m_level.set(new)

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> None:
        if self._threads:
            return
        self._stop = False
        self._spawn_loops()
        self._sup_thread = threading.Thread(
            target=self._supervisor_loop, name="deg-serve-supervisor",
            daemon=True)
        self._sup_thread.start()

    def _spawn_loops(self) -> None:
        gen = self._generation
        self._threads = [
            threading.Thread(target=self._guarded,
                             args=(self._scheduler_loop, "scheduler", gen),
                             name="deg-serve-scheduler", daemon=True),
            threading.Thread(target=self._guarded,
                             args=(self._extract_loop, "extract", gen),
                             name="deg-serve-extract", daemon=True),
        ]
        for t in self._threads:
            t.start()

    def close(self) -> None:
        """Drain the queue (every accepted request completes), stop the
        threads.  Idempotent."""
        self._stop = True
        self._queue.notify()
        for t in self._threads:
            t.join()
        self._threads = []
        if self._sup_thread is not None:
            # FIFO: any pending crash event is handled (futures failed,
            # no restart — _stop suppresses it) before the stop sentinel
            self._events.put(None)
            self._sup_thread.join()
            self._sup_thread = None
        # a submit that raced close() past the running check: cancel its
        # future rather than leave it forever pending
        for req in self._queue.pop_ready(self.max_batch):
            req.result._try_cancel()
        if self._query_log is not None:
            self._query_log.flush()

    def __enter__(self) -> "AsyncQueryEngine":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- watchdog / supervisor ---------------------------------------------
    def _guarded(self, body, name: str, gen: int) -> None:
        """Loop-thread wrapper: a dying loop becomes a crash event for
        the supervisor instead of a silent thread exit that leaves every
        outstanding ``result()`` hanging forever."""
        try:
            body()
        except BaseException as exc:    # noqa: BLE001 — watchdog boundary
            self._events.put(("crash", gen, name, exc))

    def _supervisor_loop(self) -> None:
        while True:
            ev = self._events.get()
            if ev is None:
                return
            _, gen, name, exc = ev
            if gen != self._generation:
                continue                # stale: peer of an already-handled
            self._handle_crash(name, exc)   # crash, threads replaced

    def _handle_crash(self, name: str, exc: BaseException) -> None:
        self._generation += 1           # events from these threads: stale
        self._halt = True
        err = EngineCrashedError(
            f"serving {name} thread died: {exc!r}", thread=name)
        err.__cause__ = exc
        self._crashed = err
        self.stats.crashes += 1
        self._m_crashes.inc()
        self._queue.notify()            # unblock the scheduler's waits
        self._inflight.put(None)        # unblock the extract's get()
        for t in self._threads:
            t.join(timeout=10.0)
        # fail everything outstanding, in pipeline order: the batch the
        # scheduler popped but never enqueued, the flushes in the device
        # pipeline (incl. the one extract was unpacking), then the queue
        staging, self._staging = self._staging, None
        extracting, self._extracting = self._extracting, None
        for req in (staging or []):
            req.result._fail(err)
        if extracting is not None:
            for req in extracting[0]:
                req.result._fail(err)
            if extracting[5] is not None:      # not yet released by extract
                self.index.release_view(extracting[5])
        while True:
            try:
                item = self._inflight.get_nowait()
            except _queue.Empty:
                break
            if item is None:
                continue
            for req in item[0]:
                req.result._fail(err)
            if item[5] is not None:
                self.index.release_view(item[5])
        for req in self._queue.pop_ready(1 << 30):
            req.result._fail(err)
        self._m_queue_depth.set(0)
        if self._stop or self.stats.restarts >= self.max_restarts:
            return                      # terminal: submit now raises
        # -- revive: fresh pipeline state, new loop threads -------------
        self.stats.restarts += 1
        self._m_restarts.inc()
        self._slots = threading.Semaphore(self._depth)
        self._inflight = _queue.Queue()
        self._halt = False
        self._crashed = None
        self._spawn_loops()
        # close the submit/crash race: anything pushed between the queue
        # sweep above and the new scheduler starting is simply served

    def health(self) -> dict:
        """Liveness/pressure summary for the ``/healthz`` endpoint."""
        lvl = 0 if self._ladder_ctl is None else self._ladder_ctl.level
        status = "crashed" if self._crashed is not None else \
            ("degraded" if lvl > 0 else "ok")
        return {
            "status": status,
            "queue_depth": len(self._queue),
            "max_queue": self._queue.capacity,
            "degrade_level": lvl,
            "degrade_rung": self._ladder[min(lvl, len(self._ladder) - 1)].name,
            "restarts": self.stats.restarts,
            "crashes": self.stats.crashes,
            "shed": self.stats.shed,
            "flushes": self.stats.flushes,
            "queries": self.stats.queries,
        }

    def warmup(self) -> dict:
        """Boot-time precompile of every (bucket, {plain, budget})
        program this engine can dispatch — no live request ever pays a
        trace.  With the degradation ladder armed this includes every
        rung's program (which also materializes e.g. the sq8 store), so
        stepping down under pressure never stalls on a trace.  Returns
        ``{(bucket, variant): seconds}`` compile times."""
        times: dict = {}
        seen: set = set()
        # compile against an acquired view: under live mutation the epoch's
        # frozen buffers are the only ones a concurrent writer can't donate
        # away mid-trace (shapes match the live index, so programs shared)
        view = self.index.acquire_view()
        try:
            for i, rung in enumerate(self._ladder):
                if rung.cfg in seen:
                    continue
                seen.add(rung.cfg)
                t = _buckets.precompile(view, rung.cfg, self.buckets,
                                        with_budget=True)
                for (b, variant), secs in t.items():
                    times[(b, variant if i == 0 else f"r{i}-{variant}")] \
                        = secs
        finally:
            self.index.release_view(view)
        return times

    # -- request path ------------------------------------------------------
    def submit(self, query: np.ndarray, *,
               deadline_ms: "float | None" = "unset",
               exclude: Sequence[int] = (),
               seed_vertex: Optional[int] = None) -> AsyncResult:
        """Queue one query; returns immediately.  ``deadline_ms`` is
        relative to now ("unset" = the engine default; None = no SLO).
        ``seed_vertex`` replaces the medoid seed (exploration-style
        callers add it to ``exclude`` themselves when the protocol hides
        it).

        Typed failure surface: raises
        :class:`~repro.resilience.RequestValidationError` for a malformed
        query (never enqueued), :class:`~repro.resilience.OverloadError`
        when the bounded queue rejects it, and
        :class:`~repro.resilience.EngineCrashedError` when the serving
        loops are dead beyond the supervisor's restart budget."""
        if self._crashed is not None:
            raise self._crashed
        if self._stop or not self._threads:
            raise RuntimeError("engine is not running (closed or never "
                               "started)")
        if self._validate:
            try:
                q = validate_query(query, self.index.dim)
            except RequestValidationError:
                self.stats.invalid += 1
                self._m_invalid.inc()
                raise
        else:
            q = np.asarray(query, np.float32)
        dl_ms = self.default_deadline_ms if deadline_ms == "unset" \
            else deadline_ms
        deadline = None if dl_ms is None else clock.now() + dl_ms / 1e3
        try:
            res = self._queue.push(q, exclude=list(exclude),
                                   seed_vertex=seed_vertex,
                                   deadline=deadline)
        except OverloadError:
            self.stats.shed += 1
            self._m_shed.inc()
            raise
        # close the submit/crash race: a push that slipped in after the
        # crash handler swept the queue would otherwise hang forever
        if self._crashed is not None:
            res._fail(self._crashed)
            raise self._crashed
        self._m_queue_depth.set(len(self._queue))
        return res

    def search(self, queries: np.ndarray, timeout: Optional[float] = 60.0
               ) -> tuple[np.ndarray, np.ndarray]:
        """Submit a batch and block for all results (convenience — the
        closed-loop face of the async engine, used by the bit-identity
        tests)."""
        futs = [self.submit(q) for q in np.atleast_2d(queries)]
        outs = [f.result(timeout) for f in futs]
        return (np.stack([o[0] for o in outs]),
                np.stack([o[1] for o in outs]))

    # -- scheduler thread --------------------------------------------------
    def _flush_at(self) -> tuple[Optional[float], bool]:
        """(instant the current queue content must flush, whether a
        deadline pulled it earlier): the oldest request's linger expiry,
        pulled forward if a queued deadline (minus slack and the measured
        flush latency) is nearer."""
        oldest = self._queue.oldest_submit_t()
        if oldest is None:
            return None, False
        at = oldest + self.linger_s
        nd = self._queue.next_deadline()
        if nd is not None:
            dl_at = nd - self.slack_s - self.stats.ema_flush_s
            if dl_at < at:
                return dl_at, True
        return at, False

    def _scheduler_loop(self) -> None:
        while True:
            if self._halt:
                return                # crash path: supervisor owns cleanup
            _faults.fire("scheduler.loop")
            if self._stop:
                while True:           # drain: accepted requests complete
                    reqs = self._queue.pop_ready(self.max_batch)
                    if not reqs:
                        break
                    self._dispatch(reqs)
                self._inflight.put(None)
                return
            if len(self._queue) == 0:
                self._queue.wait(0.02)
                continue
            if not self._slots.acquire(timeout=0.02):
                continue              # pipeline full; recheck stop flag
            deadline_forced = False
            while (not self._stop and not self._halt
                   and len(self._queue) < self.max_batch):
                at, forced = self._flush_at()
                now = clock.now()
                if at is None or now >= at:
                    deadline_forced = forced and at is not None
                    break
                self._queue.wait(min(at - now, 0.02))
                if len(self._queue) == 0:
                    break
            reqs = self._queue.pop_ready(self.max_batch)
            if reqs:
                if deadline_forced:
                    self.stats.forced_flushes += 1
                    self._m_forced.inc()
                self._dispatch(reqs)
            else:
                self._slots.release()

    def _dispatch(self, reqs: list[Request]) -> None:
        """Stage one bucketed flush and enqueue it (asynchronously — jax
        returns before the device finishes) for the extract thread."""
        # _staging lets the crash handler fail a batch that was popped
        # from the queue but never made it into the in-flight pipeline
        self._staging = reqs
        _faults.fire("scheduler.dispatch", batch=len(reqs))
        B = len(reqs)
        bucket = next(b for b in self.buckets if b >= B)
        # degradation ladder: backlog left *after* popping this batch is
        # the pressure signal; the whole flush dispatches at one rung
        level = 0
        if self._ladder_ctl is not None:
            level = self._ladder_ctl.observe(len(self._queue))
        rung = self._ladder[level]
        now = clock.now()
        expired = [r.deadline is not None and now > r.deadline for r in reqs]
        budget = None
        if any(expired) or rung.hop_budget is not None:
            # expired lanes run the partial-hop early extract; the rest
            # (and the padding) run the rung's cap, or uncapped at the
            # base rung.  One budgeted program per bucket regardless of
            # which lanes expired (traced operand).
            base = _buckets.NO_BUDGET if rung.hop_budget is None \
                else rung.hop_budget
            budget = np.full(bucket, base, np.int32)
            for i, ex in enumerate(expired):
                if ex:
                    budget[i] = min(self.partial_hops, int(base))
        # live-mutation epoch capture: the whole flush searches ONE
        # immutable published snapshot (or the index itself when not
        # publishing — then the single-writer contract applies).  The
        # reference is dropped by the extract thread once results are on
        # host; the epoch retires when its last in-flight flush releases.
        view = self.index.acquire_view()
        try:
            quarantine = tuple(getattr(view, "quarantine", ()) or ())
            qset = set(quarantine)
            items = []
            for r in reqs:
                excl_ids = r.exclude
                if quarantine:
                    # quarantined vertices never appear in results; a
                    # quarantined session seed falls back to the medoid
                    excl_ids = list(dict.fromkeys(
                        list(excl_ids) + list(quarantine)))
                sv = r.seed_vertex
                if sv is not None and sv in qset:
                    sv = None
                items.append(_buckets.BatchItem(
                    query=r.query, exclude=excl_ids, seed_vertex=sv))
            qs, seeds, excl = _buckets.pad_batch(items, bucket,
                                                 view.medoid(),
                                                 self._exclude_width)
            res = _buckets.dispatch(view, rung.cfg, qs, seeds, excl,
                                    hop_budget=budget)
        except BaseException:
            self.index.release_view(view)
            raise
        flush_index = self.stats.flushes
        self.stats.flushes += 1
        self.stats.queries += B
        self.stats.bucket_hist[bucket] = \
            self.stats.bucket_hist.get(bucket, 0) + 1
        self._m_flushes.inc()
        self._m_queries.inc(B)
        self._m_queue_depth.set(len(self._queue))
        if level > 0:
            self.stats.degraded += B
            self._m_degraded.inc(B)
        if self._sampler.active:          # one compare per flush at 0.0
            for r in reqs:                # single-threaded sampler use
                r.result.sampled = self._sampler.take()
        for r in reqs:
            r.result.degraded = level > 0
            r.result.degrade_level = level
            r.result.epoch = getattr(view, "epoch", None)
            r.result._mark_dispatched(flush_index)
        # in-flight count is bounded by the dispatch-slot semaphore
        # (acquired before the batch was popped), so this never blocks;
        # extract releases the slot once the flush is drained.  A list,
        # not a tuple: slot 5 (the epoch view) is cleared in place on
        # release so the crash handler can't double-release it.
        self._inflight.put([reqs, res, expired, bucket, clock.now(), view])
        self._staging = None

    # -- extract thread ----------------------------------------------------
    def _extract_loop(self) -> None:
        while True:
            item = self._inflight.get()
            if item is None:
                return
            # _extracting mirrors _staging: if this loop dies mid-item,
            # the crash handler fails the futures it had already dequeued
            self._extracting = item
            _faults.fire("extract.loop")
            reqs, res, expired, bucket, t0, view = item
            B = len(reqs)
            ids = np.asarray(res.ids)      # device->host: blocks until the
            dists = np.asarray(res.dists)  # async dispatch finished
            t_dev = clock.now()
            dt = t_dev - t0
            self.stats.ema_flush_s = dt if not self.stats.ema_flush_s \
                else 0.8 * self.stats.ema_flush_s + 0.2 * dt
            self._m_flush_lat[bucket].observe(dt * 1e3)
            # traversal counters ride the same result the flush computed
            # anyway — surfacing them costs two tiny transfers, zero
            # extra device work
            hops = np.asarray(res.hops)
            evals = np.asarray(res.evals)
            self._m_hops.inc(int(hops[:B].sum()))
            self._m_evals.inc(int(evals[:B].sum()))
            vfrac = None if res.visited_frac is None \
                else np.asarray(res.visited_frac)
            # every device read of this flush is on host: drop the epoch
            # reference (clearing the slot keeps a crash-drain from
            # double-releasing this item)
            item[5] = None
            self.index.release_view(view)
            log = self._query_log
            any_sampled = log is not None and any(
                r.result.sampled for r in reqs)
            for i, r in enumerate(reqs):
                if expired[i]:
                    self.stats.partials += 1
                    self._m_partials.inc()
                r.result.device_done_at = t_dev
                r.result._complete(ids[i].copy(), dists[i].copy(),
                                   partial=expired[i])
                # observe AFTER _complete so the histogram sees the same
                # completed_at the future exposes (log replay matches)
                self._m_latency.observe(
                    (r.result.completed_at - r.result.submitted_at) * 1e3)
                if any_sampled and r.result.sampled:
                    log.write(make_record(
                        qid=r.seq, query=r.query, k=self.cfg.k,
                        ids=ids[i], dists=dists[i],
                        hops=int(hops[i]), evals=int(evals[i]),
                        seed_vertex=r.seed_vertex,
                        exclude_n=len(r.exclude),
                        visited_frac=None if vfrac is None
                        else float(vfrac[i]),
                        budget_exhausted=bool(
                            expired[i] and self.partial_hops is not None
                            and hops[i] >= self.partial_hops),
                        partial=expired[i],
                        flush_index=r.result.flush_index, bucket=bucket,
                        latency_ms=(r.result.completed_at
                                    - r.result.submitted_at) * 1e3,
                        result=r.result,
                        t_mono=r.result.submitted_at))
            self._extracting = None
            self._slots.release()     # free the dispatch slot last, so a
            # newly formed batch sees this flush's arrivals in the queue
