"""Bucketed fixed-shape search programs — the one dispatch path under both
serving engines.

The beam engine compiles one program per operand-shape family, so an
engine that pads every flush to an ad-hoc batch size either retraces
constantly (shape per request count) or always pays the largest batch
(the pre-PR sync flush padded everything to ``max_batch``).  This module
is the middle ground both engines share:

* :func:`bucket_sizes` — the power-of-two batch buckets between
  ``floor`` and ``max_batch``; a flush of B requests is padded to
  ``pow2_bucket(B, floor)``, so steady state compiles at most
  ``len(buckets)`` programs per search configuration;
* :class:`ProgramConfig` — the frozen per-engine search knobs (k/eps/L,
  codec + rerank, multi-expansion E/backend/visited) that, together with
  a bucket, name one compiled program;
* :func:`pad_batch` — request list -> padded (queries, seeds, exclude)
  operands, exclude lanes bucketed to powers of two exactly like the
  sync engine always did;
* :func:`dispatch` — the single ``DEGIndex.search_batch`` call site for
  both ``QueryEngine.flush`` and ``AsyncQueryEngine``.  **Bit-identity
  invariant**: per-lane results do not depend on batch composition (dead
  lanes are no-ops in the lock-step loop), so sync and async flushes of
  the same request produce identical ids/dists no matter how the
  scheduler groups them — buckets change padding, never semantics;
* :func:`precompile` — boot-time warmup: traces and compiles every
  (bucket, variant) program so no live request ever pays a trace
  (``launch/serve.py --warmup``, ``AsyncQueryEngine.warmup``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.graph import INVALID, pow2_bucket
from repro.obs import clock

#: hop budget meaning "unlimited" for non-expired lanes in a budgeted
#: batch (any value above the engine's max_hops bound behaves as no cap)
NO_BUDGET = np.int32(2**31 - 1)


def bucket_sizes(max_batch: int, floor: int = 8) -> tuple[int, ...]:
    """Power-of-two batch buckets covering 1..max_batch flushes."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    floor = max(1, min(floor, max_batch))
    sizes = []
    b = pow2_bucket(floor)
    while b < max_batch:
        sizes.append(b)
        b *= 2
    sizes.append(pow2_bucket(max_batch))
    return tuple(sizes)


@dataclasses.dataclass(frozen=True)
class ProgramConfig:
    """Everything (besides the batch bucket and the operand values) that
    names one compiled search program.  Built once per engine from its
    constructor arguments / a ``configs.deg.SearchPreset``."""

    k: int = 10
    eps: float = 0.1
    beam_width: Optional[int] = None
    codec: str = "float32"
    rerank_k: Optional[int] = None
    expand_width: Optional[int] = None
    visited_size: Optional[int] = None
    hop_backend: Optional[str] = None

    @classmethod
    def from_preset(cls, preset_name: str, *, k: int = 10, eps: float = 0.1,
                    codec: str = "float32",
                    rerank_k: Optional[int] = None) -> "ProgramConfig":
        from repro.configs.deg import SEARCH_PRESETS

        p = SEARCH_PRESETS[preset_name]
        return cls(k=k, eps=eps, beam_width=p.beam_width, codec=codec,
                   rerank_k=rerank_k, expand_width=p.expand_width,
                   visited_size=p.visited_size, hop_backend=p.hop_backend)


@dataclasses.dataclass
class BatchItem:
    """One request as the dispatch layer sees it: the query vector, the
    already-resolved exclude ids (session history, seed included when the
    protocol wants it hidden), and an optional seed vertex (None = the
    index medoid)."""

    query: np.ndarray
    exclude: Sequence[int] = ()
    seed_vertex: Optional[int] = None


def pad_batch(items: Sequence[BatchItem], bucket: int, medoid: int,
              exclude_floor: int = 8
              ) -> tuple[np.ndarray, np.ndarray, Optional[np.ndarray]]:
    """Request list -> fixed-shape (queries, seeds, exclude) operands.

    Queries are padded to ``bucket`` lanes (pad lanes repeat the first
    query — any in-bounds value; their results are discarded).  A batch
    with no exclusions passes ``exclude=None`` (the exclusion-free
    program); otherwise the exclude width is the batch's need bucketed to
    a power of two above ``exclude_floor``, so one long session never
    permanently widens later flushes."""
    B = len(items)
    if not (0 < B <= bucket):
        raise ValueError(f"batch size {B} does not fit bucket {bucket}")
    qs = np.stack([np.asarray(it.query, np.float32) for it in items]
                  + [np.asarray(items[0].query, np.float32)] * (bucket - B))
    seeds = np.full((bucket, 1), medoid, np.int32)
    max_ex = max((len(it.exclude) for it in items), default=0)
    excl = None
    if max_ex:
        xw = pow2_bucket(max_ex, floor=max(1, exclude_floor))
        excl = np.full((bucket, xw), INVALID, np.int32)
    for i, it in enumerate(items):
        if it.seed_vertex is not None:
            seeds[i, 0] = it.seed_vertex
        if it.exclude:
            excl[i, : len(it.exclude)] = list(it.exclude)
    return qs, seeds, excl


def dispatch(index, cfg: ProgramConfig, qs: np.ndarray, seeds: np.ndarray,
             excl: Optional[np.ndarray],
             hop_budget: Optional[np.ndarray] = None):
    """The one ``search_batch`` call site both engines flush through.

    ``index`` is whatever ``DEGIndex.acquire_view()`` returned: the index
    itself (single-writer mode) or an immutable
    :class:`repro.core.epoch.PublishedEpoch` (live mutation under
    serving) — both expose the same ``search_batch`` surface, and their
    operand shapes match, so they share the compiled beam programs."""
    return index.search_batch(
        qs, seeds, excl, k=cfg.k, eps=cfg.eps, beam_width=cfg.beam_width,
        quantized=None if cfg.codec == "float32" else cfg.codec,
        rerank_k=cfg.rerank_k, expand_width=cfg.expand_width,
        visited_size=cfg.visited_size, hop_backend=cfg.hop_backend,
        hop_budget=hop_budget)


def precompile(index, cfg: ProgramConfig, buckets: Sequence[int], *,
               with_budget: bool = False) -> dict[tuple, float]:
    """Compile every (bucket[, budgeted]) program before traffic arrives.

    Runs one throwaway flush per shape family and blocks on the result, so
    the trace + compile cost is paid at boot, not by the first request of
    each shape.  Returns ``{(bucket, variant): seconds}`` wall times (the
    figure ``launch/serve.py --warmup`` logs).  ``with_budget`` also
    compiles the deadline-expired variant (the same shapes plus the
    per-lane ``hop_budget`` operand) that a flush containing an expired
    request uses."""
    import jax

    dim = index.dim
    medoid = index.medoid()
    times: dict[tuple, float] = {}
    variants = [("plain", None)]
    if with_budget:
        variants.append(("budget", True))
    for b in buckets:
        items = [BatchItem(query=np.zeros(dim, np.float32))] * b
        qs, seeds, excl = pad_batch(items, b, medoid)
        for name, budgeted in variants:
            budget = (np.full(b, NO_BUDGET, np.int32) if budgeted else None)
            t0 = clock.now()
            res = dispatch(index, cfg, qs, seeds, excl, hop_budget=budget)
            jax.block_until_ready(res.ids)
            times[(b, name)] = clock.now() - t0
    return times
