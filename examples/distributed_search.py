"""Sharded DEG search on a multi-device mesh (8 CPU host devices standing in
for the production pod; the same code path lowers on the 16x16 / 2x16x16
meshes in repro.launch.dryrun).

Demonstrates: round-robin sharding into per-shard sub-DEGs, the
local-search + all-gather-merge step, and graceful shard loss.

    PYTHONPATH=src python examples/distributed_search.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402

from repro.core.build import DEGParams  # noqa: E402
from repro.core.distances import exact_knn_batched  # noqa: E402
from repro.core.metrics import recall_at_k  # noqa: E402
from repro.distributed.index import build_sharded_deg  # noqa: E402
from repro.launch.mesh import make_debug_mesh  # noqa: E402


def main():
    rng = np.random.default_rng(0)
    base = rng.normal(size=(4000, 24)).astype(np.float32)
    queries = base[:128] + 0.01 * rng.normal(size=(128, 24)).astype(np.float32)

    mesh = make_debug_mesh()          # ("data", "model") = (2, 2)
    print(f"mesh: {dict(mesh.shape)}")
    sd = build_sharded_deg(base, n_shards=2,
                           params=DEGParams(degree=12, k_ext=24),
                           wave_size=16)
    print(f"built {sd.n_shards} sub-DEGs, {sd.n_total} vectors total")

    ids, dists = sd.search(mesh, queries, k=10)
    _, gt = exact_knn_batched(queries, base, 10)
    print(f"sharded recall@10 = {recall_at_k(ids, gt):.3f}")

    # preemption drill: lose shard 0 -> service continues at reduced recall
    lost = sd.drop_shard(0)
    ids2, _ = lost.search(mesh, queries, k=10)
    print(f"after losing shard 0: recall@10 = {recall_at_k(ids2, gt):.3f} "
          f"(queries keep being served, ids all from surviving shards: "
          f"{bool((ids2 % 2 == 1).all())})")


if __name__ == "__main__":
    main()
