"""Quickstart: build a Dynamic Exploration Graph, search it, explore it.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.build import DEGParams, build_deg
from repro.core.distances import exact_knn_batched
from repro.core.metrics import recall_at_k


def main():
    rng = np.random.default_rng(0)
    base = rng.normal(size=(5000, 32)).astype(np.float32)
    queries = base[:100] + 0.01 * rng.normal(size=(100, 32)).astype(np.float32)

    # 1. build incrementally (Alg. 3, scheme C + MRNG checks), then refine
    #    continuously (Alg. 5) — the paper's two algorithms.
    idx = build_deg(base, DEGParams(degree=16, k_ext=32, eps_ext=0.2),
                    wave_size=16)
    print(f"built DEG_16 over {idx.n} vectors; "
          f"avg neighbor distance {idx.builder.average_neighbor_distance():.4f}")
    idx.refine(500)
    print(f"after 500 refinement iterations: "
          f"{idx.builder.average_neighbor_distance():.4f}")

    # 2. approximate nearest neighbor search (Alg. 1, batched)
    res = idx.search(queries, k=10, eps=0.1)
    _, gt = exact_knn_batched(queries, base, 10)
    print(f"recall@10 = {recall_at_k(np.asarray(res.ids), gt):.3f}, "
          f"avg hops {float(np.mean(np.asarray(res.hops))):.1f}")

    # 3. exploration (paper Sec. 6.7): start AT an indexed vertex; the
    #    QueryEngine session guarantees already-seen vertices never reappear
    #    — the interactive-browsing workload the paper targets.
    from repro.serving.engine import QueryEngine

    eng = QueryEngine(idx, k=5, max_batch=4)
    v = 42
    for hop in range(3):
        fut = eng.explore(v, session="demo")
        eng.flush()
        ids = [int(x) for x in fut["ids"] if x >= 0]
        print(f"explore hop {hop}: from vertex {v} -> {ids}")
        v = ids[0]


if __name__ == "__main__":
    main()
