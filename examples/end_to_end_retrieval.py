"""End-to-end driver: train a recsys model, index its item embeddings with
DEG, serve batched retrieval — the paper's technique as the retrieval stage
of a recommender (paper Sec. 1, recommender use case).

Pipeline:
  1. train DIN (reduced config) on the synthetic Criteo-like click stream
     for a few hundred steps (fault-tolerant loop, checkpointed);
  2. pull the trained item-embedding table rows (the candidate corpus);
  3. build a DEG over the corpus + continuous refinement;
  4. serve batched user queries: DEG top-k vs exact top-k (overlap + speed).

    PYTHONPATH=src python examples/end_to_end_retrieval.py
"""
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.build import DEGParams, build_deg
from repro.data.recsys import CriteoLikeStream
from repro.models import recsys as R
from repro.serving.engine import QueryEngine
from repro.train.loop import LoopConfig, train_loop
from repro.train.optimizer import adamw
from repro.train.steps import make_train_step


def main(steps: int = 200, batch: int = 256):
    import dataclasses

    # reduced DIN config, but with a production-shaped item vocabulary so
    # the retrieval corpus is non-trivial (5000 items)
    cfg = dataclasses.replace(get_arch("din").reduced(),
                              vocab_sizes=(5000, 20, 30))
    stream = CriteoLikeStream(cfg, seed=0)
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(5e-3)
    step = make_train_step(lambda p, b: R.loss_fn(p, b, cfg), opt,
                           donate=False)

    def batch_fn(s):
        return {k: jnp.asarray(v) for k, v in stream.batch(s, batch).items()}

    with tempfile.TemporaryDirectory() as ckpt_dir:
        (params, _), hist = train_loop(
            step, params, opt.init(params), batch_fn,
            LoopConfig(total_steps=steps, ckpt_dir=ckpt_dir, ckpt_every=50,
                       log_every=50))
    print(f"trained {steps} steps: loss {hist[0]['loss']:.4f} -> "
          f"{hist[-1]['loss']:.4f}")

    # 2. candidate corpus = trained item-field embedding rows
    items = np.asarray(R.item_vectors(params, cfg, field=cfg.item_field))
    print(f"corpus: {items.shape[0]} items x {items.shape[1]} dims")

    # 3. DEG index + refinement
    idx = build_deg(items, DEGParams(degree=8, k_ext=16, eps_ext=0.2),
                    wave_size=16)
    idx.refine(200)

    # 4. serve: user embedding -> top-k via DEG vs exact
    n_users = 128
    qb = stream.batch(10_000, n_users)
    u = np.asarray(R.user_embedding(params, {
        k: jnp.asarray(v) for k, v in qb.items()}, cfg))
    # score by L2 in embedding space (DEG metric); exact reference
    t0 = time.time()
    d2 = ((u[:, None, :] - items[None]) ** 2).sum(-1)
    gt = np.argsort(d2, axis=1)[:, :10]
    exact_s = time.time() - t0
    eng = QueryEngine(idx, k=10, max_batch=n_users)
    ids, _ = eng.search(u)
    overlap = np.mean([len(set(ids[i]) & set(gt[i])) / 10
                       for i in range(n_users)])
    print(f"DEG retrieval: overlap@10 vs exact = {overlap:.3f}; "
          f"device search {eng.stats.total_search_s*1e3:.0f} ms vs exact "
          f"{exact_s*1e3:.0f} ms for {n_users} users")
    assert overlap > 0.7


if __name__ == "__main__":
    main()
