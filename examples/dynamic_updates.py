"""Dynamic dataset demo: online inserts + continuous refinement.

The paper's core claim (Sec. 1.2): DEG stays a well-organized graph *at all
times* because refinement runs alongside insertion.  This script interleaves
insert waves with refinement and tracks:

* time-to-findability of fresh vectors (paper Sec. 1.1 requirement),
* average neighbor distance (Eq. 4) stays controlled as the index grows,
* invariants (regularity / connectivity) hold after every phase.

    PYTHONPATH=src python examples/dynamic_updates.py
"""
import numpy as np

from repro.core.build import DEGIndex, DEGParams
from repro.core.distances import exact_knn_batched
from repro.core.invariants import check_invariants
from repro.core.metrics import recall_at_k


def main():
    rng = np.random.default_rng(0)
    dim = 24
    idx = DEGIndex(dim, DEGParams(degree=12, k_ext=24, eps_ext=0.2),
                   capacity=6000)
    waves = 6
    per_wave = 800
    for w in range(waves):
        pts = rng.normal(size=(per_wave, dim)).astype(np.float32)
        # shift the distribution each wave — the stream drifts
        pts[:, 0] += 0.5 * w
        idx.add(pts, wave_size=16)
        # fresh vectors must be findable immediately
        probe = pts[:32] + 1e-4
        res = idx.search(probe, k=1, eps=0.3, beam_width=64)
        found = np.asarray(res.ids)[:, 0]
        want = np.arange(idx.n - per_wave, idx.n)[:32]
        findable = float(np.mean(found == want))
        # continuous refinement budget per wave (Alg. 5)
        idx.refine(150, seed=w)
        ok, msgs = check_invariants(idx.builder)
        assert ok, msgs
        print(f"wave {w}: n={idx.n}, fresh-findable={findable:.2f}, "
              f"avg-nbr-dist={idx.builder.average_neighbor_distance():.4f}, "
              f"invariants ok")

    # fully dynamic (beyond-paper): delete a batch of old vectors — no
    # tombstones, slots compact, invariants hold
    n_before = idx.n
    deleted = idx.remove(range(0, 200))
    ok, msgs = check_invariants(idx.builder)
    assert ok, msgs
    print(f"deleted {deleted} vertices ({n_before} -> {idx.n}); "
          f"invariants ok, no tombstones")

    # final quality check against exact search
    base = idx.vectors[: idx.n]
    queries = base[rng.integers(0, idx.n, 200)] + \
        0.01 * rng.normal(size=(200, dim)).astype(np.float32)
    res = idx.search(queries, k=10, eps=0.1)
    _, gt = exact_knn_batched(queries, base, 10)
    print(f"final recall@10 over the grown index: "
          f"{recall_at_k(np.asarray(res.ids), gt):.3f}")


if __name__ == "__main__":
    main()
