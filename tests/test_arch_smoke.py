"""Per-architecture smoke tests: REDUCED configs, one forward/train step on
CPU, asserting output shapes and no NaNs (assignment requirement f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs

LM_ARCHS = ["phi3-mini-3.8b", "granite-3-2b", "gemma3-12b",
            "qwen3-moe-30b-a3b", "mixtral-8x22b"]
RECSYS_ARCHS = ["dcn-v2", "deepfm", "din", "dlrm-mlperf"]


def test_registry_complete():
    assert len(list_archs()) == 10
    from repro.configs import all_cells
    assert len(all_cells()) == 40


def _lm_batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab, size=(B, S + 1)).astype(np.int32)
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:])}


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_forward_and_train_step(arch):
    from repro.models import transformer as T
    from repro.train.steps import make_train_step
    from repro.train.optimizer import adamw

    spec = get_arch(arch)
    cfg = spec.reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _lm_batch(cfg)
    logits, aux = T.forward_train(params, batch["tokens"], cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()

    opt = adamw(1e-3)
    step = make_train_step(lambda p, b: T.loss_fn(p, b, cfg), opt)
    state = opt.init(params)
    before = np.asarray(params["embed"]).copy()   # step donates params
    (params2, state2), metrics = step(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.abs(np.asarray(params2["embed"]) - before).max() > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_prefill_decode_consistency(arch):
    """Greedy decode after prefill must match teacher-forced forward."""
    from repro.models import transformer as T

    spec = get_arch(arch)
    cfg = spec.reduced()
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    toks = _lm_batch(cfg, B=2, S=12, seed=1)["tokens"]
    full_logits, _ = T.forward_train(params, toks, cfg)
    last_pf, cache = T.serve_prefill(params, toks[:, :11], cfg, max_len=16)
    np.testing.assert_allclose(np.asarray(last_pf),
                               np.asarray(full_logits[:, 10]),
                               rtol=2e-2, atol=2e-2)
    logits_dec, cache = T.serve_decode_step(params, cache, toks[:, 11:12], cfg)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(full_logits[:, 11]),
                               rtol=2e-2, atol=2e-2)


def test_lm_sliding_window_ring_cache():
    """Decode far beyond the window: ring cache must stay consistent with a
    full-cache run restricted by the window mask."""
    from repro.models import transformer as T

    spec = get_arch("mixtral-8x22b")
    cfg = spec.reduced()          # window 8
    assert cfg.sliding_window == 8
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 20)).astype(np.int32))
    # reference: full attention with window mask via forward_train
    ref_logits, _ = T.forward_train(params, toks, cfg)
    # streamed: prefill 8 then decode 12 steps with ring buffers
    _, cache = T.serve_prefill(params, toks[:, :8], cfg, max_len=20)
    outs = []
    for t in range(8, 20):
        lg, cache = T.serve_decode_step(params, cache, toks[:, t : t + 1], cfg)
        outs.append(lg)
    got = np.stack([np.asarray(o) for o in outs], axis=1)[0]
    want = np.asarray(ref_logits[0, 8:])
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)


def test_moe_dispatch_matches_dense_compute():
    """Scatter-dispatch MoE == explicit per-token dense expert mix (with
    generous capacity so nothing drops)."""
    from repro.models.moe import MoEConfig, init_moe_layer, moe_ffn

    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                    capacity_factor=8.0)
    lp = jax.tree.map(lambda a: a[0],
                      init_moe_layer(jax.random.PRNGKey(0), 1, 16, cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (24, 16), jnp.float32)
    y, aux = moe_ffn(x, lp, cfg)
    # dense reference
    logits = x @ lp["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_ids = jax.lax.top_k(probs, 2)
    top_w = top_p / top_p.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x)
    for e in range(4):
        g = jax.nn.silu(x @ lp["we_gate"][e])
        u = x @ lp["we_up"][e]
        fe = (g * u) @ lp["we_down"][e]
        w = jnp.where(top_ids == e, top_w, 0.0).sum(-1)
        ref += fe * w[:, None]
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    from repro.models.moe import MoEConfig, _capacity

    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=8, capacity_factor=1.0)
    assert _capacity(64, cfg) >= 64 * 2 // 4


# ------------------------------------------------------------------ EGNN --
def _egnn_graph(cfg, n=40, e=160, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "feats": jnp.asarray(rng.normal(size=(n, cfg.d_feat)).astype(np.float32)),
        "coords": jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32)),
        "edges": jnp.asarray(rng.integers(0, n, size=(2, e)).astype(np.int32)),
        "labels": jnp.asarray(rng.integers(0, cfg.n_classes, size=n)
                              .astype(np.int32)),
    }


def test_egnn_forward_and_train():
    from repro.models import egnn as E
    from repro.train.steps import make_train_step
    from repro.train.optimizer import adamw

    cfg = get_arch("egnn").reduced()
    params = E.init_params(jax.random.PRNGKey(0), cfg)
    batch = _egnn_graph(cfg)
    logits, coords = E.egnn_forward(params, batch["feats"], batch["coords"],
                                    batch["edges"], cfg)
    assert logits.shape == (40, cfg.n_classes)
    assert coords.shape == (40, 3)
    assert np.isfinite(np.asarray(logits)).all()
    opt = adamw(1e-3)
    step = make_train_step(lambda p, b: E.loss_fn(p, b, cfg), opt)
    (p2, _), m = step(params, opt.init(params), batch)
    assert np.isfinite(float(m["loss"]))


def test_egnn_equivariance():
    """E(n) property: rotate+translate inputs => coords transform likewise,
    invariant node logits."""
    from repro.models import egnn as E

    cfg = get_arch("egnn").reduced()
    params = E.init_params(jax.random.PRNGKey(0), cfg)
    b = _egnn_graph(cfg, seed=4)
    # random rotation via QR
    rng = np.random.default_rng(5)
    q, _ = np.linalg.qr(rng.normal(size=(3, 3)))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    R = jnp.asarray(q.astype(np.float32))
    t = jnp.asarray(rng.normal(size=(3,)).astype(np.float32))
    lg1, c1 = E.egnn_forward(params, b["feats"], b["coords"], b["edges"], cfg)
    lg2, c2 = E.egnn_forward(params, b["feats"], b["coords"] @ R.T + t,
                             b["edges"], cfg)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), rtol=1e-3,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(c1 @ R.T + t), np.asarray(c2),
                               rtol=1e-3, atol=2e-3)


def test_egnn_molecule_batched():
    from repro.models import egnn as E

    cfg = get_arch("egnn").reduced()
    params = E.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(6)
    B, n, e = 4, 10, 24
    feats = jnp.asarray(rng.normal(size=(B, n, cfg.d_feat)).astype(np.float32))
    coords = jnp.asarray(rng.normal(size=(B, n, 3)).astype(np.float32))
    edges = jnp.asarray(rng.integers(0, n, size=(B, 2, e)).astype(np.int32))
    logits, _ = E.egnn_forward_batched(params, feats, coords, edges, cfg)
    assert logits.shape == (B, n, cfg.n_classes)
    assert np.isfinite(np.asarray(logits)).all()


def test_neighbor_sampler():
    from repro.data.graphs import (random_power_law_graph, sample_neighbors,
                                   subgraph_batch, subgraph_shapes)

    g = random_power_law_graph(500, 8, seed=0)
    deg = jnp.asarray(g.degrees().astype(np.int32))
    seeds = jnp.asarray(np.arange(16, dtype=np.int32))
    nodes, edges = sample_neighbors(jnp.asarray(g.row_ptr),
                                    jnp.asarray(g.col_idx), deg, seeds,
                                    jax.random.PRNGKey(0), (4, 3))
    n_sub, n_edge = subgraph_shapes(16, (4, 3))
    assert nodes.shape == (n_sub,)
    assert edges.shape == (2, n_edge)
    nodes_np, edges_np = np.asarray(nodes), np.asarray(edges)
    # every sampled neighbor must be a real neighbor of its parent
    row_ptr, col = g.row_ptr, g.col_idx
    for c_pos, p_pos in zip(edges_np[0][:50], edges_np[1][:50]):
        child, parent = nodes_np[c_pos], nodes_np[p_pos]
        nbrs = col[row_ptr[parent]: row_ptr[parent + 1]]
        assert child in nbrs or child == parent  # self-loop fallback


# ---------------------------------------------------------------- recsys --
def _recsys_batch(cfg, B=8, seed=0):
    rng = np.random.default_rng(seed)
    b = {"sparse": jnp.asarray(np.stack(
        [rng.integers(0, v, size=B) for v in cfg.vocab_sizes], 1)
        .astype(np.int32)),
        "label": jnp.asarray(rng.integers(0, 2, size=B).astype(np.float32))}
    if cfg.n_dense:
        b["dense"] = jnp.asarray(rng.uniform(0, 10, size=(B, cfg.n_dense))
                                 .astype(np.float32))
    if cfg.kind == "din":
        hist = rng.integers(0, cfg.vocab_sizes[cfg.item_field],
                            size=(B, cfg.seq_len)).astype(np.int32)
        hist[:, cfg.seq_len // 2:] = -1  # ragged padding
        b["hist"] = jnp.asarray(hist)
    return b


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_forward_and_train(arch):
    from repro.models import recsys as R
    from repro.train.steps import make_train_step
    from repro.train.optimizer import adamw

    cfg = get_arch(arch).reduced()
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    batch = _recsys_batch(cfg)
    logits = R.forward(params, batch, cfg)
    assert logits.shape == (8,)
    assert np.isfinite(np.asarray(logits)).all()
    opt = adamw(1e-3)
    step = make_train_step(lambda p, b: R.loss_fn(p, b, cfg), opt)
    (p2, _), m = step(params, opt.init(params), batch)
    assert np.isfinite(float(m["loss"]))


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_retrieval(arch):
    from repro.models import recsys as R

    cfg = get_arch(arch).reduced()
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    batch = _recsys_batch(cfg, B=4)
    cands = jax.random.normal(jax.random.PRNGKey(1), (200, cfg.embed_dim))
    scores, ids = R.serve_retrieval(params, batch, cands, cfg, k=10)
    assert scores.shape == (4, 10) and ids.shape == (4, 10)
    assert np.isfinite(np.asarray(scores)).all()
    assert (np.diff(np.asarray(scores), axis=1) <= 1e-6).all()  # descending


def test_recsys_embedding_bag_consistency():
    """models.embedding_bag ragged == fixed on equivalent inputs."""
    from repro.models.embedding_bag import (embedding_bag_fixed,
                                            embedding_bag_ragged)

    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(50, 8)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 50, size=(6, 4)).astype(np.int32))
    fixed = embedding_bag_fixed(table, ids)
    flat = ids.reshape(-1)
    seg = jnp.repeat(jnp.arange(6), 4)
    ragged = embedding_bag_ragged(table, flat, seg, 6)
    # summation-order difference between the two paths is a couple of f32
    # ULPs on some backends
    np.testing.assert_allclose(np.asarray(fixed), np.asarray(ragged),
                               rtol=1e-5, atol=1e-6)
