"""AsyncQueryEngine: continuous batching, deadlines, fairness, cancellation.

The engine's core guarantee is bit-identity with the sync flush: both go
through ``serving/buckets.dispatch`` and per-lane results are independent
of batch composition, so HOW the scheduler grouped the requests must not
show in the results.  Pinned here against the live sync engine and the
golden range_search fixture."""
import os
import time

import numpy as np
import pytest

from repro.core.build import DEGIndex, DEGParams, build_deg
from repro.serving.async_engine import AsyncQueryEngine
from repro.serving.engine import QueryEngine
from repro.serving.scheduler import CancelledError

_FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                        "range_search_golden.npz")


@pytest.fixture(scope="module")
def index():
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(400, 8)).astype(np.float32)
    return build_deg(vecs, DEGParams(degree=8, k_ext=16), wave_size=8), vecs


def test_async_bit_identical_to_sync_flush(index):
    idx, vecs = index
    rng = np.random.default_rng(1)
    qs = vecs[:40] + 0.01 * rng.normal(size=(40, 8)).astype(np.float32)
    sync_ids, sync_dists = QueryEngine(idx, k=5, max_batch=16).search(qs)
    with AsyncQueryEngine(idx, k=5, max_batch=16,
                          deadline_ms=None) as eng:
        ids, dists = eng.search(qs)
    # exact equality: the scheduler's grouping (however the flushes fell)
    # must be invisible in the results
    np.testing.assert_array_equal(ids, sync_ids)
    np.testing.assert_array_equal(dists, sync_dists)
    assert eng.stats.partials == 0


def test_async_replays_golden_fixture():
    """The async engine serving fixture case A (shared seed vertex 3,
    k=10, eps=0.1) must reproduce the frozen seed-implementation results
    bit for bit — continuous batching is a scheduling change, never a
    semantic one."""
    from repro.core.graph import GraphBuilder

    g = np.load(_FIXTURE)
    degree = g["adjacency"].shape[1]
    cap = g["adjacency"].shape[0]
    idx = DEGIndex(g["vectors"].shape[1],
                   DEGParams(degree=degree, k_ext=2 * degree), capacity=cap)
    rows = g["vectors"][:cap]
    idx.vectors[: rows.shape[0]] = rows
    idx._put_rows(rows, 0)
    b = GraphBuilder(cap, degree)
    b.load(g["adjacency"], g["weights"], int(g["n"]))
    idx.builder = b

    with AsyncQueryEngine(idx, k=10, eps=0.1, max_batch=16,
                          deadline_ms=None) as eng:
        futs = [eng.submit(q, seed_vertex=int(g["seeds_a"][i, 0]))
                for i, q in enumerate(g["queries"])]
        outs = [f.result(120.0) for f in futs]
    np.testing.assert_array_equal(np.stack([o[0] for o in outs]),
                                  g["a_ids"])
    np.testing.assert_array_equal(np.stack([o[1] for o in outs]),
                                  g["a_dists"])


def test_deadline_expired_completes_partial(index):
    idx, vecs = index
    with AsyncQueryEngine(idx, k=5, max_batch=8, deadline_ms=0.0,
                          partial_hops=4) as eng:
        fut = eng.submit(vecs[0])
        ids, dists = fut.result(120.0)
    # expired at dispatch: served under the partial hop budget, flagged —
    # best-so-far results, not a drop
    assert fut.partial
    assert (ids >= 0).any() and np.isfinite(dists).any()
    assert eng.stats.partials == 1
    assert eng.stats.forced_flushes >= 1


def test_no_deadline_never_partial(index):
    idx, vecs = index
    with AsyncQueryEngine(idx, k=5, max_batch=8,
                          deadline_ms=None) as eng:
        futs = [eng.submit(q) for q in vecs[:20]]
        for f in futs:
            f.result(120.0)
    assert all(not f.partial for f in futs)
    assert eng.stats.partials == 0 and eng.stats.forced_flushes == 0


def test_queue_order_fairness_under_full_bucket(index):
    """A burst larger than max_batch is served oldest-first across
    consecutive flushes: flush indices must be non-decreasing in
    submission order (strict FIFO pop — never reordered by arrival
    jitter or deadline)."""
    idx, vecs = index
    with AsyncQueryEngine(idx, k=5, max_batch=8, bucket_floor=8,
                          deadline_ms=None, linger_ms=20.0) as eng:
        futs = [eng.submit(q) for q in vecs[:30]]
        for f in futs:
            f.result(120.0)
    order = [f.flush_index for f in futs]
    assert order == sorted(order)
    assert eng.stats.flushes >= 2          # the burst overfilled a bucket
    assert eng.stats.queries == 30


def test_cancel_queued_request(index):
    idx, vecs = index
    # long linger so the second request is still queued when cancelled
    with AsyncQueryEngine(idx, k=5, max_batch=8, deadline_ms=None,
                          linger_ms=200.0) as eng:
        keep = eng.submit(vecs[0])
        drop = eng.submit(vecs[1])
        assert drop.cancel()
        with pytest.raises(CancelledError):
            drop.result(120.0)
        ids, _ = keep.result(120.0)
        assert (ids >= 0).any()
    # the cancelled request never occupied a lane
    assert eng.stats.queries == 1
    assert not keep.partial


def test_cancel_after_dispatch_returns_false(index):
    idx, vecs = index
    with AsyncQueryEngine(idx, k=5, max_batch=8,
                          deadline_ms=None) as eng:
        fut = eng.submit(vecs[0])
        fut.result(120.0)
        assert not fut.cancel()            # already done: lane was paid for


def test_metrics_queue_depth_and_flush_histograms(index):
    """The engine's registry is the observable scheduler state: the
    queue-depth gauge tracks admissions, every flush lands in the
    per-bucket latency histogram, and the counters match .stats."""
    from repro.obs import LATENCY_METRIC, MetricsRegistry

    idx, vecs = index
    reg = MetricsRegistry()
    # long linger: submits accumulate before the first dispatch, so the
    # gauge deterministically reads the pending count
    eng = AsyncQueryEngine(idx, k=5, max_batch=16, deadline_ms=None,
                           linger_ms=500.0, metrics=reg)
    try:
        futs = [eng.submit(q) for q in vecs[:12]]
        assert reg.gauge("serving_queue_depth").value == 12
        for f in futs:
            f.result(120.0)
    finally:
        eng.close()
    assert reg.gauge("serving_queue_depth").value == 0
    assert reg.counter("serving_requests_total").value == 12
    assert reg.counter("serving_flushes_total").value == eng.stats.flushes
    # every flush observed into its bucket's latency histogram
    per_bucket = {b: reg.histogram("serving_flush_latency_ms",
                                   bucket=str(b)).count
                  for b in eng.buckets}
    assert sum(per_bucket.values()) == eng.stats.flushes
    for b, n_flushes in eng.stats.bucket_hist.items():
        assert per_bucket[b] == n_flushes
    # request latency histogram saw every request
    assert reg.histogram(LATENCY_METRIC).count == 12
    # hop/eval counters surfaced from the device at zero extra work
    assert reg.counter("serving_hops_total").value > 0
    assert reg.counter("serving_evals_total").value > 0


def test_metrics_deadline_partials_counter(index):
    """Deadline-expired partials are a first-class metric, not just a
    stats field — dashboards alert on shed work."""
    from repro.obs import MetricsRegistry

    idx, vecs = index
    reg = MetricsRegistry()
    with AsyncQueryEngine(idx, k=5, max_batch=8, deadline_ms=0.0,
                          partial_hops=4, metrics=reg) as eng:
        futs = [eng.submit(q) for q in vecs[:3]]
        for f in futs:
            f.result(120.0)
    n_partial = sum(f.partial for f in futs)
    assert n_partial == eng.stats.partials > 0
    assert reg.counter("serving_deadline_partials_total").value == n_partial
    assert reg.counter("serving_forced_flushes_total").value == \
        eng.stats.forced_flushes


def test_sync_engine_metrics_and_flush_clock(index):
    """The sync QueryEngine reports through the same registry names, and
    its flush timing comes from the monotonic serving clock (the old
    wall-clock read could go backwards under NTP steps)."""
    from repro.obs import LATENCY_METRIC, MetricsRegistry

    idx, vecs = index
    reg = MetricsRegistry()
    eng = QueryEngine(idx, k=5, max_batch=16, metrics=reg)
    eng.search(vecs[:10])
    assert reg.counter("serving_requests_total").value == 10
    assert reg.counter("serving_flushes_total").value >= 1
    # closed-loop request latency == the flush that served it
    assert reg.histogram(LATENCY_METRIC).count == 10
    hist_counts = sum(
        m.count for m in reg.metrics()
        if m.name == "serving_flush_latency_ms")
    assert hist_counts == reg.counter("serving_flushes_total").value


def test_close_drains_accepted_requests(index):
    idx, vecs = index
    eng = AsyncQueryEngine(idx, k=5, max_batch=8, deadline_ms=None,
                           linger_ms=500.0)
    futs = [eng.submit(q) for q in vecs[:5]]
    eng.close()                            # must not strand queued requests
    for f in futs:
        ids, _ = f.result(10.0)
        assert (ids >= 0).any()
    with pytest.raises(RuntimeError):
        eng.submit(vecs[0])                # closed engine rejects submits
