"""Crash-safe mutation WAL (persist/wal.py) + atomic snapshot writes.

The recovery contract: ``load_index(snapshot) + replay_wal(wal)`` is
**bit-identical** to the uninterrupted build — graph rows, vectors, the
RNG stream, and search results — for a crash at ANY record boundary.
Torn tails (crash mid-append) are truncated and replay proceeds;
complete-but-corrupt records raise typed errors, never silently skip.
"""
import os

import numpy as np
import pytest

from repro.core.build import DEGIndex, DEGParams
from repro.persist import (WALCorruptionError, WALError, WALWriter,
                           load_index, read_wal, recover, replay_wal,
                           save_index)
from repro.persist.wal import FILE_MAGIC
from repro.resilience import FaultInjected, FaultPlan

DIM = 6
PARAMS = DEGParams(degree=6, k_ext=12)


def _mk(capacity=96):
    return DEGIndex(DIM, PARAMS, capacity=capacity)


def _points(seed, k):
    return np.random.default_rng(seed).normal(size=(k, DIM)).astype(
        np.float32)


def _mutate(idx, upto):
    """A deterministic mutation script (add waves / remove / refine),
    truncatable at any unit count via ``upto``."""
    steps = [
        lambda: idx.add(_points(1, 12), wave_size=4),
        lambda: idx.add(_points(2, 7), wave_size=3),
        lambda: idx.remove([3, 5]),
        lambda: idx.refine(6),               # seed drawn from the stream
        lambda: idx.add(_points(3, 5), wave_size=2),
        lambda: idx.refine(4, seed=77),      # explicit seed
        lambda: idx.remove([1]),
    ]
    for step in steps[:upto]:
        step()


def _sig(idx):
    n = idx.n
    qs = _points(9, 5)
    res = idx.search_batch(qs, k=4, eps=0.1)
    return (np.asarray(idx.builder.adjacency[:n]).copy(),
            np.asarray(idx.builder.weights[:n]).copy(),
            np.asarray(idx.vectors[:n]).copy(),
            np.asarray(res.ids).copy(), np.asarray(res.dists).copy(),
            idx._rng.bit_generator.state, idx._wal_seq)


def _assert_same(a, b):
    sa, sb = _sig(a), _sig(b)
    for x, y in zip(sa[:5], sb[:5]):
        np.testing.assert_array_equal(x, y)
    assert sa[5] == sb[5], "RNG streams diverged"
    assert sa[6] == sb[6], "WAL cursors diverged"


def test_recovery_bit_identical_at_every_boundary(tmp_path):
    """Snapshot early, mutate on, then recover(snapshot, wal) after each
    further unit: the recovered index must equal the live one bit for bit
    — rows, RNG stream, search results — at EVERY record boundary."""
    for upto in range(2, 8):
        wal = tmp_path / f"wal{upto}.log"
        snap = tmp_path / f"snap{upto}.npz"
        idx = _mk()
        idx.enable_wal(wal)
        idx.add(_points(0, 10), wave_size=4)  # bootstrap + first waves
        save_index(idx, snap)                 # cursor mid-history
        _mutate(idx, upto)
        rec = recover(snap, wal, capacity=96)
        _assert_same(idx, rec)


def test_recovered_index_continues_identically(tmp_path):
    """Post-recovery mutations must follow the same trajectory as the
    index that never crashed — the replayed RNG stream is live, not just
    a display copy."""
    wal = tmp_path / "wal.log"
    snap = tmp_path / "snap.npz"
    idx = _mk()
    idx.enable_wal(wal)
    idx.add(_points(0, 10), wave_size=4)
    save_index(idx, snap)
    _mutate(idx, 4)
    rec = recover(snap, wal, capacity=96)
    for z in (idx, rec):
        z.add(_points(5, 6), wave_size=3)
        z.refine(5)                           # both draw from their stream
    _assert_same(idx, rec)


def test_uninterrupted_reference_matches_replay(tmp_path):
    """The journal adds no semantics: a second index running the same
    script with its own WAL (never crashed, never replayed) lands in the
    identical state."""
    wal_a, wal_b = tmp_path / "a.log", tmp_path / "b.log"
    snap = tmp_path / "a.npz"
    a, b = _mk(), _mk()
    for z, w in ((a, wal_a), (b, wal_b)):
        z.enable_wal(w)
        z.add(_points(0, 10), wave_size=4)
    save_index(a, snap)
    _mutate(a, 7)
    _mutate(b, 7)
    rec = recover(snap, wal_a, capacity=96)
    _assert_same(b, rec)


def test_wal_seq_cursor_skips_pre_snapshot_records(tmp_path):
    """Records before the snapshot cursor are skipped, not re-applied —
    replaying the full journal onto a mid-history snapshot must not
    double-apply the prefix."""
    wal = tmp_path / "wal.log"
    snap = tmp_path / "snap.npz"
    idx = _mk()
    idx.enable_wal(wal)
    idx.add(_points(0, 10), wave_size=4)
    _mutate(idx, 3)
    save_index(idx, snap)                     # cursor past several records
    n_before = idx.n
    idx.refine(3)                             # one post-snapshot record
    rec = recover(snap, wal, capacity=96)
    _assert_same(idx, rec)
    assert rec.n == idx.n and idx.n != n_before + 10  # prefix not re-added


def test_torn_tail_truncated_and_writer_reattaches(tmp_path):
    wal = tmp_path / "wal.log"
    w = WALWriter(wal)
    w.append(0, "add", {"wave_size": 2}, {"points": _points(0, 4)})
    w.append(1, "refine", {"iterations": 3, "seed": 5, "drew": False}, {})
    w.close()
    good = os.path.getsize(wal)
    with open(wal, "ab") as f:                # crash mid-append: half a
        f.write(b"\x52\x4c\x41\x57\x07\x00")  # record header then nothing
    recs = read_wal(wal)
    assert [r.seq for r in recs] == [0, 1]    # complete prefix survives
    assert os.path.getsize(wal) == good       # torn bytes truncated away
    w2 = WALWriter(wal)                       # writer re-attaches cleanly
    w2.append(2, "refine", {"iterations": 1, "seed": 9, "drew": False}, {})
    w2.close()
    assert [r.seq for r in read_wal(wal)] == [0, 1, 2]


def test_torn_tail_mid_payload(tmp_path):
    wal = tmp_path / "wal.log"
    w = WALWriter(wal)
    w.append(0, "add", {"wave_size": 2}, {"points": _points(0, 4)})
    w.close()
    data = open(wal, "rb").read()
    with open(wal, "wb") as f:                # payload cut short
        f.write(data[:-7])
    assert read_wal(wal) == []
    assert os.path.getsize(wal) == len(FILE_MAGIC)


def test_corrupt_record_raises_typed(tmp_path):
    wal = tmp_path / "wal.log"
    w = WALWriter(wal)
    w.append(0, "add", {"wave_size": 2}, {"points": _points(0, 4)})
    w.close()
    data = bytearray(open(wal, "rb").read())
    data[-3] ^= 0xFF                          # bit rot inside the payload
    open(wal, "wb").write(bytes(data))
    with pytest.raises(WALCorruptionError):
        read_wal(wal)
    # corruption is NOT a torn tail: the file must not be truncated
    assert open(wal, "rb").read() == bytes(data)


def test_bad_file_magic_raises(tmp_path):
    wal = tmp_path / "wal.log"
    open(wal, "wb").write(b"NOTAWAL0" + b"x" * 40)
    with pytest.raises(WALError):
        read_wal(wal)
    with pytest.raises(WALError):
        WALWriter(wal)


def test_journal_gap_raises(tmp_path):
    wal = tmp_path / "wal.log"
    w = WALWriter(wal)
    w.append(0, "refine", {"iterations": 1, "seed": 3, "drew": False}, {})
    w.append(2, "refine", {"iterations": 1, "seed": 4, "drew": False}, {})
    w.close()
    idx = _mk()
    idx.add(_points(0, 10), wave_size=4)      # un-journaled: cursor 0
    with pytest.raises(WALError, match="gap"):
        replay_wal(idx, wal)


def test_crash_at_record_boundary_via_fault_hook(tmp_path):
    """Kill the process (simulated) at the WAL-append hook: the unit that
    never journaled is also never applied, and recovery lands exactly on
    the journaled prefix — the crashed live index."""
    wal = tmp_path / "wal.log"
    snap = tmp_path / "snap.npz"
    idx = _mk()
    idx.enable_wal(wal)
    idx.add(_points(0, 10), wave_size=4)
    save_index(idx, snap)
    # the 3rd post-snapshot append dies before any bytes hit the file
    with FaultPlan().kill("wal.append", at=idx._wal_seq + 3):
        with pytest.raises(FaultInjected):
            _mutate(idx, 7)
    rec = recover(snap, wal, capacity=96)
    _assert_same(idx, rec)                    # == the surviving prefix


def test_atomic_snapshot_crash_mid_save(tmp_path):
    """A crash between writing the tmp file and the rename must leave the
    previous snapshot byte-identical and loadable, with no tmp litter."""
    snap = tmp_path / "snap.npz"
    idx = _mk()
    idx.add(_points(0, 12), wave_size=4)
    save_index(idx, snap)
    v1 = open(snap, "rb").read()
    idx.refine(3, seed=1)
    with FaultPlan().kill("snapshot.mid_save", at=1):
        with pytest.raises(FaultInjected):
            save_index(idx, snap)
    assert open(snap, "rb").read() == v1      # predecessor untouched
    assert [p for p in os.listdir(tmp_path) if ".tmp" in p] == []
    old = load_index(snap)                    # and still loadable
    assert old.n == 12


def test_sharded_manifest_save_is_atomic(tmp_path):
    """The sharded manifest funnels through the same tmp+rename commit —
    a crash mid-save keeps the previous manifest intact."""
    from repro.distributed.index import ShardedDEG, build_sharded_deg

    sh = build_sharded_deg(_points(0, 24), 2, PARAMS, wave_size=4)
    path = tmp_path / "sharded.npz"
    sh.save(path)
    v1 = open(path, "rb").read()
    with FaultPlan().kill("snapshot.mid_save", at=1):
        with pytest.raises(FaultInjected):
            sh.save(path)
    assert open(path, "rb").read() == v1
    assert ShardedDEG.load(path).n_total == 24


def test_checkpoint_not_written_mid_journaled_op(tmp_path):
    """Checkpoint ticks inside a journaled remove/refine are suppressed:
    a snapshot may only capture record boundaries, or its cursor would
    cover a half-applied record."""
    wal = tmp_path / "wal.log"
    ckpt = tmp_path / "ckpt.npz"
    idx = _mk()
    idx.enable_wal(wal)
    idx.add(_points(0, 16), wave_size=4)
    idx.enable_checkpoints(ckpt, every_waves=1)   # tick on every boundary
    before = os.path.getmtime(ckpt) if os.path.exists(ckpt) else None
    idx.refine(8)                             # refine ticks are suppressed
    after = os.path.getmtime(ckpt) if os.path.exists(ckpt) else None
    assert before == after
    idx.add(_points(4, 4), wave_size=2)       # wave boundaries still tick
    assert os.path.exists(ckpt)
    rec = recover(ckpt, wal, capacity=96)
    _assert_same(idx, rec)
