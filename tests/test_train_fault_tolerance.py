"""Fault-tolerance tests: atomic checkpoints, resume-exactness, failure
injection, elastic manifest, deterministic data replay."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt
from repro.train.loop import InjectedFailure, LoopConfig, train_loop
from repro.train.optimizer import adamw
from repro.train.steps import make_train_step


def _toy_setup(seed=0):
    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        l = jnp.mean((pred - batch["y"]) ** 2)
        return l, {"mse": l}

    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=(4, 2)).astype(np.float32)),
              "b": jnp.zeros((2,), jnp.float32)}
    opt = adamw(1e-2)
    step = make_train_step(loss_fn, opt, donate=False)

    def batch_fn(s):
        r = np.random.default_rng((7, s))
        x = r.normal(size=(8, 4)).astype(np.float32)
        w_true = np.arange(8).reshape(4, 2).astype(np.float32)
        return {"x": jnp.asarray(x),
                "y": jnp.asarray(x @ w_true + 0.01 * r.normal(size=(8, 2))
                                 .astype(np.float32))}

    return step, params, opt.init(params), batch_fn


def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(6).reshape(2, 3),
             "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
             "scalar": jnp.asarray(3, jnp.int32)}
    path = ckpt.save(str(tmp_path), 7, state, mesh_shape=(16, 16))
    assert os.path.isdir(path)
    assert ckpt.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)
    restored, manifest = ckpt.restore_latest(str(tmp_path), like)
    assert manifest["mesh_shape"] == [16, 16]
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), state, restored)


def test_checkpoint_atomic_no_partial(tmp_path):
    state = {"w": jnp.ones((3,))}
    ckpt.save(str(tmp_path), 1, state)
    # a crashed half-write leaves only a .tmp dir -> invisible to LATEST
    os.makedirs(tmp_path / "step_000000002.tmp")
    assert ckpt.latest_step(str(tmp_path)) == 1
    ckpt.save(str(tmp_path), 3, state)   # gc removes the orphan
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_checkpoint_gc_keeps_newest(tmp_path):
    state = {"w": jnp.ones((2,))}
    for s in range(5):
        ckpt.save(str(tmp_path), s, state, keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2 and steps[-1] == "step_000000004"


def test_resume_is_exact(tmp_path):
    """Crash at step 12, resume: final params must equal an uninterrupted
    run (deterministic replay contract)."""
    step, params, opt_state, batch_fn = _toy_setup()
    # uninterrupted reference
    (ref_params, _), _ = train_loop(
        step, params, opt_state, batch_fn,
        LoopConfig(total_steps=20, log_every=0))
    # interrupted run
    step2, params2, opt_state2, _ = _toy_setup()
    cfg = LoopConfig(total_steps=20, ckpt_dir=str(tmp_path), ckpt_every=5,
                     log_every=0, fail_at=12, fail_before_ckpt=True)
    with pytest.raises(InjectedFailure):
        train_loop(step2, params2, opt_state2, batch_fn, cfg)
    assert ckpt.latest_step(str(tmp_path)) == 10
    # resume (fresh process state)
    step3, params3, opt_state3, _ = _toy_setup()
    cfg2 = LoopConfig(total_steps=20, ckpt_dir=str(tmp_path), ckpt_every=5,
                      log_every=0)
    (resumed_params, _), hist = train_loop(step3, params3, opt_state3,
                                           batch_fn, cfg2)
    assert hist[0]["step"] == 11
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6), ref_params, resumed_params)


def test_loss_decreases_end_to_end():
    step, params, opt_state, batch_fn = _toy_setup()
    (_, _), hist = train_loop(step, params, opt_state, batch_fn,
                              LoopConfig(total_steps=40, log_every=0))
    assert hist[-1]["loss"] < 0.5 * hist[0]["loss"]


def test_pipeline_shard_determinism():
    from repro.data.pipeline import ShardedPipeline, lm_synthetic_batch_fn

    fn = lm_synthetic_batch_fn(vocab=50, batch=8, seq=16, seed=3)
    p0 = ShardedPipeline(fn, host_id=0, num_hosts=2)
    p1 = ShardedPipeline(fn, host_id=1, num_hosts=2)
    g = fn(5)
    b0, b1 = p0(5), p1(5)
    np.testing.assert_array_equal(
        np.concatenate([b0["tokens"], b1["tokens"]]), g["tokens"])
    # determinism: same step -> same batch
    np.testing.assert_array_equal(p0(5)["tokens"], b0["tokens"])


def test_pipeline_prefetch_stream():
    from repro.data.pipeline import ShardedPipeline, lm_synthetic_batch_fn

    fn = lm_synthetic_batch_fn(vocab=50, batch=4, seq=8, seed=0)
    p = ShardedPipeline(fn, prefetch=2).start(start_step=3)
    try:
        s, b = p.get()
        assert s == 3
        s2, _ = p.get()
        assert s2 == 4
    finally:
        p.stop()


def test_recsys_stream_learnable():
    """The planted-logit stream must be learnable: BCE under training drops
    below the no-skill baseline."""
    from repro.configs import get_arch
    from repro.data.recsys import CriteoLikeStream
    from repro.models import recsys as R

    cfg = get_arch("deepfm").reduced()
    stream = CriteoLikeStream(cfg, seed=0)
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(5e-3)
    step = make_train_step(lambda p, b: R.loss_fn(p, b, cfg), opt,
                           donate=False)
    state = opt.init(params)
    losses = []
    for s in range(30):
        b = {k: jnp.asarray(v) for k, v in stream.batch(s, 256).items()}
        (params, state), m = step(params, state, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.01
