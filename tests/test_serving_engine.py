"""QueryEngine: batching, exploration sessions, online inserts, refinement."""
import numpy as np
import pytest

from repro.core.build import DEGParams, build_deg
from repro.core.distances import exact_knn_batched
from repro.core.metrics import recall_at_k
from repro.serving.engine import QueryEngine


@pytest.fixture(scope="module")
def index():
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(800, 12)).astype(np.float32)
    return build_deg(vecs, DEGParams(degree=8, k_ext=16), wave_size=8), vecs


def test_batched_search_recall(index):
    idx, vecs = index
    rng = np.random.default_rng(1)
    qs = vecs[:50] + 0.01 * rng.normal(size=(50, 12)).astype(np.float32)
    eng = QueryEngine(idx, k=5, max_batch=16)
    ids, dists = eng.search(qs)
    _, gt = exact_knn_batched(qs, vecs[: idx.n], 5)
    assert recall_at_k(ids, gt) > 0.85
    assert eng.stats.flushes >= 4          # 50 queries / 16 per flush


def test_flush_pads_to_fixed_shape(index):
    idx, vecs = index
    eng = QueryEngine(idx, k=3, max_batch=8)
    f = eng.submit(vecs[0])
    assert not f["done"]
    eng.flush()
    assert f["done"] and f["ids"].shape == (3,)


def test_exploration_sessions_never_repeat(index):
    idx, vecs = index
    eng = QueryEngine(idx, k=5, max_batch=4)
    seen = set()
    v = 7
    for hop in range(5):
        fut = eng.explore(v, session="u1")
        eng.flush()
        ids = [int(x) for x in fut["ids"] if x >= 0]
        assert v not in ids                  # the seed itself is excluded
        assert not (set(ids) & seen)         # no repeats across the session
        seen.update(ids)
        seen.add(v)
        v = ids[0]
    # a different session is unaffected
    fut = eng.explore(7, session="u2")
    eng.flush()
    assert any(int(x) in seen for x in fut["ids"] if x >= 0)


def test_online_insert_findable(index):
    idx, vecs = index
    eng = QueryEngine(idx, k=3, max_batch=4)
    rng = np.random.default_rng(3)
    new = (10.0 + rng.normal(size=(1, 12))).astype(np.float32)  # far away
    eng.insert(new)
    new_id = idx.n - 1
    ids, _ = eng.search(new)
    assert int(ids[0, 0]) == new_id          # immediately findable


def test_refine_budget_runs(index):
    idx, vecs = index
    eng = QueryEngine(idx, k=3, max_batch=4, refine_budget=2)
    eng.search(vecs[:4])
    assert eng.stats.refine_iterations >= 0  # ran without violating invariants
    from repro.core.invariants import check_invariants

    ok, msgs = check_invariants(idx.builder)
    assert ok, msgs


def test_online_delete(index):
    idx, vecs = index
    from repro.core.invariants import check_invariants

    eng = QueryEngine(idx, k=3, max_batch=4)
    target = idx.vectors[10].copy()
    assert eng.delete(10)
    ok, msgs = check_invariants(idx.builder)
    assert ok, msgs
    ids, _ = eng.search(target[None])
    found = idx.vectors[int(ids[0, 0])]
    assert not np.allclose(found, target)
