"""Tests for incremental construction (Alg. 3) and edge optimization (Alg. 4/5)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (DEGParams, average_neighbor_distance, build_deg,
                        exact_knn, recall_at_k)
from repro.core import invariants as inv
from repro.core.baselines import random_regular_index
from repro.core.mrng import check_mrng, check_mrng_candidate
from repro.data import make_dataset


@pytest.fixture(scope="module")
def data():
    return make_dataset("gaussian", 500, 20, 16, seed=11)


def _params(**kw):
    base = dict(degree=8, k_ext=16, eps_ext=0.3, k_opt=8, i_opt=5)
    base.update(kw)
    return DEGParams(**base)


def test_build_invariants_sequential(data):
    base, _ = data
    idx = build_deg(base[:100], _params())
    inv.assert_valid_deg(idx.builder, context="sequential build")


def test_build_invariants_wave(data):
    base, _ = data
    idx = build_deg(base, _params(), wave_size=64)
    inv.assert_valid_deg(idx.builder, context="wave build")


def test_build_with_insert_opt_keeps_invariants(data):
    base, _ = data
    idx = build_deg(base[:200], _params(optimize_new=True), wave_size=16)
    inv.assert_valid_deg(idx.builder, context="insert-opt build")


def test_incremental_addition(data):
    """Incremental property (paper Table 1): vertices addable at any time,
    and new vertices are findable immediately."""
    base, queries = data
    idx = build_deg(base[:300], _params(), wave_size=32)
    idx.add(base[300:], wave_size=32)
    inv.assert_valid_deg(idx.builder, context="after incremental add")
    assert idx.n == base.shape[0]
    # search for the newly added points themselves
    res = idx.search(base[450:460], k=1, eps=0.2, beam_width=32)
    found = np.asarray(res.ids)[:, 0]
    expect = np.arange(450, 460)
    assert (found == expect).mean() >= 0.9


def test_schemes_all_valid(data):
    base, _ = data
    for scheme in "ABCD":
        idx = build_deg(base[:150], _params(scheme=scheme), wave_size=16)
        inv.assert_valid_deg(idx.builder, context=f"scheme {scheme}")


def test_refine_reduces_avg_neighbor_distance(data):
    """The core continuous-refinement claim (paper Sec. 5.3 / Fig. 7)."""
    base, _ = data
    idx = random_regular_index(base[:300], _params(), seed=3)
    nd0 = average_neighbor_distance(idx.builder)
    improved = idx.refine(150, seed=5)
    nd1 = average_neighbor_distance(idx.builder)
    inv.assert_valid_deg(idx.builder, context="after refine")
    assert improved > 0
    assert nd1 < nd0


def test_refine_improves_random_graph_search(data):
    """Fig. 7-left: optimization turns a random regular graph into a
    functioning search graph."""
    base, queries = data
    _, ti = exact_knn(queries, base[:300], 5)
    idx = random_regular_index(base[:300], _params(), seed=3)
    r0 = recall_at_k(np.asarray(idx.search(queries, k=5, eps=0.1,
                                           beam_width=24).ids),
                     np.asarray(ti))
    idx.refine(600, seed=5)
    r1 = recall_at_k(np.asarray(idx.search(queries, k=5, eps=0.1,
                                           beam_width=24).ids),
                     np.asarray(ti))
    assert r1 > r0 + 0.1


def test_optimize_edge_rollback_on_failure(data):
    """Alg. 4 step (6): if no improving constellation exists the graph is
    unchanged."""
    from repro.core.optimize import optimize_edge

    base, _ = data
    idx = build_deg(base[:200], _params(), wave_size=16)
    idx.refine(500, seed=1)          # near-converged: most swaps now fail
    adj_before = idx.builder.adjacency.copy()
    w_before = idx.builder.weights.copy()
    failures = 0
    for v in range(0, 40):
        nbr = int(idx.builder.neighbors(v)[0])
        ok = optimize_edge(idx, v, nbr, i_opt=2, k_opt=4, eps_opt=0.001)
        if not ok:
            failures += 1
        inv.assert_valid_deg(idx.builder, context=f"after optimize({v})")
    assert failures > 0  # at least some must fail and roll back cleanly


def test_mrng_check_basics():
    """checkMRNG on a hand-built triangle: the long edge of a triangle whose
    third vertex is a shared neighbor violates MRNG."""
    from repro.core.graph import GraphBuilder

    b = GraphBuilder(8, 4)
    for _ in range(6):
        b.add_vertex()
    # vertices 0-1-2 triangle: w(0,2)=w(1,2)=1, w(0,1)=3 (>max -> violates)
    b.add_edge(0, 1, 3.0)
    b.add_edge(0, 2, 1.0)
    b.add_edge(1, 2, 1.0)
    assert not check_mrng(b, 0, 1, 3.0)
    assert check_mrng(b, 0, 2, 1.0)
    # candidate version: selected=[2] at dist 1, candidate adjacent to 2
    assert not check_mrng_candidate(b, 1, 3.0, [2], [1.0])
    assert check_mrng_candidate(b, 1, 0.5, [2], [1.0])
    assert check_mrng_candidate(b, 1, 3.0, [], [])


@settings(max_examples=8, deadline=None)
@given(n=st.integers(30, 120), seed=st.integers(0, 1000),
       intrinsic=st.sampled_from([2, 4, 8]))
def test_build_always_valid_property(n, seed, intrinsic):
    """Property: DEG invariants hold for arbitrary datasets/orders."""
    from repro.data.synthetic import planted_manifold

    pts = planted_manifold(n, 12, intrinsic_dim=intrinsic, seed=seed)
    idx = build_deg(pts, _params(degree=6, k_ext=12, k_opt=6), wave_size=8)
    inv.assert_valid_deg(idx.builder)
    assert idx.n == n


def test_duplicate_points_build(data):
    """Degenerate input: exact duplicates must not break invariants."""
    base, _ = data
    pts = np.concatenate([base[:50], base[:20]], axis=0)
    idx = build_deg(pts, _params(degree=6, k_ext=12, k_opt=6), wave_size=8)
    inv.assert_valid_deg(idx.builder, context="duplicates")


def test_batched_refine_sweep_improves_edges(data):
    """The batched Alg. 5 candidate-search path (one device call per chunk
    of edge tasks) must improve >= 1 edge per sweep on a synthetic corpus
    and keep every DEG invariant."""
    from repro.core.metrics import average_neighbor_distance
    from repro.core.optimize import refine_sweep

    base, _ = data
    idx = random_regular_index(base[:200], _params(), seed=4)
    nd0 = average_neighbor_distance(idx.builder)
    improved = refine_sweep(idx, list(range(48)),
                            i_opt=idx.params.i_opt, k_opt=idx.params.k_opt,
                            eps_opt=idx.params.eps_opt)
    assert improved >= 1
    inv.assert_valid_deg(idx.builder, context="after batched refine_sweep")
    assert average_neighbor_distance(idx.builder) < nd0
    # DEGIndex.refine routes through the same batched path
    assert idx.refine(32, seed=0) >= 1
    inv.assert_valid_deg(idx.builder, context="after DEGIndex.refine")
