"""obs/metrics: log-bucketed histograms, registry merge, exports.

The histogram is the load-bearing piece: serving p50/p99 are *bucket*
percentiles (a pure function of the counts), which is what makes them
mergeable across engines/shards and exactly reproducible from the query
log.  Pinned here: bucket error bounds vs exact numpy percentiles, merge
== union of observations, and snapshot / Prometheus round trips.
"""
import json

import numpy as np
import pytest

from repro.obs import (DEFAULT_LATENCY_BOUNDS_MS, MetricsRegistry,
                       log_buckets)


def test_log_buckets_geometric_and_sorted():
    b = log_buckets(0.1, 1000.0, growth=2.0)
    assert list(b) == sorted(b)
    assert b[0] == pytest.approx(0.1)
    assert b[-1] >= 1000.0
    ratios = np.diff(np.log(np.asarray(b)))
    assert np.allclose(ratios, np.log(2.0))


def test_default_bounds_cover_serving_range():
    b = DEFAULT_LATENCY_BOUNDS_MS
    assert b[0] <= 0.05 and b[-1] >= 80_000.0
    assert len(b) < 80          # coarse enough to stay cheap to export


def test_histogram_percentile_within_bucket_error():
    """Bucket percentiles interpolate inside the winning bucket, so the
    worst-case relative error vs exact numpy is the bucket growth factor
    (1.25 for the default bounds)."""
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=2.5, sigma=1.0, size=20_000)  # ~12ms median
    reg = MetricsRegistry()
    h = reg.histogram("lat_ms")
    for s in samples:
        h.observe(float(s))
    for q in (50.0, 90.0, 99.0, 99.9):
        exact = float(np.percentile(samples, q))
        approx = h.percentile(q)
        assert exact / 1.25 <= approx <= exact * 1.25, (q, exact, approx)
    assert h.count == len(samples)
    assert h.sum == pytest.approx(samples.sum(), rel=1e-9)
    assert h.mean == pytest.approx(samples.mean(), rel=1e-9)


def test_histogram_merge_equals_union():
    """Merging two histograms must be indistinguishable from one
    histogram that saw every observation — the cross-engine /
    cross-shard rollup contract."""
    rng = np.random.default_rng(1)
    a_s, b_s = rng.exponential(10.0, 5000), rng.exponential(40.0, 3000)
    ra, rb, runion = MetricsRegistry(), MetricsRegistry(), MetricsRegistry()
    for s in a_s:
        ra.histogram("m").observe(float(s))
    for s in b_s:
        rb.histogram("m").observe(float(s))
    for s in np.concatenate([a_s, b_s]):
        runion.histogram("m").observe(float(s))
    ra.merge_from(rb)
    merged, union = ra.histogram("m"), runion.histogram("m")
    assert merged.counts == union.counts
    assert merged.count == union.count
    assert merged.percentile(50) == union.percentile(50)
    assert merged.percentile(99) == union.percentile(99)


def test_merge_rejects_mismatched_bounds():
    ra, rb = MetricsRegistry(), MetricsRegistry()
    ra.histogram("m", bounds=log_buckets(0.1, 100.0)).observe(1.0)
    rb.histogram("m", bounds=log_buckets(0.1, 200.0)).observe(1.0)
    with pytest.raises(ValueError):
        ra.merge_from(rb)


def test_registry_merge_counters_and_gauges():
    ra, rb = MetricsRegistry(), MetricsRegistry()
    ra.counter("req_total").inc(5)
    rb.counter("req_total").inc(7)
    rb.counter("only_b_total").inc(2)
    ra.gauge("depth").set(3)
    rb.gauge("depth").set(4)            # gauges sum across shards
    ra.merge_from(rb)
    assert ra.counter("req_total").value == 12
    assert ra.counter("only_b_total").value == 2
    assert ra.gauge("depth").value == 7


def test_labels_create_distinct_series():
    reg = MetricsRegistry()
    reg.counter("flushes_total", bucket="16").inc(3)
    reg.counter("flushes_total", bucket="32").inc(1)
    assert reg.counter("flushes_total", bucket="16").value == 3
    assert reg.counter("flushes_total", bucket="32").value == 1


def test_kind_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_snapshot_round_trip():
    from repro.obs import (EPOCH_GAUGE, EPOCH_PUBLISH_TOTAL,
                           EPOCH_RETIRED_LAG_MS, SCRUB_AUDITED_TOTAL,
                           SCRUB_QUARANTINED_TOTAL, SCRUB_REPAIRED_TOTAL)

    reg = MetricsRegistry()
    reg.counter("req_total", engine="async").inc(9)
    reg.gauge("depth").set(4)
    h = reg.histogram("lat_ms")
    for v in (0.2, 1.0, 5.0, 5.0, 50.0):
        h.observe(v)
    # the live-mutation metric family survives the round trip too
    reg.gauge(EPOCH_GAUGE).set(7)
    reg.counter(EPOCH_PUBLISH_TOTAL).inc(8)
    reg.counter(SCRUB_AUDITED_TOTAL).inc(1200)
    reg.counter(SCRUB_QUARANTINED_TOTAL).inc(3)
    reg.counter(SCRUB_REPAIRED_TOTAL).inc(3)
    lag = reg.histogram(EPOCH_RETIRED_LAG_MS)
    for v in (0.1, 2.5, 40.0):
        lag.observe(v)
    doc = json.loads(reg.snapshot_json())
    back = MetricsRegistry.from_snapshot(doc)
    assert back.counter("req_total", engine="async").value == 9
    assert back.gauge("depth").value == 4
    hb = back.histogram("lat_ms")
    assert hb.counts == h.counts and hb.count == h.count
    assert hb.percentile(50) == h.percentile(50)
    assert back.gauge(EPOCH_GAUGE).value == 7
    assert back.counter(EPOCH_PUBLISH_TOTAL).value == 8
    assert back.counter(SCRUB_AUDITED_TOTAL).value == 1200
    assert back.counter(SCRUB_QUARANTINED_TOTAL).value == 3
    assert back.counter(SCRUB_REPAIRED_TOTAL).value == 3
    assert back.histogram(EPOCH_RETIRED_LAG_MS).count == lag.count
    # and the round trip is a fixed point
    assert back.snapshot_json() == reg.snapshot_json()


def test_prometheus_exposition_shape():
    reg = MetricsRegistry()
    reg.counter("req_total", engine="async").inc(3)
    h = reg.histogram("lat_ms")
    for v in (0.5, 2.0, 1000.0):
        h.observe(v)
    text = reg.to_prometheus()
    assert "# TYPE req_total counter" in text
    assert 'req_total{engine="async"} 3' in text
    assert "# TYPE lat_ms histogram" in text
    # cumulative buckets, closed by +Inf == _count
    cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
            if line.startswith("lat_ms_bucket")]
    assert cums == sorted(cums)
    assert 'lat_ms_bucket{le="+Inf"} 3' in text
    assert "lat_ms_count 3" in text


def test_zero_sample_histogram_percentile():
    h = MetricsRegistry().histogram("empty_ms")
    assert np.isnan(h.percentile(50))
    assert h.count == 0


def test_metrics_http_endpoint():
    """In-process scrape of the /metrics endpoint (ephemeral port):
    Prometheus text and the JSON snapshot both reflect live registry
    state; unknown paths 404."""
    import urllib.error
    import urllib.request

    from repro.obs import serve_metrics

    reg = MetricsRegistry()
    reg.counter("req_total").inc(3)
    reg.histogram("lat_ms").observe(2.5)
    srv = serve_metrics(reg, 0)
    try:
        text = urllib.request.urlopen(srv.url, timeout=10).read().decode()
        assert "req_total 3" in text
        assert 'lat_ms_bucket{le="+Inf"} 1' in text
        reg.counter("req_total").inc()       # scrapes see live state
        text = urllib.request.urlopen(srv.url, timeout=10).read().decode()
        assert "req_total 4" in text
        base = srv.url.rsplit("/", 1)[0]
        doc = json.loads(urllib.request.urlopen(
            base + "/metrics.json", timeout=10).read())
        assert any(m["name"] == "req_total" and m["value"] == 4
                   for m in doc["metrics"])
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(base + "/nope", timeout=10)
    finally:
        srv.close()


def test_no_wall_clock_in_serving_path():
    """The in-repo mirror of the CI lint: serving latency math runs on
    one clock (obs/clock.py, perf_counter).  A wall-clock read under
    serving/ or obs/ corrupts deadlines and spans when NTP steps."""
    import os

    src = os.path.join(os.path.dirname(__file__), os.pardir, "src",
                       "repro")
    offenders = []
    for sub in ("serving", "obs"):
        for dirpath, _, names in os.walk(os.path.join(src, sub)):
            for name in names:
                if not name.endswith(".py"):
                    continue
                p = os.path.join(dirpath, name)
                with open(p, encoding="utf-8") as f:
                    for ln, line in enumerate(f, 1):
                        if "time.time()" in line:
                            offenders.append(f"{p}:{ln}")
    assert not offenders, (
        "wall-clock reads in the serving path (use repro.obs.clock): "
        + ", ".join(offenders))
