"""Differential recall: the full engine-knob grid vs brute force.

Every (codec, expand_width, hop_backend) combination of the query engine
searches the same seeded index and is held to a pinned recall@10 floor
against ``baselines/brute_force`` — a knob combination can't silently
regress (e.g. a visited-filter bug that only bites the fused hop, or a
rerank path that only bites sq8).  The pallas hop runs in interpret mode
off-TPU (``kernels/fused_hop/ops._default_interpret``), so the grid covers
both hop programs everywhere.
"""
from __future__ import annotations

import itertools

import numpy as np
import pytest

from repro.core.baselines.brute_force import BruteForceIndex
from repro.core.build import DEGParams, build_deg
from repro.core.metrics import recall_at_k

pytestmark = pytest.mark.slow

K = 10
#: pinned floors — measured 0.9875 across the whole grid on the seeded
#: dataset; compressed traversal gets a little slack (rerank restores most
#: of it, but codes are lossy).  pq on this corpus (dim 8 -> one 8-dim
#: subspace, 200 rows < 256 centroids) reconstructs near-exactly, so it
#: is held to the float floor.
FLOORS = {"float32": 0.95, "fp16": 0.95, "sq8": 0.92, "pq": 0.95}
GRID = sorted(itertools.product(
    ["float32", "fp16", "sq8", "pq"], [1, 2], ["jnp", "pallas"]))


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(42)
    base = rng.normal(size=(200, 8)).astype(np.float32)
    queries = rng.normal(size=(16, 8)).astype(np.float32)
    idx = build_deg(base, DEGParams(degree=8, k_ext=16), wave_size=8,
                    refine_iterations=50)
    _, gt_ids = BruteForceIndex(base).search(queries, K)
    return idx, queries, np.asarray(gt_ids)


@pytest.mark.parametrize("codec,expand_width,hop_backend", GRID)
def test_recall_floor(corpus, codec, expand_width, hop_backend):
    idx, queries, gt = corpus
    res = idx.search(queries, k=K, eps=0.2,
                     quantized=None if codec == "float32" else codec,
                     expand_width=expand_width, hop_backend=hop_backend)
    rec = recall_at_k(np.asarray(res.ids), gt)
    assert rec >= FLOORS[codec], (
        f"recall@{K}={rec:.4f} under floor {FLOORS[codec]} for "
        f"codec={codec} E={expand_width} hop={hop_backend}")


def test_grid_agrees_with_itself(corpus):
    """E/hop are engine reshapes, not semantics: within one codec, every
    (E, hop) combination must return the same result *set* quality — their
    recalls may not diverge by more than one result out of k."""
    idx, queries, gt = corpus
    for codec in FLOORS:
        recs = []
        for E, hop in itertools.product([1, 2], ["jnp", "pallas"]):
            res = idx.search(queries, k=K, eps=0.2,
                             quantized=None if codec == "float32" else codec,
                             expand_width=E, hop_backend=hop)
            recs.append(recall_at_k(np.asarray(res.ids), gt))
        assert max(recs) - min(recs) <= 1.0 / K + 1e-9, (codec, recs)
