"""Pinned v1 snapshot fixture: forward-compat + round-trip guarantees.

The fixture (``tests/data/index_snapshot_golden.npz``, see the gen script)
is a complete persisted index with its expected search outputs embedded.
These tests pin three contracts:

* the current code keeps **reading v1** and serves bit-identical results
  from it (on-disk compatibility is part of the index's API);
* a snapshot with an **unknown format_version is rejected** with a clear
  typed error — never half-loaded;
* corruption (bad checksum, missing section, wrong kind, foreign npz)
  fails loudly at load time.
"""
from __future__ import annotations

import json
import os

import numpy as np
import pytest

from repro.core.build import DEGIndex
from repro.core.invariants import check_invariants
from repro.persist import (SnapshotChecksumError, SnapshotFormatError,
                           load_index, read_snapshot)

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "index_snapshot_golden.npz")


def _patched_copy(tmp_path, mutate):
    """Copy the golden archive with ``mutate(meta_dict, arrays_dict)``
    applied — the hook for forging versions / flipping bytes."""
    with np.load(GOLDEN) as z:
        arrays = {k: z[k].copy() for k in z.files}
    meta = json.loads(bytes(arrays.pop("__meta__")).decode("utf-8"))
    mutate(meta, arrays)
    blob = np.frombuffer(json.dumps(meta).encode("utf-8"), dtype=np.uint8)
    path = tmp_path / "patched.npz"
    np.savez_compressed(path, __meta__=blob, **arrays)
    return path


@pytest.fixture(scope="module")
def golden_index():
    return load_index(GOLDEN)


def test_golden_loads_and_is_valid(golden_index):
    assert golden_index.n == 120
    ok, msgs = check_invariants(golden_index.builder)
    assert ok, msgs
    assert "sq8" in golden_index._stores


def test_golden_search_pinned_exact(golden_index):
    _, sections = read_snapshot(GOLDEN)
    exp = sections["expected"]
    res = golden_index.search_batch(exp["queries"], k=10, eps=0.1)
    np.testing.assert_array_equal(np.asarray(res.ids), exp["exact_ids"])
    np.testing.assert_array_equal(np.asarray(res.dists), exp["exact_dists"])


def test_golden_search_pinned_sq8(golden_index):
    _, sections = read_snapshot(GOLDEN)
    exp = sections["expected"]
    res = golden_index.search_batch(exp["queries"], k=10, eps=0.1,
                                    quantized="sq8")
    np.testing.assert_array_equal(np.asarray(res.ids), exp["sq8_ids"])
    np.testing.assert_array_equal(np.asarray(res.dists), exp["sq8_dists"])


def test_golden_round_trips(golden_index, tmp_path):
    """load -> save -> load is state-identical under the current code."""
    p = tmp_path / "resaved.npz"
    golden_index.save(p)
    again = DEGIndex.load(p)
    np.testing.assert_array_equal(
        golden_index.builder.adjacency[: golden_index.n],
        again.builder.adjacency[: again.n])
    np.testing.assert_array_equal(
        golden_index.builder.weights[: golden_index.n],
        again.builder.weights[: again.n])
    np.testing.assert_array_equal(golden_index.vectors[: golden_index.n],
                                  again.vectors[: again.n])
    np.testing.assert_array_equal(
        np.asarray(golden_index._stores["sq8"].data),
        np.asarray(again._stores["sq8"].data))
    assert (golden_index._rng.bit_generator.state
            == again._rng.bit_generator.state)


def test_unknown_format_version_rejected(tmp_path):
    def bump(meta, arrays):
        meta["format_version"] = 999

    path = _patched_copy(tmp_path, bump)
    with pytest.raises(SnapshotFormatError, match="format_version 999"):
        load_index(path)


def test_checksum_corruption_rejected(tmp_path):
    def flip(meta, arrays):
        arr = arrays["graph/adjacency"]
        arr.flat[0] = arr.flat[0] + 1

    path = _patched_copy(tmp_path, flip)
    with pytest.raises(SnapshotChecksumError, match="graph/adjacency"):
        load_index(path)


def test_missing_section_rejected(tmp_path):
    def drop(meta, arrays):
        del arrays["vectors/data"]

    path = _patched_copy(tmp_path, drop)
    with pytest.raises(SnapshotFormatError, match="vectors/data"):
        load_index(path)


def test_wrong_kind_rejected(tmp_path):
    def rekind(meta, arrays):
        meta["kind"] = "sharded_deg"

    path = _patched_copy(tmp_path, rekind)
    with pytest.raises(SnapshotFormatError, match="kind"):
        load_index(path)


def test_foreign_npz_rejected(tmp_path):
    path = tmp_path / "foreign.npz"
    np.savez(path, stuff=np.arange(3))
    with pytest.raises(SnapshotFormatError, match="not a repro snapshot"):
        load_index(path)
