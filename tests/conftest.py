"""Test-session shims.

``hypothesis`` is not available in every execution image; the property
tests only use a tiny slice of its API (``given`` / ``settings`` /
``strategies.integers|floats|sampled_from``), so when the real library is
missing we install a deterministic mini-implementation that draws a fixed
number of pseudo-random examples per test.  With the real library on the
path this file is a no-op.
"""
from __future__ import annotations

import inspect
import random
import sys
import types


def _install_hypothesis_stub() -> None:
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def floats(min_value, max_value):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda r: r.choice(seq))

    st.integers, st.floats, st.sampled_from = integers, floats, sampled_from

    def given(**strategies):
        def deco(fn):
            # pytest must only see the non-drawn params (they are fixtures);
            # build a wrapper whose signature is the original's minus the
            # strategy-provided names.
            fixture_names = [p for p in inspect.signature(fn).parameters
                             if p not in strategies]

            def wrapper(**fixtures):
                n = getattr(wrapper, "_max_examples", 20)
                rng = random.Random(0)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(**fixtures, **drawn)

            wrapper.__signature__ = inspect.Signature(
                [inspect.Parameter(p, inspect.Parameter.POSITIONAL_OR_KEYWORD)
                 for p in fixture_names])
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco

    def settings(max_examples=20, deadline=None, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    mod.given, mod.settings, mod.strategies = given, settings, st
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


try:  # pragma: no cover - depends on the execution image
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover
    _install_hypothesis_stub()
