"""Test-session shims.

``hypothesis`` is not available in every execution image; the property
tests only use a tiny slice of its API (``given`` / ``settings`` /
``strategies.integers|floats|sampled_from`` and the ``stateful`` rule
machinery), so when the real library is missing we install a deterministic
mini-implementation: ``given`` draws a fixed number of pseudo-random
examples per test, and ``stateful.RuleBasedStateMachine.TestCase`` runs a
seeded random walk over the machine's rules (preconditions respected,
invariants checked after every step — no shrinking, but the same pass/fail
contract).  With the real library on the path this file is a no-op.
"""
from __future__ import annotations

import inspect
import random
import sys
import types


def _install_hypothesis_stub() -> None:
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def floats(min_value, max_value):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda r: r.choice(seq))

    st.integers, st.floats, st.sampled_from = integers, floats, sampled_from

    def given(**strategies):
        def deco(fn):
            # pytest must only see the non-drawn params (they are fixtures);
            # build a wrapper whose signature is the original's minus the
            # strategy-provided names.
            fixture_names = [p for p in inspect.signature(fn).parameters
                             if p not in strategies]

            def wrapper(**fixtures):
                n = getattr(wrapper, "_max_examples", 20)
                rng = random.Random(0)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(**fixtures, **drawn)

            wrapper.__signature__ = inspect.Signature(
                [inspect.Parameter(p, inspect.Parameter.POSITIONAL_OR_KEYWORD)
                 for p in fixture_names])
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco

    class settings:
        """Decorator (``@settings(...)`` on a ``@given`` test) AND plain
        config object (``Machine.TestCase.settings = settings(...)``) —
        the two usages the real library supports that our tests need."""

        def __init__(self, max_examples=20, stateful_step_count=20,
                     deadline=None, **_ignored):
            self.max_examples = max_examples
            self.stateful_step_count = stateful_step_count
            self.deadline = deadline

        def __call__(self, fn):
            fn._max_examples = self.max_examples
            return fn

    mod.given, mod.settings, mod.strategies = given, settings, st
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
    _install_stateful_stub(mod, st)


def _install_stateful_stub(mod, st) -> None:
    """Minimal ``hypothesis.stateful``: rule/initialize/invariant/
    precondition decorators + a TestCase that random-walks the machine."""
    import unittest

    sf = types.ModuleType("hypothesis.stateful")

    def rule(**strategies):
        def deco(fn):
            fn._hyp_rule = strategies
            return fn

        return deco

    def initialize(**strategies):
        def deco(fn):
            fn._hyp_init = strategies
            return fn

        return deco

    def invariant():
        def deco(fn):
            fn._hyp_invariant = True
            return fn

        return deco

    def precondition(pred):
        def deco(fn):
            preds = list(getattr(fn, "_hyp_preconditions", []))
            preds.append(pred)
            fn._hyp_preconditions = preds
            return fn

        return deco

    def _members(cls, attr):
        out = []
        for name in sorted(dir(cls)):
            f = getattr(cls, name, None)
            if callable(f) and hasattr(f, attr):
                out.append(f)
        return out

    def _run_machine(make_machine, cfg) -> None:
        """``make_machine``: any zero-arg callable returning a machine —
        covers both the class itself and the real API's factory form."""
        n_runs = getattr(cfg, "max_examples", 5)
        n_steps = getattr(cfg, "stateful_step_count", 20)
        for run in range(n_runs):
            rng = random.Random(run)
            machine = make_machine()
            cls = type(machine)
            inits = _members(cls, "_hyp_init")
            rules = _members(cls, "_hyp_rule")
            invariants = _members(cls, "_hyp_invariant")
            try:
                for f in inits:
                    f(machine, **{k: s.draw(rng)
                                  for k, s in f._hyp_init.items()})
                for f in invariants:
                    f(machine)
                for _ in range(n_steps):
                    ready = [f for f in rules
                             if all(p(machine) for p in
                                    getattr(f, "_hyp_preconditions", ()))]
                    if not ready:
                        break
                    f = rng.choice(ready)
                    f(machine, **{k: s.draw(rng)
                                  for k, s in f._hyp_rule.items()})
                    for g in invariants:
                        g(machine)
            finally:
                machine.teardown()

    class RuleBasedStateMachine:
        def teardown(self) -> None:  # same hook the real library calls
            pass

        def __init_subclass__(cls, **kw):
            super().__init_subclass__(**kw)

            class TestCase(unittest.TestCase):
                settings = None

                def runTest(self) -> None:
                    _run_machine(cls, type(self).settings or mod.settings())

            TestCase.__qualname__ = cls.__qualname__ + ".TestCase"
            cls.TestCase = TestCase

    def run_state_machine_as_test(factory, settings=None):
        _run_machine(factory, settings or mod.settings())

    sf.RuleBasedStateMachine = RuleBasedStateMachine
    sf.rule, sf.initialize = rule, initialize
    sf.invariant, sf.precondition = invariant, precondition
    sf.run_state_machine_as_test = run_state_machine_as_test
    sf.__stub__ = True
    mod.stateful = sf
    sys.modules["hypothesis.stateful"] = sf


try:  # pragma: no cover - depends on the execution image
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover
    _install_hypothesis_stub()
