"""Tests for the batched range search (Algorithm 1)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (DEGParams, build_deg, exact_knn, medoid_seed,
                        range_search, recall_at_k)
from repro.core.graph import INVALID
from repro.data import make_dataset


@pytest.fixture(scope="module")
def small_index():
    base, queries = make_dataset("gaussian", 800, 30, 16, seed=7)
    p = DEGParams(degree=8, k_ext=16, eps_ext=0.3, k_opt=8)
    idx = build_deg(base, p, wave_size=32)
    return base, queries, idx


def test_high_recall(small_index):
    base, queries, idx = small_index
    _, ti = exact_knn(queries, base, 10)
    res = idx.search(queries, k=10, eps=0.2, beam_width=64)
    assert recall_at_k(np.asarray(res.ids), np.asarray(ti)) >= 0.9


def test_no_duplicates_in_results(small_index):
    _, queries, idx = small_index
    res = idx.search(queries, k=10, eps=0.2)
    ids = np.asarray(res.ids)
    for row in ids:
        valid = row[row != INVALID]
        assert len(set(valid.tolist())) == len(valid)


def test_results_sorted_by_distance(small_index):
    _, queries, idx = small_index
    res = idx.search(queries, k=10, eps=0.2)
    d = np.asarray(res.dists)
    assert (np.diff(d, axis=1) >= -1e-6).all()


def test_distances_are_true_metric(small_index):
    base, queries, idx = small_index
    res = idx.search(queries, k=5, eps=0.2)
    ids = np.asarray(res.ids)
    dists = np.asarray(res.dists)
    for qi in range(5):
        for j in range(5):
            v = ids[qi, j]
            if v == INVALID:
                continue
            true = np.linalg.norm(idx.vectors[v] - np.asarray(queries[qi]))
            assert dists[qi, j] == pytest.approx(true, rel=1e-4, abs=1e-4)


def test_beam_width_monotone_recall(small_index):
    """Wider beam (the ef knob) must not reduce recall on average."""
    base, queries, idx = small_index
    _, ti = exact_knn(queries, base, 10)
    recalls = []
    for L in (12, 32, 96):
        res = idx.search(queries, k=10, eps=0.2, beam_width=L)
        recalls.append(recall_at_k(np.asarray(res.ids), np.asarray(ti)))
    assert recalls[0] <= recalls[1] + 0.05
    assert recalls[1] <= recalls[2] + 0.05
    assert recalls[-1] >= 0.9


def test_invalid_seeds_handled(small_index):
    base, queries, idx = small_index
    g = idx.frozen()
    seeds = jnp.asarray(
        np.array([[0, INVALID, 0], [INVALID, 3, 3]], dtype=np.int32))
    res = range_search(g, idx._dev_vectors, jnp.asarray(queries[:2]), seeds,
                       k=5, eps=0.2)
    assert np.asarray(res.ids).shape == (2, 5)
    assert (np.asarray(res.ids)[:, 0] != INVALID).all()


def test_exploration_excludes_seed(small_index):
    base, queries, idx = small_index
    seeds = [3, 50, 200]
    res = idx.explore(seeds, k=10)
    ids = np.asarray(res.ids)
    for row, s in zip(ids, seeds):
        assert s not in row.tolist()
    # exploration from an indexed vertex should find its true neighbors well:
    # seed == query means the approach phase is free (paper Sec. 6.7)
    _, ti = exact_knn(idx.vectors[seeds], base, 11)
    true_wo_self = np.asarray(ti)[:, 1:]
    rec = recall_at_k(ids, true_wo_self)
    assert rec >= 0.8


def test_exploration_exclude_list(small_index):
    base, queries, idx = small_index
    _, ti = exact_knn(idx.vectors[[10]], base, 6)
    banned = np.asarray(ti)[:, 1:4]      # ban 3 nearest
    res = idx.explore([10], k=5, exclude=banned)
    ids = set(np.asarray(res.ids)[0].tolist())
    for b in banned[0]:
        assert int(b) not in ids


def test_medoid_seed(small_index):
    base, _, idx = small_index
    s = medoid_seed(idx._dev_vectors, idx.n)
    assert 0 <= s < idx.n


def test_hops_and_evals_reported(small_index):
    _, queries, idx = small_index
    res = idx.search(queries, k=10, eps=0.2)
    assert (np.asarray(res.hops) > 0).all()
    assert (np.asarray(res.evals) >= np.asarray(res.hops)).all()


@settings(max_examples=10, deadline=None)
@given(k=st.integers(1, 8), eps=st.floats(0.0, 0.5), b=st.integers(1, 4))
def test_search_shapes_property(small_index, k, eps, b):
    base, queries, idx = small_index
    res = idx.search(queries[:b], k=k, eps=eps)
    assert np.asarray(res.ids).shape == (b, k)
    assert np.asarray(res.dists).shape == (b, k)
    d = np.asarray(res.dists)
    assert not np.isnan(d).any()


def test_exploration_excluded_traversed_not_returned(small_index):
    """Exploration protocol (paper Sec. 6.7): excluded vertices must never
    appear in results, yet navigation still passes THROUGH them — excluding
    the seed's entire neighborhood must not wall off the rest of the graph."""
    base, _, idx = small_index
    v = 17
    ring = [int(u) for u in idx.builder.neighbors(v)]     # all d neighbors
    excl = np.asarray([[v] + ring], np.int32)
    res = idx.search_batch(base[v][None], np.asarray([[v]], np.int32), excl,
                           k=8, eps=0.2)
    ids = [int(x) for x in np.asarray(res.ids)[0] if x != INVALID]
    banned = set([v] + ring)
    assert ids, "exploration returned nothing"
    assert not (set(ids) & banned)        # never in results ...
    # ... but traversal went through the ring: every returned vertex is
    # outside the seed's immediate neighborhood, i.e. >= 2 hops away, and
    # the lane did expand vertices
    assert int(np.asarray(res.hops)[0]) >= 2
    # the results must be *good* despite the exclusion: close to the
    # exact nearest non-banned vertices
    d = np.linalg.norm(base[: idx.n] - base[v], axis=1)
    d[list(banned)] = np.inf
    best = float(np.sort(d)[0])
    assert float(np.asarray(res.dists)[0, 0]) <= best * 1.5


def test_extract_stable_on_duplicate_distances():
    """Tie-determinism (satellite): ``beam.extract`` must resolve duplicate
    distances by beam position (stable sort), exactly like
    ``search.exact_rerank`` — not by whatever a non-stable argsort does."""
    import jax.numpy as jnp

    from repro.core import beam

    ids = jnp.asarray([[5, 3, 9, 2], [8, 1, 4, 6]], jnp.int32)
    dists = jnp.asarray([[1.0, 1.0, 1.0, 2.0], [0.5, 0.5, 0.5, 0.5]],
                        jnp.float32)
    st = beam.BeamState(ids=ids, dists=dists,
                        checked=jnp.ones((2, 4), bool),
                        excluded=jnp.zeros((2, 4), bool),
                        hops=jnp.zeros((2,), jnp.int32),
                        evals=jnp.zeros((2,), jnp.int32))
    out_ids, out_d = beam.extract(st, 3)
    np.testing.assert_array_equal(np.asarray(out_ids),
                                  [[5, 3, 9], [8, 1, 4]])
    # and it agrees with exact_rerank's stable tie order on the same data
    from repro.core.search import exact_rerank

    vecs = jnp.zeros((10, 4), jnp.float32)        # all-equal -> all ties
    r_ids, _ = exact_rerank(vecs, jnp.zeros((2, 4)), ids, k=3)
    np.testing.assert_array_equal(np.asarray(out_ids), np.asarray(r_ids))


def test_extract_dedup_keeps_first_occurrence():
    import jax.numpy as jnp

    from repro.core import beam

    st = beam.BeamState(
        ids=jnp.asarray([[7, 7, 3, 7]], jnp.int32),
        dists=jnp.asarray([[1.0, 1.0, 2.0, 3.0]], jnp.float32),
        checked=jnp.ones((1, 4), bool), excluded=jnp.zeros((1, 4), bool),
        hops=jnp.zeros((1,), jnp.int32), evals=jnp.zeros((1,), jnp.int32))
    out_ids, out_d = beam.extract(st, 3, dedup=True)
    np.testing.assert_array_equal(np.asarray(out_ids), [[7, 3, INVALID]])
    assert np.isinf(np.asarray(out_d)[0, 2])


def test_search_graph_full_plumbing(small_index):
    """search_graph forwards the complete range_search signature
    (satellite): exclude, merge_backend, rerank_k/exact_vectors, engine
    knobs — none silently dropped."""
    import jax.numpy as jnp

    from repro.core.search import search_graph

    base, queries, idx = small_index
    g = idx.frozen()
    vecs = idx._dev_vectors
    qs = jnp.asarray(queries[:6])

    # exclude: banned vertices never in results
    banned = np.asarray(idx.search(queries[:6], k=3, eps=0.2).ids)
    res = search_graph(g, vecs, qs, k=5, eps=0.2,
                       exclude=jnp.asarray(banned))
    for row, b in zip(np.asarray(res.ids), banned):
        assert not (set(row.tolist()) & set(b.tolist()))

    # merge_backend (argsort = seed semantics) must be honored and agree
    res_a = search_graph(g, vecs, qs, k=5, eps=0.2,
                         merge_backend="argsort")
    res_j = search_graph(g, vecs, qs, k=5, eps=0.2)
    np.testing.assert_array_equal(np.asarray(res_a.ids),
                                  np.asarray(res_j.ids))

    # rerank_k + exact_vectors: two-stage over the sq8 store returns
    # exact float distances
    store = idx.store_for("sq8")
    res_q = search_graph(g, store, qs, k=5, eps=0.2, seed=idx.medoid(),
                         rerank_k=20, exact_vectors=vecs)
    ids = np.asarray(res_q.ids)
    d = np.asarray(res_q.dists)
    for qi in range(ids.shape[0]):
        for j in range(ids.shape[1]):
            if ids[qi, j] == INVALID:
                continue
            true = np.linalg.norm(idx.vectors[ids[qi, j]]
                                  - np.asarray(queries[qi]))
            assert d[qi, j] == pytest.approx(true, rel=1e-4, abs=1e-4)

    # engine knobs reach the beam engine (E>1 runs and matches range_search)
    from repro.core import range_search as _rs

    res_e = search_graph(g, vecs, qs, k=5, eps=0.2, seed=0, expand_width=2)
    seeds = jnp.zeros((6, 1), jnp.int32)
    ref = _rs(g, vecs, qs, seeds, k=5, eps=0.2, expand_width=2)
    np.testing.assert_array_equal(np.asarray(res_e.ids),
                                  np.asarray(ref.ids))


def test_medoid_seed_cached_and_invalidated(small_index):
    """DEGIndex caches the medoid entry vertex and recomputes only after
    the vector set changes (satellite: no device reduction per query)."""
    base, _, idx = small_index
    m0 = idx.medoid()
    assert idx._medoid is not None
    assert idx.medoid() == m0            # cache hit, same value
    # mutation invalidates
    rng = np.random.default_rng(0)
    idx.add(rng.normal(size=(4, base.shape[1])).astype(np.float32),
            wave_size=4)
    assert idx._medoid is None
    m1 = idx.medoid()
    assert 0 <= m1 < idx.n
