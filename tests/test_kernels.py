"""Per-kernel validation: shape/dtype sweeps, allclose vs the pure-jnp oracle
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.l2_topk import l2_topk, l2_topk_ref
from repro.kernels.gather_dist import gather_dist, gather_dist_ref
from repro.kernels.bag_lookup import bag_lookup, bag_lookup_ref


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


# ---------------------------------------------------------------- l2_topk --
@pytest.mark.parametrize("B,N,m,k", [
    (8, 512, 128, 10),
    (3, 1000, 33, 5),      # unaligned everything
    (16, 2048, 128, 100),  # paper-style k=100
    (1, 513, 960, 1),
])
def test_l2_topk_matches_ref(B, N, m, k):
    rng = np.random.default_rng(B * 1000 + N)
    q = _rand(rng, (B, m), jnp.float32)
    x = _rand(rng, (N, m), jnp.float32)
    d, i = l2_topk(q, x, k, interpret=True)
    rd, ri = l2_topk_ref(q, x, k)
    np.testing.assert_allclose(np.asarray(d), np.asarray(rd), rtol=1e-5,
                               atol=1e-5)
    # ids may differ on exact distance ties; compare via distances
    full = np.linalg.norm(np.asarray(q)[:, None] - np.asarray(x)[None], axis=2)
    got = np.take_along_axis(full, np.asarray(i), axis=1)
    np.testing.assert_allclose(got, np.asarray(rd), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l2_topk_dtypes(dtype):
    rng = np.random.default_rng(0)
    q = _rand(rng, (4, 64), dtype)
    x = _rand(rng, (256, 64), dtype)
    d, i = l2_topk(q, x, 8, interpret=True)
    rd, ri = l2_topk_ref(q, x, 8)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(d), np.asarray(rd), rtol=tol,
                               atol=tol)


def test_l2_topk_squared_mode():
    rng = np.random.default_rng(1)
    q = _rand(rng, (4, 32), jnp.float32)
    x = _rand(rng, (128, 32), jnp.float32)
    d2, _ = l2_topk(q, x, 4, squared=True, interpret=True)
    d, _ = l2_topk(q, x, 4, squared=False, interpret=True)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d) ** 2, rtol=1e-4,
                               atol=1e-5)


def test_l2_topk_padding_never_leaks():
    """Padded base rows must never appear in the ids."""
    rng = np.random.default_rng(2)
    q = _rand(rng, (2, 16), jnp.float32)
    x = _rand(rng, (130, 16), jnp.float32)   # pads to 256
    _, i = l2_topk(q, x, 50, interpret=True)
    assert (np.asarray(i) < 130).all()
    assert (np.asarray(i) >= 0).all()


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 9), N=st.integers(16, 300), m=st.integers(4, 80),
       k=st.integers(1, 12))
def test_l2_topk_property(B, N, m, k):
    k = min(k, N)
    rng = np.random.default_rng(B * 7 + N)
    q = _rand(rng, (B, m), jnp.float32)
    x = _rand(rng, (N, m), jnp.float32)
    d, i = l2_topk(q, x, k, interpret=True)
    rd, _ = l2_topk_ref(q, x, k)
    np.testing.assert_allclose(np.asarray(d), np.asarray(rd), rtol=1e-4,
                               atol=1e-4)
    assert (np.diff(np.asarray(d), axis=1) >= -1e-6).all()


# ------------------------------------------------------------ gather_dist --
@pytest.mark.parametrize("N,m,B,d", [
    (256, 128, 4, 16),
    (100, 33, 2, 7),       # unaligned
    (1024, 128, 8, 30),    # DEG degree 30
])
def test_gather_dist_matches_ref(N, m, B, d):
    rng = np.random.default_rng(N + m)
    v = _rand(rng, (N, m), jnp.float32)
    q = _rand(rng, (B, m), jnp.float32)
    ids = jnp.asarray(rng.integers(0, N, size=(B, d)), jnp.int32)
    out = gather_dist(v, ids, q, interpret=True)
    ref = gather_dist_ref(v, ids, q)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)


def test_gather_dist_clamps_invalid():
    rng = np.random.default_rng(3)
    v = _rand(rng, (32, 16), jnp.float32)
    q = _rand(rng, (2, 16), jnp.float32)
    ids = jnp.asarray(np.array([[0, -1, 5], [31, -1, -1]]), jnp.int32)
    out = np.asarray(gather_dist(v, ids, q, interpret=True))
    # -1 clamps to row 0; caller masks those lanes — only require no NaN/crash
    assert np.isfinite(out).all()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_dist_dtypes(dtype):
    rng = np.random.default_rng(4)
    v = _rand(rng, (64, 32), dtype)
    q = _rand(rng, (3, 32), dtype)
    ids = jnp.asarray(rng.integers(0, 64, size=(3, 9)), jnp.int32)
    out = gather_dist(v, ids, q, interpret=True)
    ref = gather_dist_ref(v, ids, q)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=tol,
                               atol=tol)


def test_gather_dist_agrees_with_search_path():
    """The kernel must agree with the jnp path used inside range_search."""
    from repro.core.search import _neighbor_distances_jnp

    rng = np.random.default_rng(5)
    v = _rand(rng, (128, 24), jnp.float32)
    q = _rand(rng, (4, 24), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 128, size=(4, 8)), jnp.int32)
    a = gather_dist(v, ids, q, interpret=True)
    b = _neighbor_distances_jnp(v, q, ids, "l2")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


# ------------------------------------------------------------- bag_lookup --
@pytest.mark.parametrize("V,E,B,F", [
    (1000, 16, 8, 26),     # DLRM-ish
    (37, 7, 3, 5),         # tiny unaligned
    (5000, 128, 4, 13),
])
def test_bag_lookup_matches_ref(V, E, B, F):
    rng = np.random.default_rng(V + E)
    t = _rand(rng, (V, E), jnp.float32)
    ids = jnp.asarray(rng.integers(0, V, size=(B, F)), jnp.int32)
    w = jnp.asarray(rng.uniform(0.1, 2.0, size=(B, F)).astype(np.float32))
    out = bag_lookup(t, ids, w, interpret=True)
    ref = bag_lookup_ref(t, ids, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)


def test_bag_lookup_invalid_ids_zero_weight():
    rng = np.random.default_rng(6)
    t = _rand(rng, (50, 8), jnp.float32)
    ids = jnp.asarray(np.array([[3, -1, 7], [-1, -1, 2]]), jnp.int32)
    out = np.asarray(bag_lookup(t, ids, interpret=True))
    ref = np.asarray(t)[[3, 7]].reshape(2, -1, 8)
    np.testing.assert_allclose(out[0], np.asarray(t)[3] + np.asarray(t)[7],
                               rtol=1e-5)
    np.testing.assert_allclose(out[1], np.asarray(t)[2], rtol=1e-5)


def test_bag_lookup_unweighted_default():
    rng = np.random.default_rng(7)
    t = _rand(rng, (20, 4), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 20, size=(5, 3)), jnp.int32)
    out = bag_lookup(t, ids, interpret=True)
    ref = bag_lookup_ref(t, ids, jnp.ones((5, 3)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)


def test_bag_lookup_matches_model_embedding_bag():
    """Kernel vs the segment_sum-based EmbeddingBag in the model substrate."""
    from repro.models.embedding_bag import embedding_bag_fixed

    rng = np.random.default_rng(8)
    t = _rand(rng, (100, 12), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 100, size=(6, 4)), jnp.int32)
    a = bag_lookup(t, ids, interpret=True)
    b = embedding_bag_fixed(t, ids)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_gather_dist_bf16_path():
    """bf16 vector payload: distances must match the f32 oracle to bf16
    precision (the fused-kernel half-traffic path, EXPERIMENTS.md §Perf)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.gather_dist import ops as gd_ops
    from repro.kernels.gather_dist import ref as gd_ref

    rng = np.random.default_rng(7)
    vecs = rng.normal(size=(200, 48)).astype(np.float32)
    qs = rng.normal(size=(8, 48)).astype(np.float32)
    ids = rng.integers(0, 200, size=(8, 12)).astype(np.int32)
    got = gd_ops.gather_dist(jnp.asarray(vecs, jnp.bfloat16),
                             jnp.asarray(ids),
                             jnp.asarray(qs, jnp.bfloat16))
    want = gd_ref.gather_dist_ref(jnp.asarray(vecs), jnp.asarray(ids),
                                  jnp.asarray(qs))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0.05, atol=0.05)
