"""Stateful lifecycle suite: a rule machine interleaving the full index
lifecycle — add / delete / refine / search / save / load — asserting the
DEG structural invariants (Table 1) after EVERY step and bit-identical
``search_batch`` results across every save→load round trip.

Every mutation is journaled to a WAL (persist/wal.py), and the walk
includes crash rules: kill between records and recover from
snapshot+WAL (must be bit-identical to the live index — or, once an
``epoch_publish`` marker is journaled, to the last *published* state),
tear the journal tail mid-append and recover from the surviving prefix.
The walk also publishes epochs mid-stream and injects seeded adjacency
corruption that the integrity scrubber must quarantine and repair.  The
structural invariants are re-checked after every recovery like any
other step.

Runs under real Hypothesis (``RuleBasedStateMachine``) or the deterministic
random-walk stub in ``conftest.py`` — same rules, same pass/fail contract.
"""
from __future__ import annotations

import shutil
import tempfile
from pathlib import Path

import numpy as np
import pytest

from hypothesis import settings
from hypothesis import strategies as st
from hypothesis.stateful import (RuleBasedStateMachine, initialize, invariant,
                                 precondition, rule)

from repro.core.build import DEGIndex, DEGParams
from repro.core.invariants import check_invariants

pytestmark = pytest.mark.slow

DIM = 6
DEGREE = 6
MAX_N = 72          # bounds step cost; shapes stay in a few jit buckets


def _search_sig(index: DEGIndex, queries: np.ndarray, quantized=None):
    res = index.search_batch(queries, k=5, eps=0.1, quantized=quantized)
    return np.asarray(res.ids).copy(), np.asarray(res.dists).copy()


class LifecycleMachine(RuleBasedStateMachine):
    """One live index + a persisted twin path through tmpdir snapshots."""

    @initialize(seed=st.integers(0, 2**16))
    def setup(self, seed):
        self.rng = np.random.default_rng(seed)
        self.tmp = Path(tempfile.mkdtemp(prefix="deg-lifecycle-"))
        self.wal = self.tmp / "wal.log"
        self.base_snap = self.tmp / "base.npz"
        self.idx = DEGIndex(DIM, DEGParams(degree=DEGREE, k_ext=2 * DEGREE),
                            capacity=MAX_N)
        # journal from the first mutation; recovery replays onto base_snap
        self.idx.enable_wal(self.wal)
        # past the K_{d+1} bootstrap and big enough that deletes are legal
        self.idx.add(self._points(DEGREE + 4), wave_size=4)
        self.idx.save(self.base_snap)
        self.queries = self.rng.normal(size=(4, DIM)).astype(np.float32)
        # state at the last journaled epoch_publish marker (None = no
        # marker since the recovery base): the crash rules' landing point
        self.pub_state = None

    def teardown(self):
        if hasattr(self, "tmp"):
            shutil.rmtree(self.tmp, ignore_errors=True)

    def _points(self, k: int) -> np.ndarray:
        return self.rng.normal(size=(k, DIM)).astype(np.float32)

    # -- rules -----------------------------------------------------------
    @precondition(lambda self: self.idx.n < MAX_N - 6)
    @rule(count=st.integers(1, 5), wave=st.integers(1, 4))
    def add_points(self, count, wave):
        self.idx.add(self._points(count), wave_size=wave)

    @precondition(lambda self: self.idx.n > DEGREE + 2)
    @rule(pick=st.integers(0, 10**6))
    def delete_vertex(self, pick):
        n_before = self.idx.n
        assert self.idx.remove([pick % n_before]) == 1
        assert self.idx.n == n_before - 1

    @rule(iters=st.integers(1, 3), seed=st.integers(0, 99))
    def refine(self, iters, seed):
        self.idx.refine(iters, seed=seed)

    @rule()
    def search_sane(self):
        ids, dists = _search_sig(self.idx, self.queries)
        valid = ids != -1
        assert (ids[valid] >= 0).all() and (ids[valid] < self.idx.n).all()
        d = np.where(valid, dists, np.inf)
        assert (np.diff(d, axis=1) >= -1e-6).all(), "results not sorted"
        # every row has k real results once n >= k
        assert valid.all()

    @rule(codec=st.sampled_from(["float32", "sq8"]))
    def save_load_roundtrip(self, codec):
        """Restore must be search-identical, exact AND quantized paths."""
        if codec != "float32":
            self.idx.store_for(codec)      # materialize so it persists
        path = self.tmp / "snap.npz"
        self.idx.save(path)
        twin = DEGIndex.load(path)
        assert twin.n == self.idx.n
        q = None if codec == "float32" else codec
        a_ids, a_d = _search_sig(self.idx, self.queries, quantized=q)
        b_ids, b_d = _search_sig(twin, self.queries, quantized=q)
        np.testing.assert_array_equal(a_ids, b_ids)
        np.testing.assert_array_equal(a_d, b_d)

    @rule()
    def reload_and_continue(self):
        """Swap the live index for its restored twin — the rest of the walk
        exercises mutability of a freshly-restored index."""
        path = self.tmp / "swap.npz"
        self.idx.save(path)
        self.idx = DEGIndex.load(path)
        # journaling must survive the swap: re-attach the WAL, and the
        # fresh snapshot becomes the recovery base (its cursor is ahead
        # of base_snap's, so replay just skips more prefix)
        self.idx.enable_wal(self.wal)
        shutil.copyfile(path, self.base_snap)
        self.pub_state = None          # any marker is now behind the cursor

    @precondition(lambda self: self.idx.n >= DEGREE + 4)
    @rule()
    def publish_epoch(self):
        """Journal an epoch_publish marker — the recovery commit point.
        Capture the at-publish state the crash rules must land on."""
        if not self.idx.publishing:
            self.idx.enable_publishing()   # publishes (and journals) epoch 0
        else:
            self.idx.publish()
        self.pub_state = (self.idx.n, self.idx._wal_seq,
                          self.idx._rng.bit_generator.state,
                          _search_sig(self.idx, self.queries))

    @precondition(lambda self: self.idx.n >= 24)
    @rule(flips=st.integers(1, 2), cseed=st.integers(0, 99))
    def corrupt_scrub_repair(self, flips, cseed):
        """Seeded in-RAM corruption, then scrub passes until the graph is
        healed and the quarantine drains; Table 1 is re-checked by the
        machine invariant after the rule."""
        from repro.serving.scrub import IntegrityScrubber, corrupt_adjacency

        corrupt_adjacency(self.idx, flips, seed=cseed)
        scrub = IntegrityScrubber(self.idx, publish=False)
        for _ in range(5):
            s = scrub.run_pass()
            if not self.idx.quarantine and s["flagged"] == 0:
                break
        assert not self.idx.quarantine, "scrub never converged"
        # repairs are deliberately not journaled (see serving/scrub.py
        # docstring): the healed state becomes the new recovery base so
        # later crash rules stay bit-exact
        self.idx.save(self.base_snap)
        self.pub_state = None

    # -- crash / recovery rules ------------------------------------------
    def _assert_recovered_equal(self, rec):
        if self.pub_state is not None:
            # a publish marker gates recovery: land exactly on the last
            # published epoch, not on the unpublished journal tail
            n, seq, rng_state, (a_ids, a_d) = self.pub_state
            assert rec.n == n
            assert rec._wal_seq == seq
            assert rec._rng.bit_generator.state == rng_state
        else:
            assert rec.n == self.idx.n
            assert rec._wal_seq == self.idx._wal_seq
            assert rec._rng.bit_generator.state == \
                self.idx._rng.bit_generator.state
            a_ids, a_d = _search_sig(self.idx, self.queries)
        b_ids, b_d = _search_sig(rec, self.queries)
        np.testing.assert_array_equal(a_ids, b_ids)
        np.testing.assert_array_equal(a_d, b_d)

    @rule()
    def crash_recover(self):
        """Kill between WAL records (the live index IS the state at the
        last record boundary): snapshot + replay must reproduce it bit for
        bit, and the walk continues on the recovered index."""
        from repro.persist import recover

        rec = recover(self.base_snap, self.wal, capacity=MAX_N)
        self._assert_recovered_equal(rec)
        self.idx = rec                 # WAL re-enabled by recover()

    @rule()
    def torn_tail_recover(self):
        """Crash mid-append: a half-written record at the tail must be
        truncated on recovery, landing on the complete-record prefix."""
        from repro.persist import recover

        with open(self.wal, "ab") as f:    # half a record header
            f.write(b"\x52\x4c\x41\x57\x03\x00\x00")
        rec = recover(self.base_snap, self.wal, capacity=MAX_N)
        self._assert_recovered_equal(rec)
        self.idx = rec

    # -- invariants (checked after every rule) ---------------------------
    @invariant()
    def graph_invariants(self):
        idx = getattr(self, "idx", None)
        if idx is None or idx.builder is None:
            return
        ok, msgs = check_invariants(idx.builder)
        assert ok, f"invariants broken at n={idx.n}: {msgs}"

    @invariant()
    def counters_consistent(self):
        idx = getattr(self, "idx", None)
        if idx is None or idx.builder is None:
            return
        assert idx.builder.n == idx.n <= idx.capacity


LifecycleMachine.TestCase.settings = settings(
    max_examples=3, stateful_step_count=12, deadline=None)
TestLifecycle = LifecycleMachine.TestCase
